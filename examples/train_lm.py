"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

The full stack: Lance mini-block token storage -> scan loader -> sharded
train_step -> async checkpoints -> fault monitor.  Uses a width-reduced
smollm config sized to ~100M params so it runs on the CPU container; the
same driver takes --full on a pod.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.train import train
from repro.models.registry import param_counts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M-param config: smollm-360m narrowed (d_model 960->512, 12 layers)
    import repro.configs as C

    base = get_config("smollm-360m")
    cfg100 = dataclasses.replace(
        base, name="smollm-100m", n_layers=12, d_model=512, d_ff=1536,
        n_heads=8, n_kv_heads=4, head_dim=64, vocab=32768)
    C.ARCHS["smollm-100m"] = cfg100
    total, _ = param_counts(cfg100)
    print(f"[example] training {cfg100.name}: {total/1e6:.1f}M params")

    loss, last = train("smollm-100m", reduced=False, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir="/tmp/ckpt_100m", ckpt_every=100)
    print(f"[example] finished step {last-1}, loss {loss:.4f}")


if __name__ == "__main__":
    main()
