"""Quickstart: the paper's core idea in 80 lines.

Writes one table in four structural encodings, then compares random access
IOPS / read amplification / search-cache size — reproducing the paper's
headline numbers (full-zip: <=2 IOPS & no cache; Arrow List<String>: 5 IOPS
in 3 dependent phases; Parquet: 1 IOP with page-size amplification).
Then the ingest path: append fragments to a live versioned dataset through
the write-back store and take freshly written rows back out, NVMe-warm.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import FileReader, WriteOptions, write_table
from repro.core.io_sim import NVME, model_time
from repro.data import synth

N_ROWS = 4_000
TAKE = 64


def append_then_take():
    """Ingest: three appends -> three manifest versions, then random access
    over the committed dataset (served warm from the blocks the write path
    just filled)."""
    from repro.dataset import DatasetWriter

    w = DatasetWriter(flush="write-back", opts=WriteOptions("lance"))
    for _ in range(3):
        w.append({"c": synth.paper_type("string", 1_000, seed=w.version)})
    rng = np.random.default_rng(0)
    rows = rng.choice(w.n_rows, TAKE, replace=False)
    w.reset_io()
    w.take("c", rows)
    st = w.io_stats()
    tiers = {s.name: s for s in w.tier_stats()}
    print(f"appended 3 fragments -> manifest v{w.version} "
          f"({w.n_rows} rows, dirty after commit: {w.dirty_bytes} B)")
    print(f"take {TAKE} fresh rows: {st.n_iops/TAKE:.2f} iops/row, "
          f"nvme hit-rate {tiers['nvme_970evo'].hit_rate:.2f}, "
          f"s3 reads {tiers['s3'].n_iops} (warm from the write path)")
    print(f"old versions stay readable: v1 has {w.reader(1).n_rows} rows\n")


def main():
    rng = np.random.default_rng(0)
    rows = rng.choice(N_ROWS, TAKE, replace=False)

    print(f"{'encoding':16s} {'type':12s} {'iops/row':>9s} {'read-amp':>9s} "
          f"{'phases':>7s} {'cache B':>9s} {'modelled rows/s':>16s}")
    for tname in ["string", "string-list", "vector"]:
        arr = synth.paper_type(tname, N_ROWS, seed=1)
        for enc, opts in [
            ("lance-adaptive", WriteOptions("lance")),
            ("lance-fullzip", WriteOptions("lance-fullzip")),
            ("parquet-8k", WriteOptions("parquet", page_bytes=8 * 1024)),
            ("arrow", WriteOptions("arrow")),
        ]:
            fr = FileReader(write_table({"c": arr}, opts))
            fr.take("c", rows)  # warm nothing: takes are cold by design
            fr.reset_io()
            fr.take("c", rows)
            st = fr.io_stats()
            t = model_time(st, NVME)
            print(f"{enc:16s} {tname:12s} {st.n_iops/TAKE:9.2f} "
                  f"{st.read_amplification:9.1f} {st.max_phase:7d} "
                  f"{fr.search_cache_bytes():9d} {TAKE/t:16,.0f}")
        print()
    append_then_take()


if __name__ == "__main__":
    main()
