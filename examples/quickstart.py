"""Quickstart: the paper's core idea in 60 lines.

Writes one table in four structural encodings, then compares random access
IOPS / read amplification / search-cache size — reproducing the paper's
headline numbers (full-zip: <=2 IOPS & no cache; Arrow List<String>: 5 IOPS
in 3 dependent phases; Parquet: 1 IOP with page-size amplification).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import FileReader, WriteOptions, write_table
from repro.core.io_sim import NVME, model_time
from repro.data import synth

N_ROWS = 4_000
TAKE = 64


def main():
    rng = np.random.default_rng(0)
    rows = rng.choice(N_ROWS, TAKE, replace=False)

    print(f"{'encoding':16s} {'type':12s} {'iops/row':>9s} {'read-amp':>9s} "
          f"{'phases':>7s} {'cache B':>9s} {'modelled rows/s':>16s}")
    for tname in ["string", "string-list", "vector"]:
        arr = synth.paper_type(tname, N_ROWS, seed=1)
        for enc, opts in [
            ("lance-adaptive", WriteOptions("lance")),
            ("lance-fullzip", WriteOptions("lance-fullzip")),
            ("parquet-8k", WriteOptions("parquet", page_bytes=8 * 1024)),
            ("arrow", WriteOptions("arrow")),
        ]:
            fr = FileReader(write_table({"c": arr}, opts))
            fr.take("c", rows)  # warm nothing: takes are cold by design
            fr.reset_io()
            fr.take("c", rows)
            st = fr.io_stats()
            t = model_time(st, NVME)
            print(f"{enc:16s} {tname:12s} {st.n_iops/TAKE:9.2f} "
                  f"{st.read_amplification:9.1f} {st.max_phase:7d} "
                  f"{fr.search_cache_bytes():9d} {TAKE/t:16,.0f}")
        print()


if __name__ == "__main__":
    main()
