"""Serving example: retrieval-augmented batched generation.

The paper's two access patterns in one loop, now with a *real* ANN front
end — row ids come from an index, not from the caller:
 1. **vector search** — an IVF index trained over the embedding column and
    stored *as dataset fragments* (`repro.dataset.IvfIndex`): centroids +
    posting lists live in the same global address space as the data, so
    index reads and data reads share ONE NVMe block cache + IO scheduler.
    `Retriever.search()` probes centroids, batch-fetches posting lists,
    scores candidates with the Pallas distance/top-k kernel, and takes the
    winners — every step priced on the shared tiered store;
 2. **sequential decode** — batched generation with a prefill + KV-cache
    decode loop on a reduced model.

  PYTHONPATH=src python examples/retrieval_serve.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import WriteOptions
from repro.data import synth
from repro.dataset import DatasetWriter, IvfIndex, write_fragments
from repro.models.registry import build_model
from repro.serve.engine import BatchedEngine, Retriever

N_DOCS = 5_000
N_FRAGMENTS = 4
N_PARTITIONS = 32
NPROBE = 8


def main():
    rng = np.random.default_rng(0)
    # 1. build the document store as a fragmented dataset: embeddings
    # (full-zip: fixed 2 KiB values), split across N_FRAGMENTS Lance files
    # behind one shared tiered store (NVMe block cache over S3), then train
    # the IVF index and commit it as fragments of the SAME address space.
    emb = synth.scenario("embeddings", N_DOCS)
    files = write_fragments({"embedding": emb}, N_FRAGMENTS,
                            WriteOptions("lance"))
    writer = DatasetWriter(files=files, store="tiered")
    index = IvfIndex.build(writer, "embedding", n_partitions=N_PARTITIONS,
                           n_fragments=2, seed=0)
    retriever = Retriever(writer.reader(), "embedding", index=index)

    # real ANN queries: perturbed copies of stored docs — *global* row ids
    # come back from the index, spanning every fragment
    targets = rng.integers(0, N_DOCS, 4)
    queries = np.asarray(emb.values, np.float32)[targets] \
        + 0.05 * rng.standard_normal((4, 512)).astype(np.float32)
    writer.reset_io()
    res = retriever.search(queries, k=8, nprobe=NPROBE)
    stats = writer.io_stats()
    t_cold = retriever.modelled_time()
    # (the index build's training scan already warmed the shared cache —
    # one budget for index and data is the point of index-as-fragments)
    print(f"[search] 4 queries x top-8 over {N_FRAGMENTS} fragments "
          f"({N_PARTITIONS} partitions, nprobe={NPROBE}): "
          f"{res.n_candidates} candidates scored, {stats.n_iops} IOPS, "
          f"modelled time {t_cold*1e3:.2f} ms")
    print(f"[search] q0 neighbors: {res.ids[0].tolist()} (target {targets[0]})")
    # the repeat search is served by the shared NVMe cache — index reads
    # (centroids, postings) and data reads warm the same budget
    writer.reset_io()
    retriever.search(queries, k=8, nprobe=NPROBE)
    nvme, s3 = retriever.tier_stats()
    print(f"[search] warm repeat: nvme_hit_rate={nvme.hit_rate:.2f}, "
          f"s3_iops={s3.n_iops}, modelled {retriever.modelled_time()*1e3:.2f} ms")

    # 2. generate with the fetched context (reduced model, greedy decode)
    cfg = reduced_config("qwen2-72b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = BatchedEngine(model, params, max_new=16)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (4, 32)), jnp.int32)
    out = engine.generate({"tokens": prompts}, n_new=16)
    print(f"[serve] generated {out.tokens.shape} tokens "
          f"(batch={out.tokens.shape[0]}, steps={out.steps})")
    print("[serve] sample:", out.tokens[0][:10])


if __name__ == "__main__":
    main()
