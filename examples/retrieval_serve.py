"""Serving example: retrieval-augmented batched generation.

The paper's two access patterns in one loop:
 1. **random access** — fetch query-neighbor embeddings/documents from a
    Lance file with full-zip take() (<=2 IOPS/row, no search cache);
 2. **sequential decode** — batched generation with a prefill + KV-cache
    decode loop on a reduced model.

  PYTHONPATH=src python examples/retrieval_serve.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import WriteOptions, write_table
from repro.core.io_sim import NVME, model_time
from repro.data import synth
from repro.models.registry import build_model
from repro.serve.engine import BatchedEngine, Retriever

N_DOCS = 5_000


def main():
    rng = np.random.default_rng(0)
    # 1. build the document store: embeddings (full-zip: fixed 2 KiB values)
    emb = synth.scenario("embeddings", N_DOCS)
    fbytes = write_table({"embedding": emb}, WriteOptions("lance"))
    retriever = Retriever(fbytes, "embedding")

    # fake ANN results: 8 neighbors per query, 4 queries
    neighbor_ids = rng.integers(0, N_DOCS, (4, 8))
    vecs, stats = retriever.fetch(neighbor_ids.reshape(-1))
    t = model_time(stats, NVME)
    print(f"[retrieve] {neighbor_ids.size} rows: {stats.n_iops} IOPS, "
          f"amp={stats.read_amplification:.2f}, modelled NVMe time {t*1e3:.2f} ms")

    # 2. generate with the fetched context (reduced model, greedy decode)
    cfg = reduced_config("qwen2-72b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = BatchedEngine(model, params, max_new=16)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (4, 32)), jnp.int32)
    out = engine.generate({"tokens": prompts}, n_new=16)
    print(f"[serve] generated {out.tokens.shape} tokens "
          f"(batch={out.tokens.shape[0]}, steps={out.steps})")
    print("[serve] sample:", out.tokens[0][:10])


if __name__ == "__main__":
    main()
