"""Serving example: retrieval-augmented batched generation.

The paper's two access patterns in one loop, now over a *fragmented*
dataset:
 1. **random access** — fetch query-neighbor embeddings from a multi-file
    Lance dataset with full-zip take() (<=2 IOPS/row, no search cache).
    All fragments sit behind ONE shared NVMe block cache + IO scheduler
    (`repro.dataset`), so global row ids fan out to per-fragment takes that
    coalesce in a single dispatch and warm a single cache budget;
 2. **sequential decode** — batched generation with a prefill + KV-cache
    decode loop on a reduced model.

  PYTHONPATH=src python examples/retrieval_serve.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import WriteOptions
from repro.data import synth
from repro.dataset import write_fragments
from repro.models.registry import build_model
from repro.serve.engine import BatchedEngine, Retriever

N_DOCS = 5_000
N_FRAGMENTS = 4


def main():
    rng = np.random.default_rng(0)
    # 1. build the document store as a fragmented dataset: embeddings
    # (full-zip: fixed 2 KiB values), split across N_FRAGMENTS Lance files
    # served through one shared tiered store (NVMe block cache over S3).
    emb = synth.scenario("embeddings", N_DOCS)
    files = write_fragments({"embedding": emb}, N_FRAGMENTS,
                            WriteOptions("lance"))
    retriever = Retriever(files, "embedding", store="tiered")

    # fake ANN results: 8 neighbors per query, 4 queries — *global* row ids
    # spanning every fragment
    neighbor_ids = rng.integers(0, N_DOCS, (4, 8))
    vecs, stats = retriever.fetch(neighbor_ids.reshape(-1))
    t_cold = retriever.modelled_time()
    print(f"[retrieve] {neighbor_ids.size} rows over {N_FRAGMENTS} fragments: "
          f"{stats.n_iops} IOPS, amp={stats.read_amplification:.2f}, "
          f"modelled cold time {t_cold*1e3:.2f} ms")
    # the repeat fetch is served by the dataset-wide NVMe cache
    retriever.fetch(neighbor_ids.reshape(-1))
    nvme, s3 = retriever.tier_stats()
    print(f"[retrieve] warm refetch: nvme_hit_rate={nvme.hit_rate:.2f}, "
          f"s3_iops={s3.n_iops}, modelled {retriever.modelled_time()*1e3:.2f} ms")

    # 2. generate with the fetched context (reduced model, greedy decode)
    cfg = reduced_config("qwen2-72b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = BatchedEngine(model, params, max_new=16)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (4, 32)), jnp.int32)
    out = engine.generate({"tokens": prompts}, n_new=16)
    print(f"[serve] generated {out.tokens.shape} tokens "
          f"(batch={out.tokens.shape[0]}, steps={out.steps})")
    print("[serve] sample:", out.tokens[0][:10])


if __name__ == "__main__":
    main()
