"""Generate the EXPERIMENTS.md §Roofline tables from dry-run + roofline JSON.

  PYTHONPATH=src python -m benchmarks.make_tables \
      results/roofline_baseline.json results/roofline_opt.json
"""

import json
import sys

sys.path.insert(0, "src")

from repro.configs import ARCHS, SHAPES, get_config
from repro.models.registry import model_flops, supports_shape

PEAK = 197e12


def load(path):
    with open(path) as f:
        rows = json.load(f)
    return {(r["arch"], r["shape"], r["mesh"]): r for r in rows}


def fraction(r, mf_chip):
    dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    return (mf_chip / PEAK) / dom if dom > 0 else float("nan")


def main():
    base = load(sys.argv[1])
    opt = load(sys.argv[2]) if len(sys.argv) > 2 else None

    print("| arch | shape | mesh | compute s | memory s | collective s | dominant |"
          " MODEL/HLO flops | roofline frac (base) |" +
          (" frac (opt) |" if opt else ""))
    print("|---|---|---|---|---|---|---|---|---|" + ("---|" if opt else ""))
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = supports_shape(get_config(arch), SHAPES[shape])
            for mesh, chips in [("16x16", 256), ("2x16x16", 512)]:
                key = (arch, shape, mesh)
                if not ok:
                    if mesh == "16x16":
                        print(f"| {arch} | {shape} | - | - | - | - | skipped | - | - |"
                              + (" - |" if opt else ""))
                    continue
                r = base.get(key)
                if r is None:
                    continue
                mf = model_flops(get_config(arch), SHAPES[shape]) / chips
                ratio = mf / max(r["hlo_flops_per_chip"], 1)
                fb = fraction(r, mf)
                row = (f"| {arch} | {shape} | {mesh} | {r['t_compute_s']:.3f} |"
                       f" {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} |"
                       f" {r['dominant']} | {ratio:.2f} | {fb:.3f} |")
                if opt:
                    ro = opt.get(key)
                    fo = fraction(ro, mf) if ro else float("nan")
                    row += f" {fo:.3f} |"
                print(row)


if __name__ == "__main__":
    main()
