"""Roofline analysis from dry-run HLO (task §ROOFLINE).

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, which under-counts a
scan-over-layers model by ~n_layers and contains no collective traffic at
all.  This module parses the optimized HLO text instead:

* builds the call graph (ENTRY -> fusions/calls/while bodies) with
  **multiplicities** from while trip counts (largest integer constant in the
  condition computation — exact for lax.scan lowering);
* counts dot/convolution FLOPs from operand/result shapes x multiplicity;
* sums collective payload bytes (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute) x multiplicity;
* estimates HBM traffic as (result + operand bytes) of non-fused
  instructions x multiplicity.

Terms (TPU v5e): compute = FLOPs / (chips x 197e12), memory = bytes /
(chips x 819e9), collective = coll_bytes / (chips x 50e9).

  PYTHONPATH=src python -m benchmarks.roofline results/dryrun/hlo [--json out]
"""

from __future__ import annotations

import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
               "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


class Instr:
    __slots__ = ("name", "shape", "op", "rest", "operands")

    def __init__(self, name, shape, op, rest):
        self.name = name
        self.shape = shape
        self.op = op
        self.rest = rest
        self.operands: List[str] = []


def parse_hlo(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "{" in line:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op, rest = m.groups()
            ins = Instr(name, shape, op, rest)
            # operand names: up to the first "),"
            paren = rest.split(")")[0]
            ins.operands = _OPERAND_RE.findall(paren)
            comps[cur].append(ins)
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def _trip_count(comps, cond_name: str, while_rest: str = "") -> int:
    # XLA annotates scan-lowered loops with the exact trip count
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_rest)
    if m:
        return int(m.group(1))
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def multiplicities(comps) -> Dict[str, float]:
    entry = comps["__entry_name__"]
    mult: Dict[str, float] = {entry: 1.0}
    fusion_bodies = set()
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        m = mult.get(cname, 1.0)
        for ins in comps.get(cname, []):
            called = _CALL_RE.findall(ins.rest)
            if not called:
                continue
            factor = 1.0
            if ins.op == "while":
                mm = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                body = mm.group(1) if mm else None
                cond = mc.group(1) if mc else None
                trips = _trip_count(comps, cond, ins.rest) if cond else 1
                for c in filter(None, [body, cond]):
                    mult[c] = mult.get(c, 0.0) + m * trips
                    if c not in seen:
                        seen.add(c)
                        order.append(c)
                continue
            if ins.op == "fusion":
                for c in called:
                    fusion_bodies.add(c)
            for c in called:
                mult[c] = mult.get(c, 0.0) + m * factor
                if c not in seen:
                    seen.add(c)
                    order.append(c)
    mult["__fusion_bodies__"] = fusion_bodies  # type: ignore
    return mult


def dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    out_elems = shape_elems(ins.shape)
    lhs = symtab.get(ins.operands[0]) if ins.operands else None
    k = 1
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if lhs and mdims:
        m2 = _SHAPE_RE.search(lhs)
        if m2:
            dims = [int(d) for d in m2.group(2).split(",") if d]
            for di in mdims.group(1).split(","):
                if di and int(di) < len(dims):
                    k *= dims[int(di)]
    return 2.0 * out_elems * k


def analyze_text(text: str) -> Dict:
    comps = parse_hlo(text)
    mult = multiplicities(comps)
    fusion_bodies: set = mult.pop("__fusion_bodies__")  # type: ignore
    entry_name = comps.pop("__entry_name__")
    comps.pop("__entry__")

    flops = 0.0
    coll_bytes: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    traffic = 0.0
    NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "while", "fusion", "call", "conditional",
                  "after-all", "partition-id"}
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = {i.name: i.shape for i in instrs}
        in_fusion = cname in fusion_bodies
        for ins in instrs:
            if ins.op in ("dot", "convolution"):
                flops += m * dot_flops(ins, symtab)
            if ins.op in COLLECTIVES:
                coll_bytes[ins.op] += m * shape_bytes(ins.shape)
            if not in_fusion and ins.op not in NO_TRAFFIC:
                out_b = shape_bytes(ins.shape)
                in_b = sum(shape_bytes(symtab.get(o, "")) for o in ins.operands)
                traffic += m * (out_b + in_b)
    return {
        "hlo_flops_per_chip": flops,
        "collective_bytes_per_chip": coll_bytes,
        "collective_total_per_chip": sum(coll_bytes.values()),
        "hbm_traffic_per_chip": traffic,
    }


def roofline_terms(analysis: Dict, n_chips: int) -> Dict:
    """SPMD HLO shapes are PER-DEVICE, so the parsed sums are per-chip
    already; each term divides by one chip's peak.  (Equivalently:
    global_bytes/(chips x bw) with global = per_chip x chips — the task
    formula with the global quantities.)"""
    t_comp = analysis["hlo_flops_per_chip"] / PEAK_FLOPS
    t_mem = analysis["hbm_traffic_per_chip"] / HBM_BW
    t_coll = analysis["collective_total_per_chip"] / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dominant}


def analyze_file(path: str) -> Dict:
    with open(path) as f:
        text = f.read()
    base = os.path.basename(path).replace(".hlo", "")
    arch, shape, mesh = base.split("__")
    n_chips = 512 if mesh == "2x16x16" else 256
    out = analyze_text(text)
    out.update({"arch": arch, "shape": shape, "mesh": mesh, "n_chips": n_chips})
    out.update(roofline_terms(out, n_chips))
    return out


def main():
    hlo_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun/hlo"
    out_json = None
    if "--json" in sys.argv:
        out_json = sys.argv[sys.argv.index("--json") + 1]
    rows = []
    for fname in sorted(os.listdir(hlo_dir)):
        if not fname.endswith(".hlo"):
            continue
        r = analyze_file(os.path.join(hlo_dir, fname))
        rows.append(r)
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"comp={r['t_compute_s']*1e3:9.3f}ms mem={r['t_memory_s']*1e3:9.3f}ms "
              f"coll={r['t_collective_s']*1e3:9.3f}ms dom={r['dominant']}",
              flush=True)
    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
