import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration harness (§Perf): lower one (arch, shape) cell with config
overrides, re-derive the roofline terms, print before/after-comparable rows.

  PYTHONPATH=src python -m benchmarks.perf_iter qwen2-72b train_4k \
      --set remat=dots --tag B1
"""

import argparse
import dataclasses
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

import jax

from roofline import analyze_text, roofline_terms
from repro.configs import ARCHS, SHAPES, get_config
from repro.models.registry import model_flops
from repro.launch import dryrun as DR
from repro.launch.mesh import make_production_mesh


def run(arch: str, shape_name: str, overrides=None, moe_overrides=None,
        tag: str = "base", multi_pod: bool = False, hlo_out=None):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if moe_overrides:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
    # temporarily install the modified config
    old = ARCHS[arch]
    ARCHS[arch] = cfg
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        with jax.set_mesh(mesh):
            fn, args, in_sh, out_sh, donate = DR.build_cell(arch, shape_name, mesh)
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        text = compiled.as_text()
        if hlo_out:
            with open(hlo_out, "w") as f:
                f.write(text)
        a = analyze_text(text)
        n_chips = 512 if multi_pod else 256
        a.update(roofline_terms(a, n_chips))
        mf = model_flops(cfg, SHAPES[shape_name]) / n_chips
        mem = compiled.memory_analysis()
        a["peak_gib"] = getattr(mem, "peak_memory_in_bytes", 0) / 2**30
        a["model_flops_per_chip"] = mf
        a["useful_ratio"] = mf / max(a["hlo_flops_per_chip"], 1)
        dom_t = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"])
        a["roofline_fraction"] = (mf / 197e12) / dom_t if dom_t else 0.0
        print(f"[{tag}] {arch} {shape_name}  compile={time.time()-t0:.0f}s")
        print(f"[{tag}]   comp={a['t_compute_s']:8.3f}s mem={a['t_memory_s']:8.3f}s "
              f"coll={a['t_collective_s']:8.3f}s dom={a['dominant']}")
        cb = a["collective_bytes_per_chip"]
        print(f"[{tag}]   AG={cb['all-gather']/2**30:.1f} AR={cb['all-reduce']/2**30:.1f} "
              f"RS={cb['reduce-scatter']/2**30:.1f} A2A={cb['all-to-all']/2**30:.1f} "
              f"CP={cb['collective-permute']/2**30:.1f} GiB/chip  "
              f"traffic={a['hbm_traffic_per_chip']/2**30:.0f} GiB")
        print(f"[{tag}]   useful_flops_ratio={a['useful_ratio']:.2f} "
              f"roofline_fraction={a['roofline_fraction']:.3f} peak={a['peak_gib']:.2f} GiB",
              flush=True)
        return a
    finally:
        ARCHS[arch] = old


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override k=v (remat=dots, dtype=bfloat16...)")
    ap.add_argument("--moe-set", action="append", default=[])
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--hlo-out", default=None)
    args = ap.parse_args()

    def parse(kvs):
        out = {}
        for kv in kvs:
            k, v = kv.split("=", 1)
            for cast in (int, float):
                try:
                    v = cast(v)
                    break
                except ValueError:
                    continue
            if v in ("True", "False"):
                v = v == "True"
            out[k] = v
        return out

    run(args.arch, args.shape, overrides=parse(args.set) or None,
        moe_overrides=parse(args.moe_set) or None, tag=args.tag,
        hlo_out=args.hlo_out)


if __name__ == "__main__":
    main()
