"""Benchmark harness — one function per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows.  Three result tiers per
DESIGN.md §2.2: counted IOPS/bytes (exact), measured CPU wall-time (real),
modelled NVMe/S3 latency (paper Fig-1 device model applied to the counted
trace).  Dataset sizes are scaled down from the paper's 1 B rows to CPU
scale; rates are per-row so the comparisons carry.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig10 fig13
  PYTHONPATH=src python -m benchmarks.run --store tiered fig11

``--store {flat,tiered,flat-s3,hot}`` picks the storage stack every
benchmark reader is built on: ``flat`` is the seed behaviour (every read
priced on NVMe), ``tiered`` routes reads through the NVMe block cache over
S3 from ``repro.store``, ``flat-s3`` is the cold object store, ``hot`` adds
a RAM tier.  Under a non-flat stack the modelled column is priced with the
store's per-tier accounting (``FileReader.modelled_time``); counted IOPS
stay store-independent, and the measured (CPU) column includes the
simulator's block-classification overhead.  The ``store`` benchmark
reproduces the headline cold-S3 / NVMe-warm / flat-NVMe comparison
regardless of the flag; the ``dataset`` benchmark compares one shared NVMe
budget against per-file split stores over a fragmented dataset
(``BENCH_dataset.json``); the ``ingest`` benchmark compares write-back vs
write-through flush policies on append-heavy and mixed append/take ingest
into a live versioned dataset (``BENCH_ingest.json``).
"""

from __future__ import annotations

import json
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import arrays as A, types as T
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.io_sim import NVME, S3, model_time
from repro.data import synth
from repro.obs import Tracer, attribute

ROWS = {"scalar": 200_000, "string": 100_000, "scalar-list": 50_000,
        "string-list": 30_000, "vector": 4_000, "vector-list": 1_500,
        "image": 800, "image-list": 300}
TAKE_N = 256  # one paper 'take' op

STORE_SPEC = "flat"  # set by --store; every benchmark reader is built on it
SMOKE = False  # set by --smoke; tiny row counts for CI
TRACER = None  # set by --trace PATH; threaded through every reader
TRACE_PATH = None


def _reader(file_bytes, **kw) -> FileReader:
    return FileReader(file_bytes, store=STORE_SPEC, tracer=TRACER, **kw)


def _emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}")


def _git_sha():
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10).stdout.strip() or None
    except OSError:
        return None


def _run_meta() -> dict:
    """Run provenance stamped into every BENCH_*.json: without it the perf
    trajectory across PRs is a pile of unlabelled numbers."""
    return {"git_sha": _git_sha(), "store": STORE_SPEC, "smoke": SMOKE,
            "traced": TRACER is not None,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())}


def _dump_json(path: str, results: dict) -> None:
    """The single bench artifact write site: stamps run metadata and refuses
    NaN/Infinity (``allow_nan=False`` — non-standard JSON tokens used to
    leak in through empty-cache hit rates)."""
    results.setdefault("meta", {})["run"] = _run_meta()
    with open(path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True, allow_nan=False)


def _take_bench(arr, opts, n_rows, repeats=3):
    fr = _reader(write_table({"c": arr}, opts))
    rng = np.random.default_rng(0)
    rows = rng.choice(n_rows, min(TAKE_N, n_rows), replace=False)
    fr.take("c", rows[:4])  # warm code paths
    fr.reset_io()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fr.take("c", rows)
    dt = (time.perf_counter() - t0) / repeats
    st = fr.io_stats()
    st.n_iops //= repeats
    st.bytes_read //= repeats
    st.useful_bytes //= repeats
    if STORE_SPEC == "flat":
        t_model = model_time(st, NVME)
    else:
        # price the counted trace on the configured tier stack instead
        t_model = fr.modelled_time() / repeats
    rows_s = len(rows) / max(t_model, dt)  # disk- or cpu-bound, whichever binds
    return dt, st, t_model, rows_s, fr


def _scan_bench(arr, opts, repeats=3):
    fr = _reader(write_table({"c": arr}, opts))
    fr.scan("c")
    fr.reset_io()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fr.scan("c")
    dt = (time.perf_counter() - t0) / repeats
    st = fr.io_stats()
    st.bytes_read //= repeats
    return dt, st, fr


# ---------------------------------------------------------------------------


def fig1_device_model():
    """Fig 1: device characteristics used by the model tier."""
    from repro.core.io_sim import IOStats

    for dev in (NVME, S3):
        for size in [4096, 64 * 1024, 1 << 20]:
            st = IOStats(n_iops=1000, bytes_read=1000 * size,
                         useful_bytes=1000 * size, max_phase=1)
            t = model_time(st, dev)
            _emit(f"fig1/{dev.name}/rand{size//1024}KiB", t / 1000 * 1e6,
                  f"iops={1000/t:.0f}")


def fig10_parquet_random_access():
    """Fig 10: Parquet random access across types + page-size sweep, and the
    §5 headline: optimized config is ~60x the default config."""
    for tname, n in ROWS.items():
        arr = synth.paper_type(tname, n, seed=1)
        dt, st, t_nvme, rows_s, _ = _take_bench(
            arr, WriteOptions("parquet", page_bytes=8192), n)
        _emit(f"fig10/parquet8k/{tname}", dt / TAKE_N * 1e6,
              f"rows_per_s={rows_s:.0f};iops_row={st.n_iops/TAKE_N:.2f};"
              f"amp={st.read_amplification:.1f}")
    # page size sweep on scalars (8KiB .. 1MiB 'default')
    arr = synth.paper_type("scalar", ROWS["scalar"], seed=1)
    base = None
    for ps in [8 << 10, 64 << 10, 256 << 10, 1 << 20]:
        dt, st, t_nvme, rows_s, _ = _take_bench(
            arr, WriteOptions("parquet", page_bytes=ps), ROWS["scalar"])
        if ps == 8 << 10:
            base = rows_s
        _emit(f"fig10/pagesize/{ps>>10}KiB", dt / TAKE_N * 1e6,
              f"rows_per_s={rows_s:.0f}")
    # the 60x claim: default (1MiB pages + dict, cold) vs optimized (8KiB)
    dt_d, st_d, t_d, rows_d, _ = _take_bench(
        arr, WriteOptions("parquet", page_bytes=1 << 20, dict_encode=True),
        ROWS["scalar"])
    _emit("fig10/default_vs_tuned", dt_d / TAKE_N * 1e6,
          f"speedup={base/rows_d:.0f}x;default_rows_s={rows_d:.0f};"
          f"tuned_rows_s={base:.0f}")
    # analytic extrapolation to the paper's 1B-row scale (no coalescing):
    # tuned = one 8KiB IOP/row; default = one 1MiB page + dict page per take
    t_tuned = max(1 / NVME.iops_4k, 8192 / NVME.seq_bw)
    t_default = (1 << 20) / NVME.seq_bw + (1 << 20) * 8 / NVME.seq_bw / TAKE_N
    _emit("fig10/default_vs_tuned_1Brow_model", 0.0,
          f"speedup={t_default/t_tuned:.0f}x;tuned_rows_s={1/t_tuned:.0f};"
          f"default_rows_s={1/t_default:.0f}")


def fig11_encodings_random_access():
    """Fig 11: Arrow-style vs Lance 2.1 (adaptive) random access + nesting."""
    for tname, n in ROWS.items():
        arr = synth.paper_type(tname, n, seed=1)
        for enc, opts in [("arrow", WriteOptions("arrow")),
                          ("lance", WriteOptions("lance"))]:
            dt, st, t_nvme, rows_s, fr = _take_bench(arr, opts, n)
            _emit(f"fig11/{enc}/{tname}", dt / TAKE_N * 1e6,
                  f"nvme_rows_per_s={TAKE_N/max(t_nvme,1e-9):.0f};"
                  f"iops_row={st.n_iops/TAKE_N:.2f};"
                  f"phases={st.max_phase};cache={fr.search_cache_bytes()}")
    # nesting depth: scalar wrapped in k list levels
    take_rows = np.arange(0, 2000, 97)
    for depth in [0, 1, 2, 3]:
        typ = T.int64()
        py = list(range(2000))
        for _ in range(depth):
            typ = T.List(typ)
            py = [[v] for v in py]
        arr = A.from_pylist(py, typ)
        for enc, opts in [("arrow", WriteOptions("arrow")),
                          ("lance-fullzip", WriteOptions("lance-fullzip"))]:
            fr = _reader(write_table({"c": arr}, opts))
            fr.reset_io()
            fr.take("c", take_rows)
            st = fr.io_stats()
            _emit(f"fig11/nesting{depth}/{enc}", 0.0,
                  f"iops_row={st.n_iops/len(take_rows):.2f};phases={st.max_phase}")


def fig12_fullzip_vs_miniblock():
    """Fig 12: full-zip is lighter-weight for random access at all sizes."""
    for width in [8, 32, 128, 512, 2048]:
        n = max(2_000, 200_000 * 8 // width)
        rng = np.random.default_rng(0)
        arr = A.FixedSizeListArray(
            T.FixedSizeList(T.Primitive("float32", nullable=False), width // 4),
            np.ones(n, bool),
            rng.standard_normal((n, width // 4)).astype(np.float32))
        for enc in ["lance-fullzip", "lance-miniblock"]:
            dt, st, t_nvme, rows_s, _ = _take_bench(arr, WriteOptions(enc), n)
            _emit(f"fig12/{enc}/{width}B", dt / TAKE_N * 1e6,
                  f"rows_per_s={rows_s:.0f};cpu_us_row={dt/TAKE_N*1e6:.1f};"
                  f"amp={st.read_amplification:.1f}")


def _lance_codec(sc):
    # the paper's table: names Dict+FSST, prompts/reviews FSST, dates bitpack,
    # code/images/websites LZ4(->zstd stand-in), embeddings none
    return {"names": "fsst_lite", "prompts": "fsst_lite", "reviews": "fsst_lite",
            "code": "zstd_chunk", "images": "zstd_chunk",
            "websites": "zstd_chunk"}.get(sc, "zstd_chunk")


def _raw_bytes(arr):
    if isinstance(arr, A.VarBinaryArray):
        return int(arr.offsets[-1]) + 8 * len(arr)
    if isinstance(arr, (A.FixedSizeListArray, A.PrimitiveArray)):
        return arr.values.nbytes
    if isinstance(arr, A.ListArray):
        return _raw_bytes(arr.child) + 8 * len(arr)
    raise TypeError(type(arr))


def fig13_compression():
    """Fig 13: Lance compresses like Parquet across the scenario corpus."""
    for sc in synth.SCENARIOS:
        n = 2_000 if sc in ("images", "websites", "code") else 20_000
        arr = synth.scenario(sc, n)
        raw = _raw_bytes(arr)
        for enc, opts in [
            ("parquet", WriteOptions("parquet", bytes_codec="zstd_chunk",
                                     dict_encode=sc == "names")),
            ("lance", WriteOptions("lance", bytes_codec="zstd_chunk")),
            ("lance-fsst", WriteOptions("lance", bytes_codec="fsst_lite")),
        ]:
            fr = _reader(write_table({"c": arr}, opts))
            ratio = raw / fr.data_bytes()
            _emit(f"fig13/{enc}/{sc}", 0.0,
                  f"ratio={ratio:.2f};disk_bytes={fr.data_bytes()}")


def fig14_16_full_scan():
    """Fig 14/16: scan throughput, Parquet vs Lance (values/s + disk MB/s)."""
    for sc in ["names", "prompts", "dates", "embeddings"]:
        n = 30_000 if sc != "embeddings" else 4_000
        arr = synth.scenario(sc, n)
        best = {}
        for enc, opts in [
            ("parquet", WriteOptions("parquet", bytes_codec="zstd_chunk")),
            ("lance", WriteOptions("lance", bytes_codec="zstd_chunk")),
        ]:
            dt, st, fr = _scan_bench(arr, opts)
            vals_s = n / dt
            disk_mbs = st.bytes_read / dt / 1e6
            best[enc] = vals_s
            _emit(f"fig16/{enc}/{sc}", dt * 1e6,
                  f"vals_per_s={vals_s:.0f};disk_MBps={disk_mbs:.0f}")
        _emit(f"fig16/normalized/{sc}", 0.0,
              f"lance_over_parquet={best['lance']/best['parquet']:.2f}")


def fig17_scan_decode_cost():
    """Fig 17: mini-block scan decode is vectorized; full-zip unzips
    per-value (CPU-bound)."""
    n = 60_000
    rng = np.random.default_rng(0)
    vals = [bytes(rng.integers(97, 123, 16, dtype=np.uint8)) for _ in range(n)]
    arr = A.VarBinaryArray.build(vals, utf8=True)
    per_val = {}
    for enc in ["lance-miniblock", "lance-fullzip"]:
        dt, st, fr = _scan_bench(arr, WriteOptions(enc), repeats=2)
        per_val[enc] = dt / n * 1e6
        _emit(f"fig17/{enc}/string16B", dt / n * 1e6, f"vals_per_s={n/dt:.0f}")
    _emit("fig17/miniblock_advantage", 0.0,
          f"fullzip_over_miniblock={per_val['lance-fullzip']/per_val['lance-miniblock']:.1f}x")


def fig18_struct_packing():
    """Fig 18: packed structs trade single-field scan for whole-struct take."""
    n = 30_000
    rng = np.random.default_rng(0)
    for k in [2, 3, 4, 5]:
        children = [(f"f{i}", A.PrimitiveArray.build(
            rng.integers(0, 1 << 40, n).astype(np.int64), nullable=False))
            for i in range(k)]
        arr = A.StructArray.build(children, nullable=False)
        rows = rng.choice(n, TAKE_N, replace=False)
        fr = _reader(write_table({"s": arr},
                                  WriteOptions("lance", packed_columns=("s",))))
        fr.reset_io()
        t0 = time.perf_counter()
        fr.take("s", rows)
        dt_p = time.perf_counter() - t0
        st = fr.io_stats()
        t_take_packed = max(model_time(st, NVME), dt_p)
        fr.reset_io()
        t0 = time.perf_counter()
        fr.scan_packed_field("s", ["f0"])
        dt_scan_p = time.perf_counter() - t0
        fr2 = _reader(write_table({"s": arr}, WriteOptions("lance")))
        fr2.reset_io()
        t0 = time.perf_counter()
        fr2.take("s", rows)
        dt_s = time.perf_counter() - t0
        st2 = fr2.io_stats()
        t_take_shred = max(model_time(st2, NVME), dt_s)
        _emit(f"fig18/fields{k}", dt_p * 1e6,
              f"take_rows_s_packed={TAKE_N/t_take_packed:.0f};"
              f"take_rows_s_shredded={TAKE_N/t_take_shred:.0f};"
              f"iops_packed={st.n_iops};iops_shredded={st2.n_iops};"
              f"scan1field_us={dt_scan_p*1e6:.0f}")


def store_tiering():
    """The tiered-store headline: a take-heavy random-access workload priced
    cold from S3, through an NVMe block cache (cold fill then warm hits),
    and on bare NVMe.  The modelled NVMe-warm time must beat cold S3."""
    from repro.store import TieredStore

    n = ROWS["vector"]
    arr = synth.paper_type("vector", n, seed=1)
    fb = write_table({"c": arr}, WriteOptions("lance"))
    rng = np.random.default_rng(0)
    rows = rng.choice(n, TAKE_N, replace=False)

    fr_s3 = FileReader(fb, store="flat-s3")
    fr_s3.take("c", rows)
    t_cold_s3 = fr_s3.modelled_time()
    _emit("store/cold_s3", t_cold_s3 * 1e6,
          f"rows_per_s={TAKE_N/t_cold_s3:.0f}")

    fr = FileReader(fb, store="tiered")
    fr.take("c", rows)
    t_fill = fr.modelled_time()
    miss_stats = {s.name: s for s in fr.tier_stats()}
    _emit("store/tiered_fill", t_fill * 1e6,
          f"rows_per_s={TAKE_N/t_fill:.0f};"
          f"s3_iops={miss_stats['s3'].n_iops}")
    fr.reset_io()
    fr.take("c", rows)
    t_warm = fr.modelled_time()
    warm = {s.name: s for s in fr.tier_stats()}
    nv = warm["nvme_970evo"]
    _emit("store/tiered_warm", t_warm * 1e6,
          f"rows_per_s={TAKE_N/t_warm:.0f};hit_rate={nv.hit_rate:.2f};"
          f"s3_iops={warm['s3'].n_iops}")

    fr_nvme = FileReader(fb)  # flat NVMe
    fr_nvme.take("c", rows)
    t_nvme = fr_nvme.modelled_time()
    _emit("store/flat_nvme", t_nvme * 1e6, f"rows_per_s={TAKE_N/t_nvme:.0f}")

    assert t_warm < t_cold_s3, "NVMe-warm tiered take must beat cold S3"
    _emit("store/warm_over_cold", 0.0,
          f"speedup={t_cold_s3/t_warm:.0f}x;warm_lt_cold={t_warm < t_cold_s3}")

    # capacity-pressured cache: working set larger than the cache forces
    # evictions; hit rate and speedup degrade gracefully
    fr_small = FileReader(fb, store=lambda d: TieredStore.cached(d, cache_bytes=1 << 20))
    for _ in range(2):
        fr_small.take("c", rows)
    ev = {s.name: s for s in fr_small.tier_stats()}["nvme_970evo"]
    _emit("store/tiered_1MiB_cache", fr_small.modelled_time() * 1e6,
          f"hit_rate={ev.hit_rate:.2f};evictions={ev.evictions}")


def take_decode():
    """Random-access hot path trajectory: rows/s and the decode-vs-IO time
    split for the batched take pipeline (mini-block + full-zip) at
    1k/10k/100k random row ids (with duplicates, as a serving workload
    would).  Wall time is decode/orchestration CPU (IO is simulated);
    modelled IO prices the counted trace on the device model.  Results are
    written to BENCH_take.json so future PRs can track the hot path."""
    counts = [64, 256] if SMOKE else [1_000, 10_000, 100_000]
    n = 20_000 if SMOKE else 200_000
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1 << 20, n).astype(np.int64)
    validity = rng.random(n) > 0.03
    mb = A.PrimitiveArray.build(vals, validity=validity)
    fz = A.FixedSizeListArray(
        T.FixedSizeList(T.Primitive("float32", nullable=False), 32),
        np.ones(n, bool), rng.standard_normal((n, 32)).astype(np.float32))
    # pre-PR reader throughput on these exact datasets/seed (per-row decode
    # loops, measured before the batched pipeline landed) — the trajectory's
    # fixed origin for the >=5x acceptance gate
    baseline = {"miniblock": {"1000": 25780, "10000": 29956},
                "fullzip": {"1000": 48117, "10000": 45494}}
    results = {"meta": {"n_rows": n, "smoke": SMOKE, "store": STORE_SPEC,
                        "row_counts": counts,
                        "baseline_note": "pre-PR rows/s measured on the "
                                         "per-row-loop reader (PR 2 seed)"},
               "pre_pr_baseline": baseline}
    for name, arr, opts in [
        ("miniblock", mb, WriteOptions("lance-miniblock")),
        ("fullzip", fz, WriteOptions("lance-fullzip")),
    ]:
        fr = _reader(write_table({"c": arr}, opts))
        results[name] = {}
        for k in counts:
            rows = rng.integers(0, n, k)
            fr.take("c", rows)  # warm code paths (decode is never cached)
            fr.reset_io()
            t0 = time.perf_counter()
            fr.take("c", rows)
            dt = time.perf_counter() - t0
            st = fr.io_stats()
            if STORE_SPEC == "flat":
                t_io = model_time(st, NVME)
            else:
                t_io = fr.modelled_time()
            rows_s = k / max(dt, t_io)
            cell = {"rows_per_s": round(rows_s), "cpu_decode_s": round(dt, 6),
                    "model_io_s": round(t_io, 6), "n_iops": st.n_iops,
                    "bytes_read": st.bytes_read,
                    "read_amplification": round(st.read_amplification, 3)}
            base = baseline.get(name, {}).get(str(k))
            if base:
                cell["speedup_vs_pre_pr"] = round(rows_s / base, 2)
            results[name][str(k)] = cell
            _emit(f"take_decode/{name}/{k}", dt * 1e6,
                  f"rows_per_s={rows_s:.0f};cpu_decode_s={dt:.4f};"
                  f"model_io_s={t_io:.4f};iops={st.n_iops}"
                  + (f";speedup={rows_s / base:.1f}x" if base else ""))
        fr.drop_caches()
    # variable-width cases (utf8 + nested list): the Fig-17 decode cost the
    # fixed-stride cells above cannot see.  A separate rng keeps the cells
    # above bit-identical to their historical draws.
    rng2 = np.random.default_rng(7)
    n2 = 20_000 if SMOKE else 200_000
    utf8 = _var_utf8(rng2, n2)
    nested = _nested_utf8(rng2, n2 // 4)
    for name, arr, nn in [("fullzip-utf8", utf8, n2),
                          ("fullzip-list", nested, n2 // 4)]:
        fr = _reader(write_table({"c": arr}, WriteOptions("lance-fullzip")))
        results[name] = {}
        for k in counts:
            rows = rng2.integers(0, nn, k)
            fr.take("c", rows)
            fr.reset_io()
            t0 = time.perf_counter()
            fr.take("c", rows)
            dt = time.perf_counter() - t0
            st = fr.io_stats()
            t_io = model_time(st, NVME) if STORE_SPEC == "flat" else fr.modelled_time()
            results[name][str(k)] = {
                "rows_per_s": round(k / max(dt, t_io)),
                "cpu_decode_s": round(dt, 6), "model_io_s": round(t_io, 6),
                "n_iops": st.n_iops, "bytes_read": st.bytes_read,
                "read_amplification": round(st.read_amplification, 3)}
            _emit(f"take_decode/{name}/{k}", dt * 1e6,
                  f"rows_per_s={k / max(dt, t_io):.0f};iops={st.n_iops}")
        fr.drop_caches()
    results["serving_latency"] = _serving_latency_cell(mb)
    results["pallas_fallback_probe"] = _pallas_fallback_probe(rng)
    _dump_json("BENCH_take.json", results)
    _emit("take_decode/written", 0.0, "path=BENCH_take.json")


def _serving_latency_cell(arr) -> dict:
    """Per-request latency attribution over a stream of small takes against
    the tiered store: every queue drain's modelled cost is decomposed onto
    the rows it served (repro.obs.attrib), giving the p50/p99/p999 a serving
    SLO actually cares about — the mean hides the cold-tier tail entirely.
    Deterministic (counted traces x device constants), so bench_gate can
    diff the percentiles exactly."""
    n_req, rows_per_req = (32, 16) if SMOKE else (256, 32)
    rng3 = np.random.default_rng(11)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance-miniblock")),
                    store="tiered", tracer=TRACER)
    n = len(arr)
    t0 = time.perf_counter()
    for _ in range(n_req):
        fr.take("c", rng3.integers(0, n, rows_per_req))
    dt = time.perf_counter() - t0
    att = attribute(fr.store, queue_depth=fr.scheduler.queue_depth)
    # the acceptance invariant: attributed per-tier sums reproduce each
    # tier's model_time to float exactness (residual is reported, not hidden)
    residual = 0.0
    sums = att.tier_sums()
    devices = [lvl.device for lvl in fr.store.levels] + [fr.store.backing]
    for stats, dev in zip(fr.store.tier_stats(), devices):
        mt = stats.model_time(dev, fr.scheduler.queue_depth)
        if mt > 0:
            residual = max(residual, abs(sums.get(stats.name, 0.0) - mt) / mt)
    # each take declared len(rows) logical requests, so the attributed
    # per-request latency is already per-row
    pct = att.percentiles("take:c") or {}
    per_row = {k: round(v * 1e6, 4) for k, v in pct.items() if k != "count"}
    cell = {"n_takes": n_req, "rows_per_take": rows_per_req, "store": "tiered",
            "per_row_us": per_row, "n_attributed_requests": pct.get("count"),
            "attribution_residual_rel": residual,
            "model_total_s": round(att.total, 6),
            "cpu_wall_s": round(dt, 6)}
    _emit("take_decode/serving_latency", dt * 1e6,
          f"p50_us={per_row.get('p50')};p99_us={per_row.get('p99')};"
          f"p999_us={per_row.get('p999')};residual={residual:.2e}")
    return cell


def _pallas_fallback_probe(rng) -> dict:
    """Force the kernel route off the Pallas path (float values are VPU-only
    in the mini-block gather kernel) and report the structured fallback
    reasons the tracer counted.  Runs against the session tracer when
    --trace is set so the exported Chrome trace carries the instant events;
    otherwise a local tracer keeps the probe self-contained."""
    tr = TRACER if TRACER is not None else Tracer()
    n = 4_096
    arr = A.PrimitiveArray.build(rng.standard_normal(n).astype(np.float32))
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance-miniblock")),
                    store=STORE_SPEC, decode="pallas", tracer=tr)
    fr.take("c", rng.integers(0, n, 64))
    reasons = tr.metrics.counter_values("decode.fallback")
    n_events = sum(1 for e in tr.events
                   if e.get("name") == "pallas_fallback")
    cell = {"reasons": reasons, "n_events": n_events}
    _emit("take_decode/pallas_fallback_probe", 0.0,
          f"n_events={n_events};reasons={len(reasons)}")
    return cell


def _var_utf8(rng, n: int) -> A.VarBinaryArray:
    """Flat utf8, ~16 B average values, 3% nulls — the Fig-17 shape shared
    by the ``take_decode`` variable-width cells and the ``decode`` headline
    (and its embedded pre-PR baseline)."""
    lens = rng.integers(4, 28, n)
    validity = rng.random(n) > 0.03
    kept = np.where(validity, lens, 0)  # nulls occupy no bytes
    offs = np.zeros(n + 1, np.int64)
    np.cumsum(kept, out=offs[1:])
    return A.VarBinaryArray(T.Utf8(True), validity, offs,
                            rng.integers(97, 123, int(offs[-1]), dtype=np.uint8))


def _nested_utf8(rng, n_rows: int) -> A.ListArray:
    """list<utf8> rows (0-8 strings of 2-16 B, null lists and null items):
    variable-width entries behind a repetition index — the shape where the
    per-value walk was the Fig-17 bottleneck for nested data."""
    lvalid = rng.random(n_rows) > 0.05
    lens_l = np.where(lvalid, rng.integers(0, 8, n_rows), 0)
    loffs = np.zeros(n_rows + 1, np.int64)
    np.cumsum(lens_l, out=loffs[1:])
    n_child = int(loffs[-1])
    cvalid = rng.random(n_child) > 0.05
    ckept = np.where(cvalid, rng.integers(2, 16, n_child), 0)
    coffs = np.zeros(n_child + 1, np.int64)
    np.cumsum(ckept, out=coffs[1:])
    child = A.VarBinaryArray(
        T.Utf8(True), cvalid, coffs,
        rng.integers(97, 123, int(coffs[-1]), dtype=np.uint8))
    return A.ListArray.build(child, loffs, validity=lvalid)


def decode_bench():
    """The row-parallel full-zip decode headline (BENCH_decode.json).

    Variable-width full-zip random access is CPU-bound on decode (the
    paper's §6.3/Fig-17 cost): entry positions depend on embedded lengths.
    This benchmark times the row-parallel frontier decode against the
    retained per-value walk (``FullZipReader._decode_entries_walk`` — the
    exact pre-PR decode loop) on the same fetched spans, so the speedup is a
    like-for-like decode comparison, plus the end-to-end take and scan.
    The embedded ``pre_pr_take_baseline`` numbers are full-take rows/s
    measured on the per-value-walk reader immediately before this PR landed
    (same machine, same dataset shapes) — the trajectory's fixed origin.
    """
    counts = [256, 1_024] if SMOKE else [1_000, 10_000]
    n = 20_000 if SMOKE else 200_000
    rng = np.random.default_rng(0)
    utf8 = _var_utf8(rng, n)
    # nested list<utf8>: multi-entry variable-width rows exercise the
    # frontier depth (one vectorized step per entry-per-row)
    n_l = n // 4
    nested = _nested_utf8(rng, n_l)
    # pre-PR full-take rows/s on these exact datasets/seed (per-value-walk
    # reader at the PR-3 tip, flat NVMe store)
    baseline = {"utf8": {"1000": 119618, "10000": 120030},
                "list": {"1000": 68143, "10000": 59884}}
    results = {"meta": {"n_rows": n, "smoke": SMOKE, "store": STORE_SPEC,
                        "row_counts": counts,
                        "baseline_note": "pre-PR full-take rows/s measured on "
                                         "the per-value-walk reader"},
               "pre_pr_take_baseline": baseline}
    import repro.core.fullzip as _fz

    for name, arr, nn in [("utf8", utf8, n), ("list", nested, n_l)]:
        fr = _reader(write_table({"c": arr}, WriteOptions("lance-fullzip")))
        reader = fr._leaf_readers("c")[0]
        m = reader.meta
        results[name] = {}
        for k in counts:
            rows = rng.integers(0, nn, k)
            fr.take("c", rows)  # warm code paths (decode is never cached)
            fr.reset_io()
            t0 = time.perf_counter()
            fr.take("c", rows)
            dt = time.perf_counter() - t0
            st = fr.io_stats()
            t_io = model_time(st, NVME) if STORE_SPEC == "flat" else fr.modelled_time()
            # isolated decode: fetch the unique-row spans once, then time the
            # row-parallel frontier vs the retained per-value walk on the
            # exact same concatenated bytes
            urows = np.unique(rows)
            R = m["R"]
            with fr.scheduler.batch("decode-bench") as io:
                idx, _ = io.read_many(reader.base + urows * R,
                                      np.full(len(urows), 2 * R, np.int64))
                mat = idx.reshape(len(urows), 2 * R)
                lo = _fz._from_le(mat[:, :R]).astype(np.int64)
                hi = _fz._from_le(mat[:, R:]).astype(np.int64)
                spans, _ = io.read_many(
                    reader.base + m["zip_base"] + lo, hi - lo, phase=1)
            seg = np.zeros(len(urows) + 1, np.int64)
            np.cumsum(hi - lo, out=seg[1:])

            def timeit(fn, reps=3):
                fn()
                t0 = time.perf_counter()
                for _ in range(reps):
                    fn()
                return (time.perf_counter() - t0) / reps

            t_new = timeit(lambda: reader._decode_entries(spans, seg_offs=seg))
            t_walk = timeit(lambda: reader._decode_entries_walk(spans))
            cell = {"rows_per_s": round(k / max(dt, t_io)),
                    "cpu_take_s": round(dt, 6), "model_io_s": round(t_io, 6),
                    "n_iops": st.n_iops, "bytes_read": st.bytes_read,
                    "decode_rows_per_s": round(len(urows) / t_new),
                    "walk_rows_per_s": round(len(urows) / t_walk),
                    "decode_speedup_vs_walk": round(t_walk / t_new, 2)}
            base = baseline.get(name, {}).get(str(k))
            if base and not SMOKE:
                cell["take_speedup_vs_pre_pr"] = round(k / max(dt, t_io) / base, 2)
            results[name][str(k)] = cell
            _emit(f"decode/{name}/{k}", dt * 1e6,
                  f"rows_per_s={k / max(dt, t_io):.0f};"
                  f"decode_speedup_vs_walk={t_walk / t_new:.1f}x;"
                  f"iops={st.n_iops}")
        # scan: windowed row-parallel decode vs the walk on the whole column
        fr.scan("c")
        t0 = time.perf_counter()
        fr.scan("c")
        t_scan = time.perf_counter() - t0
        raw = fr.disk.read(reader.base + m["zip_base"], m["zip_bytes"])
        t_walk = time.perf_counter()
        reader._decode_entries_walk(raw, n_hint=m["n_entries"])
        t_walk = time.perf_counter() - t_walk
        results[name]["scan"] = {
            "vals_per_s": round(nn / t_scan),
            "walk_decode_s": round(t_walk, 6), "scan_s": round(t_scan, 6)}
        _emit(f"decode/{name}/scan", t_scan * 1e6,
              f"vals_per_s={nn / t_scan:.0f};walk_decode_s={t_walk:.4f}")
        fr.drop_caches()
    # fused gather route: fixed-stride take through kernels.fullzip_gather
    # (interpret mode on CPU — parity is the point, wall time is not TPU time)
    fz = A.FixedSizeListArray(
        T.FixedSizeList(T.Primitive("float32", nullable=False), 32),
        np.ones(2000, bool),
        rng.standard_normal((2000, 32)).astype(np.float32))
    fb = write_table({"c": fz}, WriteOptions("lance-fullzip"))
    rows = rng.integers(0, 2000, 64 if SMOKE else 1000)
    got_np = _reader(fb, decode="numpy").take("c", rows)
    got_pl = _reader(fb, decode="pallas").take("c", rows)
    gather_ok = bool(np.array_equal(got_np.values, got_pl.values)
                     and np.array_equal(got_np.validity, got_pl.validity))
    results["gather_route"] = {"pallas_bit_identical": gather_ok}
    _emit("decode/gather_route", 0.0, f"pallas_bit_identical={gather_ok}")
    assert gather_ok, "pallas gather route must match the host permutation"
    # the acceptance gate: decode rows/s on the largest variable-width take
    # vs the per-value walk on identical bytes.  (End-to-end take rows/s is
    # additionally capped by the NVMe IO model — ~23 ms for 10k 2-IOP rows —
    # so the decode-vs-walk ratio is the term this PR moves; the per-cell
    # take_speedup_vs_pre_pr tracks the end-to-end trajectory.)  Smoke mode
    # gates a relaxed threshold: tiny takes amortize vectorization worse.
    floor = 2 if SMOKE else 5
    sp = results["utf8"][str(counts[-1])]["decode_speedup_vs_walk"]
    results["headline"] = {
        "gate": f"utf8/{counts[-1]} decode_speedup_vs_walk >= {floor}",
        "decode_speedup_vs_walk": sp,
        "note": "walk = retained pre-PR per-value decode loop "
                "(_decode_entries_walk) timed on the same fetched spans",
    }
    assert sp >= floor, f"row-parallel decode must be >={floor}x the walk, got {sp}x"
    _dump_json("BENCH_decode.json", results)
    _emit("decode/written", 0.0, "path=BENCH_decode.json")


def dataset_take():
    """The multi-file headline: an 8-fragment dataset served take-heavy with
    a *skewed* (hot-fragment) row mix, under one shared NVMe budget vs the
    same budget statically split into per-file stores.  The shared store
    arbitrates the whole budget toward the hot fragments and coalesces
    cross-file spans in one dispatch per phase, so it must win on rows/s and
    on second-pass NVMe hit rate.  Results go to BENCH_dataset.json."""
    from repro.dataset import DatasetReader, write_fragments
    from repro.store import TieredStore

    n_frag = 4 if SMOKE else 8
    per_frag = 1_000 if SMOKE else 6_000
    take_n = 1_200 if SMOKE else 10_000
    n_hot = 2          # fragments receiving the bulk of the traffic
    hot_frac = 0.85
    width = 512        # float32 lanes -> 2 KiB embedding rows (~2 per block)
    n = n_frag * per_frag
    rng = np.random.default_rng(0)
    arr = A.FixedSizeListArray(
        T.FixedSizeList(T.Primitive("float32", nullable=False), width),
        np.ones(n, bool), rng.standard_normal((n, width)).astype(np.float32))
    files = write_fragments({"c": arr}, n_frag, WriteOptions("lance-fullzip"))
    payload = sum(len(f) for f in files)
    # one NVMe budget, sized to hold the hot fragments but not the dataset
    budget = int(1.25 * n_hot * payload / n_frag)
    row_starts = np.arange(n_frag, dtype=np.int64) * per_frag

    def skewed_rows():
        hot = rng.integers(0, n_hot * per_frag, int(take_n * hot_frac))
        cold = rng.integers(0, n, take_n - len(hot))
        return np.concatenate([hot, cold])

    # one row draw per pass, replayed for BOTH configurations, so the
    # shared-vs-split comparison is over identical requests
    pass_rows = [skewed_rows(), skewed_rows()]

    def one_pass(take_fn, readers, rows):
        for r in readers:
            r.reset_io()
        t0 = time.perf_counter()
        take_fn(rows)
        dt = time.perf_counter() - t0
        t_model = sum(r.modelled_time() for r in readers)
        tiers = [s for r in readers for s in r.tier_stats()]
        nvme = [s for s in tiers if s.name == "nvme_970evo"]
        s3 = [s for s in tiers if s.name == "s3"]
        hits, misses = sum(s.hits for s in nvme), sum(s.misses for s in nvme)
        return {
            "rows_per_s": round(take_n / max(dt, t_model)),
            "cpu_s": round(dt, 6), "model_io_s": round(t_model, 6),
            "nvme_hit_rate": round(hits / max(hits + misses, 1), 4),
            "s3_iops": sum(s.n_iops for s in s3),
            "nvme_iops": sum(s.n_iops for s in nvme),
        }

    # shared: the whole dataset behind one cache + scheduler
    shared = DatasetReader(
        files, store=lambda d: TieredStore.cached(d, cache_bytes=budget),
        tracer=TRACER)
    shared_res = {f"pass{i + 1}": one_pass(
        lambda rows: shared.take("c", rows), [shared], pass_rows[i])
        for i in range(2)}

    # per-file: the seed world — N disjoint stores, budget split N ways
    per_file = [
        FileReader(fb, store=lambda d: TieredStore.cached(
            d, cache_bytes=max(budget // n_frag, 4096)))
        for fb in files
    ]

    def per_file_take(rows):
        fi = np.searchsorted(row_starts, rows, side="right") - 1
        for f in np.unique(fi):
            per_file[f].take("c", rows[fi == f] - row_starts[f])

    per_file_res = {f"pass{i + 1}": one_pass(per_file_take, per_file,
                                             pass_rows[i])
                    for i in range(2)}

    results = {
        "meta": {"n_fragments": n_frag, "rows_per_fragment": per_frag,
                 "take_n": take_n, "hot_fragments": n_hot,
                 "hot_fraction": hot_frac, "row_bytes": 4 * width,
                 "payload_bytes": payload, "nvme_budget_bytes": budget,
                 "smoke": SMOKE},
        "shared_store": shared_res,
        "per_file_store": per_file_res,
        "headline": {
            "rows_s_speedup_pass2": round(
                shared_res["pass2"]["rows_per_s"]
                / max(per_file_res["pass2"]["rows_per_s"], 1), 2),
            "s3_iops_saved_pass2": per_file_res["pass2"]["s3_iops"]
            - shared_res["pass2"]["s3_iops"],
        },
    }
    _dump_json("BENCH_dataset.json", results)
    for kind, res in [("shared", shared_res), ("per_file", per_file_res)]:
        for p, cell in res.items():
            _emit(f"dataset/{kind}/{p}", cell["cpu_s"] * 1e6,
                  f"rows_per_s={cell['rows_per_s']};"
                  f"hit_rate={cell['nvme_hit_rate']};s3_iops={cell['s3_iops']}")
    _emit("dataset/headline", 0.0,
          f"speedup_pass2={results['headline']['rows_s_speedup_pass2']}x;"
          f"s3_iops_saved={results['headline']['s3_iops_saved_pass2']};"
          "path=BENCH_dataset.json")
    assert shared_res["pass2"]["rows_per_s"] >= per_file_res["pass2"]["rows_per_s"], \
        "shared store must serve at least per-file rows/s"
    assert shared_res["pass2"]["nvme_hit_rate"] > per_file_res["pass2"]["nvme_hit_rate"], \
        "shared store must warm better than split per-file budgets"


def ingest_bench():
    """The write-path headline (BENCH_ingest.json): append-heavy and mixed
    append/take ingest into a live dataset, write-back vs write-through
    flush under the same NVMe budget.

    Every config appends the same fragments and (in the mixed workload)
    takes the same random rows, committing every ``commit_every`` appends.
    Write-through pays one backing (S3) queue drain per append; write-back
    absorbs appends into the NVMe tier dirty and batches the S3 writes at
    the commit fence / watermark / deadline — same bytes eventually written,
    far fewer S3 round trips, with the bytes-at-risk (``dirty_bytes`` /
    crash-``lost_bytes``) accounting making the durability trade explicit.
    The gate: write-back must beat write-through on mixed append/take
    NVMe-warm throughput (modelled, same budget)."""
    from repro.dataset import DatasetWriter
    from repro.store import TieredStore

    n_appends = 6 if SMOKE else 24
    rows_per = 400 if SMOKE else 2_000
    take_n = 200 if SMOKE else 1_000
    commit_every = 3
    width = 64  # float32 lanes -> 256 B rows
    n_total = n_appends * rows_per
    budget = max(int(1.5 * n_total * width * 4), 1 << 20)

    def run_config(policy, workload):
        rng = np.random.default_rng(0)  # same draws for every config
        w = DatasetWriter(
            store=lambda d: TieredStore.cached(d, cache_bytes=budget),
            flush=policy, opts=WriteOptions("lance-fullzip"), tracer=TRACER)
        n_ops = n_total
        t0 = time.perf_counter()
        for i in range(n_appends):
            vals = rng.standard_normal((rows_per, width)).astype(np.float32)
            arr = A.FixedSizeListArray(
                T.FixedSizeList(T.Primitive("float32", nullable=False), width),
                np.ones(rows_per, bool), vals)
            w.append({"c": arr}, commit=(i + 1) % commit_every == 0)
            if workload == "mixed" and w.version:
                rows = rng.integers(0, w.n_rows, take_n)
                w.take("c", rows)
                n_ops += take_n
        w.commit()
        dt = time.perf_counter() - t0
        t_model = w.modelled_time()
        tiers = {s.name: s for s in w.tier_stats()}
        s3, nvme = tiers["s3"], tiers["nvme_970evo"]
        return {
            "rows_per_s": round(n_ops / max(dt, t_model)),
            "cpu_s": round(dt, 6), "model_io_s": round(t_model, 6),
            "s3_write_iops": s3.write_iops, "s3_flush_iops": s3.flush_iops,
            "s3_bytes_written": s3.bytes_written,
            "s3_read_iops": s3.n_iops,
            "nvme_write_iops": nvme.write_iops,
            "nvme_hit_rate": round(nvme.hit_rate, 4)
            if nvme.hits + nvme.misses else None,
            "peak_dirty_after_run": nvme.dirty_bytes,
            "logical_write_iops": w.write_stats().n_iops,
            "logical_write_bytes": w.write_stats().bytes_read,
        }

    results = {"meta": {"n_appends": n_appends, "rows_per_append": rows_per,
                        "take_n": take_n, "commit_every": commit_every,
                        "row_bytes": width * 4, "nvme_budget_bytes": budget,
                        "smoke": SMOKE}}
    for workload in ("append", "mixed"):
        for policy in ("write-through", "write-back"):
            cell = run_config(policy, workload)
            results[f"{workload}/{policy}"] = cell
            _emit(f"ingest/{workload}/{policy}", cell["cpu_s"] * 1e6,
                  f"rows_per_s={cell['rows_per_s']};"
                  f"s3_write_iops={cell['s3_write_iops']};"
                  f"model_io_s={cell['model_io_s']}")
    wb, wt = results["mixed/write-back"], results["mixed/write-through"]
    results["headline"] = {
        "gate": "mixed write-back rows_per_s > mixed write-through",
        "mixed_speedup": round(wb["rows_per_s"] / max(wt["rows_per_s"], 1), 2),
        "append_speedup": round(
            results["append/write-back"]["rows_per_s"]
            / max(results["append/write-through"]["rows_per_s"], 1), 2),
        "s3_write_iops_saved_mixed": wt["s3_write_iops"] - wb["s3_write_iops"],
    }
    _emit("ingest/headline", 0.0,
          f"mixed_speedup={results['headline']['mixed_speedup']}x;"
          f"append_speedup={results['headline']['append_speedup']}x;"
          "path=BENCH_ingest.json")
    assert wb["rows_per_s"] > wt["rows_per_s"], \
        "write-back must beat write-through on mixed append/take throughput"
    _dump_json("BENCH_ingest.json", results)
    _emit("ingest/written", 0.0, "path=BENCH_ingest.json")


def serve_bench():
    """Multi-tenant serving headline (BENCH_serve.json): Zipf-skewed
    concurrent takers + a write-back ingest tenant over one shared tiered
    store, priced by the scheduler's event-loop serving plane.

    The same executed workload (identical classification, cache state and
    per-tier accounting — both timings are pure overlays on the drain log)
    is priced under interleaved event-loop dispatch and under the old
    serial batch-drain; the gate asserts interleaved wins on p99
    per-request latency.  Tenants carry QoS weights (premium 4x standard)
    and the ingest tenant's append/flush drains share the device queues
    with the reads — the flush-vs-concurrent-reads interleaving the
    event loop exists to fix.  Per-row latency attribution
    (repro.obs.attribute) runs over the same trace with reads and flushes
    in flight together; its per-tier residual against model_time is
    reported, not hidden."""
    from repro.core.io_sim import Degradation
    from repro.dataset import DatasetWriter
    from repro.obs import (NULL_TRACER, BurnWindow, MetricsPlane,
                           SLOMonitor)
    from repro.serve.workload import (TenantSpec, ZipfWorkload, drive,
                                      tenant_summary)
    from repro.store import EventLoop, TieredStore

    n_frag = 4 if SMOKE else 8
    rows_per = 1_000 if SMOKE else 6_000
    n_requests = 96 if SMOKE else 1_500
    arrival_rate = 200.0       # requests per virtual second
    width = 32                 # float32 lanes -> 128 B rows
    qd = 32                    # shallow queue: concurrency must share rounds
    n_total = n_frag * rows_per
    # cache holds ~half the data: the Zipf head goes NVMe-warm, the tail
    # keeps paying S3 round trips — the serving tail the percentiles see
    budget = max(int(0.5 * n_total * width * 4), 1 << 18)

    def table(rng, n):
        vals = rng.standard_normal((n, width)).astype(np.float32)
        arr = A.FixedSizeListArray(
            T.FixedSizeList(T.Primitive("float32", nullable=False), width),
            np.ones(n, bool), vals)
        return {"c": arr}

    rng = np.random.default_rng(7)
    seeds = [write_table(table(rng, rows_per), WriteOptions("lance-fullzip"))
             for _ in range(n_frag)]
    w = DatasetWriter(
        files=seeds,
        store=lambda d: TieredStore.cached(d, cache_bytes=budget),
        flush="write-back", opts=WriteOptions("lance-fullzip"),
        queue_depth=qd, tracer=TRACER)

    tenants = [
        TenantSpec("premium", share=1.0, weight=4.0, rows_per_request=32),
        TenantSpec("standard", share=2.0, weight=1.0, rows_per_request=32),
    ]
    wl = ZipfWorkload(n_rows=w.n_rows, tenants=tenants,
                      n_requests=n_requests, zipf_s=1.05,
                      arrival_rate=arrival_rate, seed=3)
    reqs = wl.generate()
    rng2 = np.random.default_rng(13)
    t0 = time.perf_counter()
    inter, serial, win = drive(
        w, "c", reqs, qos=wl.qos(),
        append_table=lambda: table(rng2, rows_per // 4),
        append_every=max(n_requests // 8, 1), commit_every=2)
    dt = time.perf_counter() - t0

    names = [t.name for t in tenants] + ["ingest"]
    sum_inter = tenant_summary(inter, names)
    sum_serial = tenant_summary(serial, names)
    tiers = {s.name: s for s in w.tier_stats()}
    s3, nvme = tiers["s3"], tiers["nvme_970evo"]

    # attribution exactness with reads and flushes in flight together
    att = attribute(w.store, queue_depth=qd)
    residual = 0.0
    sums = att.tier_sums()
    devices = [lvl.device for lvl in w.store.levels] + [w.store.backing]
    for stats, dev in zip(w.tier_stats(), devices):
        mt = stats.model_time(dev, qd)
        if mt > 0:
            residual = max(residual, abs(sums.get(stats.name, 0.0) - mt) / mt)
    pct = att.percentiles("take:c") or {}
    per_row_us = {k: round(v * 1e6, 4) for k, v in pct.items()
                  if k != "count"}

    p99_i = sum_inter["all"]["p99"]
    p99_s = sum_serial["all"]["p99"]

    # ---- live metrics plane + SLO: healthy re-pricing -------------------
    # Objectives ride on TenantSpec; thresholds derive from the healthy
    # run's own (deterministic, virtual-clock) latencies so the healthy
    # phase never breaches and any post-degradation breach is real signal.
    for spec in tenants:
        healthy = tenant_summary(inter, [spec.name])[spec.name]
        spec.slo_ms = round(healthy["max"] * 1.1, 6)
        spec.slo_target = 0.99 if spec.name == "premium" else 0.95
    slo_windows = (BurnWindow(long_s=0.5, short_s=0.0625,
                              burn_threshold=2.0),)
    plane_h = MetricsPlane(window=0.25, n_windows=8, rel_err=0.01)
    slo_tracer = TRACER if TRACER is not None else NULL_TRACER
    slo_h = SLOMonitor(wl.slo_objectives(), windows=slo_windows,
                       tracer=slo_tracer, registry=plane_h.registry,
                       plane=plane_h)
    inter_sampled = win.run("interleaved", plane=plane_h, slo=slo_h)
    # hard contract: sampling is read-only — completions bit-identical
    assert inter_sampled.completions == inter.completions, \
        "metrics plane/SLO sampling must not perturb event-loop timing"
    assert not slo_h.alerts, \
        "healthy run must not breach (objectives derived from its own max)"

    # ---- mid-run NVMe degradation + detection gates ---------------------
    # NVMe "grey failure": 200x latency, 1% throughput from t_deg onward.
    # The factors are deliberately strong — S3's 30 ms round trips dominate
    # healthy latency, so a mild NVMe stutter hides inside the S3 tail;
    # this is the firmware-stall / thermal-throttle shape where the fast
    # tier becomes the bottleneck.
    t_deg = round(inter.makespan * 0.5, 6)
    fault = Degradation(start=t_deg, latency_factor=200.0,
                        throughput_factor=0.01)
    devices = w.scheduler._devices()
    nvme_dev = next(d for d in devices if d.name.startswith("nvme"))
    deg_devices = [d.with_fault(fault) if d is nvme_dev else d
                   for d in devices]
    plane_d = MetricsPlane(window=0.25, n_windows=8, rel_err=0.01)
    slo_d = SLOMonitor(wl.slo_objectives(), windows=slo_windows,
                       tracer=slo_tracer, registry=plane_d.registry,
                       plane=plane_d)
    deg = EventLoop(deg_devices, queue_depth=qd, qos=wl.qos(),
                    plane=plane_d, slo=slo_d).run(win.jobs,
                                                  mode="interleaved")
    sum_deg = tenant_summary(deg, names)
    alert = slo_d.first_alert("premium")
    detect_bound_s = 1.0  # gated: breach must fire within this much
    assert alert is not None, \
        "NVMe degradation must fire slo.breach.premium"
    detect_delay = alert.at - t_deg
    assert 0.0 <= detect_delay <= detect_bound_s, \
        f"premium burn alert took {detect_delay:.3f}s virtual " \
        f"(bound {detect_bound_s}s after degradation at t={t_deg}s)"
    util = plane_d.series[f"tier.{nvme_dev.name}.utilization"]
    pre = util.between(0.0, t_deg)
    post = util.between(t_deg, float("inf"))
    pre_util = sum(pre) / len(pre) if pre else 0.0
    post_util = sum(post) / len(post) if post else 0.0
    assert post_util >= 0.9 and post_util > pre_util, \
        f"degraded NVMe utilization must saturate " \
        f"(pre={pre_util:.3f}, post={post_util:.3f})"
    if TRACER is not None and TRACER.enabled:
        plane_d.to_trace(TRACER)  # virtual-clock counter tracks

    # ---- closed-loop arrival comparison cell ----------------------------
    # Same tenants and Zipf skew, fixed client population with think time.
    # Coordinated omission: under load the closed loop throttles its own
    # arrivals, so its percentiles are not comparable to open-loop ones as
    # measurements of the same server — the cell reports both to show the
    # contrast, the open-loop numbers stay the headline.
    w2 = DatasetWriter(
        files=seeds,
        store=lambda d: TieredStore.cached(d, cache_bytes=budget),
        flush="write-back", opts=WriteOptions("lance-fullzip"),
        queue_depth=qd, tracer=TRACER)
    wl_c = ZipfWorkload(n_rows=w2.n_rows, tenants=tenants,
                        n_requests=n_requests, zipf_s=1.05, seed=3,
                        arrival="closed", think_time=0.02,
                        clients_per_tenant=4)
    inter_c, serial_c, _win_c = drive(w2, "c", wl_c.generate(),
                                      qos=wl_c.qos(), think_time=0.02)
    sum_closed = tenant_summary(inter_c, names)

    results = {
        "meta": {"n_fragments": n_frag, "rows_per_fragment": rows_per,
                 "n_requests": n_requests, "arrival_rate_per_s": arrival_rate,
                 "queue_depth": qd, "nvme_budget_bytes": budget,
                 "zipf_s": wl.zipf_s, "smoke": SMOKE,
                 "cpu_wall_s": round(dt, 6)},
        "workload": {
            "n_jobs": len(inter.completions),
            "n_take_requests": n_requests,
            "n_flush_drains": sum(
                1 for c in inter.completions if c.label.startswith("flush:")),
        },
        "interleaved_ms": sum_inter,
        "serial_ms": sum_serial,
        "tier_occupancy": inter.tiers,
        "counted": {
            "s3_iops": s3.n_iops, "s3_bytes_read": s3.bytes_read,
            "s3_write_iops": s3.write_iops,
            "s3_rmw_iops": s3.rmw_iops, "s3_rmw_bytes": s3.rmw_bytes,
            "nvme_iops": nvme.n_iops, "nvme_write_iops": nvme.write_iops,
            "nvme_hit_rate": round(nvme.hit_rate, 4)
            if nvme.hits + nvme.misses else None,
            "logical_read_iops": w.io_stats().n_iops,
            "logical_read_bytes": w.io_stats().bytes_read,
            "logical_write_iops": w.write_stats().n_iops,
        },
        "attribution": {"per_row_us": per_row_us,
                        "n_attributed_requests": pct.get("count"),
                        "residual_rel": residual},
        "slo": {
            "objectives": {t.name: {"slo_ms": t.slo_ms,
                                    "target": t.slo_target}
                           for t in tenants},
            "burn_window": {"long_s": slo_windows[0].long_s,
                            "short_s": slo_windows[0].short_s,
                            "threshold": slo_windows[0].burn_threshold},
            "healthy_breaches": slo_h.breach_counts(),
            "degraded": {
                "t_degradation_s": t_deg,
                "latency_factor": fault.latency_factor,
                "throughput_factor": fault.throughput_factor,
                "first_premium_alert_t": round(alert.at, 6),
                "detection_delay_s": round(detect_delay, 6),
                "detection_bound_s": detect_bound_s,
                "breaches": slo_d.breach_counts(),
                "nvme_utilization_pre": round(pre_util, 6),
                "nvme_utilization_post": round(post_util, 6),
                "table": slo_d.table(),
            },
        },
        "metrics_plane": plane_d.export(max_points=64),
        "closed_loop": {
            "arrival": "closed", "think_time_s": wl_c.think_time,
            "clients_per_tenant": wl_c.clients_per_tenant,
            "interleaved_ms": sum_closed,
            "makespan_s": round(inter_c.makespan, 6),
            "open_vs_closed_p99_ms": {
                "open": round(p99_i, 6),
                "closed": round(sum_closed["all"]["p99"], 6),
            },
            "caveat": "closed-loop percentiles hide coordinated omission; "
                      "not comparable to open-loop as server measurements",
        },
        "headline": {
            "gate": "interleaved event-loop p99 < serial batch-drain p99",
            "p50_interleaved_ms": round(sum_inter["all"]["p50"], 6),
            "p99_interleaved_ms": round(p99_i, 6),
            "p999_interleaved_ms": round(sum_inter["all"]["p999"], 6),
            "p50_serial_ms": round(sum_serial["all"]["p50"], 6),
            "p99_serial_ms": round(p99_s, 6),
            "p999_serial_ms": round(sum_serial["all"]["p999"], 6),
            "p99_speedup_serial_over_interleaved": round(p99_s / p99_i, 3),
            "p99_premium_ms": round(sum_inter["premium"]["p99"], 6),
            "p99_standard_ms": round(sum_inter["standard"]["p99"], 6),
        },
    }
    _emit("serve/latency", dt * 1e6,
          f"p99_interleaved_ms={p99_i:.3f};p99_serial_ms={p99_s:.3f};"
          f"speedup={p99_s / p99_i:.2f}x;jobs={len(inter.completions)};"
          f"residual={residual:.2e}")
    assert p99_i < p99_s, \
        "event-loop interleaved dispatch must beat serial batch-drain " \
        f"on p99 per-request latency ({p99_i:.3f} ms vs {p99_s:.3f} ms)"
    # QoS weights (premium 4x) are reported, not asserted: p99 for either
    # tenant is dominated by whether its rank-99 request hit a cold S3 row
    # (one 30 ms round trip), which weights cannot buy off — they only cut
    # queueing delay under round contention.
    _dump_json("BENCH_serve.json", results)
    _emit("serve/written", 0.0, "path=BENCH_serve.json")
    with open("BENCH_serve.prom", "w") as f:
        f.write(plane_d.prometheus_text())
    _emit("serve/slo", detect_delay * 1e6,
          f"detect_delay_s={detect_delay:.4f};"
          f"nvme_util_post={post_util:.3f};"
          f"breaches={slo_d.breach_counts()};path=BENCH_serve.prom")


def chaos_bench():
    """Fault-tolerant serving headline (BENCH_chaos.json): the Zipf
    multi-tenant workload of the serve bench driven through scripted fault
    scenarios, priced by the event loop's recovery layer (retry/backoff,
    tier failover, SLO-driven shedding).

    One captured service window is re-priced per scenario — classification,
    cache state and logical accounting are identical across all of them,
    only the fault schedule and recovery knobs differ (``window.run`` is
    pure).  Scenarios and their gates:

    * **healthy** — the recovery layer compiled in on healthy tiers is
      bit-identical to the bare event loop (ARCHITECTURE.md contract #8);
    * **transient** — 5% NVMe op errors over the middle half of the run:
      retries + failover keep premium availability >= 99.9%;
    * **blackout** — NVMe never comes back from t=0.3*makespan: failover
      re-homes every exhausted unit on S3 (zero failed requests); the
      ablation with ``failover=False`` must fail requests, or the gate is
      vacuous;
    * **correlated brownout** — one TransientErrors window stamped on NVMe
      *and* S3 (shared switch/AZ shape): retries ride it out;
    * **shed drill** — a controlled overload (premium + 2x standard at a
      rate only a healthy NVMe sustains) through a mid-run NVMe slowdown:
      the burn-driven Shedder must trip exactly once (hysteresis + hold-
      down, no flapping), reject only standard, keep premium availability
      at 100%, pull premium burn back under the page threshold after one
      settle interval, and bound recovery after the fault clears.  The
      drill uses synthetic fixed-shape drains so the overload margin is
      exact — the gate is about the control loop, not cache luck.
    """
    from repro.core.io_sim import (Blackout, CorrelatedFault, Degradation,
                                   TransientErrors)
    from repro.dataset import DatasetWriter
    from repro.obs import (BurnWindow, MetricsPlane, Shedder, SLObjective,
                           SLOMonitor)
    from repro.serve.workload import (FaultScenario, TenantSpec,
                                      ZipfWorkload, drive, run_scenario,
                                      tenant_summary)
    from repro.store import EventLoop, QoS, RetryPolicy, TieredStore, build_job
    from repro.store.stats import DrainRecord

    n_frag = 4 if SMOKE else 8
    rows_per = 800 if SMOKE else 4_000
    n_requests = 72 if SMOKE else 600
    width = 32
    qd = 32
    n_total = n_frag * rows_per
    budget = max(int(0.5 * n_total * width * 4), 1 << 18)

    def table(rng, n):
        vals = rng.standard_normal((n, width)).astype(np.float32)
        arr = A.FixedSizeListArray(
            T.FixedSizeList(T.Primitive("float32", nullable=False), width),
            np.ones(n, bool), vals)
        return {"c": arr}

    rng = np.random.default_rng(7)
    seeds = [write_table(table(rng, rows_per), WriteOptions("lance-fullzip"))
             for _ in range(n_frag)]
    w = DatasetWriter(
        files=seeds,
        store=lambda d: TieredStore.cached(d, cache_bytes=budget),
        flush="write-back", opts=WriteOptions("lance-fullzip"),
        queue_depth=qd, tracer=TRACER)
    tenants = [
        TenantSpec("premium", share=1.0, weight=4.0, priority=1,
                   rows_per_request=32),
        TenantSpec("standard", share=2.0, weight=1.0, rows_per_request=32),
    ]
    wl = ZipfWorkload(n_rows=w.n_rows, tenants=tenants,
                      n_requests=n_requests, zipf_s=1.05,
                      arrival_rate=200.0, seed=3)
    t0 = time.perf_counter()
    healthy, _serial, win = drive(w, "c", wl.generate(), qos=wl.qos())
    dt = time.perf_counter() - t0
    names = [t.name for t in tenants]
    M = healthy.makespan
    devices = w.scheduler._devices()
    nvme_name = next(d.name for d in devices if d.name.startswith("nvme"))
    s3_name = w.store.backing.name

    # ---- healthy-path bit-identity (contract #8) ------------------------
    # drive() priced with the scheduler's compiled-in RetryPolicy; the bare
    # loop with no policy must produce the same bits on healthy tiers.
    bare = EventLoop(devices, queue_depth=qd, qos=wl.qos()).run(win.jobs)
    assert bare.completions == healthy.completions, \
        "recovery layer must be invisible on healthy tiers"
    assert healthy.availability() == 1.0

    def counters_of(res, prefix):
        return {k: v for k, v in sorted(res.counters.items())
                if k.startswith(prefix)}

    def cell(res):
        return {
            "makespan_s": round(res.makespan, 6),
            "availability": round(res.availability(), 6),
            "availability_premium": round(res.availability("premium"), 6),
            "n_failed": len(res.errors),
            "counters": {k: v for k, v in sorted(res.counters.items())},
            "premium_p99_ms": (tenant_summary(res, names)["premium"]["p99"]
                               if res.availability("premium") > 0 else None),
        }

    # ---- scenario: transient NVMe errors --------------------------------
    sc_t = FaultScenario(
        "transient_nvme",
        faults=((nvme_name, TransientErrors(0.25 * M, 0.75 * M,
                                            error_prob=0.05, seed=11)),),
        description="5% op errors on the cache tier, middle half of run")
    res_t = run_scenario(win, sc_t, qos=wl.qos())
    avail_premium_t = res_t.availability("premium")
    assert avail_premium_t >= 0.999, \
        f"premium availability {avail_premium_t} < 99.9% under " \
        "transient NVMe errors (retry/failover must absorb them)"
    assert res_t.counters.get(f"retry.{nvme_name}", 0) > 0
    # recovery is priced, not free — but under contention a backed-off
    # unit frees round slots for other jobs, so the *global* makespan can
    # move either way by round-granularity slack; availability is the gate

    # ---- scenario: NVMe blackout, failover on/off -----------------------
    black = Blackout(0.3 * M)  # never comes back
    sc_b_on = FaultScenario("blackout_failover",
                            faults=((nvme_name, black),))
    sc_b_off = FaultScenario("blackout_no_failover",
                             faults=((nvme_name, black),),
                             retry=RetryPolicy(failover=False))
    res_b_on = run_scenario(win, sc_b_on, qos=wl.qos())
    res_b_off = run_scenario(win, sc_b_off, qos=wl.qos())
    assert len(res_b_on.errors) == 0, \
        "failover to S3 must absorb a permanent NVMe blackout"
    assert res_b_on.counters.get(f"failover.{nvme_name}", 0) > 0
    assert len(res_b_off.errors) > 0, \
        "ablation must fail requests, else the failover gate is vacuous"

    # ---- scenario: correlated NVMe+S3 brownout --------------------------
    cf = CorrelatedFault(TransientErrors(0.25 * M, 0.6 * M,
                                         error_prob=0.03, seed=5),
                         (nvme_name, s3_name))
    sc_c = FaultScenario(
        "correlated_brownout",
        faults=tuple((n, cf.fault) for n in cf.devices),
        description="one error window stamped on NVMe and S3 together")
    res_c = run_scenario(win, sc_c, qos=wl.qos())
    avail_c = res_c.availability()
    assert avail_c >= 0.999, \
        f"availability {avail_c} < 99.9% under correlated brownout"

    # ---- scenario: SLO-driven shed drill --------------------------------
    # Controlled overload: 64-op single-tier drains, one premium + two
    # standard arrivals per 300 us slot.  A healthy NVMe round at qd=64
    # services one job per ~90 us; the 2x degraded tier can only sustain
    # the premium stream alone, so shedding standard is exactly the relief
    # that restores the premium SLO.
    n_drill = 300
    drill_jobs = []
    seq = 0
    from repro.core.io_sim import NVME as NVME_DEV, S3 as S3_DEV
    drill_devices = [NVME_DEV, S3_DEV]
    for i in range(n_drill):
        for tenant in ("premium", "standard", "standard"):
            seq += 1
            rec = DrainRecord(f"{tenant}/{i}", 1,
                              {0: ({0: 64}, {0: 64 * 4096})})
            drill_jobs.append(build_job(rec, drill_devices, tenant=tenant,
                                        submit=i * 3e-4, seq=seq))
    drill_qos = QoS(priority={"premium": 1})
    healthy_d = EventLoop(drill_devices, queue_depth=64,
                          qos=drill_qos).run(drill_jobs)
    Md = healthy_d.makespan
    obj_s = healthy_d.percentiles("premium")["p99"] * 5.0
    burn_win = BurnWindow(long_s=Md / 8, short_s=Md / 64, burn_threshold=2.0)
    deg = Degradation(0.2 * Md, 0.8 * Md, latency_factor=2.0,
                      throughput_factor=1.0)
    drill_faulted = [drill_devices[0].with_fault(deg), drill_devices[1]]

    def drill(shed_on):
        mon = SLOMonitor({"premium": SLObjective(obj_s, target=0.99)},
                         windows=(burn_win,))
        sh = Shedder(mon, protect=("premium",), shed=("standard",),
                     on_burn=4.0, off_burn=1.0,
                     hold_s=Md / 4) if shed_on else None
        plane = MetricsPlane(window=Md / 16, n_windows=8, rel_err=0.01)
        res = EventLoop(drill_faulted, queue_depth=64, qos=drill_qos,
                        retry=RetryPolicy(), plane=plane, slo=mon,
                        shedder=sh).run(drill_jobs)
        return res, sh, plane

    res_on, sh, plane_on = drill(True)
    res_off, _, _ = drill(False)

    def burn_at(res, t):
        """Offline premium burn over the long window ending at ``t``."""
        bad = tot = 0
        for c in res.completions:
            if c.tenant != "premium" or c.error == "shed":
                continue
            if t - burn_win.long_s <= c.done <= t:
                tot += 1
                bad += (c.error is not None) or (c.latency > obj_s)
        return (bad / tot) / 0.01 if tot else 0.0

    assert sh.trips == 1, \
        f"shedder tripped {sh.trips}x: hysteresis + hold-down must " \
        "prevent flapping"
    assert res_on.counters.get("shed.standard", 0) > 0
    assert "shed.premium" not in res_on.counters
    assert res_on.availability("premium") == 1.0
    settle = sh.engaged_at[0] + 3.0 * burn_win.long_s
    burn_on = burn_at(res_on, settle)
    burn_off = burn_at(res_off, settle)
    page_burn = 4.0
    assert burn_on < page_burn, \
        f"premium burn {burn_on} still above page threshold " \
        f"{page_burn} one settle interval after shedding engaged"
    assert burn_off > page_burn, \
        "unshedded ablation must stay above the page threshold, " \
        "else the shedding gate is vacuous"

    def recovery_after(res, t_end):
        last = max((c.done for c in res.completions
                    if c.tenant == "premium" and c.error != "shed"
                    and (c.error is not None or c.latency > obj_s)),
                   default=t_end)
        return max(0.0, last - t_end)

    rec_on = recovery_after(res_on, deg.end)
    rec_off = recovery_after(res_off, deg.end)
    rec_bound = 0.1 * Md
    assert rec_on <= rec_bound, \
        f"premium recovery {rec_on}s after fault end exceeds {rec_bound}s"
    assert res_on.makespan < res_off.makespan
    if TRACER is not None and TRACER.enabled:
        plane_on.to_trace(TRACER)

    fault_summary = {
        "availability_premium_transient": round(avail_premium_t, 6),
        "availability_correlated": round(avail_c, 6),
        "blackout_failed_with_failover": len(res_b_on.errors),
        "blackout_failed_without_failover": len(res_b_off.errors),
        "blackout_failovers": res_b_on.counters.get(
            f"failover.{nvme_name}", 0),
        "transient_retries": res_t.counters.get(f"retry.{nvme_name}", 0),
        "shed_trips": sh.trips,
        "shed_standard": res_on.counters.get("shed.standard", 0),
        "shed_premium": res_on.counters.get("shed.premium", 0),
        "premium_burn_after_settle_shed": round(burn_on, 6),
        "premium_burn_after_settle_noshed": round(burn_off, 6),
        "recovery_s_with_shedding": round(rec_on, 6),
        "recovery_s_without_shedding": round(rec_off, 6),
    }
    results = {
        "meta": {"n_fragments": n_frag, "rows_per_fragment": rows_per,
                 "n_requests": n_requests, "queue_depth": qd,
                 "nvme_budget_bytes": budget, "smoke": SMOKE,
                 "n_drill_requests": 3 * n_drill,
                 "cpu_wall_s": round(dt, 6)},
        "healthy": {
            "makespan_s": round(M, 6),
            "bit_identical_with_recovery_layer": True,
            "interleaved_ms": tenant_summary(healthy, names),
        },
        "scenarios": {
            "transient_nvme": cell(res_t),
            "blackout_failover": cell(res_b_on),
            "blackout_no_failover": cell(res_b_off),
            "correlated_brownout": cell(res_c),
            "shed_drill": {
                "makespan_healthy_s": round(Md, 6),
                "objective_s": round(obj_s, 9),
                "burn_window_s": {"long": round(burn_win.long_s, 9),
                                  "short": round(burn_win.short_s, 9)},
                "degradation": {"start_s": round(deg.start, 6),
                                "end_s": round(deg.end, 6),
                                "latency_factor": deg.latency_factor},
                "engaged_at_s": round(sh.engaged_at[0], 6),
                "released_at_s": (round(sh.released_at[0], 6)
                                  if sh.released_at else None),
                "with_shedding": cell(res_on),
                "without_shedding": cell(res_off),
            },
        },
        "fault": fault_summary,
        "headline": {
            "gate": "premium availability >= 99.9% under transient errors; "
                    "zero failed under blackout with failover; shedding "
                    "holds premium burn under the page threshold",
            **fault_summary,
        },
    }
    _dump_json("BENCH_chaos.json", results)
    _emit("chaos/transient", res_t.makespan * 1e6,
          f"avail_premium={avail_premium_t:.6f};"
          f"retries={fault_summary['transient_retries']}")
    _emit("chaos/blackout", res_b_on.makespan * 1e6,
          f"failed_on={len(res_b_on.errors)};"
          f"failed_off={len(res_b_off.errors)};"
          f"failovers={fault_summary['blackout_failovers']}")
    _emit("chaos/shed", res_on.makespan * 1e6,
          f"trips={sh.trips};shed={fault_summary['shed_standard']};"
          f"burn_on={burn_on:.3f};burn_off={burn_off:.3f};"
          f"recovery_s={rec_on:.6f}")
    _emit("chaos/written", dt * 1e6, "path=BENCH_chaos.json")


def search_bench():
    """Vector retrieval headline (BENCH_search.json): IVF index stored *as
    dataset fragments* serving Zipf-skewed ANN queries through the shared
    tiered store, scored by the Pallas distance/top-k kernel.

    The corpus is a mixture of Gaussians — IVF's recall story depends on
    the data having partition structure (isotropic noise has none: every
    partition boundary cuts through true neighbourhoods) — and it is
    written in *partition-clustered row order* (docs sorted by mode).
    That layout is the point, not a convenience: a posting list over a
    clustered corpus is a handful of contiguous row runs, so the
    candidate fetch coalesces into big extent reads priced at sequential
    bandwidth.  Scattered postings pay the device's 4 KiB read floor per
    row, which costs *more* than scanning everything — an index over an
    unclustered corpus loses to brute force on this device model, and
    should.

    Queries are perturbed copies of stored docs drawn by Zipf popularity,
    driven through a service window so every search step — centroid take,
    posting take, candidate take, winner take — is priced per request by
    the event loop.  The serving tier is sized to the dataset (NVMe holds
    data + index after the index build's training scan and one warmup
    batch; S3 stays the durable origin), so the measured pass is steady
    state.  Gates:

    * **recall@k >= 0.9** against exact float64 brute force at
      ``nprobe``/``n_partitions`` probing;
    * **search QPS > full-scan QPS** — the ablation answers the same query
      stream by taking every row on an identically provisioned store (what
      brute force costs); probing ``nprobe/n_partitions`` of the corpus
      must beat reading all of it, or the index is decoration;
    * **warm repeat is NVMe-served** — re-running the last query touches
      only cached blocks (index reads warm the same budget as data reads).
    """
    from repro.dataset import DatasetWriter, IvfIndex, write_fragments
    from repro.serve.engine import Retriever
    from repro.serve.workload import TenantSpec, ZipfWorkload, tenant_summary
    from repro.store import TieredStore

    n_frag = 4 if SMOKE else 8
    rows_per = 3_200 if SMOKE else 8_000
    dim = 64
    n_partitions = 32 if SMOKE else 64
    nprobe = 4 if SMOKE else 8
    k = 10
    n_requests = 48 if SMOKE else 256
    qd = 32
    n_docs = n_frag * rows_per
    # serving tier sized to the dataset: NVMe holds data + index, S3 is
    # the durable origin paid once (by the build scan and the warmup)
    budget = 2 * n_docs * dim * 4

    # clustered corpus: one Gaussian mode per eventual partition, means
    # far apart relative to the within-mode spread, so a query near a
    # stored doc keeps its true neighbours inside a handful of partitions.
    # Rows are *sorted by mode* — partition-clustered layout — so each
    # k-means posting list is a few contiguous row runs.
    rng = np.random.default_rng(11)
    means = 4.0 * rng.standard_normal((n_partitions, dim)).astype(np.float32)
    modes = np.sort(rng.integers(0, n_partitions, n_docs))
    vecs = means[modes] \
        + 0.25 * rng.standard_normal((n_docs, dim)).astype(np.float32)
    emb = A.FixedSizeListArray.build(vecs)
    seeds = write_fragments({"embedding": emb}, n_frag, WriteOptions("lance"))
    w = DatasetWriter(
        files=seeds,
        store=lambda d: TieredStore.cached(d, cache_bytes=budget),
        queue_depth=qd, tracer=TRACER)
    t0 = time.perf_counter()
    ivf = IvfIndex.build(w, "embedding", n_partitions=n_partitions,
                         n_fragments=2, seed=0)
    build_stats = w.io_stats()
    retr = Retriever(w.reader(), "embedding", index=ivf)

    wl = ZipfWorkload(n_rows=n_docs,
                      tenants=[TenantSpec("search", rows_per_request=1)],
                      n_requests=n_requests, zipf_s=1.05,
                      arrival_rate=2_000.0, seed=3)
    reqs = wl.generate()
    qrng = np.random.default_rng(5)
    queries = [vecs[int(req.rows[0])]
               + 0.05 * qrng.standard_normal(dim).astype(np.float32)
               for req in reqs]
    # warmup: one batched search over the whole query set promotes every
    # probed partition, posting run and winner block into the NVMe tier —
    # the measured pass below is the steady-state serving regime
    retr.search(np.stack(queries), k=k, nprobe=nprobe)
    w.reset_io()
    got = []
    with w.scheduler.service_window(wl.qos()) as win:
        for i, req in enumerate(reqs):
            with win.request(tenant="search", at=req.at,
                             request=f"search/{i}"):
                res = retr.search(queries[i], k=k, nprobe=nprobe)
            got.append(res.ids[0])
        inter = win.run("interleaved")
        serial = win.run("serial")
    dt = time.perf_counter() - t0
    st = w.io_stats()
    tiers = {s.name: s for s in w.tier_stats()}
    s3, nvme = tiers["s3"], tiers["nvme_970evo"]

    # exact recall@k against float64 brute force (per query, so the full
    # run never materialises an (n_requests, n_docs) distance matrix)
    v64 = vecs.astype(np.float64)
    hits = 0
    for q, ids in zip(queries, got):
        d = ((v64 - q.astype(np.float64)) ** 2).sum(-1)
        top = set(np.argsort(d, kind="stable")[:k].tolist())
        hits += sum(int(i) in top for i in ids if i >= 0)
    recall = hits / (n_requests * k)

    # warm repeat: the last query's blocks are the most recently used —
    # serving it again must touch NVMe only (shared index + data budget)
    w.reset_io()
    retr.search(queries[-1], k=k, nprobe=nprobe)
    wtiers = {s.name: s for s in w.tier_stats()}
    warm_hit = wtiers["nvme_970evo"].hit_rate
    warm_s3 = wtiers["s3"].n_iops

    # full-scan ablation: same query stream answered by taking every row
    # on an identically provisioned (and identically warmed) fresh store
    n_abl = n_requests if SMOKE else min(n_requests, 64)
    w2 = DatasetWriter(
        files=seeds,
        store=lambda d: TieredStore.cached(d, cache_bytes=budget),
        queue_depth=qd, tracer=TRACER)
    all_rows = np.arange(n_docs, dtype=np.int64)
    w2.take("embedding", all_rows)  # warm: the scan set is NVMe-resident too
    w2.reset_io()
    with w2.scheduler.service_window(wl.qos()) as win2:
        for i, req in enumerate(reqs[:n_abl]):
            with win2.request(tenant="search", at=req.at,
                              request=f"scan/{i}"):
                w2.take("embedding", all_rows)
        inter_fs = win2.run("interleaved")
    qps_search = n_requests / inter.makespan
    qps_scan = n_abl / inter_fs.makespan
    sum_inter = tenant_summary(inter, ["search"])

    results = {
        "meta": {"n_docs": n_docs, "dim": dim, "n_fragments": n_frag,
                 "n_requests": n_requests, "queue_depth": qd,
                 "nvme_budget_bytes": budget, "zipf_s": wl.zipf_s,
                 "smoke": SMOKE, "cpu_wall_s": round(dt, 6)},
        "index": {
            "n_partitions": n_partitions, "nprobe": nprobe, "k": k,
            "index_rows": n_partitions,
            "index_versions": len(ivf.writer.versions),
            "build_logical_iops": build_stats.n_iops,
            "build_logical_bytes": build_stats.bytes_read,
        },
        "counted": {
            "logical_iops": st.n_iops,
            "logical_bytes": st.bytes_read,
            "iops_per_query": round(st.n_iops / n_requests, 4),
            "s3_iops": s3.n_iops, "s3_bytes_read": s3.bytes_read,
            "nvme_iops": nvme.n_iops,
            "nvme_hit_rate": round(nvme.hit_rate, 4)
            if nvme.hits + nvme.misses else None,
        },
        "warm_repeat": {
            "nvme_hit_rate": round(warm_hit, 4),
            "s3_iops": warm_s3,
        },
        "latency": {"interleaved_ms": sum_inter,
                    "serial_all_p99_ms":
                        tenant_summary(serial, ["search"])["all"]["p99"]},
        "fullscan_ablation": {
            "n_requests": n_abl,
            "makespan_s": round(inter_fs.makespan, 6),
            "logical_iops": w2.io_stats().n_iops,
            "logical_bytes": w2.io_stats().bytes_read,
        },
        "headline": {
            "gate": "recall@k >= 0.9; search qps > full-scan qps; "
                    "warm repeat NVMe-served",
            "recall_at_k": round(recall, 6),
            "search_qps": round(qps_search, 3),
            "fullscan_qps": round(qps_scan, 3),
            "qps_search_over_fullscan": round(qps_search / qps_scan, 3),
            "p50_search_ms": round(sum_inter["all"]["p50"], 6),
            "p99_search_ms": round(sum_inter["all"]["p99"], 6),
            "makespan_s": round(inter.makespan, 6),
            "warm_nvme_hit_rate": round(warm_hit, 4),
        },
    }
    assert recall >= 0.9, \
        f"IVF recall@{k} must stay >= 0.9 at nprobe={nprobe}/" \
        f"{n_partitions} on clustered data (got {recall:.4f})"
    assert qps_search > qps_scan, \
        f"index-served QPS must beat the full-scan ablation " \
        f"({qps_search:.2f} vs {qps_scan:.2f})"
    assert warm_hit == 1.0 and warm_s3 == 0, \
        f"warm repeat must be fully NVMe-served " \
        f"(hit_rate={warm_hit:.4f}, s3_iops={warm_s3})"
    _emit("search/recall", dt * 1e6,
          f"recall_at_{k}={recall:.4f};nprobe={nprobe}/{n_partitions};"
          f"iops_per_query={st.n_iops / n_requests:.1f}")
    _emit("search/qps", inter.makespan * 1e6,
          f"search_qps={qps_search:.1f};fullscan_qps={qps_scan:.1f};"
          f"speedup={qps_search / qps_scan:.1f}x;"
          f"warm_nvme_hit_rate={warm_hit:.2f}")
    _dump_json("BENCH_search.json", results)
    _emit("search/written", 0.0, "path=BENCH_search.json")


def kernel_bench():
    """Device decode paths: ref-oracle throughput on CPU + kernel validation
    (interpret mode executes the kernel body; wall-time is not TPU time)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compression import bitpack
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    n, bits = 1 << 20, 11
    v = rng.integers(0, 1 << bits, n, dtype=np.uint64)
    words = jnp.asarray(ops.pack_words(bitpack(v, bits)))
    f = jax.jit(lambda w: ops.bitunpack(w, n, bits, use_pallas=False))
    f(words).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        f(words).block_until_ready()
    dt = (time.perf_counter() - t0) / 10
    _emit("kernel/bitunpack_ref_jit", dt * 1e6, f"Mvals_per_s={n/dt/1e6:.0f}")
    got = np.asarray(ops.bitunpack(words, n, bits))  # pallas interpret
    assert (got == v).all()
    _emit("kernel/bitunpack_pallas_validated", 0.0, "allclose=True")

    zipped = jnp.asarray(rng.integers(0, 256, (100_000, 64), dtype=np.uint8))
    rows = jnp.asarray(rng.integers(0, 100_000, 4096).astype(np.int32))
    g = jax.jit(lambda z, r: ops.fullzip_gather(z, r, use_pallas=False))
    g(zipped, rows).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        g(zipped, rows).block_until_ready()
    dt = (time.perf_counter() - t0) / 20
    _emit("kernel/fullzip_gather_ref_jit", dt * 1e6,
          f"Mrows_per_s={4096/dt/1e6:.1f}")


def loader_bench():
    """Training input pipeline: tokens/s through the Lance scan loader."""
    from repro.data.loader import TokenLoader, write_token_file

    fb = write_token_file(n_rows=512, seq_len=512, vocab=32_000)
    loader = TokenLoader(fb, batch=8, seq_len=512)
    try:
        next(iter(loader))
        t0 = time.perf_counter()
        n = 0
        for i, b in enumerate(loader):
            n += b["tokens"].size
            if i >= 20:
                break
        dt = time.perf_counter() - t0
        _emit("loader/tokens", dt / 20 * 1e6, f"Mtok_per_s={n/dt/1e6:.1f}")
    finally:
        loader.close()


ALL = [fig1_device_model, fig10_parquet_random_access,
       fig11_encodings_random_access, fig12_fullzip_vs_miniblock,
       fig13_compression, fig14_16_full_scan, fig17_scan_decode_cost,
       fig18_struct_packing, store_tiering, take_decode, decode_bench,
       dataset_take, ingest_bench, serve_bench, chaos_bench, search_bench,
       kernel_bench, loader_bench]


def _bench_names():
    """Every name a positional arg may use: full function names plus their
    leading-word tags (``take`` selects ``take_decode``)."""
    names = set()
    for fn in ALL:
        names.add(fn.__name__)
        names.add(fn.__name__.split("_")[0])
    return names


def _parse_args(argv):
    global STORE_SPEC, SMOKE, TRACER, TRACE_PATH
    want = set()
    it = iter(argv)
    for a in it:
        if a == "--store":
            STORE_SPEC = next(it, None)
            if STORE_SPEC is None:
                raise SystemExit("--store requires a value (flat|tiered|flat-s3|hot)")
        elif a.startswith("--store="):
            STORE_SPEC = a.split("=", 1)[1]
        elif a == "--trace":
            TRACE_PATH = next(it, None)
            if TRACE_PATH is None:
                raise SystemExit("--trace requires an output path")
        elif a.startswith("--trace="):
            TRACE_PATH = a.split("=", 1)[1]
        elif a == "--smoke":
            SMOKE = True
        elif a == "--list":
            for fn in ALL:
                print(f"{fn.__name__.split('_')[0]:12s} {fn.__name__}")
            raise SystemExit(0)
        elif a.startswith("-"):
            raise SystemExit(f"unknown option {a}")
        else:
            want.add(a)
    if STORE_SPEC not in ("flat", "tiered", "flat-s3", "hot"):
        raise SystemExit(f"--store must be flat|tiered|flat-s3|hot, got {STORE_SPEC}")
    # a typo'd benchmark name used to select nothing and exit 0 — a CI run
    # that silently measured nothing looked green
    unknown = want - _bench_names()
    if unknown:
        avail = ", ".join(sorted(fn.__name__ for fn in ALL))
        raise SystemExit(
            f"unknown benchmark(s): {', '.join(sorted(unknown))}\n"
            f"available: {avail}  (or their first-word tags; see --list)")
    if TRACE_PATH is not None:
        TRACER = Tracer()
    return want


def main() -> None:
    want = _parse_args(sys.argv[1:])
    print("name,us_per_call,derived")
    for fn in ALL:
        tag = fn.__name__.split("_")[0]
        if want and tag not in want and fn.__name__ not in want:
            continue
        fn()
    if TRACER is not None:
        n = TRACER.export(TRACE_PATH)
        _emit("trace/written", 0.0, f"path={TRACE_PATH};events={n}")


if __name__ == "__main__":
    main()
