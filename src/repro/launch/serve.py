"""Serving driver: Lance-backed retrieval + batched generation.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --reduced \
      --batch 4 --prompt-len 32 --new 16 --docs 5000
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..core import WriteOptions, write_table
from ..core.io_sim import NVME, S3, model_time
from ..data import synth
from ..models.registry import build_model
from ..serve.engine import BatchedEngine, Retriever


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--docs", type=int, default=5_000)
    ap.add_argument("--neighbors", type=int, default=8)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # document store (the random-access consumer)
    emb = synth.scenario("embeddings", args.docs)
    retriever = Retriever(write_table({"embedding": emb}, WriteOptions("lance")),
                          "embedding")
    ids = rng.integers(0, args.docs, (args.batch, args.neighbors)).reshape(-1)
    t0 = time.perf_counter()
    _, stats = retriever.fetch(ids)
    t_cpu = time.perf_counter() - t0
    print(f"[retrieve] {len(ids)} rows: {stats.n_iops} IOPS "
          f"amp={stats.read_amplification:.2f} cpu={t_cpu*1e3:.1f}ms "
          f"nvme={model_time(stats, NVME)*1e3:.2f}ms "
          f"s3={model_time(stats, S3)*1e3:.1f}ms")

    # generation (the sequential consumer)
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = BatchedEngine(model, params, max_new=args.new)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (args.batch, args.prompt_len)),
                          jnp.int32)
    t0 = time.perf_counter()
    out = engine.generate({"tokens": prompts}, n_new=args.new)
    dt = time.perf_counter() - t0
    tok_s = args.batch * args.new / dt
    print(f"[serve] {args.batch}x{args.new} tokens in {dt:.2f}s "
          f"({tok_s:.1f} tok/s on host CPU, {cfg.name})")


if __name__ == "__main__":
    main()
