import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods x 256 chips of TPU v5e.  For every
cell we report ``memory_analysis()`` (fits-in-HBM evidence) and
``cost_analysis()`` (FLOPs/bytes for the §Roofline terms), and optionally
dump the optimized HLO for the collective-bytes parser
(benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_config
from ..compat import set_mesh
from ..dist.sharding import ShardingPolicy
from ..models.registry import (
    build_model,
    cache_specs,
    input_specs,
    model_flops,
    param_counts,
    supports_shape,
)
from ..train.optimizer import make_optimizer
from ..train.train_loop import make_train_step
from .mesh import make_production_mesh

# archs big enough to need ZeRO-3 weight sharding on the data axis
FSDP_ARCHS = {"qwen2-72b", "qwen1.5-32b", "grok-1-314b", "llama-3.2-vision-90b",
              "deepseek-v2-lite-16b", "qwen1.5-4b"}
# sub-1B archs: the 16-wide TP axis only replicates compute; use 256-way DP
# (§Perf A3).  Overridable per-cell via build_cell(pure_dp=...).
PURE_DP_ARCHS = {"smollm-360m", "mamba2-780m", "seamless-m4t-medium"}


def abstract_init(model, seed: int = 0):
    """(ShapeDtypeStruct params, specs) without allocating anything."""
    captured = {}

    def f(key):
        p, s = model.init(key)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(seed))
    return shapes, captured["specs"]


def build_cell(arch: str, shape_name: str, mesh, fsdp=None, pure_dp=None):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if pure_dp is None:
        pure_dp = arch in PURE_DP_ARCHS and shape.kind == "train"
    policy = ShardingPolicy(mesh, fsdp=(arch in FSDP_ARCHS if fsdp is None else fsdp),
                            pure_dp=pure_dp)
    dp_total = 1
    for ax in policy.batch_axes():
        dp_total *= mesh.shape[ax]
    model = build_model(cfg, mesh=mesh, batch_axes=policy.batch_axes(),
                        data_size=mesh.shape["data"],
                        use_sharded_moe=cfg.moe is not None)
    p_shapes, p_specs = abstract_init(model)
    p_sh = policy.param_shardings(p_specs)

    ins = input_specs(cfg, shape)
    batch_shapes = {k: v[0] for k, v in ins.items()}

    def in_sharding(sds, spec):
        resolved = policy.act_spec(spec)
        # small-batch decode (long_500k): batch cannot shard -> replicate it
        if resolved and resolved[0] is not None and sds.shape[0] % dp_total != 0:
            resolved = P(None, *tuple(resolved)[1:])
        return NamedSharding(mesh, resolved)

    batch_sh = {k: in_sharding(v[0], v[1]) for k, v in ins.items()}

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_shapes = jax.eval_shape(opt.init, p_shapes)
        opt_specs = opt.state_specs(p_specs)
        opt_sh = policy.param_shardings(opt_specs)
        step_fn = make_train_step(model, opt)
        args = (p_shapes, opt_shapes, batch_shapes,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_sh, opt_sh, batch_sh, NamedSharding(mesh, P()))
        out_sh = (p_sh, opt_sh, None)
        donate = (0, 1)
        return step_fn, args, in_sh, out_sh, donate

    if shape.kind == "prefill":
        c_shapes, c_specs = cache_specs(cfg, shape, dp_total)
        c_sh = policy.act_shardings(c_specs)

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        args = (p_shapes, batch_shapes)
        in_sh = (p_sh, batch_sh)
        out_sh = (None, c_sh) if _cache_matches(model, cfg) else None
        return prefill_fn, args, in_sh, None, ()

    # decode
    c_shapes, c_specs = cache_specs(cfg, shape, dp_total)
    c_sh = policy.act_shardings(c_specs)

    def decode_fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    args = (p_shapes, c_shapes, batch_shapes["tokens"])
    in_sh = (p_sh, c_sh, batch_sh["tokens"])
    out_sh = (None, c_sh)
    donate = (1,)
    return decode_fn, args, in_sh, out_sh, donate


def _cache_matches(model, cfg):
    return False  # prefill output shardings left to GSPMD (documented)


def run_cell(arch: str, shape_name: str, multi_pod: bool, hlo_dir=None, fsdp=None):
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = supports_shape(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    try:
        with set_mesh(mesh):
            fn, args, in_sh, out_sh, donate = build_cell(arch, shape_name, mesh, fsdp=fsdp)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        total, active = param_counts(cfg)
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "hlo_flops": float(cost.get("flops", -1)),
            "hlo_bytes": float(cost.get("bytes accessed", -1)),
            "model_flops": model_flops(cfg, shape),
            "params_total": total,
            "params_active": active,
            "bytes_per_device": {
                "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak": int(getattr(mem, "peak_memory_in_bytes", 0)),
            },
            "n_chips": n_chips,
        })
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            fname = os.path.join(hlo_dir, f"{arch}__{shape_name}__{result['mesh']}.hlo")
            with open(fname, "w") as f:
                f.write(compiled.as_text())
            result["hlo_file"] = fname
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        result["status"] = "fail"
        result["error"] = f"{type(e).__name__}: {str(e)[:500]}"
        result["traceback"] = traceback.format_exc()[-2000:]
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", default=None, help="directory for results json + hlo")
    ap.add_argument("--hlo", action="store_true", help="dump optimized HLO")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    hlo_dir = os.path.join(args.out, "hlo") if (args.out and args.hlo) else None

    results = []
    for mp in pods:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, mp, hlo_dir=hlo_dir)
                status = r["status"]
                extra = (f"flops={r.get('hlo_flops', 0):.3e} "
                         f"peak={r.get('bytes_per_device', {}).get('peak', 0)/2**30:.2f}GiB "
                         f"compile={r.get('compile_s', 0)}s"
                         if status == "ok" else r.get("reason", r.get("error", "")))
                print(f"[{r['mesh']}] {arch:24s} {shape:12s} {status:8s} {extra}",
                      flush=True)
                results.append(r)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        mode = "all" if args.all else f"{args.arch}_{args.shape}"
        with open(os.path.join(args.out, f"dryrun_{mode}_{args.multi_pod}.json"), "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_fail = sum(1 for r in results if r["status"] == "fail")
    print(f"\n{len(results)} cells: "
          f"{sum(1 for r in results if r['status']=='ok')} ok, "
          f"{sum(1 for r in results if r['status']=='skipped')} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
