"""End-to-end training driver.

Wires every substrate together: Lance-backed token loader (full-scan path),
model zoo, optimizer, sharded train_step, async checkpointing, heartbeat /
straggler monitoring and crash-restart with exact data-cursor resume.

On this CPU container it trains reduced configs on the host mesh; on a pod
it takes ``--mesh production``.  Example (the ~100M-scale run used by
examples/train_lm.py):

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..data.loader import TokenLoader, write_token_file
from ..compat import set_mesh
from ..dist.checkpoint import Checkpointer
from ..dist.fault import DataCursor, HeartbeatMonitor, RestartPolicy, run_with_restarts
from ..dist.sharding import ShardingPolicy
from ..models.registry import build_model
from ..train.optimizer import make_optimizer
from ..train.train_loop import make_train_step
from .mesh import make_host_mesh, make_production_mesh


def train(arch: str, *, reduced: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir=None, ckpt_every: int = 25,
          mesh_kind: str = "host", microbatches: int = 1, lr: float = 3e-4,
          log_every: int = 10, inject_failure_at=None):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    mesh = make_host_mesh() if mesh_kind == "host" else make_production_mesh()
    policy = ShardingPolicy(mesh, fsdp=False)
    model = build_model(cfg, mesh=mesh, batch_axes=policy.batch_axes(),
                        data_size=mesh.shape["data"], use_sharded_moe=False)

    with set_mesh(mesh):
        params, specs = model.init(jax.random.PRNGKey(0))
        p_sh = policy.param_shardings(specs)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_sh)
        opt = make_optimizer(cfg.optimizer, lr=lr)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(model, opt, microbatches=microbatches),
                          donate_argnums=(0, 1))

        # data: a Lance-encoded token file (full-scan consumer)
        fbytes = write_token_file(n_rows=max(64, batch * 4), seq_len=seq,
                                  vocab=cfg.vocab, seed=0)
        loader = TokenLoader(fbytes, batch=batch, seq_len=seq, seed=0)

        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        start = 0
        if ckpt:
            restored, s = ckpt.restore_latest({"params": params, "opt": opt_state},
                                              {"params": p_sh, "opt": None})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                start = s + 1
                print(f"[train] resumed from step {s}")

        hb = HeartbeatMonitor(on_straggler=lambda s, dt, med: print(
            f"[fault] step {s} straggled: {dt:.3f}s vs median {med:.3f}s"))
        state = {"params": params, "opt": opt_state, "loss": None,
                 "injected": False}

        def do_step(step: int):
            hb.start_step()
            batch_np = loader.batch_for_step(step)
            if (inject_failure_at is not None and step == inject_failure_at
                    and not state["injected"]):
                state["injected"] = True
                raise RuntimeError("injected failure (fault-tolerance test)")
            b = {k: jnp.asarray(v) for k, v in batch_np.items()}
            state["params"], state["opt"], metrics = step_fn(
                state["params"], state["opt"], b, jnp.int32(step))
            state["loss"] = float(metrics["loss"])
            dt = hb.end_step(step)
            if step % log_every == 0:
                print(f"[train] step {step} loss={state['loss']:.4f} {dt*1e3:.0f}ms",
                      flush=True)
            if ckpt and step and step % ckpt_every == 0:
                ckpt.save(step, {"params": state["params"], "opt": state["opt"]})

        def on_failure(e: Exception) -> int:
            print(f"[fault] step failed ({e}); restoring latest checkpoint")
            if ckpt:
                restored, s = ckpt.restore_latest({"params": state["params"],
                                                   "opt": state["opt"]},
                                                  {"params": p_sh, "opt": None})
                if restored is not None:
                    state["params"], state["opt"] = restored["params"], restored["opt"]
                    return s + 1
            return 0

        last = run_with_restarts(do_step, start_step=start, n_steps=steps - start,
                                 policy=RestartPolicy(), on_failure=on_failure)
        if ckpt:
            ckpt.save(last - 1, {"params": state["params"], "opt": state["opt"]},
                      blocking=True)
        loader.close()
        return state["loss"], last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", choices=["host", "production"], default="host")
    args = ap.parse_args()
    loss, last = train(args.arch, reduced=args.reduced, steps=args.steps,
                       batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, mesh_kind=args.mesh,
                       microbatches=args.microbatches, lr=args.lr)
    print(f"[train] done at step {last - 1}, final loss {loss:.4f}")


if __name__ == "__main__":
    main()
