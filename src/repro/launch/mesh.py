"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real device count).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has — used by smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
