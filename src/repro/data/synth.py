"""Synthetic data generators.

Two families:
* the paper's eight random-access benchmark types (§6.1, table p.8) with the
  exact average sizes and 10% top-level nulls;
* scenario datasets standing in for the §6.2 compression corpus (names,
  prompts, dates, reviews, code, images, embeddings, websites) — synthetic
  with matching statistics (zipfian vocab for text, sorted dates, random
  bytes for compressed images, unit-norm float vectors).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from ..core import arrays as A
from ..core import types as T

__all__ = ["paper_type", "PAPER_TYPES", "scenario", "SCENARIOS", "token_corpus"]

PAPER_TYPES = [
    "scalar", "string", "scalar-list", "string-list",
    "vector", "vector-list", "image", "image-list",
]


def _nulls(rng, n, frac=0.10):
    return rng.random(n) >= frac


def paper_type(name: str, n: int, seed: int = 0) -> A.Array:
    """The §6.1 table: avg sizes 8B/16B/40B/80B/3Ki/15Ki/20Ki/100Ki."""
    rng = np.random.default_rng(seed)
    v = _nulls(rng, n)
    if name == "scalar":
        return A.PrimitiveArray(T.uint64(), v, rng.integers(0, 1 << 60, n).astype(np.uint64))
    if name == "string":  # avg 16 bytes
        lens = rng.integers(8, 25, n)
        return _strings(rng, v, lens)
    if name == "scalar-list":  # avg 40 bytes = ~5 u64
        return _list_of(rng, v, lambda m: A.PrimitiveArray(
            T.uint64(nullable=False), np.ones(m, bool),
            rng.integers(0, 1 << 60, m).astype(np.uint64)), lo=2, hi=8, n=n)
    if name == "string-list":  # avg 80 bytes = ~5 strings of 16
        def mk(m):
            s = _strings(rng, np.ones(m, bool), rng.integers(8, 25, m))
            s.type = s.type.with_nullable(False)
            return s
        return _list_of(rng, v, mk, lo=2, hi=8, n=n)
    if name == "vector":  # FSL<f32,768> = 3 KiB
        return A.FixedSizeListArray(
            T.FixedSizeList(T.Primitive("float32", nullable=False), 768), v,
            rng.standard_normal((n, 768)).astype(np.float32))
    if name == "vector-list":  # ~5 vectors = 15 KiB
        def mkv(m):
            return A.FixedSizeListArray(
                T.FixedSizeList(T.Primitive("float32", nullable=False), 768, nullable=False),
                np.ones(m, bool), rng.standard_normal((m, 768)).astype(np.float32))
        return _list_of(rng, v, mkv, lo=3, hi=8, n=n)
    if name == "image":  # Binary ~20 KiB (already-compressed payload)
        lens = rng.integers(15_000, 25_000, n)
        return _binary(rng, v, lens)
    if name == "image-list":  # ~5 images = 100 KiB
        def mkb(m):
            b = _binary(rng, np.ones(m, bool), rng.integers(15_000, 25_000, m))
            b.type = b.type.with_nullable(False)
            return b
        return _list_of(rng, v, mkb, lo=3, hi=8, n=n)
    raise KeyError(name)


def _strings(rng, validity, lens) -> A.VarBinaryArray:
    lens = np.where(validity, lens, 0).astype(np.int64)
    offsets = np.zeros(len(lens) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    data = rng.integers(97, 123, int(offsets[-1])).astype(np.uint8)
    return A.VarBinaryArray(T.utf8(), validity.copy(), offsets, data)


def _binary(rng, validity, lens) -> A.VarBinaryArray:
    lens = np.where(validity, lens, 0).astype(np.int64)
    offsets = np.zeros(len(lens) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    data = rng.integers(0, 256, int(offsets[-1])).astype(np.uint8)
    return A.VarBinaryArray(T.binary(), validity.copy(), offsets, data)


def _list_of(rng, validity, make_child, lo, hi, n) -> A.ListArray:
    lens = np.where(validity, rng.integers(lo, hi, n), 0).astype(np.int64)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    child = make_child(int(offsets[-1]))
    return A.ListArray(T.List(child.type), validity.copy(), offsets, child)


# ---------------------------------------------------------------------------
# compression scenario corpus (synthetic stand-ins, §6.2)
# ---------------------------------------------------------------------------

SCENARIOS = ["names", "prompts", "dates", "reviews", "code", "images",
             "embeddings", "websites"]

_WORDS = None


def _word_bank(rng, n_words=2000, zipf=1.3):
    global _WORDS
    if _WORDS is None:
        lens = rng.integers(3, 10, n_words)
        _WORDS = [bytes(rng.integers(97, 123, l, dtype=np.uint8)) for l in lens]
    probs = 1.0 / np.arange(1, n_words + 1) ** zipf
    return _WORDS, probs / probs.sum()


def _text(rng, n, words_per, zipf=1.3) -> A.VarBinaryArray:
    bank, p = _word_bank(rng)
    vals = []
    for _ in range(n):
        k = rng.integers(*words_per)
        idx = rng.choice(len(bank), k, p=p)
        vals.append(b" ".join(bank[i] for i in idx))
    return A.VarBinaryArray.build(vals, utf8=True)


def scenario(name: str, n: int, seed: int = 0) -> A.Array:
    rng = np.random.default_rng(seed)
    if name == "names":  # low-cardinality (dictionary-friendly)
        bank = [bytes(rng.integers(65, 91, rng.integers(4, 9), dtype=np.uint8))
                for _ in range(800)]
        vals = [bank[i] for i in rng.integers(0, len(bank), n)]
        return A.VarBinaryArray.build(vals, utf8=True)
    if name == "prompts":
        return _text(rng, n, (20, 120))
    if name == "dates":  # TPC-H ship date: sorted-ish int32 days
        base = rng.integers(8000, 12000, n).astype(np.int32)
        return A.PrimitiveArray(T.int32(), np.ones(n, bool), np.sort(base))
    if name == "reviews":
        return _text(rng, n, (30, 200))
    if name == "code":  # repetitive structured text
        lines = [b"def f_%d(x):\n    return x + %d\n" % (i % 97, i % 13) for i in range(64)]
        vals = [b"".join(lines[rng.integers(0, 64)] for _ in range(rng.integers(5, 40)))
                for _ in range(n)]
        return A.VarBinaryArray.build(vals)
    if name == "images":  # already-compressed: incompressible bytes
        return _binary(rng, np.ones(n, bool), rng.integers(8_000, 30_000, n))
    if name == "embeddings":  # CLIP-like unit vectors f32[512]
        x = rng.standard_normal((n, 512)).astype(np.float32)
        x /= np.linalg.norm(x, axis=1, keepdims=True)
        return A.FixedSizeListArray(
            T.FixedSizeList(T.Primitive("float32", nullable=False), 512),
            np.ones(n, bool), x)
    if name == "websites":  # html-ish with heavy tag repetition
        tags = [b"<div class='c%d'>" % (i % 23) for i in range(23)] + [b"</div>", b"<p>", b"</p>"]
        bank, p = _word_bank(rng)
        vals = []
        for _ in range(n):
            parts = []
            for _ in range(rng.integers(10, 80)):
                parts.append(tags[rng.integers(0, len(tags))])
                parts.append(bank[rng.choice(len(bank), p=p)])
            vals.append(b"".join(parts))
        return A.VarBinaryArray.build(vals)
    raise KeyError(name)


def token_corpus(n_rows: int, seq_len: int, vocab: int, seed: int = 0) -> A.Array:
    """Tokenized documents as List<int32> (the training-pipeline column)."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(seq_len // 2, seq_len * 2, n_rows).astype(np.int64)
    offsets = np.zeros(n_rows + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    # zipfian tokens compress realistically under bitpack/RLE
    flat = (rng.zipf(1.3, int(offsets[-1])) % vocab).astype(np.int32)
    child = A.PrimitiveArray(T.int32(nullable=False), np.ones(len(flat), bool), flat)
    return A.ListArray(T.List(child.type, nullable=False), np.ones(n_rows, bool),
                       offsets, child)
