"""Training input pipeline over Lance-encoded storage.

Full-scan consumer of the paper's format (DESIGN.md §2): token documents are
stored as a mini-block-encoded ``List<int32>`` column; the loader scans
chunks sequentially, packs documents into fixed-length training sequences,
shuffles within a window, and prefetches batches on a background thread
(host decode overlaps device step — the standard TPU input pipeline shape).

The deterministic cursor (seed, step) -> batch makes restarts resume exactly
(dist.fault.DataCursor); ``device_decode=True`` routes the final bit-unpack
through the Pallas mini-block kernel instead of host numpy, demonstrating
the HBM->VMEM decode path.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..core import arrays as A
from ..core.file import FileReader, WriteOptions, write_table
from . import synth

__all__ = ["write_token_file", "TokenLoader"]

# End-of-stream marker the producer enqueues on exit (normal stop or crash)
# so a blocked consumer always wakes instead of deadlocking on an empty
# queue whose producer is gone.
_SENTINEL = object()


def write_token_file(n_rows: int, seq_len: int, vocab: int, seed: int = 0,
                     encoding: str = "lance") -> bytes:
    corpus = synth.token_corpus(n_rows, seq_len, vocab, seed)
    return write_table({"tokens": corpus}, WriteOptions(encoding))


class TokenLoader:
    """Sequential-scan loader with shuffle window + prefetch."""

    def __init__(self, file_bytes: bytes, *, batch: int, seq_len: int,
                 seed: int = 0, shuffle_window: int = 4096, prefetch: int = 2,
                 start_step: int = 0):
        self.reader = FileReader(file_bytes)
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.window = shuffle_window
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._done = False  # consumer-side latch: sentinel seen / stopped
        self._step = start_step
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- deterministic token stream --------------------------------------
    def _token_stream(self) -> np.ndarray:
        arr = self.reader.scan("tokens")
        assert isinstance(arr, A.ListArray)
        return arr.child.values  # flattened token ids

    def _producer(self):
        try:
            flat = self._token_stream()
            per_batch = self.batch * (self.seq_len + 1)
            n_batches = len(flat) // per_batch
            rng = np.random.default_rng(self.seed)
            order = rng.permutation(n_batches)
            step = self._step
            while not self._stop.is_set():
                b = order[step % n_batches]
                chunk = flat[b * per_batch : (b + 1) * per_batch]
                toks = chunk.reshape(self.batch,
                                     self.seq_len + 1).astype(np.int32)
                try:
                    self._q.put((step, {"tokens": toks}), timeout=1.0)
                    step += 1
                except queue.Full:
                    continue
        finally:
            # Always leave a sentinel, whether we stopped cleanly or died
            # on an exception: a consumer blocked in __next__ must wake.
            # The producer owns the queue at this point, so if it is full
            # we discard a prefetched batch to make room — never block.
            while True:
                try:
                    self._q.put_nowait(_SENTINEL)
                    break
                except queue.Full:
                    try:
                        self._q.get_nowait()
                    except queue.Empty:
                        pass

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        """Next prefetched batch; raises ``StopIteration`` (never hangs)
        once the producer has exited — clean stop, crash, or a ``stop()``
        that raced the last put."""
        while True:
            if self._done:
                raise StopIteration
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                # no data: only keep waiting while the producer is alive
                # and nobody asked us to stop
                if self._stop.is_set() or not self._thread.is_alive():
                    self._done = True
                    raise StopIteration from None
                continue
            if item is _SENTINEL:
                self._done = True
                raise StopIteration
            _step, batch = item
            return batch

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        """Pure (seed, step) -> batch mapping for exact restart resume."""
        flat = self._token_stream()
        per_batch = self.batch * (self.seq_len + 1)
        n_batches = len(flat) // per_batch
        rng = np.random.default_rng(self.seed)
        order = rng.permutation(n_batches)
        b = order[step % n_batches]
        chunk = flat[b * per_batch : (b + 1) * per_batch]
        return {"tokens": chunk.reshape(self.batch, self.seq_len + 1).astype(np.int32)}

    def stop(self):
        """Stop the producer and unblock any consumer: subsequent
        ``__next__`` calls raise ``StopIteration`` instead of deadlocking."""
        self._stop.set()
        self._thread.join(timeout=5.0)

    # historical name; same semantics
    close = stop
