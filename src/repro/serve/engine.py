"""Batched serving engine: prefill + decode loop with jit'd steps, plus the
random-access retrieval path (the paper's `take`) for embedding/document
fetch — search results feed generation, storage feeds search.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.file import FileReader

__all__ = ["BatchedEngine", "Retriever"]


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray  # (B, n_gen)
    steps: int


class BatchedEngine:
    """Static-batch generate: prefill once, decode N steps with a
    pre-allocated cache (capacity = prompt + max_new)."""

    def __init__(self, model, params, max_new: int = 32):
        self.model = model
        self.params = params
        self.max_new = max_new
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def _pad_cache(self, cache, extra: int):
        fam = self.model.cfg.family

        def pad(x, axis):
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[axis] = (0, extra)
            return jnp.pad(x, cfgpad)

        if fam in ("dense", "moe"):
            keys = cache["layers"].keys()
            lay = {k: pad(v, 2) for k, v in cache["layers"].items()}
            return {"layers": lay, "length": cache["length"]}
        if fam == "ssm":
            return cache  # state caches need no capacity
        if fam == "hybrid":
            return {"mamba": cache["mamba"],
                    "shared": {k: pad(v, 2) for k, v in cache["shared"].items()},
                    "length": cache["length"]}
        if fam == "vlm":
            return {"self": {k: pad(v, 3) for k, v in cache["self"].items()},
                    "cross": cache["cross"], "length": cache["length"]}
        if fam == "audio":
            return {"self": {k: pad(v, 2) for k, v in cache["self"].items()},
                    "cross": cache["cross"], "length": cache["length"]}
        raise ValueError(fam)

    def generate(self, batch: Dict, n_new: Optional[int] = None,
                 greedy: bool = True) -> GenResult:
        n_new = n_new or self.max_new
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, n_new + 8)
        toks = []
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            toks.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cache, cur)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return GenResult(np.concatenate(toks, axis=1), n_new)


class Retriever:
    """Random-access retrieval over a Lance file *or dataset*: the
    search-path consumer (§1: 'search workloads fetch small subsets not
    aligned with the clustered index').

    ``source`` is one Lance file (bytes), a list of fragment files (served
    through :class:`repro.dataset.DatasetReader` — one shared NVMe budget
    and cross-file coalescing over the whole dataset), or a ready
    ``FileReader``/``DatasetReader``.  ``store`` selects the tier stack
    (see :func:`repro.store.make_store`): the serving deployment shape is
    ``store="tiered"`` — an NVMe block cache over S3 that turns the hot
    working set into NVMe-priced reads while cold rows pay the object-store
    round trip ("tiered-auto" additionally adapts cache admission to the
    observed scan/take mix).
    """

    def __init__(self, source, column: str = "embedding", store=None):
        if isinstance(source, (list, tuple)):
            from ..dataset import DatasetReader

            self.reader = DatasetReader(list(source), store=store)
        elif isinstance(source, (bytes, bytearray)):
            self.reader = FileReader(source, store=store)
        else:
            if store is not None:
                raise ValueError("store is fixed by a ready reader")
            self.reader = source
        self.column = column

    def fetch(self, row_ids: np.ndarray):
        """take() — at most 2 IOPS/row via full-zip (§4.1.4).  Row ids are
        global over the dataset when serving from fragments."""
        self.reader.reset_io()
        out = self.reader.take(self.column, np.asarray(row_ids, np.int64))
        return out, self.reader.io_stats()

    def tier_stats(self):
        """Per-tier dispatched-IO stats since the last fetch."""
        return self.reader.tier_stats()

    def modelled_time(self) -> float:
        return self.reader.modelled_time()
