"""Batched serving engine: prefill + decode loop with jit'd steps, plus the
random-access retrieval path (the paper's `take`) for embedding/document
fetch — search results feed generation, storage feeds search.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.file import FileReader
from ..kernels import ops
from ..obs import NULL_TRACER

__all__ = ["BatchedEngine", "Retriever", "SearchResult"]


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray  # (B, n_gen)
    steps: int


@dataclasses.dataclass
class SearchResult:
    """One batched IVF search: per-query winners plus the one batched take
    that materialized them.

    ``ids``/``distances`` are (Q, k); a query with fewer than ``k``
    eligible candidates pads with ``id = -1`` / ``distance = inf``.
    ``winner_rows`` is the deduplicated ascending union of valid ids —
    the row set the winner ``take`` fetched; ``values`` is that take's
    result, aligned with ``winner_rows`` (``None`` when ``fetch=False``).
    """

    ids: np.ndarray          # (Q, k) int64 global row ids, -1 at padding
    distances: np.ndarray    # (Q, k) float32 squared L2, inf at padding
    probes: np.ndarray       # (Q, nprobe) probed partition ids
    winner_rows: np.ndarray  # unique valid ids, ascending
    values: Optional[object] = None
    n_candidates: int = 0    # posting entries scored across probed parts


class BatchedEngine:
    """Static-batch generate: prefill once, decode N steps with a
    pre-allocated cache (capacity = prompt + max_new)."""

    def __init__(self, model, params, max_new: int = 32):
        self.model = model
        self.params = params
        self.max_new = max_new
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def _pad_cache(self, cache, extra: int):
        fam = self.model.cfg.family

        def pad(x, axis):
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[axis] = (0, extra)
            return jnp.pad(x, cfgpad)

        if fam in ("dense", "moe"):
            keys = cache["layers"].keys()
            lay = {k: pad(v, 2) for k, v in cache["layers"].items()}
            return {"layers": lay, "length": cache["length"]}
        if fam == "ssm":
            return cache  # state caches need no capacity
        if fam == "hybrid":
            return {"mamba": cache["mamba"],
                    "shared": {k: pad(v, 2) for k, v in cache["shared"].items()},
                    "length": cache["length"]}
        if fam == "vlm":
            return {"self": {k: pad(v, 3) for k, v in cache["self"].items()},
                    "cross": cache["cross"], "length": cache["length"]}
        if fam == "audio":
            return {"self": {k: pad(v, 2) for k, v in cache["self"].items()},
                    "cross": cache["cross"], "length": cache["length"]}
        raise ValueError(fam)

    def generate(self, batch: Dict, n_new: Optional[int] = None,
                 greedy: bool = True) -> GenResult:
        n_new = n_new or self.max_new
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, n_new + 8)
        toks = []
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(n_new):
            toks.append(np.asarray(cur))
            logits, cache = self._decode(self.params, cache, cur)
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return GenResult(np.concatenate(toks, axis=1), n_new)


class Retriever:
    """Random-access retrieval over a Lance file *or dataset*: the
    search-path consumer (§1: 'search workloads fetch small subsets not
    aligned with the clustered index').

    ``source`` is one Lance file (bytes), a list of fragment files (served
    through :class:`repro.dataset.DatasetReader` — one shared NVMe budget
    and cross-file coalescing over the whole dataset), or a ready
    ``FileReader``/``DatasetReader``.  ``store`` selects the tier stack
    (see :func:`repro.store.make_store`): the serving deployment shape is
    ``store="tiered"`` — an NVMe block cache over S3 that turns the hot
    working set into NVMe-priced reads while cold rows pay the object-store
    round trip ("tiered-auto" additionally adapts cache admission to the
    observed scan/take mix).
    """

    def __init__(self, source, column: str = "embedding", store=None,
                 index=None, decode: Optional[str] = None):
        if isinstance(source, (list, tuple)):
            from ..dataset import DatasetReader

            self.reader = DatasetReader(list(source), store=store,
                                        decode=decode)
        elif isinstance(source, (bytes, bytearray)):
            self.reader = FileReader(source, store=store, decode=decode)
        else:
            if store is not None:
                raise ValueError("store is fixed by a ready reader")
            self.reader = source
        self.column = column
        # ``index``: an IvfIndex whose attached writer shares this reader's
        # scheduler/store — :meth:`search` turns queries into row ids.
        # ``decode`` selects the kernel route for both file decode and the
        # search distance/top-k ("numpy" = jnp oracles, default Pallas).
        self.index = index
        self.decode = decode

    def fetch(self, row_ids: np.ndarray):
        """take() — at most 2 IOPS/row via full-zip (§4.1.4).  Row ids are
        global over the dataset when serving from fragments."""
        self.reader.reset_io()
        out = self.reader.take(self.column, np.asarray(row_ids, np.int64))
        return out, self.reader.io_stats()

    def search(self, query, k: int = 10, nprobe: int = 4,
               fetch: bool = True, index_version: Optional[int] = None,
               ) -> SearchResult:
        """IVF search: probe partitions → batched posting-list fetch →
        distance/top-k kernel → one batched ``take`` of the winners.

        Every IO lands on the retriever's shared scheduler/store — index
        reads (centroids, posting lists) and data reads (candidate
        vectors, winner rows) share one cache budget and one drain log, so
        per-request attribution sees the whole search, not just its data
        half.  Accepts one query ``(D,)`` or a batch ``(Q, D)``;
        multi-query batches score one shared candidate matrix under a
        per-query partition mask, so each query still sees exactly its own
        ``nprobe`` probes.  Deterministic end to end: k-means is seeded,
        ties break toward the lowest row id, and the numpy/Pallas kernel
        routes are bit-identical (``decode`` knob).
        """
        if self.index is None:
            raise ValueError(
                "no index attached — IvfIndex.build(writer, column) first")
        q = np.atleast_2d(np.asarray(query, np.float32))
        nq = q.shape[0]
        p = self.index.n_partitions
        k = int(k)
        nprobe = min(max(1, int(nprobe)), p)
        use_pallas = self.decode != "numpy"
        tracer = getattr(self.reader, "tracer", NULL_TRACER)
        with tracer.span("search", cat="serve", n_queries=nq, k=k,
                         nprobe=nprobe):
            # 1. probe: nearest centroids per query (centroid rows come
            # through the shared store; warm after the first search)
            cent = self.index.centroids(index_version)
            _, probes = ops.ivf_topk(
                q, cent, np.arange(p, dtype=np.int32), nprobe,
                use_pallas=use_pallas, tracer=tracer)
            probes = np.asarray(probes, np.int64)           # (Q, nprobe)
            # 2. one batched posting fetch for the union of probed parts
            parts = np.unique(probes)
            posts = self.index.postings(parts, index_version)
            cand_ids = np.concatenate(posts) if posts else \
                np.zeros(0, np.int64)
            # per-query eligibility: candidate row -> owning partition,
            # eligible iff that partition is in the query's probe set
            probed = np.zeros((nq, p), bool)
            probed[np.repeat(np.arange(nq), nprobe), probes.reshape(-1)] = True
            part_of = np.repeat(parts, [len(pl) for pl in posts])
            mask = probed[:, part_of]                       # (Q, N)
            # 3. one batched take of the candidate vectors, then the kernel
            cand = self.reader.take(self.column, cand_ids)
            d, w = ops.ivf_topk(q, np.asarray(cand.values, np.float32),
                                cand_ids, k, mask=mask,
                                use_pallas=use_pallas, tracer=tracer)
            d = np.asarray(d, np.float32)
            w = np.asarray(w, np.int64)
            w[w == ops.IVF_ID_SENTINEL] = -1
            # 4. one batched take of the deduplicated winner rows — the
            # response payload, served (and priced) like any data read
            winners = np.unique(w[w >= 0])
            values = None
            if fetch and winners.size:
                values = self.reader.take(self.column, winners)
            return SearchResult(ids=w, distances=d, probes=probes,
                                winner_rows=winners, values=values,
                                n_candidates=int(cand_ids.size))

    def tier_stats(self):
        """Per-tier dispatched-IO stats since the last fetch."""
        return self.reader.tier_stats()

    def modelled_time(self) -> float:
        return self.reader.modelled_time()
