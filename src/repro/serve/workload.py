"""Multi-tenant Zipf serving workload over the dataset layer.

The "millions of users" shape from the ROADMAP: many concurrent takers with
Zipf-skewed row popularity (a small hot set absorbs most of the traffic,
a long cold tail keeps missing) driving ``DatasetReader.take`` through one
shared tiered store, optionally mixed with an ingest tenant whose appends
and flush runs compete for the same device queues.

This module only *generates and drives* the workload; the timing comes from
the scheduler's event-loop serving plane (:mod:`repro.store.evloop`).  The
driver executes every request inside one :class:`~repro.store.ServiceWindow`
so the same executed trace can be priced under interleaved event-loop
dispatch and under the old serial batch-drain, and per-tenant
p50/p99/p999 latency compared between the two — the serving benchmark's
headline gate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..store import QoS, ServiceResult, latency_percentiles

__all__ = ["TenantSpec", "ServeRequest", "ZipfWorkload", "drive",
           "tenant_summary"]


@dataclasses.dataclass
class TenantSpec:
    """One serving tenant: its share of the request stream and its QoS
    standing (weight feeds the event loop's weighted-fair round packing,
    priority its strict classes)."""

    name: str
    share: float = 1.0
    weight: float = 1.0
    priority: int = 0
    rows_per_request: int = 32


@dataclasses.dataclass
class ServeRequest:
    """One arrival: a tenant asks for ``rows`` at virtual time ``at``."""

    tenant: str
    at: float
    rows: np.ndarray


class ZipfWorkload:
    """Deterministic multi-tenant request generator.

    Row popularity is bounded Zipf over the global row ids: row rank k is
    drawn with probability proportional to ``1 / k**zipf_s``, so low ids
    are hot (they share fragments, so the cache's sector granularity gets
    real spatial locality) and the tail stays cold.  Arrivals are a Poisson
    process at ``arrival_rate`` requests per virtual second, tenants drawn
    by their ``share``.  Everything derives from ``seed`` — two instances
    with equal parameters generate identical request streams, which is what
    lets the benchmark compare dispatch models on the same workload."""

    def __init__(self, n_rows: int, tenants: Sequence[TenantSpec],
                 n_requests: int, zipf_s: float = 1.1,
                 arrival_rate: float = 50.0, seed: int = 0):
        if n_rows <= 0 or n_requests <= 0:
            raise ValueError("n_rows and n_requests must be positive")
        self.n_rows = int(n_rows)
        self.tenants = list(tenants)
        self.n_requests = int(n_requests)
        self.zipf_s = float(zipf_s)
        self.arrival_rate = float(arrival_rate)
        self.seed = int(seed)
        ranks = np.arange(1, self.n_rows + 1, dtype=np.float64)
        p = ranks ** -self.zipf_s
        self._popularity = p / p.sum()

    def qos(self, starvation_rounds: int = 16) -> QoS:
        """The QoS knobs implied by the tenant specs."""
        return QoS(weights={t.name: t.weight for t in self.tenants},
                   priority={t.name: t.priority for t in self.tenants},
                   starvation_rounds=starvation_rounds)

    def generate(self) -> List[ServeRequest]:
        rng = np.random.default_rng(self.seed)
        shares = np.array([t.share for t in self.tenants], dtype=np.float64)
        shares /= shares.sum()
        who = rng.choice(len(self.tenants), size=self.n_requests, p=shares)
        gaps = rng.exponential(1.0 / self.arrival_rate, size=self.n_requests)
        arrivals = np.cumsum(gaps)
        out: List[ServeRequest] = []
        for k in range(self.n_requests):
            spec = self.tenants[int(who[k])]
            rows = rng.choice(self.n_rows, size=spec.rows_per_request,
                              p=self._popularity)
            out.append(ServeRequest(spec.name, float(arrivals[k]),
                                    np.asarray(rows, dtype=np.int64)))
        return out


def drive(
    writer,
    column: str,
    requests: Sequence[ServeRequest],
    qos: Optional[QoS] = None,
    append_table=None,
    append_every: int = 0,
    commit_every: int = 4,
) -> Tuple[ServiceResult, ServiceResult]:
    """Execute the request stream through ``writer``'s shared scheduler and
    price it under both dispatch models.

    Every take runs inside ``window.request`` (tenant + arrival tag); with
    ``append_table`` (a zero-arg callable returning a table) the ``ingest``
    tenant appends a fragment every ``append_every`` requests, committing
    every ``commit_every`` appends — so write-back flush runs land inside
    the window and share the queues with the reads, which is precisely the
    interleaving the tentpole is about.  Returns ``(interleaved, serial)``
    results over the *same* executed workload: classification, cache state
    and accounting are identical, only the dispatch timing differs."""
    sch = writer.scheduler
    n_appends = 0
    with sch.service_window(qos) as win:
        for i, req in enumerate(requests):
            with win.request(tenant=req.tenant, at=req.at,
                             request=f"{req.tenant}/{i}"):
                writer.take(column, req.rows)
            if append_table is not None and append_every \
                    and (i + 1) % append_every == 0:
                n_appends += 1
                with win.request(tenant="ingest", at=req.at,
                                 request=f"ingest/{n_appends}"):
                    writer.append(append_table(),
                                  commit=(n_appends % commit_every == 0))
        interleaved = win.run("interleaved")
        serial = win.run("serial")
    return interleaved, serial


def tenant_summary(result: ServiceResult, tenants: Sequence[str],
                   scale: float = 1e3) -> Dict[str, Dict]:
    """Per-tenant nearest-rank latency summaries (default milliseconds),
    plus the whole-population row under ``"all"``."""
    out: Dict[str, Dict] = {}
    pops = {name: [] for name in tenants}
    everything = []
    for c in result.completions:
        everything.append(c.latency * scale)
        if c.tenant in pops:
            pops[c.tenant].append(c.latency * scale)
    for name in tenants:
        summary = latency_percentiles(pops[name])
        if summary is not None:
            out[name] = summary
    out["all"] = latency_percentiles(everything)
    return out
