"""Multi-tenant Zipf serving workload over the dataset layer.

The "millions of users" shape from the ROADMAP: many concurrent takers with
Zipf-skewed row popularity (a small hot set absorbs most of the traffic,
a long cold tail keeps missing) driving ``DatasetReader.take`` through one
shared tiered store, optionally mixed with an ingest tenant whose appends
and flush runs compete for the same device queues.

This module only *generates and drives* the workload; the timing comes from
the scheduler's event-loop serving plane (:mod:`repro.store.evloop`).  The
driver executes every request inside one :class:`~repro.store.ServiceWindow`
so the same executed trace can be priced under interleaved event-loop
dispatch and under the old serial batch-drain, and per-tenant
p50/p99/p999 latency compared between the two — the serving benchmark's
headline gate.

Two arrival models (``ZipfWorkload(arrival=...)``):

* ``"open"`` (default, the seed behaviour) — a Poisson process at
  ``arrival_rate`` requests per virtual second.  Arrivals do not wait for
  responses, so queueing delay piles onto latency exactly as a loadgen
  firing on a schedule would measure it.
* ``"closed"`` — a fixed population of ``clients_per_tenant`` clients per
  tenant; each client issues its next request ``think_time`` virtual
  seconds after its previous response completes.  **Coordinated-omission
  caveat**: a closed loop *slows its own arrival process down* when the
  server degrades — queueing delay that an open-loop client would have
  measured simply never happens, because the stalled client isn't sending.
  Closed-loop percentiles therefore look flattering under saturation and
  must never be compared against open-loop ones as if they measured the
  same thing; the serve bench reports both side by side for exactly this
  contrast (see Schroeder et al., "Open Versus Closed", NSDI'06).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.slo import SLObjective
from ..store import (QoS, RetryPolicy, ServiceResult, ServiceWindow,
                     latency_percentiles)

__all__ = ["TenantSpec", "ServeRequest", "ZipfWorkload", "drive",
           "tenant_summary", "FaultScenario", "run_scenario"]


@dataclasses.dataclass
class TenantSpec:
    """One serving tenant: its share of the request stream and its QoS
    standing (weight feeds the event loop's weighted-fair round packing,
    priority its strict classes).

    ``slo_ms`` opts the tenant into SLO monitoring: "``slo_target`` of
    requests complete under ``slo_ms`` milliseconds of virtual time" —
    :meth:`ZipfWorkload.slo_objectives` lifts these into the
    :class:`~repro.obs.SLOMonitor`'s objective map.  ``None`` (default)
    means no objective, and the monitor ignores the tenant."""

    name: str
    share: float = 1.0
    weight: float = 1.0
    priority: int = 0
    rows_per_request: int = 32
    slo_ms: Optional[float] = None
    slo_target: float = 0.99


@dataclasses.dataclass
class ServeRequest:
    """One arrival: a tenant asks for ``rows`` at virtual time ``at``.

    ``client`` is set only by the closed-loop generator: requests of one
    client form a chain (each issues after the previous one's response
    plus think time), and ``at`` is only the chain's starting offset."""

    tenant: str
    at: float
    rows: np.ndarray
    client: Optional[str] = None


class ZipfWorkload:
    """Deterministic multi-tenant request generator.

    Row popularity is bounded Zipf over the global row ids: row rank k is
    drawn with probability proportional to ``1 / k**zipf_s``, so low ids
    are hot (they share fragments, so the cache's sector granularity gets
    real spatial locality) and the tail stays cold.  Arrivals are a Poisson
    process at ``arrival_rate`` requests per virtual second (``arrival=
    "open"``) or a fixed-population think-time loop (``arrival="closed"``
    — see the module docstring's coordinated-omission caveat), tenants
    drawn by their ``share``.  Everything derives from ``seed`` — two
    instances with equal parameters generate identical request streams,
    which is what lets the benchmark compare dispatch models on the same
    workload.  The open-loop stream for a given (seed, n_requests, ...) is
    bit-identical to the seed behaviour regardless of the new knobs: the
    closed-loop parameters draw nothing from the generator in open mode."""

    def __init__(self, n_rows: int, tenants: Sequence[TenantSpec],
                 n_requests: int, zipf_s: float = 1.1,
                 arrival_rate: float = 50.0, seed: int = 0,
                 arrival: str = "open", think_time: float = 0.0,
                 clients_per_tenant: int = 4):
        if n_rows <= 0 or n_requests <= 0:
            raise ValueError("n_rows and n_requests must be positive")
        if arrival not in ("open", "closed"):
            raise ValueError(f"unknown arrival model {arrival!r}")
        self.n_rows = int(n_rows)
        self.tenants = list(tenants)
        self.n_requests = int(n_requests)
        self.zipf_s = float(zipf_s)
        self.arrival_rate = float(arrival_rate)
        self.seed = int(seed)
        self.arrival = arrival
        self.think_time = float(think_time)
        self.clients_per_tenant = max(1, int(clients_per_tenant))
        ranks = np.arange(1, self.n_rows + 1, dtype=np.float64)
        p = ranks ** -self.zipf_s
        self._popularity = p / p.sum()

    def qos(self, starvation_rounds: int = 16) -> QoS:
        """The QoS knobs implied by the tenant specs."""
        return QoS(weights={t.name: t.weight for t in self.tenants},
                   priority={t.name: t.priority for t in self.tenants},
                   starvation_rounds=starvation_rounds)

    def slo_objectives(self) -> Dict[str, SLObjective]:
        """Tenant name -> :class:`SLObjective` for every tenant that set
        ``slo_ms`` (the SLO monitor's objective map)."""
        return {t.name: SLObjective(latency_s=t.slo_ms / 1e3,
                                    target=t.slo_target)
                for t in self.tenants if t.slo_ms is not None}

    def generate(self) -> List[ServeRequest]:
        rng = np.random.default_rng(self.seed)
        shares = np.array([t.share for t in self.tenants], dtype=np.float64)
        shares /= shares.sum()
        who = rng.choice(len(self.tenants), size=self.n_requests, p=shares)
        if self.arrival == "open":
            gaps = rng.exponential(1.0 / self.arrival_rate,
                                   size=self.n_requests)
            arrivals = np.cumsum(gaps)
        out: List[ServeRequest] = []
        client_rr: Dict[str, int] = {}
        for k in range(self.n_requests):
            spec = self.tenants[int(who[k])]
            rows = rng.choice(self.n_rows, size=spec.rows_per_request,
                              p=self._popularity)
            rows = np.asarray(rows, dtype=np.int64)
            if self.arrival == "open":
                out.append(ServeRequest(spec.name, float(arrivals[k]), rows))
            else:
                # closed loop: round-robin the tenant's requests over its
                # client population; the driver chains each client's
                # requests on completion + think time, so `at` is just the
                # chain origin (everything starts "now")
                i = client_rr.get(spec.name, 0)
                client_rr[spec.name] = i + 1
                client = f"{spec.name}/c{i % self.clients_per_tenant}"
                out.append(ServeRequest(spec.name, 0.0, rows, client=client))
        return out


def drive(
    writer,
    column: str,
    requests: Sequence[ServeRequest],
    qos: Optional[QoS] = None,
    append_table=None,
    append_every: int = 0,
    commit_every: int = 4,
    think_time: float = 0.0,
) -> Tuple[ServiceResult, ServiceResult, ServiceWindow]:
    """Execute the request stream through ``writer``'s shared scheduler and
    price it under both dispatch models.

    Every take runs inside ``window.request`` (tenant + arrival tag); with
    ``append_table`` (a zero-arg callable returning a table) the ``ingest``
    tenant appends a fragment every ``append_every`` requests, committing
    every ``commit_every`` appends — so write-back flush runs land inside
    the window and share the queues with the reads, which is precisely the
    interleaving the tentpole is about.  Requests carrying a ``client``
    (closed-loop streams) are chained per client with ``think_time``
    virtual seconds between a response and the next issue.

    Returns ``(interleaved, serial, window)`` over the *same* executed
    workload: classification, cache state and accounting are identical,
    only the dispatch timing differs.  The window is returned so callers
    can re-price the captured jobs with a metrics plane, an SLO monitor,
    degraded devices, or different queue depths attached
    (``window.run(...)`` is pure)."""
    sch = writer.scheduler
    n_appends = 0
    with sch.service_window(qos) as win:
        for i, req in enumerate(requests):
            with win.request(tenant=req.tenant, at=req.at,
                             request=f"{req.tenant}/{i}",
                             client=req.client, think=think_time):
                writer.take(column, req.rows)
            if append_table is not None and append_every \
                    and (i + 1) % append_every == 0:
                n_appends += 1
                with win.request(tenant="ingest", at=req.at,
                                 request=f"ingest/{n_appends}"):
                    writer.append(append_table(),
                                  commit=(n_appends % commit_every == 0))
        interleaved = win.run("interleaved")
        serial = win.run("serial")
    return interleaved, serial, win


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """One scripted chaos scenario: fault windows stamped onto named tiers
    of an already-executed :class:`~repro.store.ServiceWindow`, plus the
    recovery knobs to price it with.

    ``faults`` maps device name -> fault schedule (``TransientErrors``,
    ``Blackout``, ``Degradation``, ...); a :class:`CorrelatedFault` is the
    one-window-many-tiers convenience for the same thing.  ``retry=None``
    keeps the window's compiled-in policy (the scheduler default);
    ``RetryPolicy(failover=False)`` is the ablation that shows failover
    earning its keep."""

    name: str
    faults: Tuple[Tuple[str, object], ...] = ()
    retry: Optional[RetryPolicy] = None
    description: str = ""

    def apply(self, devices) -> List:
        """Stamp the scenario's fault windows onto a device list (returned
        re-built; the input models are immutable and shared)."""
        by_name = {}
        for name, fault in self.faults:
            by_name.setdefault(name, []).append(fault)
        unknown = set(by_name) - {d.name for d in devices}
        if unknown:
            raise ValueError(f"unknown device(s) {sorted(unknown)}")
        out = []
        for d in devices:
            for fault in by_name.get(d.name, ()):
                d = d.with_fault(fault)
            out.append(d)
        return out


def run_scenario(window: ServiceWindow, scenario: FaultScenario,
                 qos: Optional[QoS] = None, slo=None,
                 shedder=None) -> ServiceResult:
    """Re-price a captured service window under one fault scenario.

    Pure in the window (``window.run`` never mutates captured jobs), so one
    executed trace can be driven through a whole scenario script; the
    ``shedder`` carries hysteresis state across a single run — rebuild or
    ``reset()`` it per scenario."""
    devices = scenario.apply(window.scheduler._devices())
    return window.run("interleaved", qos=qos, devices=devices,
                      retry=scenario.retry, slo=slo, shedder=shedder)


def tenant_summary(result: ServiceResult, tenants: Sequence[str],
                   scale: float = 1e3) -> Dict[str, Dict]:
    """Per-tenant nearest-rank latency summaries (default milliseconds),
    plus the whole-population row under ``"all"``."""
    out: Dict[str, Dict] = {}
    pops = {name: [] for name in tenants}
    everything = []
    for c in result.completions:
        everything.append(c.latency * scale)
        if c.tenant in pops:
            pops[c.tenant].append(c.latency * scale)
    for name in tenants:
        summary = latency_percentiles(pops[name])
        if summary is not None:
            out[name] = summary
    out["all"] = latency_percentiles(everything)
    return out
