"""Paged KV cache with a Lance-style block layout.

The mini-block idea mapped to serving (DESIGN.md §2): KV entries are stored
in fixed power-of-two **blocks** (the mini-block chunk), located through a
**block table** (the search cache / repetition index).  Fetching the blocks
of a request is the full-zip gather pattern — one DMA per block, driven by
the table — implemented on device by ``kernels.fullzip_gather``.

This module is the host-side allocator + the device gather wrapper; the
batched engine in ``engine.py`` uses the dense (B, S) cache for simplicity,
while this paged variant backs the retrieval example and the serving
benchmarks (fragmentation-free growth for ragged request lengths).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops

__all__ = ["PagedKVCache"]

BLOCK = 128  # tokens per block (power of two, lane-aligned)


@dataclasses.dataclass
class _Req:
    block_ids: List[int]
    length: int


class PagedKVCache:
    """One layer's paged K or V store: (n_blocks, BLOCK, kv_features)."""

    def __init__(self, n_blocks: int, kv_features: int, dtype=jnp.bfloat16):
        self.store = jnp.zeros((n_blocks, BLOCK * kv_features), dtype)
        self.kv_features = kv_features
        self.free: List[int] = list(range(n_blocks - 1, -1, -1))
        self.reqs: Dict[int, _Req] = {}

    # -- allocation ---------------------------------------------------------
    def add_request(self, rid: int) -> None:
        self.reqs[rid] = _Req([], 0)

    def release(self, rid: int) -> None:
        self.free.extend(self.reqs.pop(rid).block_ids)

    def _ensure_capacity(self, rid: int, length: int) -> None:
        r = self.reqs[rid]
        while len(r.block_ids) * BLOCK < length:
            if not self.free:
                raise MemoryError("KV pool exhausted")
            r.block_ids.append(self.free.pop())

    # -- writes ---------------------------------------------------------------
    def append(self, rid: int, kv: np.ndarray) -> None:
        """kv: (n_new, kv_features) host array appended at the request tail."""
        r = self.reqs[rid]
        n_new = kv.shape[0]
        self._ensure_capacity(rid, r.length + n_new)
        store = np.array(self.store).reshape(-1, BLOCK, self.kv_features)
        pos = r.length
        for i in range(n_new):
            b = r.block_ids[(pos + i) // BLOCK]
            store[b, (pos + i) % BLOCK] = kv[i]
        r.length += n_new
        self.store = jnp.asarray(store.reshape(self.store.shape))

    # -- reads -------------------------------------------------------------
    def block_table(self, rid: int) -> np.ndarray:
        return np.array(self.reqs[rid].block_ids, dtype=np.int32)

    def gather(self, rid: int) -> jax.Array:
        """Fetch a request's KV as (length, kv_features) via the full-zip
        gather kernel (1 DMA per block — the paper's IOP bound)."""
        r = self.reqs[rid]
        table = jnp.asarray(self.block_table(rid))
        blocks = ops.fullzip_gather(self.store, table)  # (n_blocks, BLOCK*F)
        out = blocks.reshape(-1, self.kv_features)
        return out[: r.length]

    @property
    def utilization(self) -> float:
        used = sum(len(r.block_ids) for r in self.reqs.values())
        total = used + len(self.free)
        return used / total if total else 0.0
