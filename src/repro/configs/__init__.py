"""Assigned architecture configs (exact numbers from the task pool).

Vocab sizes not divisible by the 16-wide model axis are padded up to a
multiple of 256 (Megatron-style vocab padding) — recorded per config.
"""

from __future__ import annotations

from .base import MLACfg, ModelConfig, MoECfg, SHAPES, ShapeCfg, SSMCfg

__all__ = ["ARCHS", "get_config", "reduced_config", "SHAPES", "ModelConfig", "ShapeCfg"]


def _pad_vocab(v: int, m: int = 256) -> int:
    return ((v + m - 1) // m) * m


ARCHS = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# -- dense -------------------------------------------------------------------
# [hf:HuggingFaceTB/SmolLM-135M; hf] llama-arch small
_reg(ModelConfig(
    name="smollm-360m", family="dense", n_layers=32, d_model=960,
    n_heads=15, n_kv_heads=5, head_dim=64, d_ff=2560, vocab=49152,
    tie_embeddings=True, optimizer="adamw", remat="none",  # §Perf A4
))

# [hf:Qwen/Qwen1.5-0.5B; hf] QKV bias
_reg(ModelConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912, vocab=151936,
    qkv_bias=True, rope_theta=1e6,
))

# [arXiv:2407.10671; hf] GQA, QKV bias
_reg(ModelConfig(
    name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568, vocab=152064,
    qkv_bias=True, rope_theta=1e6, remat="dots",  # §Perf B1
))

# [hf:Qwen/Qwen1.5-0.5B; hf] QKV bias
_reg(ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, head_dim=128, d_ff=27392, vocab=152064,
    qkv_bias=True, rope_theta=1e6, remat="dots",  # §Perf B1
))

# -- ssm ----------------------------------------------------------------------
# [arXiv:2405.21060; unverified] SSD; vocab 50280 padded -> 50432 for TP
_reg(ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab=_pad_vocab(50280),
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True, subquadratic=True, remat="none",  # §Perf A4
))

# -- moe ------------------------------------------------------------------------
# [hf:xai-org/grok-1; unverified] 8 experts top-2; adafactor for state memory
_reg(ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32768),
    optimizer="adafactor", remat="dots",  # §Perf B1/C2
))

# [arXiv:2405.04434; hf] MLA kv_lora=512; 64 routed top-6 + 2 shared
# (the pool line's "160 routed" belongs to full V2 — see DESIGN.md §4)
_reg(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, d_ff_shared=1408),
    mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
))

# -- hybrid -----------------------------------------------------------------------
# [arXiv:2411.15242; unverified] Mamba2 backbone + weight-tied shared attn block
_reg(ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
    shared_attn_every=6, subquadratic=True,
))

# -- vlm --------------------------------------------------------------------------
# [hf:meta-llama/Llama-3.2-11B-Vision; unverified] cross-attn image layers
_reg(ModelConfig(
    name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672, vocab=128256,
    cross_attn_every=5, n_vision_tokens=1601, d_vision=1280, rope_theta=5e5,
    remat="dots",  # §Perf B1
))

# -- audio ------------------------------------------------------------------------
# [arXiv:2308.11596; hf] enc-dec; vocab 256206 padded -> 256256 for TP
_reg(ModelConfig(
    name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=_pad_vocab(256206),
    enc_dec=True, n_enc_layers=12, n_dec_layers=12, d_audio=80,
))


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    import dataclasses

    cfg = ARCHS[name]
    kw = dict(
        n_layers=min(cfg.n_layers, 4), d_model=128, d_ff=256, vocab=512,
        head_dim=32,
        n_heads=4 if cfg.n_heads else 0, n_kv_heads=2 if cfg.n_kv_heads else 0,
    )
    if cfg.family == "hybrid":
        kw["n_layers"] = 5
        kw["shared_attn_every"] = 2
        kw["n_heads"], kw["n_kv_heads"] = 4, 4
    if cfg.ssm is not None:
        kw["ssm"] = SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=32,
                           n_groups=1, chunk=32)
    if cfg.moe is not None:
        kw["moe"] = MoECfg(n_experts=4, top_k=2, d_ff_expert=64,
                           n_shared=cfg.moe.n_shared, d_ff_shared=64 if cfg.moe.n_shared else 0)
    if cfg.mla is not None:
        kw["mla"] = MLACfg(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
    if cfg.family == "vlm":
        kw["n_layers"] = 5
        kw["cross_attn_every"] = 5
        kw["n_vision_tokens"] = 16
        kw["d_vision"] = 32
    if cfg.family == "audio":
        kw["n_enc_layers"] = 2
        kw["n_dec_layers"] = 2
        kw["d_audio"] = 16
    return dataclasses.replace(cfg, **kw)
