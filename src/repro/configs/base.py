"""Model configuration dataclasses for the architecture zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoECfg", "MLACfg", "SSMCfg", "ModelConfig", "SHAPES", "ShapeCfg"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid: period of the shared attention block (0 = none)
    shared_attn_every: int = 0
    # vlm: every Nth layer is a gated cross-attention layer (0 = none)
    cross_attn_every: int = 0
    n_vision_tokens: int = 1601
    d_vision: int = 1280
    # audio / encoder-decoder
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    d_audio: int = 80  # stub frontend feature dim
    tie_embeddings: bool = False
    # numerics / optimizer
    dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor
    remat: str = "full"  # none | full | dots
    # long-context support marker (sub-quadratic sequence mixing)
    subquadratic: bool = False

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524_288, 1),
}
