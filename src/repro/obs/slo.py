"""Per-tenant SLO monitoring: latency objectives, rolling error budgets,
multi-window burn-rate alerts — all on the virtual clock.

The SLO model is the standard serving one.  A tenant's objective is
"fraction ``target`` of requests complete under ``latency_s``"; the
**error budget** is the allowed bad fraction ``1 - target``.  The **burn
rate** over a window is how fast that budget is being consumed::

    burn(window) = bad_fraction(window) / (1 - target)

Burn 1.0 means "exactly on budget"; burn 14 on a 99.9% objective means the
month's budget burns in ~2 days.  Single-window alerts are either slow
(long window → detection lag) or noisy (short window → one straggler
pages), so we use the multi-window form: alert only when **both** a long
and a short window exceed the threshold — the long window proves the
problem is material, the short window proves it is *still happening*
(and resets the alert promptly once the incident ends).

Everything is evaluated incrementally as completions land in the event
loop: :meth:`SLOMonitor.observe` is O(window occupancy) amortized, keeps a
per-tenant deque of ``(t, bad)`` pairs pruned to the longest window, and
emits on the *rising edge* only — one :class:`SLOAlert` per incident, an
``slo.breach.<tenant>`` counter increment, and an instant into the tracer
so the breach lands on the Perfetto timeline next to the utilization
counter tracks that explain it.  Times are virtual seconds throughout;
the monitor never touches the host clock, so alert timing is exactly
reproducible and the serve benchmark can *gate* detection latency.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from .metrics import MetricsRegistry
from .timeseries import MetricsPlane, NULL_PLANE
from .trace import NULL_TRACER

__all__ = ["SLObjective", "BurnWindow", "SLOAlert", "SLOMonitor", "Shedder",
           "DEFAULT_WINDOWS"]


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """"``target`` of requests under ``latency_s`` virtual seconds"."""

    latency_s: float
    target: float = 0.99

    def __post_init__(self):
        if self.latency_s <= 0:
            raise ValueError("latency_s must be positive")
        if not 0.0 < self.target < 1.0:
            raise ValueError("target must be in (0, 1)")

    @property
    def budget(self) -> float:
        """Allowed bad fraction (the error budget)."""
        return 1.0 - self.target


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """A long/short window pair with a shared burn-rate threshold."""

    long_s: float
    short_s: float
    burn_threshold: float = 2.0

    def __post_init__(self):
        if not 0 < self.short_s <= self.long_s:
            raise ValueError("need 0 < short_s <= long_s")
        if self.burn_threshold <= 0:
            raise ValueError("burn_threshold must be positive")


# Scaled-down analogue of the classic 1h/5m + 6h/30m page pairs: virtual
# serving runs span seconds, not hours, so windows do too.
DEFAULT_WINDOWS: Tuple[BurnWindow, ...] = (
    BurnWindow(long_s=2.0, short_s=0.25, burn_threshold=2.0),
)


@dataclasses.dataclass(frozen=True)
class SLOAlert:
    """One rising-edge burn alert (an incident start, not a sample)."""

    tenant: str
    at: float               # virtual time of the triggering completion
    window: BurnWindow
    burn_long: float
    burn_short: float


class _TenantState:
    __slots__ = ("events", "bad_total", "n_total", "active")

    def __init__(self, n_windows: int):
        # (t, bad) completions, pruned to the longest window
        self.events: Deque[Tuple[float, bool]] = deque()
        self.bad_total = 0
        self.n_total = 0
        self.active = [False] * n_windows  # per-BurnWindow rising-edge latch


class SLOMonitor:
    """Evaluates burn-rate objectives as completions land.

    ``objectives`` maps tenant name -> :class:`SLObjective`; tenants
    without an objective are ignored (observe is a cheap no-op for them).
    Counters land in ``registry`` (``slo.requests.<t>``, ``slo.bad.<t>``,
    ``slo.breach.<t>``), burn gauges in ``plane``
    (``slo.<t>.burn.<long_s>s``), alert instants in ``tracer``.
    """

    def __init__(self, objectives: Dict[str, SLObjective],
                 windows: Tuple[BurnWindow, ...] = DEFAULT_WINDOWS,
                 tracer=NULL_TRACER, registry: Optional[MetricsRegistry] = None,
                 plane: MetricsPlane = NULL_PLANE):
        if not windows:
            raise ValueError("need at least one BurnWindow")
        self.objectives = dict(objectives)
        self.windows = tuple(windows)
        self.tracer = tracer
        self.registry = registry if registry is not None else MetricsRegistry()
        self.plane = plane
        self.alerts: List[SLOAlert] = []
        self._horizon = max(w.long_s for w in self.windows)
        self._tenants: Dict[str, _TenantState] = {}

    # -- core ----------------------------------------------------------------
    def observe(self, tenant: str, t: float, latency: float,
                error: bool = False) -> None:
        """Record one completion at virtual time ``t`` and re-evaluate the
        tenant's burn windows.  ``error=True`` marks a *failed* request
        (retries exhausted, no failover target): it consumes error budget
        unconditionally, whatever its latency — a fast failure is still a
        failure."""
        obj = self.objectives.get(tenant)
        if obj is None:
            return
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantState(len(self.windows))
        bad = error or latency > obj.latency_s
        st.events.append((t, bad))
        st.n_total += 1
        self.registry.counter(f"slo.requests.{tenant}").inc()
        if error:
            self.registry.counter(f"slo.errors.{tenant}").inc()
        if bad:
            st.bad_total += 1
            self.registry.counter(f"slo.bad.{tenant}").inc()
        # prune to the longest window (events arrive in completion order,
        # which the event loop emits with non-decreasing t)
        floor = t - self._horizon
        ev = st.events
        while ev and ev[0][0] < floor:
            ev.popleft()

        for wi, w in enumerate(self.windows):
            burn_long = self._burn(st, t, w.long_s, obj)
            burn_short = self._burn(st, t, w.short_s, obj)
            self.plane.sample(f"slo.{tenant}.burn.{w.long_s:g}s", t, burn_long)
            firing = (burn_long >= w.burn_threshold
                      and burn_short >= w.burn_threshold)
            if firing and not st.active[wi]:
                st.active[wi] = True  # rising edge: one alert per incident
                alert = SLOAlert(tenant=tenant, at=t, window=w,
                                 burn_long=burn_long, burn_short=burn_short)
                self.alerts.append(alert)
                self.registry.counter(f"slo.breach.{tenant}").inc()
                self.tracer.instant(
                    f"slo_breach:{tenant}", cat="slo",
                    args={"tenant": tenant, "t_virtual": t,
                          "burn_long": burn_long, "burn_short": burn_short,
                          "window_s": w.long_s,
                          "threshold": w.burn_threshold})
            elif not firing:
                st.active[wi] = False
        return None

    def _burn(self, st: _TenantState, t: float, window_s: float,
              obj: SLObjective) -> float:
        lo = t - window_s
        n = bad = 0
        # events is pruned to the longest window; scan newest-first and
        # stop at the window edge so short windows cost their occupancy
        for et, ebad in reversed(st.events):
            if et < lo:
                break
            n += 1
            bad += ebad
        if n == 0:
            return 0.0
        return (bad / n) / obj.budget

    # -- queries -------------------------------------------------------------
    def current_burn(self, tenant: str, t: float,
                     window_s: Optional[float] = None) -> float:
        """The tenant's burn rate over the trailing ``window_s`` seconds
        ending at virtual time ``t`` (default: the first configured long
        window).  0.0 for unknown tenants or empty windows — the query a
        :class:`Shedder` polls at admission time."""
        obj = self.objectives.get(tenant)
        st = self._tenants.get(tenant)
        if obj is None or st is None:
            return 0.0
        if window_s is None:
            window_s = self.windows[0].long_s
        return self._burn(st, t, float(window_s), obj)

    def first_alert(self, tenant: str) -> Optional[SLOAlert]:
        for a in self.alerts:
            if a.tenant == tenant:
                return a
        return None

    def breach_counts(self) -> Dict[str, int]:
        return self.registry.counter_values("slo.breach.")

    def table(self) -> List[Dict]:
        """Per-tenant summary rows (the obs_report SLO table)."""
        rows = []
        for tenant in sorted(self.objectives):
            obj = self.objectives[tenant]
            st = self._tenants.get(tenant)
            n = st.n_total if st else 0
            bad = st.bad_total if st else 0
            first = self.first_alert(tenant)
            rows.append({
                "tenant": tenant,
                "objective_ms": obj.latency_s * 1e3,
                "target": obj.target,
                "requests": n,
                "bad": bad,
                "bad_fraction": (bad / n) if n else None,
                "budget": obj.budget,
                "breaches": self.registry.counter(
                    f"slo.breach.{tenant}").value,
                "first_alert_t": first.at if first else None,
            })
        return rows


class Shedder:
    """SLO-driven load shedding with hysteresis.

    Watches the *protected* tenants' multi-window burn through a
    :class:`SLOMonitor` and, while any of them is burning budget faster
    than ``on_burn`` on **both** the long and short window (the same
    both-windows rule the alerts use: the long window proves the problem
    is material, the short one that it is still happening), rejects
    incoming requests from the ``shed`` tenants.  Shedding stays engaged
    until the worst protected burn falls below ``off_burn`` — the
    hysteresis band keeps the policy from flapping at the threshold as
    shed load itself relieves the burn.

    The event loop calls :meth:`admit` once per job arrival (on the
    virtual clock, before the job consumes any queue slot); a rejected
    job completes immediately with ``error="shed"`` and is *not* fed to
    the SLO monitor — rejections are the policy's output, not evidence
    about the protected tenants' service.  Stateful across one run: call
    :meth:`reset` (or build a fresh instance) before re-running a window
    so repeated runs stay pure.
    """

    def __init__(self, monitor: SLOMonitor, protect, shed,
                 on_burn: float = 4.0, off_burn: float = 1.0,
                 hold_s: float = 0.0,
                 window: Optional[BurnWindow] = None):
        if on_burn <= off_burn:
            raise ValueError("need on_burn > off_burn (hysteresis band)")
        if hold_s < 0:
            raise ValueError("hold_s must be >= 0")
        self.monitor = monitor
        self.protect = tuple(protect)
        self.shed = frozenset(shed)
        if self.shed & set(self.protect):
            raise ValueError("a tenant cannot be both protected and shed")
        self.on_burn = float(on_burn)
        self.off_burn = float(off_burn)
        # hold-down: release only after the burn has stayed below off_burn
        # for hold_s seconds.  The level band alone cannot prevent limit
        # cycling — successful shedding drives the burn to zero while the
        # underlying fault persists, so a pure level release re-admits the
        # flood and re-trips; the timer makes the controller wait out the
        # dip before trusting it.
        self.hold_s = float(hold_s)
        self.window = window if window is not None else monitor.windows[0]
        self.active = False
        self.trips = 0          # rising edges (shedding engagements)
        self.engaged_at: List[float] = []
        self.released_at: List[float] = []
        self._below_since: Optional[float] = None

    def reset(self) -> None:
        """Forget the hysteresis state (for pure re-runs)."""
        self.active = False
        self.trips = 0
        self.engaged_at = []
        self.released_at = []
        self._below_since = None

    def _worst_burn(self, t: float) -> float:
        w = self.window
        worst = 0.0
        for tenant in self.protect:
            # both-windows firing burn: min(long, short) >= threshold
            # iff both exceed it
            b = min(self.monitor.current_burn(tenant, t, w.long_s),
                    self.monitor.current_burn(tenant, t, w.short_s))
            if b > worst:
                worst = b
        return worst

    def admit(self, tenant: str, t: float) -> bool:
        """Admission decision for one arrival at virtual time ``t``;
        updates the hysteresis state machine as a side effect."""
        burn = self._worst_burn(t)
        if self.active:
            if burn < self.off_burn:
                if self._below_since is None:
                    self._below_since = t
                if t - self._below_since >= self.hold_s:
                    self.active = False
                    self._below_since = None
                    self.released_at.append(t)
            else:
                self._below_since = None
        elif burn >= self.on_burn:
            self.active = True
            self._below_since = None
            self.trips += 1
            self.engaged_at.append(t)
        return not (self.active and tenant in self.shed)
