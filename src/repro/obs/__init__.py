"""Observability for the tiered IO stack: tracing, metrics, attribution.

Three pieces, layered below everything else in the package (no ``repro``
imports at module level, so any layer can depend on ``obs``):

* :mod:`repro.obs.trace` — span :class:`Tracer` with a Chrome/Perfetto
  trace-event exporter; zero-cost no-op when disabled (:data:`NULL_TRACER`).
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of counters and
  histograms queryable from tests and the bench harness.
* :mod:`repro.obs.attrib` — :func:`attribute` decomposes each tier's
  ``model_time`` onto the logical requests that occupied each queue drain,
  yielding per-request modeled latencies and p50/p99/p999 summaries.
"""

from .attrib import Attribution, DrainCost, attribute
from .metrics import Counter, Histogram, MetricsRegistry, percentile
from .trace import NULL_SPAN, NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Attribution",
    "Counter",
    "DrainCost",
    "Histogram",
    "MetricsRegistry",
    "NULL_SPAN",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "attribute",
    "percentile",
]
