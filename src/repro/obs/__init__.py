"""Observability for the tiered IO stack: tracing, metrics, attribution.

Three pieces, layered below everything else in the package (no ``repro``
imports at module level, so any layer can depend on ``obs``):

* :mod:`repro.obs.trace` — span :class:`Tracer` with a Chrome/Perfetto
  trace-event exporter; zero-cost no-op when disabled (:data:`NULL_TRACER`).
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of counters and
  histograms queryable from tests and the bench harness.
* :mod:`repro.obs.attrib` — :func:`attribute` decomposes each tier's
  ``model_time`` onto the logical requests that occupied each queue drain,
  yielding per-request modeled latencies and p50/p99/p999 summaries.
* :mod:`repro.obs.timeseries` — the live plane: mergeable log-bucket
  histograms, virtual-clock gauge series, and the :class:`MetricsPlane`
  container (zero-cost when disabled: :data:`NULL_PLANE`).
* :mod:`repro.obs.slo` — per-tenant latency objectives and rolling
  multi-window error-budget burn-rate alerts (:class:`SLOMonitor`), plus
  the burn-driven admission :class:`Shedder` the event loop consults.
"""

from .attrib import Attribution, DrainCost, attribute
from .metrics import (Counter, Histogram, MetricsRegistry, percentile,
                      prometheus_text)
from .slo import (DEFAULT_WINDOWS, BurnWindow, Shedder, SLOAlert,
                  SLObjective, SLOMonitor)
from .timeseries import (NULL_PLANE, GaugeSeries, LogBucketHistogram,
                         MetricsPlane, WindowedHistogram)
from .trace import NULL_SPAN, NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Attribution",
    "BurnWindow",
    "Counter",
    "DEFAULT_WINDOWS",
    "DrainCost",
    "GaugeSeries",
    "Histogram",
    "LogBucketHistogram",
    "MetricsPlane",
    "MetricsRegistry",
    "NULL_PLANE",
    "NULL_SPAN",
    "NULL_TRACER",
    "NullTracer",
    "SLOAlert",
    "SLObjective",
    "SLOMonitor",
    "Shedder",
    "Tracer",
    "WindowedHistogram",
    "attribute",
    "percentile",
    "prometheus_text",
]
