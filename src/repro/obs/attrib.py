"""Per-request latency attribution over the tiered store's priced IO model.

``TierStats.model_time`` prices a tier's whole dispatched trace as one
number: a throughput-limited term plus one queue-drain latency term per
(batch, phase).  That is the right contract for end-to-end totals, but the
serving story (ROADMAP: tail-latency p999) needs the inverse mapping — *which
logical requests occupied each drain, and what did that drain cost them*.

This module computes that decomposition from the store's **drain log**: the
:class:`~repro.store.TieredStore` records, at every ``end_batch``, which
per-(tier, phase) op/byte buckets the batch drained, plus the batch label and
how many logical requests (rows of a ``take``) the batch carried.  Given the
log, :func:`attribute` rebuilds each tier's cost with *identical arithmetic*
to ``model_time`` and splits it per drain:

* the **throughput term** ``max(ops / iops_limit, bytes / seq_bw)`` is a
  property of the whole trace (``iops_limit`` depends on the global average
  op size), so it is distributed across drains proportionally to each
  drain's dispatched bytes on that tier (ops when the tier moved no bytes);
* the **latency terms** ``ceil(ops / qd) * dev.latency`` are already
  per-(drain, phase) and are assigned where they arose.

The invariant (tested at 1e-9 relative): for every tier, the attributed
drain costs sum to exactly that tier's ``model_time``.  The proportional
split uses a remainder assignment on the last occupied drain so the sum is
exact in floating point, not just close.

A drain's cost divided by its request count is the modeled per-request
latency; drains that carried no counted requests (scans, flushes, open
buckets) count as one implicit request so nothing priced ever goes
unattributed.  :meth:`Attribution.percentiles` turns the resulting
per-request population into the p50/p99/p999 summary the benchmarks report.

Deliberately import-free of ``repro.store``: the store object is duck-typed
(``levels``/``backing``/``backing_stats``/``drain_log``), keeping ``obs``
below every other layer in the import graph.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from .metrics import percentile

__all__ = ["DrainCost", "Attribution", "attribute"]


@dataclasses.dataclass
class DrainCost:
    """One queue drain's attributed cost, split per tier.

    ``tier_costs`` is keyed by tier index (fastest level first, backing
    device last — the same order as ``TieredStore.tier_stats()``).
    """

    label: str
    n_requests: int
    tier_costs: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def total(self) -> float:
        return sum(self.tier_costs.values())

    @property
    def effective_requests(self) -> int:
        """Drains that carried no counted requests (scans, flushes, open
        buckets) are one implicit request — cost is never dropped."""
        return self.n_requests if self.n_requests > 0 else 1

    @property
    def per_request(self) -> float:
        return self.total / self.effective_requests


@dataclasses.dataclass
class Attribution:
    """The full decomposition: one :class:`DrainCost` per logged drain."""

    tier_names: List[str]
    drains: List[DrainCost]

    def tier_sums(self) -> Dict[str, float]:
        """Per-tier attributed totals; equals each tier's ``model_time``."""
        sums = {name: 0.0 for name in self.tier_names}
        for d in self.drains:
            for idx, cost in d.tier_costs.items():
                sums[self.tier_names[idx]] += cost
        return sums

    @property
    def total(self) -> float:
        return sum(d.total for d in self.drains)

    def per_request_latencies(
        self, label_prefix: Optional[str] = None
    ) -> List[float]:
        """One modeled latency per logical request: each drain's cost spread
        uniformly over the requests it carried.  ``label_prefix`` restricts
        to matching drains (e.g. ``"take"``) — the percentiles then describe
        just that request class."""
        out: List[float] = []
        for d in self.drains:
            if label_prefix is not None and not d.label.startswith(label_prefix):
                continue
            out.extend([d.per_request] * d.effective_requests)
        return out

    def percentiles(
        self, label_prefix: Optional[str] = None
    ) -> Optional[Dict[str, float]]:
        """p50/p99/p999 summary of the per-request population, or ``None``
        when no drain matched (never NaN — these land in JSON artifacts)."""
        lats = self.per_request_latencies(label_prefix)
        if not lats:
            return None
        return {
            "count": len(lats),
            "mean": sum(lats) / len(lats),
            "p50": percentile(lats, 50),
            "p99": percentile(lats, 99),
            "p999": percentile(lats, 99.9),
            "max": percentile(lats, 100),
        }


def attribute(store, queue_depth: int = 256) -> Attribution:
    """Decompose every tier's ``model_time`` onto the store's drain log.

    ``store`` is duck-typed: needs ``levels`` (each with ``.stats`` and
    ``.device``), ``backing``/``backing_stats``, and ``drain_log`` (records
    with ``.label``/``.n_requests``/``.tiers``).  Open (not yet drained)
    phase buckets are attributed to a virtual ``"(open)"`` drain so the
    per-tier sums match ``model_time`` even mid-batch.
    """
    tiers = [(lvl.stats, lvl.device) for lvl in store.levels]
    tiers.append((store.backing_stats, store.backing))
    names = [s.name for s, _ in tiers]

    records = list(store.drain_log)
    open_buckets: Dict[int, tuple] = {}
    for idx, (s, _) in enumerate(tiers):
        if s.phase_ops:
            open_buckets[idx] = (dict(s.phase_ops), dict(s.phase_bytes))
    if open_buckets:
        records.append(_OpenDrain(open_buckets))

    drains = [DrainCost(r.label, r.n_requests) for r in records]
    qd = max(1, queue_depth)

    for idx, (s, dev) in enumerate(tiers):
        total_ops = s.n_iops + s.write_iops
        if total_ops == 0:
            continue
        # throughput term: identical arithmetic to TierStats.model_time
        total_bytes = s.bytes_read + s.bytes_written
        avg = max(total_bytes / total_ops, 1.0)
        eff = max(avg, dev.min_read)
        iops_limit = min(dev.iops_4k, dev.seq_bw / eff)
        t_tp = max(total_ops / iops_limit, total_bytes / dev.seq_bw)

        # split weight: dispatched bytes per drain on this tier (ops if the
        # tier somehow moved no bytes)
        weights: List[float] = []
        for r in records:
            buckets = r.tiers.get(idx)
            if buckets is None:
                weights.append(0.0)
            elif total_bytes:
                weights.append(float(sum(buckets[1].values())))
            else:
                weights.append(float(sum(buckets[0].values())))
        wsum = sum(weights)
        last_occupied = max(
            (i for i, w in enumerate(weights) if w > 0), default=None
        )

        assigned = 0.0
        for i, r in enumerate(records):
            cost = 0.0
            buckets = r.tiers.get(idx)
            if buckets is not None:
                for ops in buckets[0].values():
                    cost += math.ceil(ops / qd) * dev.latency
            if wsum > 0 and weights[i] > 0:
                if i == last_occupied:
                    # remainder assignment: the tier sum equals t_tp exactly
                    share = t_tp - assigned
                else:
                    share = t_tp * (weights[i] / wsum)
                    assigned += share
                cost += share
            if cost:
                drains[i].tier_costs[idx] = cost
        if last_occupied is None and t_tp:
            # priced ops with no logged drain (shouldn't happen through the
            # scheduler; defensive for hand-driven stores)
            drains.append(DrainCost("(unattributed)", 0, {idx: t_tp}))

    return Attribution(tier_names=names, drains=drains)


class _OpenDrain:
    """Virtual drain record for phase buckets not yet archived."""

    __slots__ = ("label", "n_requests", "tiers")

    def __init__(self, tiers: Dict[int, tuple]):
        self.label = "(open)"
        self.n_requests = 0
        self.tiers = tiers
