"""Live metrics plane: mergeable log-bucket histograms and virtual-clock
gauge time series.

This module is the *continuous* half of the observability layer.  The raw
:class:`~repro.obs.metrics.Histogram` keeps every sample — exact, but it
cannot window (dropping old samples means rescanning) and merging two of
them concatenates sample lists.  Serving telemetry needs the opposite
trade: bounded memory per stream, exact merge across tenants and windows,
and quantiles good to a *configured* relative error.  That is the
log-bucket histogram (the DDSketch construction):

* bucket ``i`` covers ``(gamma^(i-1), gamma^i]`` with
  ``gamma = (1 + rel_err) / (1 - rel_err)``, so reporting the bucket's
  geometric midpoint ``2 * gamma^i / (gamma + 1)`` is within ``rel_err``
  relative of any sample in the bucket;
* storage is one count per *occupied* bucket (O(log(max/min) / rel_err)
  worst case, O(1) per observe);
* merge is bucket-wise addition — exact, associative, commutative — which
  is what lets per-window and per-tenant histograms roll up without
  re-observing anything.

:class:`WindowedHistogram` rotates a ring of log-bucket histograms on the
**virtual clock** (the event loop's simulated seconds, not host time): each
window covers ``window`` virtual seconds, the live horizon is ``n_windows``
of them, and rotation never loses counts — an expired window's population
moves to the ``dropped`` tally and stays visible in the cumulative
``total`` histogram (invariant: ``total.count == dropped + live counts``).

:class:`GaugeSeries` is the plain time-series half: ``(t, value)`` samples
appended at event-loop round/completion boundaries — per-tier utilization,
outstanding-queue occupancy, in-flight jobs — and at batch close for the
store-side gauges (cache hit rate, dirty bytes, admission state).

:class:`MetricsPlane` bundles the three (series + windowed latency
histograms + a :class:`~repro.obs.metrics.MetricsRegistry` for counters)
behind the same zero-cost contract as the tracer: the disabled plane (the
:data:`NULL_PLANE` singleton) allocates nothing — ``sample()`` returns
before creating anything — and an *enabled* plane is purely observational:
priced times and logical IOPS are bit-identical with sampling on or off
(tested).  Exporters: Perfetto counter tracks (``"C"`` events on the
virtual clock) into a :class:`~repro.obs.trace.Tracer`, a Prometheus text
dump, and a JSON form the bench artifacts embed for
``tools/obs_report.py``'s terminal dashboard.

Like the rest of ``repro.obs`` this module imports nothing from the wider
package (``metrics`` only), so every layer above can depend on it.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .metrics import MetricsRegistry, prometheus_text

__all__ = ["LogBucketHistogram", "WindowedHistogram", "GaugeSeries",
           "MetricsPlane", "NULL_PLANE"]


class LogBucketHistogram:
    """Bounded-relative-error quantile sketch with exact merge.

    ``rel_err`` is the quantile accuracy guarantee: for any q,
    ``quantile(q)`` is within ``rel_err`` *relative* of the exact
    nearest-rank value over the observed samples (zeros are tracked exactly
    in their own bucket; negative values are rejected — these are latency /
    occupancy populations).  ``min``/``max``/``sum`` are tracked exactly, so
    ``mean`` and the extreme quantiles carry no bucket error.
    """

    __slots__ = ("rel_err", "gamma", "_lg", "buckets", "zero_count",
                 "count", "sum", "min", "max")

    def __init__(self, rel_err: float = 0.01):
        if not 0.0 < rel_err < 1.0:
            raise ValueError("rel_err must be in (0, 1)")
        self.rel_err = float(rel_err)
        self.gamma = (1.0 + self.rel_err) / (1.0 - self.rel_err)
        self._lg = math.log(self.gamma)
        self.buckets: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- observe / merge -----------------------------------------------------
    def observe(self, value: float, n: int = 1) -> None:
        value = float(value)
        if value < 0.0:
            raise ValueError("log-bucket histogram takes non-negative samples")
        if n <= 0:
            return
        self.count += n
        self.sum += value * n
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        if value == 0.0:
            self.zero_count += n
            return
        i = math.ceil(math.log(value) / self._lg)
        self.buckets[i] = self.buckets.get(i, 0) + n

    def merge(self, other: "LogBucketHistogram") -> "LogBucketHistogram":
        """Fold ``other`` into this histogram (exact: the result is
        indistinguishable from having observed both populations here).
        Requires equal ``rel_err`` — bucket boundaries must line up."""
        if other.rel_err != self.rel_err:
            raise ValueError("cannot merge histograms with different rel_err")
        for i, n in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + n
        self.zero_count += other.zero_count
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def copy(self) -> "LogBucketHistogram":
        h = LogBucketHistogram(self.rel_err)
        h.buckets = dict(self.buckets)
        h.zero_count = self.zero_count
        h.count = self.count
        h.sum = self.sum
        h.min = self.min
        h.max = self.max
        return h

    # -- quantiles -----------------------------------------------------------
    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def _rep(self, i: int) -> float:
        """Bucket representative: the geometric midpoint of
        ``(gamma^(i-1), gamma^i]`` — max relative error ``rel_err``."""
        return 2.0 * self.gamma ** i / (self.gamma + 1.0)

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile (``q`` in [0, 100]) to within ``rel_err``
        relative; raises on an empty histogram (same contract as
        :func:`repro.obs.metrics.percentile`)."""
        if self.count == 0:
            raise ValueError("quantile of empty histogram")
        if q <= 0:
            return self.min
        if q >= 100:
            return self.max
        rank = math.ceil(q / 100.0 * self.count)
        seen = self.zero_count
        if rank <= seen:
            return 0.0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if rank <= seen:
                # clamp into the exactly-tracked extremes: the top bucket's
                # midpoint may overshoot max (and the bottom undershoot min)
                return min(max(self._rep(i), self.min), self.max)
        return self.max  # pragma: no cover - counts always telescope

    def summary(self) -> Dict[str, Optional[float]]:
        """Same shape as ``Histogram.summary`` (``None`` fields when empty,
        never NaN)."""
        if self.count == 0:
            return {"count": 0, "mean": None, "p50": None, "p99": None,
                    "p999": None, "max": None}
        return {"count": self.count, "mean": self.mean,
                "p50": self.quantile(50), "p99": self.quantile(99),
                "p999": self.quantile(99.9), "max": self.max}

    def bucket_bounds(self) -> List[Tuple[float, int]]:
        """Sorted ``(upper_bound, count)`` pairs (zeros under bound 0.0) —
        the cumulative-bucket form the Prometheus exporter renders as
        ``_bucket{le=...}`` samples."""
        out: List[Tuple[float, int]] = []
        if self.zero_count:
            out.append((0.0, self.zero_count))
        for i in sorted(self.buckets):
            out.append((self.gamma ** i, self.buckets[i]))
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LogBucketHistogram(n={self.count}, "
                f"buckets={len(self.buckets)}, rel_err={self.rel_err})")


class WindowedHistogram:
    """A ring of log-bucket histograms rotating on the virtual clock.

    ``observe(t, v)`` lands ``v`` in the window covering virtual time ``t``
    (window ``w`` covers ``[w * window, (w + 1) * window)`` seconds); the
    live horizon is the most recent ``n_windows`` windows.  Rotation is
    lazy and **never loses counts**: a window that ages out of the horizon
    adds its population to ``dropped``, and the cumulative ``total``
    histogram observes everything forever — the tested invariant is
    ``total.count == dropped + sum(live window counts)``.  ``merged()``
    folds the live windows into one histogram (exact, by construction), so
    windowed quantiles carry the same ``rel_err`` bound as the buckets.
    """

    __slots__ = ("window", "n_windows", "rel_err", "total", "dropped",
                 "_ring", "_last_wid")

    def __init__(self, window: float = 1.0, n_windows: int = 8,
                 rel_err: float = 0.01):
        if window <= 0 or n_windows <= 0:
            raise ValueError("window and n_windows must be positive")
        self.window = float(window)
        self.n_windows = int(n_windows)
        self.rel_err = float(rel_err)
        self.total = LogBucketHistogram(rel_err)
        self.dropped = 0
        # ring slot -> (window id, histogram); lazily (re)populated
        self._ring: List[Optional[Tuple[int, LogBucketHistogram]]] = (
            [None] * self.n_windows)
        self._last_wid = -1

    def _wid(self, t: float) -> int:
        return max(int(t // self.window), 0)

    def observe(self, t: float, value: float) -> None:
        wid = self._wid(t)
        self._last_wid = max(self._last_wid, wid)
        if wid <= self._last_wid - self.n_windows:
            # a straggler older than the whole horizon: counted (total),
            # but it has no live window to land in
            self.total.observe(value)
            self.dropped += 1
            return
        slot = wid % self.n_windows
        cur = self._ring[slot]
        if cur is None or cur[0] != wid:
            if cur is not None and cur[0] < wid:
                self.dropped += cur[1].count  # rotation: counts move, not die
            self._ring[slot] = cur = (wid, LogBucketHistogram(self.rel_err))
        cur[1].observe(value)
        self.total.observe(value)

    def _live(self) -> List[LogBucketHistogram]:
        """Live-horizon histograms, expiring stale slots (a jump of more
        than ``n_windows`` windows can leave slots the rotation never
        touched)."""
        out: List[LogBucketHistogram] = []
        floor = self._last_wid - self.n_windows
        for slot, cur in enumerate(self._ring):
            if cur is None:
                continue
            if cur[0] <= floor:
                self.dropped += cur[1].count
                self._ring[slot] = None
            else:
                out.append(cur[1])
        return out

    @property
    def live_count(self) -> int:
        return sum(h.count for h in self._live())

    def merged(self) -> LogBucketHistogram:
        """One histogram over the live horizon (exact bucket-wise merge)."""
        out = LogBucketHistogram(self.rel_err)
        for h in self._live():
            out.merge(h)
        return out

    def quantile(self, q: float) -> float:
        return self.merged().quantile(q)

    def summary(self) -> Dict[str, Optional[float]]:
        s = self.merged().summary()
        s["window_s"] = self.window * self.n_windows
        s["lifetime_count"] = self.total.count
        return s


class GaugeSeries:
    """One gauge sampled on the virtual clock: parallel ``(t, value)``
    arrays, append-only.  Memory is one float pair per sample — bounded by
    the run length, and the exporter downsamples, never the collector."""

    __slots__ = ("name", "ts", "vs")

    def __init__(self, name: str):
        self.name = name
        self.ts: List[float] = []
        self.vs: List[float] = []

    def sample(self, t: float, value: float) -> None:
        self.ts.append(float(t))
        self.vs.append(float(value))

    def __len__(self) -> int:
        return len(self.ts)

    def last(self) -> Optional[float]:
        return self.vs[-1] if self.vs else None

    def between(self, t0: float, t1: float) -> List[float]:
        """Values sampled in ``[t0, t1)``."""
        return [v for t, v in zip(self.ts, self.vs) if t0 <= t < t1]

    def export(self, max_points: int = 0) -> Dict:
        """JSON-safe form; ``max_points`` > 0 downsamples with a
        deterministic stride (first-of-every-k plus the final sample) so
        artifacts stay diffable and bounded."""
        ts, vs = self.ts, self.vs
        n = len(ts)
        if max_points and n > max_points:
            step = -(-n // max_points)  # ceil
            idx = list(range(0, n, step))
            if idx[-1] != n - 1:
                idx.append(n - 1)
            ts = [ts[i] for i in idx]
            vs = [vs[i] for i in idx]
        return {"t": [round(t, 9) for t in ts],
                "v": [round(v, 9) for v in vs],
                "n_samples": n}


class MetricsPlane:
    """The live plane: gauge series + windowed latency histograms + a
    counter registry, all on the virtual clock.

    Zero-cost contract (mirrors the tracer): the disabled plane is the
    shared :data:`NULL_PLANE` singleton; every collection method returns
    before allocating, so instrumented code needs no ``if``.  An enabled
    plane is purely observational — it reads simulation state, it never
    steers it (priced times and logical IOPS/bytes are bit-identical with
    sampling on, tested).
    """

    def __init__(self, enabled: bool = True, window: float = 1.0,
                 n_windows: int = 8, rel_err: float = 0.01):
        self.enabled = bool(enabled)
        self.window = float(window)
        self.n_windows = int(n_windows)
        self.rel_err = float(rel_err)
        self.series: Dict[str, GaugeSeries] = {}
        self.latency: Dict[str, WindowedHistogram] = {}
        self.registry = MetricsRegistry()

    # -- collection ----------------------------------------------------------
    def gauge(self, name: str) -> GaugeSeries:
        g = self.series.get(name)
        if g is None:
            g = self.series[name] = GaugeSeries(name)
        return g

    def sample(self, name: str, t: float, value: float) -> None:
        """One gauge sample at virtual time ``t``; no-op when disabled."""
        if not self.enabled:
            return
        self.gauge(name).sample(t, value)

    def observe_latency(self, name: str, t: float, value: float) -> None:
        """One latency observation into the named windowed histogram."""
        if not self.enabled:
            return
        h = self.latency.get(name)
        if h is None:
            h = self.latency[name] = WindowedHistogram(
                self.window, self.n_windows, self.rel_err)
        h.observe(t, value)

    def counter(self, name: str):
        return self.registry.counter(name)

    # -- exporters -----------------------------------------------------------
    def to_trace(self, tracer, scale: float = 1e6) -> int:
        """Emit every gauge series as Perfetto counter-track (``"C"``)
        events into ``tracer``, timestamped on the *virtual* clock
        (``t * scale`` microseconds).  Returns the number of events."""
        n = 0
        for name in sorted(self.series):
            g = self.series[name]
            for t, v in zip(g.ts, g.vs):
                tracer.counter(name, {"value": v}, ts=t * scale)
                n += 1
        return n

    def prometheus_text(self) -> str:
        """Prometheus text exposition: registry counters/histograms, plus
        each gauge's last value and each windowed latency histogram as a
        cumulative-bucket ``histogram`` family."""
        from .metrics import _prom_name
        out = [prometheus_text(self.registry)]
        for name in sorted(self.series):
            g = self.series[name]
            if not g.vs:
                continue
            pn = _prom_name(name)
            out.append(f"# TYPE {pn} gauge\n{pn} {g.vs[-1]!r}\n")
        for name in sorted(self.latency):
            h = self.latency[name].merged()
            pn = _prom_name(name)
            lines = [f"# TYPE {pn} histogram"]
            cum = 0
            for ub, cnt in h.bucket_bounds():
                cum += cnt
                lines.append(f'{pn}_bucket{{le="{ub!r}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{pn}_sum {h.sum!r}")
            lines.append(f"{pn}_count {h.count}")
            out.append("\n".join(lines) + "\n")
        return "".join(out)

    def export(self, max_points: int = 256) -> Dict:
        """The JSON form embedded in bench artifacts (NaN-free by
        construction) and rendered by ``tools/obs_report.py``."""
        return {
            "series": {name: g.export(max_points)
                       for name, g in sorted(self.series.items())},
            "latency": {name: h.summary()
                        for name, h in sorted(self.latency.items())},
            "counters": self.registry.counter_values(),
        }


NULL_PLANE = MetricsPlane(enabled=False)
