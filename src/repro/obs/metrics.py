"""Counters and histograms queryable from tests and the bench harness.

A :class:`MetricsRegistry` is the aggregate companion to the event-stream
tracer: where the trace answers "what happened when", the registry answers
"how many / how distributed" without parsing the event list.  Counters are
monotonic ints (pallas-fallback reasons, admission flips, flush drains);
histograms collect raw float samples and report quantiles by exact
nearest-rank selection — at the sample counts we deal in (10^2..10^5
per-request latencies) there is no reason to approximate.

Everything here is plain Python with no repro imports, so the obs package
sits below every other layer and can never participate in an import cycle.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

__all__ = ["Counter", "Histogram", "MetricsRegistry", "percentile",
           "prometheus_text"]


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (``q`` in [0, 100]) over raw samples.

    Exact, deterministic, and matches what a serving dashboard means by
    "p99": the smallest sample ≥ the given fraction of the population.
    Raises on an empty sequence — callers decide how to render "no data"
    (the bench harness emits ``null``, never NaN).
    """
    if not samples:
        raise ValueError("percentile of empty sample set")
    xs = sorted(samples)
    if q <= 0:
        return xs[0]
    if q >= 100:
        return xs[-1]
    rank = math.ceil(q / 100.0 * len(xs))
    return xs[rank - 1]


class Counter:
    """A monotonic integer counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter({self.name}={self.value})"


class Histogram:
    """Raw-sample histogram with exact quantiles."""

    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = name
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    def observe_many(self, values: Sequence[float]) -> None:
        self.samples.extend(float(v) for v in values)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def total(self) -> float:
        return sum(self.samples)

    @property
    def mean(self) -> float:
        return self.total / len(self.samples) if self.samples else 0.0

    def quantile(self, q: float) -> float:
        return percentile(self.samples, q)

    def summary(self) -> Dict[str, Optional[float]]:
        """The fixed percentile set the benchmarks report.

        An empty histogram reports ``count: 0`` with every statistic
        ``None`` — never NaN and never a raise (``percentile`` raises on an
        empty sample set by design, but a *summary* of "no data yet" is a
        well-defined answer, and ``None`` is what the NaN-free bench policy
        serializes as ``null``)."""
        if not self.samples:
            return {"count": 0, "mean": None, "p50": None, "p99": None,
                    "p999": None, "max": None}
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(50),
            "p99": self.quantile(99),
            "p999": self.quantile(99.9),
            "max": self.quantile(100),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Histogram({self.name}, n={self.count})"


class MetricsRegistry:
    """Name-keyed counters and histograms, created on first touch."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(name)
        return h

    def counter_values(self, prefix: str = "") -> Dict[str, int]:
        """Snapshot of all counters whose name starts with ``prefix``."""
        return {
            name: c.value for name, c in sorted(self.counters.items())
            if name.startswith(prefix)
        }

    def summaries(self, prefix: str = "") -> Dict[str, Dict]:
        """Snapshot of all histogram summaries whose name starts with
        ``prefix`` — the aggregate counterpart of :meth:`counter_values`
        (empty histograms report ``count: 0`` / ``None`` statistics, so a
        snapshot never raises)."""
        return {
            name: h.summary() for name, h in sorted(self.histograms.items())
            if name.startswith(prefix)
        }

    def reset(self) -> None:
        self.counters = {}
        self.histograms = {}


def _prom_name(name: str) -> str:
    """Sanitize a dotted metric name into the Prometheus charset."""
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    return s if not s[:1].isdigit() else "_" + s


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render a registry in the Prometheus text exposition format.

    Counters become ``# TYPE <name> counter`` samples; raw-sample histograms
    become ``summary`` families (``{quantile="..."}`` gauges plus ``_sum`` /
    ``_count``), quantiles by the same exact nearest-rank estimator the
    bench artifacts use.  Deterministic output (sorted names), so the dump
    itself can be diffed across runs."""
    lines: List[str] = []
    for name, c in sorted(registry.counters.items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn} {c.value}")
    for name, h in sorted(registry.histograms.items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} summary")
        for q in (0.5, 0.99, 0.999):
            if h.count:
                lines.append(f'{pn}{{quantile="{q}"}} {h.quantile(q * 100)!r}')
        lines.append(f"{pn}_sum {h.total!r}")
        lines.append(f"{pn}_count {h.count}")
    return "\n".join(lines) + "\n"
