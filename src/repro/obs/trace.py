"""Lightweight span tracer with a Chrome/Perfetto trace-event exporter.

The observability layer's data plane: every layer of the read/write stack
(``FileReader``/``DatasetReader``/``DatasetWriter`` at the top, the
``IOScheduler``'s open -> coalesce -> classify -> dispatch -> drain pipeline,
``FlushPolicy`` drains, the kernel decode route) opens spans on the tracer
threaded through the :class:`~repro.store.IOScheduler`.  Three event kinds:

* **spans** (``ph: "X"`` complete events) — timed regions, context-manager
  API, nestable;
* **instants** (``ph: "i"``) — structured point events: admission-policy
  flips, flush-on-evict writes, and the *pallas fallback-reason* telemetry
  (a ``pallas_fallback`` event whenever ``decode="pallas"`` silently routes
  to numpy, with the reason — float values, variable-width leaf, >31-bit
  packing, opaque codec — in ``args``);
* **counters** (``ph: "C"``) — counter tracks sampled at batch close: queue
  depth, per-tier hit rate, resident/dirty bytes.

Zero-cost when disabled: the default tracer is the module singleton
:data:`NULL_TRACER` (``enabled=False``); its ``span()`` returns the shared
:data:`NULL_SPAN` singleton, so a disabled trace allocates **no span
objects** and appends nothing.  Instrumented code never needs an ``if``:
``with tracer.span(...)`` is safe and free either way.  The hard contract
(tested): logical IOPS/bytes and every priced time are bit-identical whether
tracing is on or off — the tracer observes the pipeline, it never steers it.

Timestamps are host-wall microseconds since tracer construction
(``time.perf_counter``): they time the *simulation's* orchestration work.
The modelled device time lives in span ``args`` where the instrumentation
site provides it.  :meth:`Tracer.export` writes the standard
``{"traceEvents": [...]}`` JSON object form — open it at
https://ui.perfetto.dev or ``chrome://tracing``.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from .metrics import MetricsRegistry

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN"]


class _NullSpan:
    """Shared no-op span: entering/exiting does nothing, setting args is
    swallowed.  A module singleton — disabled tracing allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        pass


NULL_SPAN = _NullSpan()


class _Span:
    """One open span; appended to the tracer's event list on exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_ts", "tid")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: Dict,
                 tid: Optional[int] = None):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.tid = tid
        self._ts = tracer._now_us()

    def set(self, **args) -> None:
        """Attach/overwrite span args from inside the region."""
        self.args.update(args)

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        tr.events.append({
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self._ts, "dur": tr._now_us() - self._ts,
            "pid": tr.pid,
            "tid": self.tid if self.tid is not None else tr.tid,
            "args": self.args,
        })
        return False


class Tracer:
    """Collects Chrome-trace events; ``enabled=False`` is a strict no-op.

    One tracer per IO path: pass it to ``FileReader``/``DatasetReader``/
    ``DatasetWriter`` (or directly to ``IOScheduler``) and every layer below
    shares it.  ``metrics`` is a :class:`~repro.obs.metrics.MetricsRegistry`
    fed alongside the event list (fallback-reason counters, span-less
    counts) so tests can query aggregates without parsing the trace.
    """

    def __init__(self, enabled: bool = True, pid: int = 1, tid: int = 1):
        self.enabled = bool(enabled)
        self.pid = pid
        self.tid = tid
        self.events: List[Dict] = []
        self.metrics = MetricsRegistry()
        self._tracks: Dict[str, int] = {}
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    # -- event API -----------------------------------------------------------
    def span(self, name: str, cat: str = "io", tid: Optional[int] = None,
             **args):
        """Open a timed span (context manager).  ``tid`` overrides the
        tracer's default track — the scheduler uses one track per request so
        concurrent takers render as separate Perfetto lanes.  Returns the
        shared :data:`NULL_SPAN` when disabled — no allocation, no
        recording."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args, tid=tid)

    def track(self, key: Optional[str]) -> int:
        """Intern ``key`` as a stable per-request track id (tid).

        The first time a key is seen a Chrome ``thread_name`` metadata event
        is emitted so Perfetto labels the lane with the request id; repeat
        calls return the same tid.  ``None`` (or disabled) falls back to the
        tracer's default track."""
        if not self.enabled or key is None:
            return self.tid
        tid = self._tracks.get(key)
        if tid is None:
            tid = self.tid + 1 + len(self._tracks)
            self._tracks[key] = tid
            self.events.append({
                "name": "thread_name", "ph": "M", "ts": self._now_us(),
                "pid": self.pid, "tid": tid, "args": {"name": str(key)},
            })
        return tid

    def instant(self, name: str, cat: str = "event", **args) -> None:
        """A structured point event (thread-scoped instant)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now_us(), "pid": self.pid, "tid": self.tid,
            "args": args,
        })

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "counter", ts: Optional[float] = None) -> None:
        """One sample on a counter track (Perfetto renders each key as a
        series under the track ``name``).  ``ts`` overrides the host-clock
        timestamp with an explicit microsecond value — the metrics plane
        uses this to replay virtual-clock gauge series as counter tracks
        (``MetricsPlane.to_trace``) so they line up with the simulated
        timeline rather than orchestration wall time."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": self._now_us() if ts is None else float(ts),
            "pid": self.pid, "tid": self.tid,
            "args": dict(values),
        })

    def fallback(self, encoding: str, reason: str, **args) -> None:
        """The structured *fallback-reason* event: ``decode="pallas"`` routed
        (part of) a decode to numpy.  ``encoding`` is the route
        (``miniblock``/``fullzip``), ``reason`` a stable slug
        (``float-values``, ``variable-width-leaf``, ``>31-bit``,
        ``opaque-codec:<name>``, ...).  Counted in ``metrics`` under
        ``decode.fallback.<encoding>.<reason>`` for test/CI queries."""
        if not self.enabled:
            return
        self.metrics.counter(f"decode.fallback.{encoding}.{reason}").inc()
        self.instant("pallas_fallback", cat="decode",
                     encoding=encoding, reason=reason, **args)

    # -- export --------------------------------------------------------------
    def trace_events(self) -> Dict:
        """The Chrome trace-event JSON object form."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the trace to ``path``; returns the number of events.
        ``allow_nan=False`` — a NaN in any event is a bug, not an artifact
        feature (see the bench NaN-leak fix)."""
        with open(path, "w") as f:
            json.dump(self.trace_events(), f, allow_nan=False)
        return len(self.events)

    def reset(self) -> None:
        self.events = []
        self.metrics = MetricsRegistry()
        self._tracks = {}
        self._t0 = time.perf_counter()


class NullTracer(Tracer):
    """The always-disabled tracer; :data:`NULL_TRACER` is the one instance
    instrumented objects default to."""

    def __init__(self):
        super().__init__(enabled=False)


NULL_TRACER = NullTracer()
