"""Multi-file dataset reader: many Lance fragments, one IO path.

The pre-dataset world built one ``TieredStore`` per ``FileReader`` — N files
meant N disjoint NVMe caches and N separate queue drains per logical
operation.  ``DatasetReader`` opens every fragment against **one** shared
:class:`~repro.store.TieredStore` + :class:`~repro.store.IOScheduler` over
the dataset's concatenated global address space (see
:mod:`repro.dataset.manifest`):

* ``take(column, global_rows)`` vector-maps rows to fragments (searchsorted
  over fragment row starts), fans out per-fragment batched leaf takes that
  all enqueue into **one** scheduler batch — spans from different files
  coalesce per dependency phase and the whole take is priced as a single
  queue drain — then stitches the per-fragment leaves together and restores
  request order with one shared
  :func:`~repro.core.encodings_base.reorder_leaf_rows` permutation;
* ``scan(column)`` streams every fragment through one prefetch-flagged
  batch, so ``SequentialReadahead`` sees a single global request stream and
  keeps reading ahead **across fragment boundaries** (the inter-file gap is
  just a footer, far below the readahead's ``max_gap``);
* the scheduler's :class:`~repro.store.WorkloadStats` watches the dataset's
  scan/take mix and auto-selects the admission policy of any cache level
  configured ``admission="auto"``.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from ..core import arrays as A
from ..core.encodings_base import concat_leaves, reorder_leaf_rows
from ..core.file import FileReader, type_from_dict
from ..core.io_sim import DiskView
from ..core.shred import unshred

from .manifest import Manifest, build_dataset_disk

__all__ = ["DatasetReader"]


class DatasetReader:
    """Reads a fragmented Lance dataset behind one shared store/scheduler.

    ``files`` is the ordered fragment list (raw file bytes).  ``store``
    accepts the same specs as :func:`repro.store.make_store` — the spec is
    resolved once over the dataset's global disk, so "tiered" gives the
    whole dataset a single NVMe budget (and "tiered-auto" additionally lets
    the workload mix pick the admission policy).
    """

    def __init__(self, files: Sequence[bytes], store=None,
                 queue_depth: int = 256, readahead="auto",
                 decode: Optional[str] = None, dict_cached: bool = False,
                 tracer=None):
        from ..store import IOScheduler, make_store

        manifest, disk = build_dataset_disk(files)
        scheduler = IOScheduler(make_store(store, disk),
                                queue_depth=queue_depth, readahead=readahead,
                                tracer=tracer)
        self._bind(manifest, disk, scheduler, decode=decode,
                   dict_cached=dict_cached)

    @classmethod
    def from_manifest(cls, manifest: Manifest, disk, scheduler,
                      decode: Optional[str] = None, dict_cached: bool = False,
                      readers: Optional[List[FileReader]] = None,
                      ) -> "DatasetReader":
        """View an already-materialized dataset (a manifest *version* over a
        shared disk + scheduler) without rebuilding the address space.  The
        dataset writer uses this for time travel: one reader per committed
        version, all sharing the writer's store/cache.  ``readers`` supplies
        pre-built per-fragment ``FileReader``\\ s (cached by the writer so a
        fragment's footer is parsed once, not once per version)."""
        self = cls.__new__(cls)
        self._bind(manifest, disk, scheduler, decode=decode,
                   dict_cached=dict_cached, readers=readers)
        return self

    def _bind(self, manifest, disk, scheduler, decode=None,
              dict_cached=False, readers=None):
        self.manifest = manifest
        self.disk = disk
        self.store = scheduler.store
        self.scheduler = scheduler
        self.tracer = scheduler.tracer
        self.fragments: List[FileReader] = readers if readers is not None else [
            FileReader(DiskView(self.disk, f.base, f.nbytes),
                       scheduler=self.scheduler, base=f.base,
                       decode=decode, dict_cached=dict_cached)
            for f in self.manifest.fragments
        ]
        self.columns = self.fragments[0].columns

    # -- geometry ------------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.manifest.n_rows

    @property
    def n_fragments(self) -> int:
        return self.manifest.n_fragments

    def locate(self, rows):
        """Vector-map global row ids to ``(fragment index, local row)``."""
        return self.manifest.locate(rows)

    # -- public API ----------------------------------------------------------
    def take(self, name: str, rows) -> A.Array:
        """Random access by *global* row ids (any order, duplicates fine).

        One scheduler batch covers every fragment's reads, so per-phase
        coalescing and queue-depth pricing see the union of all files'
        spans; the result is bit-identical to running each fragment's take
        separately and reassembling.
        """
        rows = np.asarray(rows, dtype=np.int64)
        col = self.columns[name]
        if len(rows) == 0:
            return self.fragments[0].take(name, rows)
        fi, local = self.locate(rows)
        # concat order = request rows stably grouped by fragment; inv maps
        # each request position to its row in that concatenation
        perm = np.argsort(fi, kind="stable")
        inv = np.empty(len(perm), dtype=np.int64)
        inv[perm] = np.arange(len(perm), dtype=np.int64)
        frag_ids = np.unique(fi)
        with self.tracer.span(f"dataset.take:{name}", cat="reader",
                              n_rows=len(rows), n_fragments=len(frag_ids)):
            with self.scheduler.batch(f"take:{name}") as io:
                # the global rows are the logical requests this drain's
                # modeled cost is attributed over (repro.obs.attrib)
                io.note_requests(len(rows))
                parts = [
                    self.fragments[f].take_leaves(name, local[fi == f], io)
                    for f in frag_ids
                ]
            if col["kind"] in ("arrow", "packed"):
                return A.concat(parts).take(inv)
            n_leaves = len(parts[0])
            leaves = [
                reorder_leaf_rows(concat_leaves([p[k] for p in parts]), inv)
                for k in range(n_leaves)
            ]
            return unshred(leaves, type_from_dict(col["type"]))

    def scan(self, name: str, io_chunk: int = 8 << 20) -> A.Array:
        """Full-column scan across all fragments, in global row order."""
        with self.tracer.span(f"dataset.scan:{name}", cat="reader",
                              n_fragments=len(self.fragments)):
            with self.scheduler.batch(f"scan:{name}", prefetch=True) as io:
                parts = [fr.scan_into(name, io, io_chunk=io_chunk)
                         for fr in self.fragments]
            return A.concat(parts)

    # -- accounting ----------------------------------------------------------
    def io_stats(self, coalesce_gap: int = 0):
        """Logical-trace stats over the shared scheduler (all fragments)."""
        return self.scheduler.stats(coalesce_gap)

    def tier_stats(self):
        """Per-tier dispatched-IO stats of the shared store."""
        return self.store.tier_stats()

    def workload_stats(self):
        """The shared scheduler's scan/take mix observer."""
        return self.scheduler.workload

    def modelled_time(self, queue_depth: Optional[int] = None) -> float:
        return self.scheduler.model_time(queue_depth)

    def search_cache_bytes(self, name: Optional[str] = None) -> int:
        return sum(fr.search_cache_bytes(name) for fr in self.fragments)

    def data_bytes(self, name: Optional[str] = None) -> int:
        return sum(fr.data_bytes(name) for fr in self.fragments)

    def reset_io(self) -> None:
        """Zero trace/tier counters; cache residency survives (warm stays
        warm — :meth:`drop_caches` is the cold restart)."""
        self.scheduler.reset()

    def drop_caches(self) -> None:
        self.store.drop_caches()
