"""Fragment manifest: the metadata model for a multi-file Lance dataset.

A dataset is an ordered list of Lance files ("fragments").  Rows get global
ids by concatenating fragment row ranges; bytes get global addresses by
concatenating fragment payloads (8-byte aligned) into one address space.
Both mappings live here:

* ``row_starts`` — fragment *f* holds global rows
  ``[row_starts[f], row_starts[f] + n_rows_f)``; a vectorized searchsorted
  maps any global row id to ``(fragment, local row)``;
* ``Fragment.base`` — local byte offset *o* of fragment *f* is global byte
  ``base_f + o``, so one :class:`~repro.store.BlockCache` keys blocks for
  every file (block id = global offset // sector) and the shared scheduler
  sector-aligns and coalesces across file boundaries.  A boundary block may
  serve the tail of one fragment and the head of the next — that sharing
  *is* the cross-file coalescing.

The manifest is built by parsing each file's footer (schema + row counts);
schemas must match across fragments.

Manifests are **versioned**: the dataset write path (`repro.dataset.writer`)
commits a new immutable ``Manifest`` (``version`` v1..vN) after every
flushed append/compaction, each holding its own fragment list snapshot.
Fragment payloads are never overwritten — the global address space is
append-only — so every committed version stays readable forever (time
travel) and a crash that tears uncommitted bytes can never reach back into
a committed version's address ranges.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import arrays as A
from ..core.file import WriteOptions, read_footer, write_table
from ..core.io_sim import Disk

__all__ = ["Fragment", "Manifest", "build_dataset_disk", "footer_meta",
           "write_fragments"]

FRAGMENT_ALIGN = 8  # byte alignment of fragment bases in the global space


@dataclasses.dataclass(frozen=True)
class Fragment:
    """One file of the dataset, placed in the global row/byte spaces."""

    id: int
    base: int        # global byte offset of this file's byte 0
    nbytes: int      # file size
    n_rows: int
    row_start: int   # global id of this file's row 0

    @property
    def row_stop(self) -> int:
        return self.row_start + self.n_rows


def footer_meta(fb: bytes) -> Dict:
    """Parse a Lance file's footer from raw bytes (schema + leaf metadata)."""
    meta, _ = read_footer(lambda o, s: fb[o : o + s], len(fb))
    return meta


_parse_footer = footer_meta  # internal alias (kept for call sites)


class Manifest:
    """Fragment list + the global row/byte address maps.

    ``version`` is 0 for a plain (unversioned) manifest built directly from
    files; the dataset writer numbers its committed manifests v1..vN.
    """

    def __init__(self, fragments: Sequence[Fragment], columns: List[Dict],
                 version: int = 0):
        self.fragments: List[Fragment] = list(fragments)
        self.columns = columns  # schema from fragment 0's footer
        self.version = int(version)
        self.n_rows = sum(f.n_rows for f in self.fragments)
        # row_starts[f] = first global row of fragment f (monotone, len F)
        self.row_starts = np.array([f.row_start for f in self.fragments],
                                   dtype=np.int64)

    @property
    def n_fragments(self) -> int:
        return len(self.fragments)

    @property
    def column_names(self) -> List[str]:
        return [c["name"] for c in self.columns]

    @classmethod
    def from_files(cls, files: Sequence[bytes]) -> "Manifest":
        if not files:
            raise ValueError("dataset needs at least one fragment")
        frags: List[Fragment] = []
        columns: Optional[List[Dict]] = None
        base = row = 0
        for i, fb in enumerate(files):
            meta = _parse_footer(fb)
            cols = meta["columns"]
            if columns is None:
                columns = cols
            else:
                got = [(c["name"], c["type"]) for c in cols]
                want = [(c["name"], c["type"]) for c in columns]
                if got != want:
                    raise ValueError(
                        f"fragment {i} schema {got!r} does not match "
                        f"fragment 0 schema {want!r}")
            n_rows = cols[0]["n_rows"] if cols else 0
            frags.append(Fragment(id=i, base=base, nbytes=len(fb),
                                  n_rows=n_rows, row_start=row))
            row += n_rows
            base += len(fb) + (-len(fb)) % FRAGMENT_ALIGN
        return cls(frags, columns)

    # -- global row ids ------------------------------------------------------
    def locate(self, rows) -> Tuple[np.ndarray, np.ndarray]:
        """Vector-map global row ids to ``(fragment index, local row)``."""
        rows = np.asarray(rows, dtype=np.int64)
        if len(rows) and (int(rows.min()) < 0 or int(rows.max()) >= self.n_rows):
            raise IndexError(
                f"global rows out of bounds for {self.n_rows}-row dataset")
        fi = np.searchsorted(self.row_starts, rows, side="right") - 1
        return fi, rows - self.row_starts[fi]


def build_dataset_disk(files: Sequence[bytes]) -> Tuple[Manifest, Disk]:
    """Concatenate fragment files into one global-address-space disk."""
    manifest = Manifest.from_files(files)
    total = manifest.fragments[-1].base + manifest.fragments[-1].nbytes
    mem = np.zeros(total, dtype=np.uint8)
    for frag, fb in zip(manifest.fragments, files):
        mem[frag.base : frag.base + frag.nbytes] = np.frombuffer(fb, np.uint8)
    return manifest, Disk(mem)


def write_fragments(table: Dict[str, A.Array], n_fragments: int,
                    opts: Optional[WriteOptions] = None) -> List[bytes]:
    """Split a table row-wise into ``n_fragments`` Lance files.

    The test/benchmark ingest path: contiguous, near-equal row ranges, each
    written with :func:`~repro.core.file.write_table`.
    """
    if n_fragments <= 0:
        raise ValueError("n_fragments must be positive")
    n = len(next(iter(table.values())))
    if n_fragments > max(n, 1):
        raise ValueError(f"cannot split {n} rows into {n_fragments} fragments")
    bounds = np.linspace(0, n, n_fragments + 1).astype(np.int64)
    out = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        idx = np.arange(lo, hi, dtype=np.int64)
        out.append(write_table({k: v.take(idx) for k, v in table.items()},
                               opts))
    return out
