"""IVF ANN index stored *as dataset fragments*.

The index is not a sidecar file: centroids and posting lists are columns of
a second, schema-independent fragment set written through
:meth:`DatasetWriter.attached` into the **same global address space** as the
data.  That buys the index every property fragments already have —
committed durability (flush-then-commit fence), manifest versions / time
travel, ``compact()`` — and, because its blocks carry ordinary sector ids
on the shared disk, index reads are priced by the same
:class:`~repro.store.IOScheduler`, warm the same
:class:`~repro.store.BlockCache` NVMe budget, and appear in the same drain
log / per-request attribution as the data reads they trigger.  Index, data
and cache genuinely contend for the same bytes.

Layout: one row per partition, two columns —

* ``centroid``: fixed-size-list float32[dim] (full-zip: one random-access
  IOP fetches a centroid row, though the probe path scans all of them and
  stays cache-warm after the first search);
* ``posting``: list<int64> of the partition's *global* row ids, ascending
  (mini-block bit-packed — posting lists are exactly the narrow-int shape
  the paper's §4.2 encoding is for).

Training is plain seeded Lloyd's k-means over one full scan of the vector
column (the scan is priced through the shared scheduler like any other
read).  Empty clusters keep their previous centroid, so every seed yields
a deterministic index.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from ..core import arrays as A
from ..core.file import WriteOptions
from .writer import DatasetWriter

__all__ = ["IvfIndex", "kmeans"]


def kmeans(vecs: np.ndarray, n_partitions: int, n_iters: int = 8,
           seed: int = 0):
    """Seeded Lloyd's iterations; returns ``(centroids, labels)``.

    Distances use the expanded |a-b|^2 = |a|^2 - 2ab + |b|^2 form so the
    working set stays (n, P) — never materializing (n, P, dim).
    """
    vecs = np.asarray(vecs, np.float32)
    n, dim = vecs.shape
    p = int(n_partitions)
    if not 1 <= p <= n:
        raise ValueError(f"n_partitions must be in 1..{n}, got {p}")
    rng = np.random.default_rng(seed)
    cent = vecs[np.sort(rng.choice(n, size=p, replace=False))].copy()
    vv = (vecs * vecs).sum(1)[:, None]
    labels = np.zeros(n, np.int64)
    for _ in range(max(1, int(n_iters))):
        d = vv - 2.0 * (vecs @ cent.T) + (cent * cent).sum(1)[None]
        labels = d.argmin(1)
        for j in range(p):
            members = labels == j
            if members.any():
                cent[j] = vecs[members].mean(0)
    return cent, labels


class IvfIndex:
    """An IVF partition index over one vector column of a dataset.

    Build with :meth:`build` (trains + writes + commits through an attached
    writer); query through :meth:`repro.serve.engine.Retriever.search`,
    which probes centroids, fetches posting lists, and scores candidates —
    every read on the shared tiered store.
    """

    def __init__(self, writer: DatasetWriter, column: str,
                 n_partitions: int, dim: int):
        self.writer = writer          # attached: shares the data IO path
        self.column = column
        self.n_partitions = int(n_partitions)
        self.dim = int(dim)

    @classmethod
    def build(cls, data: DatasetWriter, column: str = "embedding",
              n_partitions: int = 16, n_fragments: int = 2,
              n_iters: int = 8, seed: int = 0,
              opts: Optional[WriteOptions] = None) -> "IvfIndex":
        """Train k-means over ``data``'s committed ``column`` and commit the
        index as ``n_fragments`` fragments of an attached writer."""
        arr = data.scan(column)
        vecs = np.asarray(arr.values, np.float32)
        cent, labels = kmeans(vecs, n_partitions, n_iters, seed)
        postings = [np.flatnonzero(labels == j).astype(np.int64)
                    for j in range(int(n_partitions))]
        writer = DatasetWriter.attached(
            data, opts=opts or WriteOptions("lance"))
        per = -(-int(n_partitions) // max(1, int(n_fragments)))
        for lo in range(0, int(n_partitions), per):
            hi = min(lo + per, int(n_partitions))
            writer.append(cls._table(cent[lo:hi], postings[lo:hi]),
                          commit=False)
        writer.commit()
        return cls(writer, column, n_partitions, vecs.shape[1])

    @staticmethod
    def _table(cent: np.ndarray, postings: Sequence[np.ndarray]):
        offsets = np.zeros(len(postings) + 1, np.int64)
        np.cumsum([len(p) for p in postings], out=offsets[1:])
        child = A.PrimitiveArray.build(
            np.concatenate(postings) if postings else np.zeros(0, np.int64),
            nullable=False)
        return {"centroid": A.FixedSizeListArray.build(cent),
                "posting": A.ListArray.build(child, offsets)}

    # -- query-side accessors (all reads go through the shared store) --------
    def reader(self, version: Optional[int] = None):
        """Index fragments at a committed index-manifest version (time
        travel over the index, independent of data versions)."""
        return self.writer.reader(version)

    def centroids(self, version: Optional[int] = None) -> np.ndarray:
        """(P, dim) float32 — one batched take of every centroid row (warm
        after the first probe: P rows live in a handful of sectors)."""
        arr = self.reader(version).take(
            "centroid", np.arange(self.n_partitions, dtype=np.int64))
        return np.asarray(arr.values, np.float32)

    def postings(self, parts: Sequence[int],
                 version: Optional[int] = None) -> List[np.ndarray]:
        """Posting lists for ``parts`` — one batched take of the probed
        partitions' rows."""
        parts = np.asarray(parts, np.int64)
        arr = self.reader(version).take("posting", parts)
        off, child = arr.offsets, np.asarray(arr.child.values, np.int64)
        return [child[off[i]:off[i + 1]] for i in range(len(parts))]

    def compact(self, max_rows: Optional[int] = None):
        """Merge small index fragments (posting-list fragments fragment as
        partitions are rewritten); commits a new index manifest version and
        retargets the shared cache like any dataset compaction."""
        return self.writer.compact(max_rows or self.n_partitions)
