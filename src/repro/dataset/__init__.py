# Multi-file dataset layer: a fragment manifest with global row ids and a
# global block-address space, read through ONE shared BlockCache +
# IOScheduler so take-heavy serving over many Lance files sees a single
# NVMe budget, cross-file per-phase coalescing, and workload-driven cache
# admission.  The ingest side (DatasetWriter) appends fragments through the
# write-back store and commits versioned manifests with a flush-then-commit
# crash-safety fence.

from .manifest import (  # noqa: F401
    Fragment,
    Manifest,
    build_dataset_disk,
    footer_meta,
    write_fragments,
)
from .ivf import IvfIndex, kmeans  # noqa: F401
from .reader import DatasetReader  # noqa: F401
from .writer import DatasetWriter  # noqa: F401
