"""Dataset ingest path: appendable, versioned Lance datasets over the
write-back tiered store.

``DatasetWriter`` is the write-side dual of
:class:`~repro.dataset.reader.DatasetReader`: one growable global address
space, one shared :class:`~repro.store.TieredStore` +
:class:`~repro.store.IOScheduler`, and a
:class:`~repro.store.FlushPolicy` deciding when appended bytes become
durable on the backing device.

* :meth:`append` encodes a table into a new fragment with the existing file
  writer (:func:`~repro.core.file.write_table`), extends the global
  block-address space (8-aligned, append-only — committed bytes are never
  overwritten), and stages the fragment's bytes through one scheduler
  ``WriteBatch`` — write-through pays a backing (S3) drain per append,
  write-back absorbs the blocks dirty into the NVMe tier and lets the flush
  policy batch them.
* :meth:`commit` is the durability fence: **flush-then-commit** — every
  dirty block is flushed to the backing device *before* the new manifest
  version exists, so a crash at any point of the flush+commit sequence
  leaves every previously committed version readable (the torn bytes are
  only ever inside uncommitted fragments).
* :meth:`reader` opens any committed manifest version over the shared
  scheduler (time travel); :meth:`take`/:meth:`scan` serve the latest one.
* :meth:`compact` rewrites runs of small fragments into one (reads priced
  through the shared scheduler, the rewrite staged through the write path),
  commits the new fragment list as a version, and retargets the shared
  cache by invalidating the replaced fragments' blocks.
* :meth:`simulate_crash` is the durability model's teeth: unflushed (dirty)
  bytes are torn off the media, uncommitted fragments vanish, and the live
  state rewinds to the last committed version — per-tier ``lost_bytes``
  records what the write-back latency trade put at risk.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import arrays as A
from ..core.file import FileReader, WriteOptions, write_table
from ..core.io_sim import Disk, DiskView
from ..store import FlushPolicy, IOScheduler, make_store

from .manifest import FRAGMENT_ALIGN, Fragment, Manifest, footer_meta
from .reader import DatasetReader

__all__ = ["DatasetWriter"]


def _schema_key(columns) -> List[Tuple[str, Dict]]:
    return [(c["name"], c["type"]) for c in columns]


class DatasetWriter:
    """Appendable, versioned multi-fragment dataset behind one IO path.

    ``store`` accepts the same specs as :func:`repro.store.make_store`
    (resolved over the writer's growable global disk).  ``flush`` selects
    the write path: a :class:`~repro.store.FlushPolicy` mode string
    (``"write-through"``, ``"write-back"``, ``"flush-on-evict"``), a ready
    policy instance, or ``None`` (no policy attached: writes behave
    write-through).  ``files`` optionally seeds the dataset with existing
    fragment bytes (ingested through the write path and committed as v1).
    """

    def __init__(self, files: Sequence[bytes] = (), store="tiered",
                 flush="write-back", opts: Optional[WriteOptions] = None,
                 queue_depth: int = 256, readahead="auto",
                 decode: Optional[str] = None, dict_cached: bool = False,
                 tracer=None):
        self.opts = opts or WriteOptions()
        self.disk = Disk(np.zeros(0, np.uint8))
        self.store = make_store(store, self.disk)
        if isinstance(flush, str):
            flush = FlushPolicy(flush)
        self.store.set_flush_policy(flush)
        self.scheduler = IOScheduler(self.store, queue_depth=queue_depth,
                                     readahead=readahead, tracer=tracer)
        self.tracer = self.scheduler.tracer
        self._decode = decode
        self._dict_cached = dict_cached
        self._columns: Optional[List[Dict]] = None
        self.fragments: List[Fragment] = []   # live (to-be-committed) list
        self._pending: List[Fragment] = []    # appended since last commit
        self.versions: List[Manifest] = []    # committed manifests, v1..vN
        self._next_id = 0
        self._frag_readers: Dict[int, FileReader] = {}
        self._version_readers: Dict[int, DatasetReader] = {}
        if files:
            for fb in files:
                self._append_file(bytes(fb))
            self.commit()

    @classmethod
    def attached(cls, parent: "DatasetWriter",
                 opts: Optional[WriteOptions] = None,
                 decode: Optional[str] = None) -> "DatasetWriter":
        """A sibling writer over ``parent``'s disk / store / scheduler.

        Its fragments land in the *same* global address space (tail-appended
        and 8-aligned like any append), so their blocks carry the same
        sector ids, warm the same :class:`~repro.store.BlockCache` budget,
        and drain through the same :class:`~repro.store.IOScheduler` queues
        as the parent's data — but it keeps its own schema, fragment list
        and manifest versions, so it can commit, time-travel and
        ``compact()`` independently.  This is the index-as-fragments
        substrate: an :class:`~repro.dataset.IvfIndex` built through an
        attached writer is versioned and maintained exactly like data while
        its reads contend for the one shared IO budget.
        """
        self = cls.__new__(cls)
        self.opts = opts or parent.opts
        self.disk = parent.disk
        self.store = parent.store
        self.scheduler = parent.scheduler
        self.tracer = parent.tracer
        self._decode = decode if decode is not None else parent._decode
        self._dict_cached = parent._dict_cached
        self._columns = None
        self.fragments = []
        self._pending = []
        self.versions = []
        # disjoint reader-cache key space from the parent: ids only key
        # this writer's private _frag_readers / _version_readers dicts
        self._next_id = 0
        self._frag_readers = {}
        self._version_readers = {}
        return self

    # -- geometry ------------------------------------------------------------
    @property
    def flush_policy(self) -> Optional[FlushPolicy]:
        return self.store.flush_policy

    @property
    def version(self) -> int:
        """Latest committed manifest version (0 = nothing committed yet)."""
        return len(self.versions)

    @property
    def n_rows(self) -> int:
        """Rows visible at the latest committed version."""
        return self.versions[-1].n_rows if self.versions else 0

    @property
    def dirty_bytes(self) -> int:
        """Bytes staged but not yet durable (lost if the process dies)."""
        return sum(lvl.cache.dirty_bytes for lvl in self.store.levels)

    # -- ingest ---------------------------------------------------------------
    def _append_file(self, fb: bytes, label: str = "append") -> Fragment:
        """Stage raw fragment bytes at the end of the global address space
        through one write batch; the fragment is pending until a commit."""
        meta = footer_meta(fb)
        cols = meta["columns"]
        if self._columns is None:
            self._columns = cols
        elif _schema_key(cols) != _schema_key(self._columns):
            raise ValueError(
                f"appended schema {_schema_key(cols)!r} does not match "
                f"dataset schema {_schema_key(self._columns)!r}")
        base = len(self.disk)
        base += (-base) % FRAGMENT_ALIGN
        self.disk.grow(base + len(fb) - len(self.disk))
        fid = self._next_id
        self._next_id += 1
        with self.tracer.span(f"{label}:{fid}", cat="writer",
                              nbytes=len(fb)):
            with self.scheduler.write_batch(f"{label}:{fid}") as wb:
                wb.write(base, fb, phase=0)
        row_start = self.fragments[-1].row_stop if self.fragments else 0
        frag = Fragment(id=fid, base=base, nbytes=len(fb),
                        n_rows=cols[0]["n_rows"] if cols else 0,
                        row_start=row_start)
        self.fragments.append(frag)
        self._pending.append(frag)
        return frag

    def append(self, table: Dict[str, A.Array], commit: bool = True,
               ) -> Optional[Manifest]:
        """Encode ``table`` as a new fragment and stage it.  With
        ``commit=True`` (default) the append is made durable immediately
        (flush barrier + new manifest version); ``commit=False`` defers the
        fence — higher ingest throughput under write-back, but the staged
        rows are invisible to readers and lost on crash until the next
        :meth:`commit`."""
        self._append_file(write_table(table, self.opts))
        return self.commit() if commit else None

    def commit(self) -> Optional[Manifest]:
        """Flush-then-commit fence.  Ordering is the crash-safety contract:
        (1) every dirty block is flushed to the backing device; (2) only
        then is the new manifest version created.  An interruption anywhere
        leaves the previous version's bytes fully durable and the new
        version nonexistent — never a torn committed manifest.  Returns the
        committed manifest (the latest one when nothing new was staged, or
        ``None`` for a still-empty dataset)."""
        with self.tracer.span("commit", cat="writer",
                              n_pending=len(self._pending)) as sp:
            # (1) durability barrier (may SimulatedCrash); routed through
            # the scheduler so the flush drains hit the serving plane
            self.scheduler.flush_barrier()
            if not self.fragments:
                return None  # empty dataset: nothing to commit
            if self.versions and not self._pending \
                    and self.versions[-1].fragments == self.fragments:
                return self.versions[-1]  # nothing new: no empty version
            m = Manifest(self.fragments, self._columns,
                         version=len(self.versions) + 1)  # (2) commit point
            self.versions.append(m)
            self._pending = []
            sp.set(version=m.version)
            return m

    def flush(self) -> int:
        """Manual durability barrier without a commit (staged fragments stay
        pending but their bytes stop being at risk)."""
        return self.scheduler.flush_barrier()

    # -- reading -------------------------------------------------------------
    def _reader_for(self, frag: Fragment) -> FileReader:
        fr = self._frag_readers.get(frag.id)
        if fr is None:
            fr = FileReader(DiskView(self.disk, frag.base, frag.nbytes),
                            scheduler=self.scheduler, base=frag.base,
                            decode=self._decode, dict_cached=self._dict_cached)
            self._frag_readers[frag.id] = fr
        return fr

    def reader(self, version: Optional[int] = None) -> DatasetReader:
        """A :class:`DatasetReader` over a committed manifest version (1-based;
        default latest), sharing this writer's store/scheduler — reads it
        serves are priced on, and warm, the same NVMe budget the ingest path
        is filling."""
        if not self.versions:
            raise ValueError("nothing committed yet — append() first")
        v = len(self.versions) if version is None else int(version)
        if not 1 <= v <= len(self.versions):
            raise ValueError(f"version {v} out of range 1..{len(self.versions)}")
        ds = self._version_readers.get(v)
        if ds is None:
            m = self.versions[v - 1]
            ds = DatasetReader.from_manifest(
                m, self.disk, self.scheduler,
                readers=[self._reader_for(f) for f in m.fragments])
            self._version_readers[v] = ds
        return ds

    def take(self, name: str, rows) -> A.Array:
        """Random access by global row id at the latest committed version."""
        return self.reader().take(name, rows)

    def scan(self, name: str, io_chunk: int = 8 << 20) -> A.Array:
        """Full-column scan of the latest committed version."""
        return self.reader().scan(name, io_chunk=io_chunk)

    # -- maintenance ---------------------------------------------------------
    def compact(self, max_rows: int) -> Manifest:
        """Rewrite every run of >=2 adjacent fragments whose combined rows
        fit ``max_rows`` into one fragment (global row order unchanged).
        Reads go through the shared scheduler (compaction IO is priced like
        any other traffic), the merged payload is staged through the write
        path, and the whole rewrite commits as one new manifest version —
        after which the replaced fragments' blocks are invalidated so the
        shared cache retargets its budget at the live layout.  Old versions
        still address the old fragments (the address space is append-only)."""
        if max_rows <= 0:
            raise ValueError("max_rows must be positive")
        if self._pending:
            self.commit()
        if not self.versions:
            raise ValueError("nothing committed yet — append() first")
        with self.tracer.span("compact", cat="writer", max_rows=max_rows):
            return self._compact(max_rows)

    def _compact(self, max_rows: int) -> Manifest:
        groups: List[List[Fragment]] = []
        run: List[Fragment] = []
        for f in self.fragments:
            if run and sum(g.n_rows for g in run) + f.n_rows <= max_rows:
                run.append(f)
            else:
                groups.append(run)
                run = [f]
        groups.append(run)
        groups = [g for g in groups if g]
        if all(len(g) == 1 for g in groups):
            return self.versions[-1]  # nothing small enough to merge
        names = [c["name"] for c in self._columns]
        new_list: List[Fragment] = []
        replaced: List[Fragment] = []
        for g in groups:
            if len(g) == 1:
                new_list.append(g[0])
                continue
            readers = [self._reader_for(f) for f in g]
            table = {}
            for name in names:
                with self.scheduler.batch(f"compact:{name}",
                                          prefetch=True) as io:
                    parts = [r.scan_into(name, io) for r in readers]
                table[name] = A.concat(parts)
            merged = self._append_file(write_table(table, self.opts),
                                       label="compact")
            # _append_file put it at the tail of the live list; it belongs
            # at the group's position instead (it stays pending either way)
            self.fragments.pop()
            new_list.append(merged)
            replaced.extend(g)
        # renumber the row space (order of the new list defines global rows)
        row = 0
        final: List[Fragment] = []
        for f in new_list:
            final.append(dataclasses.replace(f, row_start=row))
            row += f.n_rows
        self.fragments = final
        m = self.commit()
        # retarget the shared cache: the replaced fragments' blocks are dead
        # weight for the live version (old versions re-fetch on demand)
        for f in replaced:
            b0 = f.base // self.store.sector
            b1 = (f.base + f.nbytes + self.store.sector - 1) // self.store.sector
            for lvl in self.store.levels:
                for bid in range(b0, b1):
                    if not lvl.cache.is_dirty(bid):
                        lvl.cache.invalidate(bid)
        return m

    # -- crash model ---------------------------------------------------------
    def simulate_crash(self) -> int:
        """Tear the unflushed state off the media and rewind to the last
        committed version: dirty blocks are discarded (counted as
        ``lost_bytes`` per tier) and their bytes inside *uncommitted*
        fragments are zeroed — committed fragments were flushed by their
        commit fence, so a shared boundary block can only lose its
        uncommitted tail.  Returns the number of bytes torn."""
        lost_extents = self.store.discard_dirty()
        self.tracer.instant(
            "simulated_crash", cat="writer",
            lost_extents=len(lost_extents), n_pending=len(self._pending))
        pend = [(f.base, f.base + f.nbytes) for f in self._pending]
        torn = 0
        for lo, hi in lost_extents:
            for plo, phi in pend:
                a, b = max(lo, plo), min(hi, phi)
                if a < b:
                    self.disk.zero(a, b)
                    torn += b - a
        self.fragments = list(self.versions[-1].fragments) \
            if self.versions else []
        self._pending = []
        if not self.versions:
            self._columns = None
        return torn

    # -- accounting ----------------------------------------------------------
    def io_stats(self, coalesce_gap: int = 0):
        """Logical *read* trace over the shared scheduler."""
        return self.scheduler.stats(coalesce_gap)

    def write_stats(self, coalesce_gap: int = 0):
        """Logical *write* trace (appends + compaction rewrites)."""
        return self.scheduler.write_stats(coalesce_gap)

    def tier_stats(self):
        """Per-tier dispatched IO incl. write/flush/dirty/lost accounting."""
        return self.store.tier_stats()

    def modelled_time(self, queue_depth: Optional[int] = None) -> float:
        return self.scheduler.model_time(queue_depth)

    def reset_io(self) -> None:
        self.scheduler.reset()

    def drop_caches(self) -> None:
        self.store.drop_caches()
