"""Dremel-style shredding with Lance-convention repetition/definition levels.

``shred`` converts a (possibly nested) :class:`repro.core.arrays.Array` into
one :class:`ShreddedLeaf` per primitive leaf.  ``unshred`` is the exact
inverse.  These leaves are what the structural encodings
(mini-block / full-zip / parquet-like) physically serialize.

Level conventions (matching the paper, Fig. 6):

* **Repetition**: ``rep == 0`` continues the innermost list; ``rep == k``
  starts a new list at the k-th level counting **outward from the innermost
  list** (so a new top-level record has ``rep == max_rep``).  Columns without
  list ancestors have ``max_rep == 0`` and carry no repetition stream.
* **Definition**: ``def == 0`` is a fully-valid leaf value.  Codes count
  termination sites from the innermost level outward: for
  ``Struct<List<String>>`` the codes are ``1 = null item``, ``2 = empty
  list``, ``3 = null list``, ``4 = null struct`` — exactly the paper's
  example.  Values are stored **sparsely** (entries with ``def != 0`` occupy
  no slot in the values array); the *encodings* decide whether to re-insert
  filler (dense full-zip) or not (mini-block / parquet pages).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List as PyList, Optional, Tuple

import numpy as np

from . import arrays as A
from . import types as T

__all__ = ["ShreddedLeaf", "shred", "unshred", "leaf_paths"]


@dataclasses.dataclass
class ShreddedLeaf:
    """One shredded leaf column."""

    path: Tuple[str, ...]  # struct field names from root to leaf ("" for non-struct hops)
    type_path: Tuple[T.DataType, ...]  # nodes root..leaf (structs/lists/leaf)
    leaf_type: T.DataType  # Primitive / FixedSizeList / Utf8 / Binary
    rep: Optional[np.ndarray]  # uint8[n_entries] lance-convention, None if max_rep == 0
    defs: Optional[np.ndarray]  # uint8[n_entries], None if max_def == 0
    values: A.Array  # sparse leaf values (non-null entries only), non-nullable type
    n_entries: int
    max_rep: int
    max_def: int
    # def-code tables (static per type path)
    def_meanings: Dict[int, str]
    # code assigned to "null item" at the leaf (0 if leaf non-nullable)
    null_item_code: int
    # number of top-level rows this leaf was shredded from
    n_rows: int

    @property
    def has_lists(self) -> bool:
        return self.max_rep > 0


# ---------------------------------------------------------------------------
# Path discovery & def-code assignment
# ---------------------------------------------------------------------------


def leaf_paths(typ: T.DataType) -> PyList[Tuple[Tuple[str, ...], Tuple[T.DataType, ...]]]:
    """Enumerate (field-name path, type path) for every leaf of ``typ``.

    FixedSizeList is a leaf (the paper treats primitive FSL as primitive).
    """
    out: PyList[Tuple[Tuple[str, ...], Tuple[T.DataType, ...]]] = []

    def walk(node: T.DataType, names: Tuple[str, ...], nodes: Tuple[T.DataType, ...]):
        nodes = nodes + (node,)
        if isinstance(node, T.Struct):
            if not node.fields:
                raise ValueError("empty struct cannot be shredded")
            for fname, ftyp in node.fields:
                walk(ftyp, names + (fname,), nodes)
        elif isinstance(node, T.List):
            walk(node.child, names, nodes)
        else:
            out.append((names, nodes))

    walk(typ, (), ())
    return out


def _def_codes(type_path: Tuple[T.DataType, ...]):
    """Assign def codes for a leaf path.

    Returns (codes, meanings, max_def, null_item_code) where ``codes`` maps
    (node_index_in_path, event) -> code; event in {"null_item", "empty",
    "null_list", "null_struct"}.
    """
    codes: Dict[Tuple[int, str], int] = {}
    meanings: Dict[int, str] = {0: "valid"}
    nxt = 1
    # walk leaf -> root
    for i in range(len(type_path) - 1, -1, -1):
        node = type_path[i]
        is_leaf = i == len(type_path) - 1
        if is_leaf:
            if node.nullable:
                codes[(i, "null_item")] = nxt
                meanings[nxt] = "null_item"
                nxt += 1
        elif isinstance(node, T.List):
            codes[(i, "empty")] = nxt
            meanings[nxt] = f"empty_list@{i}"
            nxt += 1
            if node.nullable:
                codes[(i, "null_list")] = nxt
                meanings[nxt] = f"null_list@{i}"
                nxt += 1
        elif isinstance(node, T.Struct):
            if node.nullable:
                codes[(i, "null_struct")] = nxt
                meanings[nxt] = f"null_struct@{i}"
                nxt += 1
        else:  # pragma: no cover - interior nodes are Struct/List only
            raise TypeError(node)
    max_def = nxt - 1
    null_item = codes.get((len(type_path) - 1, "null_item"), 0)
    return codes, meanings, max_def, null_item


# ---------------------------------------------------------------------------
# Shredding (vectorized walk)
# ---------------------------------------------------------------------------


def _exclusive_cumsum(x: np.ndarray) -> np.ndarray:
    out = np.zeros(len(x), dtype=np.int64)
    np.cumsum(x[:-1], out=out[1:])
    return out


def shred(arr: A.Array) -> PyList[ShreddedLeaf]:
    """Shred a nested array into leaf columns."""
    leaves = []
    for names, type_path in leaf_paths(arr.type):
        leaves.append(_shred_leaf(arr, names, type_path))
    return leaves


def _shred_leaf(arr: A.Array, names: Tuple[str, ...], type_path) -> ShreddedLeaf:
    codes, meanings, max_def, null_item = _def_codes(type_path)
    # dremel depth (1-based among List nodes, from the top) for each List node
    list_nodes = [i for i, n in enumerate(type_path) if isinstance(n, T.List)]
    max_rep = len(list_nodes)
    dremel_depth = {node_i: d + 1 for d, node_i in enumerate(list_nodes)}

    n = len(arr)
    idx = np.arange(n, dtype=np.int64)
    rep = np.zeros(n, dtype=np.uint8)  # dremel convention during the walk
    defs = np.zeros(n, dtype=np.uint8)

    node_arr: A.Array = arr
    name_cursor = 0
    for node_i, node in enumerate(type_path):
        is_leaf = node_i == len(type_path) - 1
        if is_leaf:
            live = idx >= 0
            leaf_valid = np.zeros(len(idx), dtype=bool)
            leaf_valid[live] = node_arr.validity[idx[live]]
            if node.nullable:
                null_mask = live & ~leaf_valid
                defs[null_mask] = codes[(node_i, "null_item")]
            else:
                assert bool(np.all(leaf_valid[live])), "null in non-nullable leaf"
            take_idx = idx[live & leaf_valid]
            values = node_arr.take(take_idx)
            values.type = values.type.with_nullable(False)
            values.validity = np.ones(len(take_idx), dtype=bool)
            break
        if isinstance(node, T.Struct):
            live = idx >= 0
            valid = np.zeros(len(idx), dtype=bool)
            valid[live] = node_arr.validity[idx[live]]
            if node.nullable:
                null_mask = live & ~valid
                defs[null_mask] = codes[(node_i, "null_struct")]
                idx = np.where(null_mask, -1, idx)
            else:
                assert bool(np.all(valid[live])), "null in non-nullable struct"
            node_arr = node_arr.field(names[name_cursor])
            name_cursor += 1
        elif isinstance(node, T.List):
            d = dremel_depth[node_i]
            live = idx >= 0
            valid = np.zeros(len(idx), dtype=bool)
            valid[live] = node_arr.validity[idx[live]]
            safe_idx = np.where(live, idx, 0)
            diffs = node_arr.offsets[1:] - node_arr.offsets[:-1]
            if len(diffs):
                lengths = diffs[safe_idx]
            else:  # node has zero rows (everything terminated above)
                lengths = np.zeros(len(idx), dtype=np.int64)
            lengths = np.where(live & valid, lengths, 0)

            if node.nullable:
                null_mask = live & ~valid
                defs[null_mask] = codes[(node_i, "null_list")]
            else:
                assert bool(np.all(valid[live])), "null in non-nullable list"
            empty_mask = live & valid & (lengths == 0)
            defs[empty_mask] = codes[(node_i, "empty")]

            expand = live & valid & (lengths > 0)
            counts = np.where(expand, lengths, 1)
            starts = _exclusive_cumsum(counts)
            new_m = int(counts.sum())
            # rep: inherit for first element of each group, ``d`` for the rest
            new_rep = np.repeat(rep, counts)
            is_first = np.zeros(new_m, dtype=bool)
            is_first[starts] = True
            new_rep[~is_first] = d
            # defs: carry (live expanded entries keep 0 and get set later)
            new_def = np.repeat(defs, counts)
            # idx: child offsets for expanded; -1 otherwise
            local = np.arange(new_m, dtype=np.int64) - np.repeat(starts, counts)
            base_offs = node_arr.offsets[:-1]
            base_vals = (base_offs[safe_idx] if len(base_offs)
                         else np.zeros(len(idx), dtype=np.int64))
            child_base = np.repeat(np.where(expand, base_vals, -1), counts)
            new_idx = np.where(child_base >= 0, child_base + local, -1)
            idx, rep, defs = new_idx, new_rep, new_def
            node_arr = node_arr.child
        else:  # pragma: no cover
            raise TypeError(node)

    # Convert dremel rep -> lance rep: lance = number of innermost list levels
    # restarted.  dremel r == 0 restarts all; r == depth j restarts levels
    # deeper than j, i.e. (max_rep - j) innermost levels.
    if max_rep > 0:
        lance_rep = (max_rep - rep).astype(np.uint8)
    else:
        lance_rep = None

    leaf_type = type_path[-1]
    return ShreddedLeaf(
        path=names,
        type_path=tuple(type_path),
        leaf_type=leaf_type,
        rep=lance_rep,
        defs=defs if max_def > 0 else None,
        values=values,
        n_entries=len(idx),
        max_rep=max_rep,
        max_def=max_def,
        def_meanings=meanings,
        null_item_code=null_item,
        n_rows=n,
    )


# ---------------------------------------------------------------------------
# Unshredding (inverse)
# ---------------------------------------------------------------------------


def unshred(leaves: PyList[ShreddedLeaf], root_type: T.DataType) -> A.Array:
    """Reassemble a nested array from its shredded leaves."""
    projections = [(_unshred_leaf(leaf), leaf.path) for leaf in leaves]
    return _merge(root_type, projections)


def _unshred_leaf(leaf: ShreddedLeaf) -> A.Array:
    """Reconstruct one leaf as a 'projection' array: the original type path
    with every Struct level narrowed to the single traversed field."""
    codes, _, _, _ = _def_codes(leaf.type_path)
    defs = (
        leaf.defs
        if leaf.defs is not None
        else np.zeros(leaf.n_entries, dtype=np.uint8)
    )
    rep = (
        leaf.rep
        if leaf.rep is not None
        else np.full(leaf.n_entries, 0, dtype=np.uint8)
    )
    return _build(
        leaf, leaf.type_path, 0, np.arange(leaf.n_entries), defs, rep, leaf.max_rep
    )


def _slots(rep_vals: np.ndarray, slot_level: int):
    """Group an entry run into slots: a new slot starts wherever the entry
    restarts list level ``slot_level`` or any outer level."""
    starts = rep_vals >= slot_level
    if len(starts) > 0:
        starts = starts.copy()
        starts[0] = True
    seg = np.cumsum(starts) - 1  # slot id per entry
    n_slots = int(seg[-1] + 1) if len(starts) else 0
    first_of_slot = np.nonzero(starts)[0]
    return starts, seg, n_slots, first_of_slot


def _build(
    leaf: ShreddedLeaf,
    type_path,
    node_i: int,
    entries: np.ndarray,  # indices into the global entry stream handled here
    defs: np.ndarray,
    rep: np.ndarray,
    slot_level: int,  # entries with rep >= slot_level begin a new slot here
) -> A.Array:
    node = type_path[node_i]
    is_leaf = node_i == len(type_path) - 1
    codes, _, _, _ = _def_codes(type_path)
    d = defs[entries]

    if is_leaf:
        # Entries reaching the leaf are either valid values (def == 0), null
        # items, or entries terminated at an enclosing *struct* level (which
        # still occupy a slot in the child arrays, Arrow-style).  Entries
        # terminated at list levels were consumed by the list builders above.
        valid = d == 0
        # map valid entries to consecutive value slots -- the value array is
        # sparse & ordered, so slot = rank of the entry among valid entries of
        # the *whole* stream.  Compute global ranks once.
        global_valid = (
            (leaf.defs == 0) if leaf.defs is not None else np.ones(leaf.n_entries, bool)
        )
        ranks = np.cumsum(global_valid) - 1
        out_n = len(entries)
        validity = valid.copy()
        take = ranks[entries[valid]]
        vals = leaf.values.take(take)
        return _scatter_leaf(leaf.leaf_type, out_n, validity, valid, vals)

    if isinstance(node, T.Struct):
        null_code = codes.get((node_i, "null_struct"), None)
        r = rep[entries]
        starts, seg, n_slots, first_of_slot = _slots(r, slot_level)
        d_first = d[first_of_slot] if n_slots else np.zeros(0, dtype=d.dtype)
        is_null = (
            (d_first == null_code) if null_code is not None else np.zeros(n_slots, bool)
        )
        # termination ABOVE this struct also yields an (invalid) slot here
        if null_code is not None:
            slot_above = d_first > null_code
        else:
            # codes above this struct are those > every code at/below it; the
            # largest code at/below is the max over codes of deeper nodes.
            below = [c for (ni, _), c in codes.items() if ni >= node_i]
            slot_above = d_first > max(below) if below else np.zeros(n_slots, bool)
        # Children see the SAME entries and the SAME slot structure (struct
        # does not expand); entries null at this struct still occupy one slot
        # below (Arrow keeps child slots for null struct rows).
        child = _build(leaf, type_path, node_i + 1, entries, defs, rep, slot_level)
        name = leaf.path[sum(1 for t in type_path[:node_i] if isinstance(t, T.Struct))]
        validity = ~(is_null | slot_above)
        typ = T.Struct(((name, child.type),), node.nullable)
        return A.StructArray(typ, validity, ((name, child),))

    if isinstance(node, T.List):
        level = slot_level  # this list's lance level (innermost == 1)
        empty_code = codes[(node_i, "empty")]
        null_code = codes.get((node_i, "null_list"), None)
        r = rep[entries]
        starts, seg, n_slots, first_of_slot = _slots(r, level)
        d_first = d[first_of_slot] if n_slots else np.zeros(0, dtype=d.dtype)
        slot_is_null = (
            (d_first == null_code) if null_code is not None else np.zeros(n_slots, bool)
        )
        slot_is_empty = d_first == empty_code
        # termination ABOVE this list (def codes assigned later in leaf->root
        # order are strictly larger than this list's codes)
        above_threshold = max(empty_code, null_code or 0)
        slot_above = d_first > above_threshold
        element_slot = ~(slot_is_null | slot_is_empty | slot_above)
        # element entries: those in element slots
        entry_is_element = element_slot[seg]
        child_entries = entries[entry_is_element]
        # This list's lengths count CHILD SLOTS (e.g. inner lists), not raw
        # entries: a child slot starts where rep restarts level-1 or outer.
        child_starts = rep[child_entries] >= (level - 1)
        lengths = np.bincount(
            seg[entry_is_element][child_starts], minlength=n_slots
        ).astype(np.int64)
        offsets = np.zeros(n_slots + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        child = _build(
            leaf, type_path, node_i + 1, child_entries, defs, rep, level - 1
        )
        validity = ~(slot_is_null | slot_above)
        return A.ListArray(T.List(child.type, node.nullable), validity, offsets, child)

    raise TypeError(node)  # pragma: no cover


def _scatter_leaf(leaf_type: T.DataType, out_n: int, validity: np.ndarray, valid_mask: np.ndarray, vals: A.Array) -> A.Array:
    """Scatter sparse values into a dense (with nulls) leaf array."""
    if isinstance(leaf_type, T.Primitive):
        out = np.zeros(out_n, dtype=np.dtype(leaf_type.dtype))
        out[valid_mask] = vals.values
        return A.PrimitiveArray(leaf_type, validity, out)
    if isinstance(leaf_type, T.FixedSizeList):
        out = np.zeros((out_n, leaf_type.size), dtype=np.dtype(leaf_type.child.dtype))
        out[valid_mask] = vals.values
        return A.FixedSizeListArray(leaf_type, validity, out)
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        lengths = np.zeros(out_n, dtype=np.int64)
        lengths[valid_mask] = vals.offsets[1:] - vals.offsets[:-1]
        offsets = np.zeros(out_n + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        return A.VarBinaryArray(leaf_type, validity, offsets, vals.data.copy())
    raise TypeError(leaf_type)


def _merge(typ: T.DataType, projections) -> A.Array:
    """Merge per-leaf projection arrays back into the full nested array."""
    if isinstance(typ, T.Struct):
        groups: Dict[str, list] = {}
        validity = None
        for arr, path in projections:
            assert isinstance(arr, A.StructArray)
            name = arr.children[0][0]
            groups.setdefault(name, []).append((arr.children[0][1], path[1:]))
            validity = arr.validity if validity is None else validity
        children = []
        for fname, ftyp in typ.fields:
            sub = _merge(ftyp, groups[fname])
            children.append((fname, sub))
        return A.StructArray(typ, validity, tuple(children))
    if isinstance(typ, T.List):
        # all projections share offsets/validity at this level
        first = projections[0][0]
        assert isinstance(first, A.ListArray)
        child_projs = [(arr.child, path) for arr, path in projections]
        child = _merge(typ.child, child_projs)
        return A.ListArray(typ, first.validity, first.offsets, child)
    # leaf
    arr = projections[0][0]
    arr.type = typ
    return arr
