"""Arrow-style structural encoding (paper §3.2) — the second baseline.

The nested array is stored as Arrow's dense flat buffers: one validity
bitmap per nullable level, one offsets buffer per list / var-width level, and
a values buffer.  No pages, no rep/def levels, no search cache.  Random
access must chase offsets level by level — the paper's Fig. 4 shows 5 IOPS in
3 dependent phases for ``List<String>``; this reader reproduces exactly those
counts.  Optional whole-buffer compression renders the column opaque, which
is why compressed Arrow files cannot do random access (§6.2).

This is also the structural encoding of the Lance 2.0 format that the paper
benchmarks as its "Arrow-style" representative (§5.3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import arrays as A
from . import types as T
from .encodings_base import EncodedColumn, pad_to

# one codec-selection point for the whole repo: compression.py already
# resolves zstandard-or-zlib, so Arrow buffers use the exact same pair
from .compression import _ZSTD_C as _C, _ZSTD_D as _D

__all__ = ["encode_arrow", "ArrowReader"]


def _collect_buffers(arr: A.Array, path: str, out: List[Tuple[str, str, np.ndarray, int]]):
    """Flatten into (name, role, bytes, logical_len) buffers, Arrow layout."""
    n = len(arr)
    if arr.type.nullable:
        out.append((path, "validity", np.packbits(arr.validity, bitorder="little"), n))
    if isinstance(arr, A.PrimitiveArray):
        out.append((path, "values", np.frombuffer(np.ascontiguousarray(arr.values).tobytes(), np.uint8), n))
    elif isinstance(arr, A.FixedSizeListArray):
        out.append((path, "values", np.frombuffer(np.ascontiguousarray(arr.values).tobytes(), np.uint8), n))
    elif isinstance(arr, A.VarBinaryArray):
        out.append((path, "offsets", np.frombuffer(arr.offsets.tobytes(), np.uint8), n + 1))
        out.append((path, "data", arr.data, int(arr.offsets[-1])))
    elif isinstance(arr, A.ListArray):
        out.append((path, "offsets", np.frombuffer(arr.offsets.tobytes(), np.uint8), n + 1))
        _collect_buffers(arr.child, path + ".item", out)
    elif isinstance(arr, A.StructArray):
        for name, c in arr.children:
            _collect_buffers(c, path + "." + name, out)
    else:  # pragma: no cover
        raise TypeError(type(arr))


def encode_arrow(arr: A.Array, compress: bool = False) -> EncodedColumn:
    bufs: List[Tuple[str, str, np.ndarray, int]] = []
    _collect_buffers(arr, "c", bufs)
    payload = b""
    meta_bufs = []
    for name, role, data, ln in bufs:
        raw = data.tobytes()
        if compress:
            raw = _C.compress(raw)
        off = len(payload)
        payload += pad_to(raw)
        meta_bufs.append({"name": name, "role": role, "offset": off,
                          "size": len(raw), "len": ln})
    meta = {
        "encoding": "arrow",
        "buffers": meta_bufs,
        "n_rows": len(arr),
        "compressed": compress,
    }
    # Arrow needs no search cache: buffer locations are footer metadata.
    return EncodedColumn("arrow", payload, meta, search_cache_bytes=0)


@dataclasses.dataclass
class _Buf:
    offset: int
    size: int
    len: int


class ArrowReader:
    """Reads the Arrow layout.  Returns nested ``Array`` values directly
    (this encoding has no rep/def streams)."""

    def __init__(self, meta: Dict, base: int, typ: T.DataType):
        self.meta = meta
        self.base = base
        self.type = typ
        self.bufs: Dict[Tuple[str, str], _Buf] = {
            (b["name"], b["role"]): _Buf(b["offset"], b["size"], b["len"])
            for b in meta["buffers"]
        }
        self._full_cache: Dict[Tuple[str, str], np.ndarray] = {}

    # -- raw access helpers ----------------------------------------------
    def _read_full(self, io, key, phase=0) -> np.ndarray:
        if key in self._full_cache:
            return self._full_cache[key]
        b = self.bufs[key]
        raw = io.read(self.base + b.offset, b.size, phase=phase)
        if self.meta["compressed"]:
            raw = np.frombuffer(_D.decompress(raw.tobytes()), np.uint8)
        self._full_cache[key] = raw
        return raw

    def _read_slices(self, io, key, byte_lo: np.ndarray, byte_hi: np.ndarray,
                     phase: int):
        """Batched per-buffer slice reads: all spans of one buffer go out as
        a single ``read_many`` dispatch (one logical op per span, exactly
        the trace the per-row reader produced); opaque (compressed) buffers
        are fetched whole once and sliced in memory.  Returns
        ``(data, doffs)``."""
        sizes = byte_hi - byte_lo
        if self.meta["compressed"]:
            # opaque: the entire buffer is fetched (once) + decompressed
            full = self._read_full(io, key, phase)
            doffs = np.zeros(len(sizes) + 1, dtype=np.int64)
            np.cumsum(sizes, out=doffs[1:])
            src = A.ragged_indices(byte_lo, sizes)
            return (full[src] if len(src) else np.zeros(0, np.uint8)), doffs
        b = self.bufs[key]
        return io.read_many(self.base + b.offset + byte_lo, sizes, phase=phase)

    # -- take --------------------------------------------------------------
    def take(self, rows: np.ndarray, io) -> A.Array:
        # cold random access: opaque (compressed) buffers must be re-fetched
        # per operation -- this is why compressed Arrow cannot random access
        # (paper sec 6.2)
        self._full_cache = {}
        rows = np.asarray(rows, dtype=np.int64)
        if len(rows) == 0:
            return A.from_pylist([], self.type)
        out = self._take_node(io, self.type, "c", rows, rows + 1, 0)
        io.note_useful(_array_nbytes(out))
        return out

    def _take_node(self, io, typ: T.DataType, path: str, lo: np.ndarray,
                   hi: np.ndarray, phase: int) -> A.Array:
        """Fetch the row ranges ``[lo_k, hi_k)`` of the node at ``path`` for
        all requested rows at once; ``phase`` counts the dependent round
        trips needed to learn the ranges.  Per-row spans are identical to
        the historical one-row-at-a-time reader — only the dispatch is
        batched (one ``read_many`` per buffer per level) and the extraction
        vectorized."""
        n_per = hi - lo
        n = int(n_per.sum())
        if typ.nullable:
            byte_lo = lo // 8
            byte_hi = (hi - 1) // 8 + 1  # empty ranges collapse to 0 bytes
            raw, doffs = self._read_slices(io, (path, "validity"), byte_lo,
                                           byte_hi, phase)
            bits = np.unpackbits(raw, bitorder="little")
            src = A.ragged_indices(doffs[:-1] * 8 + (lo - byte_lo * 8), n_per)
            validity = bits[src].astype(bool) if n else np.zeros(0, bool)
        else:
            validity = np.ones(n, bool)
        if isinstance(typ, (T.Primitive, T.FixedSizeList)):
            if isinstance(typ, T.Primitive):
                dt, w = np.dtype(typ.dtype), np.dtype(typ.dtype).itemsize
            else:
                dt = np.dtype(typ.child.dtype)
                w = dt.itemsize * typ.size
            raw, _ = self._read_slices(io, (path, "values"), lo * w, hi * w,
                                       phase)
            vals = np.frombuffer(raw.tobytes(), dt)
            if isinstance(typ, T.Primitive):
                return A.PrimitiveArray(typ, validity, vals[:n])
            return A.FixedSizeListArray(typ, validity,
                                        vals.reshape(n, typ.size))
        if isinstance(typ, (T.Utf8, T.Binary, T.List)):
            offs, local = self._offsets_vectors(io, path, lo, hi, phase)
            clo, chi = offs[:, 0], offs[:, 1]
            if isinstance(typ, T.List):
                child = self._take_node(io, typ.child, path + ".item", clo,
                                        chi, phase + 1)
                return A.ListArray(typ, validity, local, child)
            data, _ = self._read_slices(io, (path, "data"), clo, chi,
                                        phase + 1)
            return A.VarBinaryArray(typ, validity, local, np.asarray(data))
        if isinstance(typ, T.Struct):
            children = tuple(
                (nm, self._take_node(io, ft, path + "." + nm, lo, hi, phase))
                for nm, ft in typ.fields
            )
            return A.StructArray(typ, validity, children)
        raise TypeError(typ)  # pragma: no cover

    def _offsets_vectors(self, io, path: str, lo: np.ndarray, hi: np.ndarray,
                         phase: int):
        """Fetch each range's ``n_k + 1`` offsets in one batched dispatch.
        Returns ``(ranges, local)``: per-range ``(first, last)`` child
        bounds, plus the concatenated request-order offsets vector rebased
        so ranges chain contiguously (what ``A.concat`` built row by row)."""
        raw, doffs = self._read_slices(io, (path, "offsets"), lo * 8,
                                       (hi + 1) * 8, phase)
        all_offs = np.frombuffer(raw.tobytes(), np.int64)
        n_per = hi - lo
        first = all_offs[doffs[:-1] // 8]
        last = all_offs[doffs[1:] // 8 - 1]
        # request-order lengths: drop each range's leading offset, diff the rest
        keep = np.ones(len(all_offs), dtype=bool)
        keep[doffs[:-1] // 8] = False
        lens = all_offs[keep] - all_offs[
            np.nonzero(keep)[0] - 1] if keep.any() else np.zeros(0, np.int64)
        local = np.zeros(int(n_per.sum()) + 1, dtype=np.int64)
        np.cumsum(lens, out=local[1:])
        return np.stack([first, last], axis=1), local

    # -- scan ----------------------------------------------------------------
    def scan(self, io) -> A.Array:
        self._full_cache = {}
        arr = self._scan_node(io, self.type, "c")
        return arr

    def _scan_node(self, io, typ: T.DataType, path: str) -> A.Array:
        if typ.nullable:
            raw = self._read_full(io, (path, "validity"))
            n = self.bufs[(path, "validity")].len
            validity = np.unpackbits(raw, bitorder="little")[:n].astype(bool)
        else:
            n = None
            validity = None
        if isinstance(typ, T.Primitive):
            raw = self._read_full(io, (path, "values"))
            vals = np.frombuffer(raw.tobytes(), np.dtype(typ.dtype))
            n = self.bufs[(path, "values")].len
            vals = vals[:n]
            v = validity if validity is not None else np.ones(n, bool)
            return A.PrimitiveArray(typ, v, vals)
        if isinstance(typ, T.FixedSizeList):
            raw = self._read_full(io, (path, "values"))
            n = self.bufs[(path, "values")].len
            vals = np.frombuffer(raw.tobytes(), np.dtype(typ.child.dtype))[: n * typ.size]
            v = validity if validity is not None else np.ones(n, bool)
            return A.FixedSizeListArray(typ, v, vals.reshape(n, typ.size))
        if isinstance(typ, (T.Utf8, T.Binary)):
            offs_raw = self._read_full(io, (path, "offsets"))
            n = self.bufs[(path, "offsets")].len - 1
            offs = np.frombuffer(offs_raw.tobytes(), np.int64, count=n + 1)
            data = self._read_full(io, (path, "data"))[: int(offs[-1])]
            v = validity if validity is not None else np.ones(n, bool)
            return A.VarBinaryArray(typ, v, offs.copy(), np.asarray(data))
        if isinstance(typ, T.List):
            offs_raw = self._read_full(io, (path, "offsets"))
            n = self.bufs[(path, "offsets")].len - 1
            offs = np.frombuffer(offs_raw.tobytes(), np.int64, count=n + 1)
            child = self._scan_node(io, typ.child, path + ".item")
            v = validity if validity is not None else np.ones(n, bool)
            return A.ListArray(typ, v, offs.copy(), child)
        if isinstance(typ, T.Struct):
            children = tuple((nm, self._scan_node(io, ft, path + "." + nm)) for nm, ft in typ.fields)
            n = len(children[0][1])
            v = validity if validity is not None else np.ones(n, bool)
            return A.StructArray(typ, v, children)
        raise TypeError(typ)  # pragma: no cover


def _array_nbytes(arr: A.Array) -> int:
    if isinstance(arr, (A.PrimitiveArray, A.FixedSizeListArray)):
        return int(arr.values.nbytes)
    if isinstance(arr, A.VarBinaryArray):
        return int(arr.offsets[-1])
    if isinstance(arr, A.ListArray):
        return _array_nbytes(arr.child)
    if isinstance(arr, A.StructArray):
        return sum(_array_nbytes(c) for _, c in arr.children)
    return 0
