"""The full-zip structural encoding (paper §4.1).

For large data types (≥128 B/value) the control word (bit-packed rep/def,
§4.1.1), the per-value length (§4.1.2) and the transparently-compressed value
bytes (§4.1.3) are zipped row-major into a single buffer.  A bit-packed
**repetition index** (§4.1.4) of row start offsets enables random access in at
most 2 IOPS regardless of nesting; fixed-width columns without repetition
need no index at all (1 IOP).  Nulls in fixed-width columns are dense filler
bytes; variable-width nulls are a control word only.  There is **no search
cache** (§4.2.4) beyond any codec dictionary/symbol table.

Random access is batched (see :meth:`FullZipReader.take`): requested rows
are deduplicated before any IO, all index reads go out as one phase-0
``read_many`` batch and all zipped spans as one phase-1 batch, the
concatenated spans are decoded in a single pass, and one permutation fans
the decoded rows back out to request order.  Per-unique-row IOPS and bytes
match the historical per-row reader exactly.

Decode is **row-parallel**, not per-value.  Variable-width entry positions
depend on embedded lengths (the paper's §6.3/Fig 17 decode cost), but the
dependency chain only runs *within* a row: ``take`` already knows every
row's ``[lo, hi)`` byte span from the repetition index, so a vectorized
numpy frontier advances one entry *per row* per step — iterations are
bounded by max-entries-per-row, not total values, and flat columns (one
entry per row) decode in a single fully-vectorized step.  ``scan`` has no
row spans (the repetition index is never read on a scan, §4.1.4) and uses
log-step pointer doubling over each bounded window instead: the
entry-successor map is built for every byte position in one vectorized
pass, then squared ``log2(entries)`` times to enumerate all entry starts.
Once entry positions are known, control words, length prefixes and value
bytes are all sliced out in one gather pass each.  The historical per-value
walk is retained as ``_decode_entries_walk`` — it is the property-test
oracle and the decode benchmark's baseline.

Fixed-stride columns additionally have a fused device gather route
(``decode="pallas"``): the request-order fan-out permutation runs as one
``kernels.fullzip_gather`` block-table DMA gather over the unique zipped
rows instead of a host permutation.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from . import arrays as A
from . import types as T
from .compression import Encoded, get_bytes_codec, get_fixed_codec
from .encodings_base import (
    ColumnReader,
    EncodedColumn,
    empty_leaf,
    leaf_slice,
    reorder_leaf_rows,
)
from .rdlevels import (
    control_word_width,
    gather_le,
    level_bits,
    pack_control_words,
    unpack_control_words,
)
from .shred import ShreddedLeaf

__all__ = ["encode_fullzip", "FullZipReader"]


def _len_field_width(max_len: int) -> int:
    """Per-value length prefix, bit-packed to the nearest byte (<=8 bytes)."""
    w = max(1, (int(max_len).bit_length() + 7) // 8)
    assert w <= 8
    return w


def _le_bytes(values: np.ndarray, width: int) -> np.ndarray:
    """(n, width) little-endian byte matrix for non-negative ints."""
    v = values.astype(np.uint64)
    shifts = np.arange(width, dtype=np.uint64) * np.uint64(8)
    return ((v[:, None] >> shifts[None, :]) & np.uint64(0xFF)).astype(np.uint8)


def _from_le(mat: np.ndarray) -> np.ndarray:
    shifts = np.arange(mat.shape[1], dtype=np.uint64) * np.uint64(8)
    return (mat.astype(np.uint64) << shifts[None, :]).sum(axis=1, dtype=np.uint64)


def encode_fullzip(
    leaf: ShreddedLeaf,
    fixed_codec: str = "plain",
    bytes_codec: str = "plain_bytes",
) -> EncodedColumn:
    n = leaf.n_entries
    W = control_word_width(leaf.max_rep, leaf.max_def)
    cw = (
        pack_control_words(leaf.rep, leaf.defs, leaf.max_rep, leaf.max_def).reshape(n, W)
        if W
        else np.zeros((n, 0), dtype=np.uint8)
    )
    valid = (leaf.defs == 0) if leaf.defs is not None else np.ones(n, bool)
    n_valid = int(valid.sum())

    is_var = isinstance(leaf.leaf_type, (T.Utf8, T.Binary))
    search_cache = 0
    if is_var:
        bc = get_bytes_codec(bytes_codec)
        assert bc.transparent, "full-zip requires transparent compression (paper 4.1.3)"
        lengths = (leaf.values.offsets[1:] - leaf.values.offsets[:-1]).astype(np.uint64)
        enc = bc.encode(lengths, leaf.values.data)
        vlens = np.asarray(enc.out_lengths, dtype=np.int64)
        L = _len_field_width(int(vlens.max()) if len(vlens) else 1)
        # entry sizes: cw + (len field + bytes) for valid; cw only for null
        sizes = np.full(n, W, dtype=np.int64)
        sizes[valid] += L + vlens
        offs = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(sizes, out=offs[1:])
        out = np.zeros(int(offs[-1]), dtype=np.uint8)
        for b in range(W):
            out[offs[:-1] + b] = cw[:, b]
        vpos = offs[:-1][valid] + W
        lmat = _le_bytes(vlens.astype(np.uint64), L)
        for b in range(L):
            out[vpos + b] = lmat[:, b]
        # scatter value bytes
        out[A.ragged_indices(vpos + L, vlens)] = enc.data
        codec_meta = {k: v for k, v in enc.meta.items()}
        if "syms" in codec_meta:
            search_cache += sum(len(s) + 2 for s in codec_meta["syms"])
        vw = None
    else:
        fc = get_fixed_codec(fixed_codec)
        assert fc.transparent
        if isinstance(leaf.leaf_type, T.FixedSizeList):
            enc = fc.encode(leaf.values.values.reshape(-1))
            elem_w = fc.encoded_width(enc)
            assert elem_w is not None, "full-zip fixed path needs byte-aligned codec"
            vw = elem_w * leaf.leaf_type.size
        else:
            enc = fc.encode(leaf.values.values)
            vw = fc.encoded_width(enc)
            assert vw is not None, "full-zip fixed path needs byte-aligned codec"
        L = 0
        stride = W + vw
        out = np.zeros(n * stride, dtype=np.uint8)
        view = out.reshape(n, stride)
        if W:
            view[:, :W] = cw
        # dense: filler zeros where invalid (paper 4.1.3)
        vmat = enc.data.reshape(n_valid, vw) if n_valid else np.zeros((0, vw), np.uint8)
        view[valid, W:] = vmat
        codec_meta = enc.meta
        if "dict" in codec_meta:
            search_cache += int(np.asarray(codec_meta["dict"]).nbytes)
        offs = (np.arange(n + 1, dtype=np.int64) * stride)

    # repetition index: row start byte offsets (+ total), needed when rows
    # are not fixed-stride addressable
    has_rep_index = leaf.max_rep > 0 or is_var
    if leaf.max_rep > 0:
        row_start_mask = leaf.rep == leaf.max_rep
    else:
        row_start_mask = np.ones(n, dtype=bool)
    if has_rep_index:
        row_offsets = np.concatenate([offs[:-1][row_start_mask], offs[-1:]])
        R = _len_field_width(int(offs[-1]) if n else 1)
        ri_bytes = _le_bytes(row_offsets.astype(np.uint64), R).reshape(-1)
        payload = ri_bytes.tobytes() + out.tobytes()
        zip_base = len(ri_bytes)
    else:
        R = 0
        payload = out.tobytes()
        zip_base = 0

    meta = {
        "encoding": "fullzip",
        "W": W,
        "L": L,
        "vw": vw,
        "R": R,
        "zip_base": zip_base,
        "zip_bytes": int(offs[-1]),
        "n_rows": leaf.n_rows,
        "n_entries": n,
        "has_rep_index": has_rep_index,
        "fixed_codec": fixed_codec,
        "bytes_codec": bytes_codec,
        "codec_meta": codec_meta,
    }
    return EncodedColumn("fullzip", payload, meta, search_cache)


class FullZipReader(ColumnReader):
    """Full-zip random access + scan with row-parallel decode.

    ``decode`` selects the fixed-stride take's fan-out route: ``"numpy"``
    (host :func:`reorder_leaf_rows` permutation) or ``"pallas"`` (one
    ``kernels.fullzip_gather`` block-table DMA gather over the unique
    zipped rows; interpret mode on CPU, Mosaic on TPU).  The logical IO
    trace is identical either way.
    """

    _DECODE_WINDOW = 1 << 20  # chain-discovery sub-window (see scan)

    def __init__(self, meta: Dict, base: int, leaf_proto: ShreddedLeaf,
                 decode: str = "numpy"):
        super().__init__(meta, base, leaf_proto)
        if decode not in ("numpy", "pallas"):
            raise ValueError(f"decode must be 'numpy'|'pallas', got {decode!r}")
        self.decode = decode

    # -- fixed-stride decode -------------------------------------------
    def _decode_fixed(self, raw: np.ndarray):
        """Strided decode of ``[control word | value bytes]`` entries."""
        m = self.meta
        W, vw = m["W"], m["vw"]
        max_rep, max_def = self.proto.max_rep, self.proto.max_def
        stride = W + vw
        n = len(raw) // stride
        mat = raw[: n * stride].reshape(n, stride)
        rep, defs = (
            unpack_control_words(mat[:, :W].reshape(-1), n, max_rep, max_def)
            if W
            else (None, None)
        )
        valid = (defs == 0) if defs is not None else np.ones(n, bool)
        vbytes = mat[valid, W:].reshape(-1)
        fc = get_fixed_codec(m["fixed_codec"])
        enc = Encoded(vbytes, m["codec_meta"])
        n_valid = int(valid.sum())
        if isinstance(self.proto.leaf_type, T.FixedSizeList):
            size = self.proto.leaf_type.size
            flat = fc.decode(enc, n_valid * size)
            vals = A.FixedSizeListArray(
                self.proto.leaf_type.with_nullable(False),
                np.ones(n_valid, bool),
                np.asarray(flat).reshape(n_valid, size),
            )
        else:
            vals = A.PrimitiveArray(
                self.proto.leaf_type.with_nullable(False),
                np.ones(n_valid, bool),
                np.asarray(fc.decode(enc, n_valid)),
            )
        return rep, defs, vals

    # -- variable-width entry discovery --------------------------------
    def _advance_at(self, raw: np.ndarray, pos: np.ndarray):
        """Vectorized entry-size probe: for control words at byte positions
        ``pos`` return ``(advance, valid, vlen)``.  Reads past the buffer
        end return garbage (clipped gathers); callers bound ``pos`` so only
        lanes whose header truly fits are trusted."""
        m = self.meta
        W, L = m["W"], m["L"]
        db = level_bits(self.proto.max_def)
        if W and db:
            word = gather_le(raw, pos, W)
            valid = (word & np.uint64((1 << db) - 1)) == 0
        else:
            valid = np.ones(len(pos), dtype=bool)
        vlen = np.where(valid, gather_le(raw, pos + W, L), 0).astype(np.int64)
        adv = W + np.where(valid, L + vlen, 0)
        return adv, valid, vlen

    def _entry_starts_rows(self, raw: np.ndarray, seg_offs: np.ndarray) -> np.ndarray:
        """Row-parallel frontier walk: ``seg_offs`` are the ``n_seg + 1``
        byte bounds of independent row segments inside ``raw``.  One
        frontier position per row advances one entry per vectorized step, so
        steps are bounded by max-entries-per-row; flat columns finish in one
        step.  Returns every entry's control-word position in buffer order.
        """
        pos = seg_offs[:-1].astype(np.int64).copy()
        ends = seg_offs[1:].astype(np.int64)
        n_seg = len(pos)
        active = np.nonzero(pos < ends)[0]
        pos_steps: List[np.ndarray] = []
        row_steps: List[np.ndarray] = []
        while len(active):
            cur = pos[active]
            pos_steps.append(cur)
            row_steps.append(active)
            adv, _, _ = self._advance_at(raw, cur)
            pos[active] = cur + adv
            active = active[pos[active] < ends[active]]
        if not pos_steps:
            return np.zeros(0, dtype=np.int64)
        # entries were emitted step-major; rebuild buffer (row-major) order
        # with one direct index computation: entry s of row r lands at
        # row_entry_offset[r] + s
        rows_cat = np.concatenate(row_steps)
        per_row = np.bincount(rows_cat, minlength=n_seg)
        row_off = np.zeros(n_seg + 1, dtype=np.int64)
        np.cumsum(per_row, out=row_off[1:])
        out = np.zeros(len(rows_cat), dtype=np.int64)
        for s, (rws, ps) in enumerate(zip(row_steps, pos_steps)):
            out[row_off[rws] + s] = ps
        return out

    def _entry_starts_chain(self, raw: np.ndarray, limit: int) -> Tuple[np.ndarray, int]:
        """Pointer-doubling entry discovery for a buffer with no known row
        bounds (the scan path).  Builds the entry-successor map for every
        byte position in one vectorized pass, then squares it
        ``log2(entries)`` times to enumerate up to ``limit`` *complete*
        entry starts.  Returns ``(entry_positions, consumed_bytes)`` —
        ``consumed_bytes`` stops before a trailing partial entry, so scan
        windows can carry the tail into the next window."""
        total = len(raw)
        if total == 0 or limit <= 0:
            return np.zeros(0, dtype=np.int64), 0
        m = self.meta
        W, L = m["W"], m["L"]
        db = level_bits(self.proto.max_def)
        # successor map for every byte position, built from shifted views of
        # a zero-padded copy (the contiguous domain needs no gathers; zero
        # padding makes a truncated length prefix read as >= 0, so the
        # end-fits test below covers header truncation too)
        pad = np.zeros(total + W + L, dtype=np.uint8)
        pad[:total] = raw
        if W and db:
            if W == 1:
                valid = (pad[:total] & np.uint8((1 << db) - 1)) == 0
            else:
                word = pad[:total].astype(np.uint32)
                for b in range(1, W):
                    word |= pad[b : b + total].astype(np.uint32) << np.uint32(8 * b)
                valid = (word & np.uint32((1 << db) - 1)) == 0
        else:
            valid = None  # no null entries: every entry carries a value
        vlen = pad[W : W + total].astype(np.intp) if L else np.zeros(total, np.intp)
        for b in range(1, L):
            vlen |= pad[W + b : W + b + total].astype(np.intp) << np.intp(8 * b)
        # `end`: one-entry-advanced position for every byte position
        end = vlen + np.intp(W + L) if valid is None else np.where(
            valid, vlen + np.intp(W + L), np.intp(W))
        end += np.arange(total, dtype=np.intp)
        # intp successor map: np.take squares it without index-dtype casts;
        # an entry whose end overruns the buffer is incomplete -> sentinel
        nxt = np.empty(total + 1, dtype=np.intp)
        np.minimum(end, total, out=nxt[:total])
        nxt[total] = total
        # capped pointer doubling: square the jump table (O(total) each) only
        # until it spans WAVE entries, then enumerate in O(WAVE)-sized waves
        WAVE = 4096
        parts = [np.zeros(1, dtype=np.intp)]  # the chain starts at offset 0
        lastk = parts[0]
        count = 1
        jump, span = nxt, 1
        scratch = np.empty_like(nxt)
        while count < limit and lastk[-1] < total:
            new = np.take(jump, lastk[-span:])
            parts.append(new)
            count += len(new)
            lastk = np.concatenate([lastk, new])[-WAVE:]
            if span < WAVE and span * 2 <= count:
                np.take(jump, jump, out=scratch, mode="clip")
                jump, scratch = scratch, jump
                span *= 2
        known = np.concatenate(parts)
        starts = known[known < total][:limit].astype(np.int64, copy=False)
        # only entries that are themselves complete count; the chain stops
        # advancing at the first incomplete one by construction of `nxt`
        starts = starts[end[starts] <= total]
        consumed = int(end[starts[-1]]) if len(starts) else 0
        return starts, consumed

    # -- variable-width decode -----------------------------------------
    def _decode_var_at(self, raw: np.ndarray, entry_pos: np.ndarray):
        """Decode all entries whose control words sit at ``entry_pos`` in
        one vectorized pass: control-word gather, length-prefix gather, and
        a single repeat/arange value-byte gather."""
        m = self.meta
        W, L = m["W"], m["L"]
        max_rep, max_def = self.proto.max_rep, self.proto.max_def
        n = len(entry_pos)
        if W:
            wb = raw[
                np.minimum(entry_pos[:, None] + np.arange(W, dtype=np.int64),
                           max(len(raw) - 1, 0))
            ]
            rep, defs = unpack_control_words(wb.reshape(-1), n, max_rep, max_def)
        else:
            rep, defs = None, None
        valid = (defs == 0) if defs is not None else np.ones(n, bool)
        vpos = entry_pos[valid] + W
        vlens = gather_le(raw, vpos, L).astype(np.int64)
        src = A.ragged_indices(vpos + L, vlens)
        blob = raw[src] if len(src) else np.zeros(0, np.uint8)
        bc = get_bytes_codec(m["bytes_codec"])
        out_lens, out_data = bc.decode(Encoded(blob, m["codec_meta"]), vlens)
        offsets = np.zeros(len(out_lens) + 1, dtype=np.int64)
        np.cumsum(out_lens, out=offsets[1:])
        vals = A.VarBinaryArray(
            self.proto.leaf_type.with_nullable(False),
            np.ones(len(out_lens), bool),
            offsets,
            out_data,
        )
        return rep, defs, vals

    # ------------------------------------------------------------------
    def _decode_entries(self, raw: np.ndarray, n_hint: Optional[int] = None,
                        seg_offs: Optional[np.ndarray] = None):
        """Zipped bytes -> ``(rep, defs, values)``.  Fixed-width entries are
        strided; variable-width entries are located row-parallel (frontier
        over ``seg_offs`` row bounds when given, pointer doubling otherwise)
        and then decoded in one vectorized pass."""
        if self.meta["vw"] is not None:
            return self._decode_fixed(raw)
        if seg_offs is not None:
            entry_pos = self._entry_starts_rows(raw, seg_offs)
        else:
            limit = n_hint if n_hint is not None else len(raw)
            entry_pos, _ = self._entry_starts_chain(raw, limit)
        return self._decode_var_at(raw, entry_pos)

    # ------------------------------------------------------------------
    def _decode_entries_walk(self, raw: np.ndarray, n_hint: Optional[int] = None):
        """The historical sequential per-value walk (paper §6.3/Fig 17 cost
        model).  Retained as the decode oracle: the property tests pit the
        row-parallel paths against it, and the ``decode`` benchmark times it
        as the pre-PR baseline."""
        m = self.meta
        W, L, vw = m["W"], m["L"], m["vw"]
        max_rep, max_def = self.proto.max_rep, self.proto.max_def
        if vw is not None:
            return self._decode_fixed(raw)
        buf = raw.tobytes()
        mv = memoryview(buf)
        pos = 0
        cws: List[int] = []
        vlens: List[int] = []
        vslices: List[bytes] = []
        total = len(buf)
        db = max_def.bit_length()
        while pos < total and (n_hint is None or len(cws) < n_hint):
            if W:
                w = int.from_bytes(mv[pos : pos + W], "little")
                pos += W
            else:
                w = 0  # no lists & no nulls: every entry is a bare value
            cws.append(w)
            dval = w & ((1 << db) - 1) if db else 0
            if dval == 0:  # valid value follows
                vl = int.from_bytes(mv[pos : pos + L], "little")
                pos += L
                vslices.append(bytes(mv[pos : pos + vl]))
                vlens.append(vl)
                pos += vl
        n = len(cws)
        words = np.array(cws, dtype=np.uint32)
        wb = np.zeros((n, W), dtype=np.uint8)
        for b in range(W):
            wb[:, b] = (words >> (8 * b)).astype(np.uint8)
        rep, defs = unpack_control_words(wb.reshape(-1), n, max_rep, max_def) if W else (None, None)
        bc = get_bytes_codec(m["bytes_codec"])
        stored = np.array(vlens, dtype=np.int64)
        blob = np.frombuffer(b"".join(vslices), dtype=np.uint8) if vslices else np.zeros(0, np.uint8)
        out_lens, out_data = bc.decode(Encoded(blob, m["codec_meta"]), stored)
        offsets = np.zeros(len(out_lens) + 1, dtype=np.int64)
        np.cumsum(out_lens, out=offsets[1:])
        vals = A.VarBinaryArray(
            self.proto.leaf_type.with_nullable(False),
            np.ones(len(out_lens), bool),
            offsets,
            out_data,
        )
        return rep, defs, vals

    # ------------------------------------------------------------------
    def take(self, rows: np.ndarray, io) -> ShreddedLeaf:
        """Batched random access: rows are deduplicated before IO, every
        span is fetched in one phase-grouped ``read_many`` dispatch (index
        reads in phase 0, zipped spans in phase 1), all rows are decoded
        simultaneously (strided for fixed entries, row-parallel frontier for
        variable), and the decoded rows are fanned back out to request
        order (duplicates materialized by the final permutation — a host
        permutation, or one device gather under ``decode='pallas'`` —
        never re-read)."""
        rows = np.asarray(rows, dtype=np.int64)
        m = self.meta
        if len(rows) == 0:
            return empty_leaf(self.proto)
        urows, inv = np.unique(rows, return_inverse=True)
        if urows[0] < 0 or urows[-1] >= m["n_rows"]:
            raise IndexError(
                f"take rows out of bounds for {m['n_rows']}-row column"
            )
        n_unique = len(urows)
        if not m["has_rep_index"]:
            stride = m["W"] + m["vw"]
            data, _ = io.read_many(
                self.base + urows * stride,
                np.full(n_unique, stride, dtype=np.int64), phase=0)
            # useful bytes over *unique* rows: duplicates are fanned out from
            # the decoded result, never re-read, so amplification stays >= 1
            io.note_useful(stride * n_unique)
            if self.decode == "pallas":
                return self._take_fixed_pallas(data, n_unique, stride, inv)
            rep, defs, vals = self._decode_fixed(data)
        else:
            if self.decode == "pallas":
                # the rep-indexed path decodes variable-stride entries on the
                # host frontier; the fused gather kernel needs fixed strides
                tr = getattr(io, "tracer", None)
                if tr is not None and tr.enabled:
                    tr.fallback("fullzip", "variable-stride",
                                n_rows=int(n_unique))
            R = m["R"]
            # one IOP per row covers both adjacent index entries (start & end)
            idx, _ = io.read_many(
                self.base + urows * R,
                np.full(n_unique, 2 * R, dtype=np.int64), phase=0)
            mat = idx.reshape(n_unique, 2 * R)
            lo = _from_le(mat[:, :R]).astype(np.int64)
            hi = _from_le(mat[:, R:]).astype(np.int64)
            data, _ = io.read_many(self.base + m["zip_base"] + lo, hi - lo,
                                   phase=1)
            # the fetched [lo, hi) spans are the row bounds: decode all rows
            # in lockstep instead of walking the concatenation per value
            seg_offs = np.zeros(n_unique + 1, dtype=np.int64)
            np.cumsum(hi - lo, out=seg_offs[1:])
            rep, defs, vals = self._decode_entries(data, seg_offs=seg_offs)
            io.note_useful(int((hi - lo).sum()))
        dec = leaf_slice(self.proto, rep, defs, vals, n_unique)
        return reorder_leaf_rows(dec, inv)

    def _take_fixed_pallas(self, data: np.ndarray, n_unique: int, stride: int,
                           inv: np.ndarray) -> ShreddedLeaf:
        """Fused gather route: one block-table DMA gather fans the unique
        zipped rows out to request order on device, then the request-order
        matrix is decoded strided — bit-identical to the host
        ``reorder_leaf_rows`` permutation (fixed-stride entries are rows)."""
        from ..kernels import ops  # lazy: keep numpy-only readers jax-free
        import jax.numpy as jnp

        zipped = np.ascontiguousarray(data[: n_unique * stride]).reshape(
            n_unique, stride)
        gathered = np.asarray(ops.fullzip_gather(
            jnp.asarray(zipped), jnp.asarray(inv.astype(np.int32))))
        rep, defs, vals = self._decode_fixed(gathered.reshape(-1))
        return leaf_slice(self.proto, rep, defs, vals, len(inv))

    def scan(self, io, io_chunk: int = 8 << 20) -> ShreddedLeaf:
        """Full scan in bounded-memory windows: each ``io_chunk`` window is
        read and decoded at entry boundaries (pointer-doubling entry
        discovery; the partial-entry tail is carried into the next window),
        so peak raw-buffer RSS is O(window) instead of O(column).  The
        logical IO trace is unchanged — the repetition index is never read
        on a full scan (paper 4.1.4)."""
        m = self.meta
        total = m["zip_bytes"]
        remaining = m["n_entries"]
        fixed_stride = None if m["vw"] is None else m["W"] + m["vw"]
        tail = np.zeros(0, dtype=np.uint8)
        reps, dfs, vals = [], [], []
        for p in range(0, total, io_chunk):
            part = io.read(self.base + m["zip_base"] + p,
                           min(io_chunk, total - p), phase=0)
            window = np.concatenate([tail, part]) if len(tail) else part
            if fixed_stride is not None:
                n_here = min(len(window) // fixed_stride, remaining)
                consumed = n_here * fixed_stride
                if n_here:
                    r, d, v = self._decode_fixed(window[:consumed])
                    reps.append(r)
                    dfs.append(d)
                    vals.append(v)
                    remaining -= n_here
            else:
                # decode in sub-windows so the chain's per-byte successor
                # arrays (~34 B/byte transiently) are bounded by
                # _DECODE_WINDOW, not io_chunk; the cap widens only when a
                # single entry outgrows it
                consumed = 0
                cap = self._DECODE_WINDOW
                while consumed < len(window):
                    sub = window[consumed: consumed + cap]
                    entry_pos, used = self._entry_starts_chain(sub, remaining)
                    if not len(entry_pos):
                        if len(sub) < len(window) - consumed:
                            cap *= 2  # entry larger than the sub-window
                            continue
                        break  # partial entry: need the next io window
                    r, d, v = self._decode_var_at(sub, entry_pos)
                    reps.append(r)
                    dfs.append(d)
                    vals.append(v)
                    remaining -= len(entry_pos)
                    consumed += used
                    cap = self._DECODE_WINDOW
            tail = window[consumed:]
        if not vals:
            rep, defs, vls = self._decode_entries(
                np.zeros(0, np.uint8), n_hint=m["n_entries"])
            return leaf_slice(self.proto, rep, defs, vls, m["n_rows"])
        rep = np.concatenate(reps) if reps[0] is not None else None
        defs = np.concatenate(dfs) if dfs[0] is not None else None
        return leaf_slice(self.proto, rep, defs, A.concat(vals), m["n_rows"])
