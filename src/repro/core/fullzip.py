"""The full-zip structural encoding (paper §4.1).

For large data types (≥128 B/value) the control word (bit-packed rep/def,
§4.1.1), the per-value length (§4.1.2) and the transparently-compressed value
bytes (§4.1.3) are zipped row-major into a single buffer.  A bit-packed
**repetition index** (§4.1.4) of row start offsets enables random access in at
most 2 IOPS regardless of nesting; fixed-width columns without repetition
need no index at all (1 IOP).  Nulls in fixed-width columns are dense filler
bytes; variable-width nulls are a control word only.  There is **no search
cache** (§4.2.4) beyond any codec dictionary/symbol table.

Random access is batched (see :meth:`FullZipReader.take`): requested rows
are deduplicated before any IO, all index reads go out as one phase-0
``read_many`` batch and all zipped spans as one phase-1 batch, the
concatenated spans are decoded in a single pass, and one permutation fans
the decoded rows back out to request order.  Per-unique-row IOPS and bytes
match the historical per-row reader exactly.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from . import arrays as A
from . import types as T
from .compression import Encoded, get_bytes_codec, get_fixed_codec
from .encodings_base import (
    ColumnReader,
    EncodedColumn,
    empty_leaf,
    leaf_slice,
    reorder_leaf_rows,
)
from .rdlevels import control_word_width, pack_control_words, unpack_control_words
from .shred import ShreddedLeaf

__all__ = ["encode_fullzip", "FullZipReader"]


def _len_field_width(max_len: int) -> int:
    """Per-value length prefix, bit-packed to the nearest byte (<=8 bytes)."""
    w = max(1, (int(max_len).bit_length() + 7) // 8)
    assert w <= 8
    return w


def _le_bytes(values: np.ndarray, width: int) -> np.ndarray:
    """(n, width) little-endian byte matrix for non-negative ints."""
    v = values.astype(np.uint64)
    shifts = np.arange(width, dtype=np.uint64) * np.uint64(8)
    return ((v[:, None] >> shifts[None, :]) & np.uint64(0xFF)).astype(np.uint8)


def _from_le(mat: np.ndarray) -> np.ndarray:
    shifts = np.arange(mat.shape[1], dtype=np.uint64) * np.uint64(8)
    return (mat.astype(np.uint64) << shifts[None, :]).sum(axis=1, dtype=np.uint64)


def encode_fullzip(
    leaf: ShreddedLeaf,
    fixed_codec: str = "plain",
    bytes_codec: str = "plain_bytes",
) -> EncodedColumn:
    n = leaf.n_entries
    W = control_word_width(leaf.max_rep, leaf.max_def)
    cw = (
        pack_control_words(leaf.rep, leaf.defs, leaf.max_rep, leaf.max_def).reshape(n, W)
        if W
        else np.zeros((n, 0), dtype=np.uint8)
    )
    valid = (leaf.defs == 0) if leaf.defs is not None else np.ones(n, bool)
    n_valid = int(valid.sum())

    is_var = isinstance(leaf.leaf_type, (T.Utf8, T.Binary))
    search_cache = 0
    if is_var:
        bc = get_bytes_codec(bytes_codec)
        assert bc.transparent, "full-zip requires transparent compression (paper 4.1.3)"
        lengths = (leaf.values.offsets[1:] - leaf.values.offsets[:-1]).astype(np.uint64)
        enc = bc.encode(lengths, leaf.values.data)
        vlens = np.asarray(enc.out_lengths, dtype=np.int64)
        L = _len_field_width(int(vlens.max()) if len(vlens) else 1)
        # entry sizes: cw + (len field + bytes) for valid; cw only for null
        sizes = np.full(n, W, dtype=np.int64)
        sizes[valid] += L + vlens
        offs = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(sizes, out=offs[1:])
        out = np.zeros(int(offs[-1]), dtype=np.uint8)
        for b in range(W):
            out[offs[:-1] + b] = cw[:, b]
        vpos = offs[:-1][valid] + W
        lmat = _le_bytes(vlens.astype(np.uint64), L)
        for b in range(L):
            out[vpos + b] = lmat[:, b]
        # scatter value bytes
        src_offs = np.zeros(n_valid + 1, dtype=np.int64)
        np.cumsum(vlens, out=src_offs[1:])
        dst = np.repeat(vpos + L, vlens) + (
            np.arange(int(src_offs[-1])) - np.repeat(src_offs[:-1], vlens)
        )
        out[dst] = enc.data
        codec_meta = {k: v for k, v in enc.meta.items()}
        if "syms" in codec_meta:
            search_cache += sum(len(s) + 2 for s in codec_meta["syms"])
        vw = None
    else:
        fc = get_fixed_codec(fixed_codec)
        assert fc.transparent
        if isinstance(leaf.leaf_type, T.FixedSizeList):
            enc = fc.encode(leaf.values.values.reshape(-1))
            elem_w = fc.encoded_width(enc)
            assert elem_w is not None, "full-zip fixed path needs byte-aligned codec"
            vw = elem_w * leaf.leaf_type.size
        else:
            enc = fc.encode(leaf.values.values)
            vw = fc.encoded_width(enc)
            assert vw is not None, "full-zip fixed path needs byte-aligned codec"
        L = 0
        stride = W + vw
        out = np.zeros(n * stride, dtype=np.uint8)
        view = out.reshape(n, stride)
        if W:
            view[:, :W] = cw
        # dense: filler zeros where invalid (paper 4.1.3)
        vmat = enc.data.reshape(n_valid, vw) if n_valid else np.zeros((0, vw), np.uint8)
        view[valid, W:] = vmat
        codec_meta = enc.meta
        if "dict" in codec_meta:
            search_cache += int(np.asarray(codec_meta["dict"]).nbytes)
        offs = (np.arange(n + 1, dtype=np.int64) * stride)

    # repetition index: row start byte offsets (+ total), needed when rows
    # are not fixed-stride addressable
    has_rep_index = leaf.max_rep > 0 or is_var
    if leaf.max_rep > 0:
        row_start_mask = leaf.rep == leaf.max_rep
    else:
        row_start_mask = np.ones(n, dtype=bool)
    if has_rep_index:
        row_offsets = np.concatenate([offs[:-1][row_start_mask], offs[-1:]])
        R = _len_field_width(int(offs[-1]) if n else 1)
        ri_bytes = _le_bytes(row_offsets.astype(np.uint64), R).reshape(-1)
        payload = ri_bytes.tobytes() + out.tobytes()
        zip_base = len(ri_bytes)
    else:
        R = 0
        payload = out.tobytes()
        zip_base = 0

    meta = {
        "encoding": "fullzip",
        "W": W,
        "L": L,
        "vw": vw,
        "R": R,
        "zip_base": zip_base,
        "zip_bytes": int(offs[-1]),
        "n_rows": leaf.n_rows,
        "n_entries": n,
        "has_rep_index": has_rep_index,
        "fixed_codec": fixed_codec,
        "bytes_codec": bytes_codec,
        "codec_meta": codec_meta,
    }
    return EncodedColumn("fullzip", payload, meta, search_cache)


class FullZipReader(ColumnReader):
    # ------------------------------------------------------------------
    def _decode_entries(self, raw: np.ndarray, n_hint: Optional[int] = None):
        """Walk zipped bytes -> (rep, defs, values).  Per-value walk for
        variable width (the paper's fig 17 cost); strided for fixed."""
        m = self.meta
        W, L, vw = m["W"], m["L"], m["vw"]
        max_rep, max_def = self.proto.max_rep, self.proto.max_def
        if vw is not None:
            stride = W + vw
            n = len(raw) // stride
            mat = raw[: n * stride].reshape(n, stride)
            rep, defs = (
                unpack_control_words(mat[:, :W].reshape(-1), n, max_rep, max_def)
                if W
                else (None, None)
            )
            valid = (defs == 0) if defs is not None else np.ones(n, bool)
            vbytes = mat[valid, W:].reshape(-1)
            fc = get_fixed_codec(m["fixed_codec"])
            enc = Encoded(vbytes, m["codec_meta"])
            n_valid = int(valid.sum())
            if isinstance(self.proto.leaf_type, T.FixedSizeList):
                size = self.proto.leaf_type.size
                flat = fc.decode(enc, n_valid * size)
                vals = A.FixedSizeListArray(
                    self.proto.leaf_type.with_nullable(False),
                    np.ones(n_valid, bool),
                    np.asarray(flat).reshape(n_valid, size),
                )
            else:
                vals = A.PrimitiveArray(
                    self.proto.leaf_type.with_nullable(False),
                    np.ones(n_valid, bool),
                    np.asarray(fc.decode(enc, n_valid)),
                )
            return rep, defs, vals
        # variable width: sequential per-value walk (cannot vectorize: entry
        # positions depend on embedded lengths -- paper sec 6.3/fig 17)
        buf = raw.tobytes()
        mv = memoryview(buf)
        pos = 0
        cws: List[int] = []
        vlens: List[int] = []
        vslices: List[bytes] = []
        total = len(buf)
        db = max_def.bit_length()
        while pos < total and (n_hint is None or len(cws) < n_hint):
            if W:
                w = int.from_bytes(mv[pos : pos + W], "little")
                pos += W
            else:
                w = 0  # no lists & no nulls: every entry is a bare value
            cws.append(w)
            dval = w & ((1 << db) - 1) if db else 0
            if dval == 0:  # valid value follows
                vl = int.from_bytes(mv[pos : pos + L], "little")
                pos += L
                vslices.append(bytes(mv[pos : pos + vl]))
                vlens.append(vl)
                pos += vl
        n = len(cws)
        words = np.array(cws, dtype=np.uint32)
        wb = np.zeros((n, W), dtype=np.uint8)
        for b in range(W):
            wb[:, b] = (words >> (8 * b)).astype(np.uint8)
        rep, defs = unpack_control_words(wb.reshape(-1), n, max_rep, max_def) if W else (None, None)
        bc = get_bytes_codec(m["bytes_codec"])
        stored = np.array(vlens, dtype=np.int64)
        blob = np.frombuffer(b"".join(vslices), dtype=np.uint8) if vslices else np.zeros(0, np.uint8)
        out_lens, out_data = bc.decode(Encoded(blob, m["codec_meta"]), stored)
        offsets = np.zeros(len(out_lens) + 1, dtype=np.int64)
        np.cumsum(out_lens, out=offsets[1:])
        vals = A.VarBinaryArray(
            self.proto.leaf_type.with_nullable(False),
            np.ones(len(out_lens), bool),
            offsets,
            out_data,
        )
        return rep, defs, vals

    # ------------------------------------------------------------------
    def take(self, rows: np.ndarray, io) -> ShreddedLeaf:
        """Batched random access: rows are deduplicated before IO, every
        span is fetched in one phase-grouped ``read_many`` dispatch (index
        reads in phase 0, zipped spans in phase 1), the concatenated spans
        are decoded in a single :meth:`_decode_entries` pass, and the
        decoded rows are fanned back out to request order (duplicates
        materialized by the final permutation, never re-read)."""
        rows = np.asarray(rows, dtype=np.int64)
        m = self.meta
        if len(rows) == 0:
            return empty_leaf(self.proto)
        urows, inv = np.unique(rows, return_inverse=True)
        if urows[0] < 0 or urows[-1] >= m["n_rows"]:
            raise IndexError(
                f"take rows out of bounds for {m['n_rows']}-row column"
            )
        n_unique = len(urows)
        if not m["has_rep_index"]:
            stride = m["W"] + m["vw"]
            data, _ = io.read_many(
                self.base + urows * stride,
                np.full(n_unique, stride, dtype=np.int64), phase=0)
            rep, defs, vals = self._decode_entries(data)
            # useful bytes over *unique* rows: duplicates are fanned out from
            # the decoded result, never re-read, so amplification stays >= 1
            io.note_useful(stride * n_unique)
        else:
            R = m["R"]
            # one IOP per row covers both adjacent index entries (start & end)
            idx, _ = io.read_many(
                self.base + urows * R,
                np.full(n_unique, 2 * R, dtype=np.int64), phase=0)
            mat = idx.reshape(n_unique, 2 * R)
            lo = _from_le(mat[:, :R]).astype(np.int64)
            hi = _from_le(mat[:, R:]).astype(np.int64)
            data, _ = io.read_many(self.base + m["zip_base"] + lo, hi - lo,
                                   phase=1)
            rep, defs, vals = self._decode_entries(data)
            io.note_useful(int((hi - lo).sum()))
        dec = leaf_slice(self.proto, rep, defs, vals, n_unique)
        return reorder_leaf_rows(dec, inv)

    def scan(self, io, io_chunk: int = 8 << 20) -> ShreddedLeaf:
        m = self.meta
        # the repetition index is never read on a full scan (paper 4.1.4)
        total = m["zip_bytes"]
        parts = []
        for p in range(0, total, io_chunk):
            parts.append(
                io.read(self.base + m["zip_base"] + p, min(io_chunk, total - p), phase=0)
            )
        raw = np.concatenate(parts) if parts else np.zeros(0, np.uint8)
        rep, defs, vals = self._decode_entries(raw, n_hint=m["n_entries"])
        return leaf_slice(self.proto, rep, defs, vals, m["n_rows"])
