"""Repetition/definition level physical encodings.

Two forms are used by the structural encodings:

* **Control words** (full-zip, paper §4.1.1): rep and def are bit-packed side
  by side into a fixed 1–4 byte little-endian word per value, with no
  chunking or RLE, so the width is constant across the column chunk and a
  repetition index can point at a value's control word directly.
* **Packed streams** (mini-block, paper §4.2): rep and def are each bit-packed
  into their own per-chunk buffer (vectorized decode).
"""

from __future__ import annotations

import numpy as np

from .compression import bitpack, bitunpack

__all__ = [
    "level_bits",
    "control_word_width",
    "pack_control_words",
    "unpack_control_words",
    "gather_le",
    "pack_levels",
    "unpack_levels",
]


def level_bits(max_level: int) -> int:
    """Bits to store levels in [0, max_level]; 0 when the stream is absent."""
    return int(max_level).bit_length() if max_level > 0 else 0


def control_word_width(max_rep: int, max_def: int) -> int:
    """Bytes per control word (0 when neither stream exists)."""
    bits = level_bits(max_rep) + level_bits(max_def)
    if bits == 0:
        return 0
    w = (bits + 7) // 8
    assert w <= 4, "control words are 1-4 bytes (paper sec 4.1.1)"
    return w


def pack_control_words(rep, defs, max_rep: int, max_def: int) -> np.ndarray:
    """rep/def -> uint8 buffer of fixed-width little-endian control words.

    Layout: ``word = (rep << def_bits) | def`` — matching the paper's Fig. 6
    where the repetition bit sits above the definition bits.
    """
    w = control_word_width(max_rep, max_def)
    db = level_bits(max_def)
    n = len(rep) if rep is not None else len(defs)
    word = np.zeros(n, dtype=np.uint32)
    if defs is not None:
        word |= defs.astype(np.uint32)
    if rep is not None:
        word |= rep.astype(np.uint32) << np.uint32(db)
    out = np.zeros((n, w), dtype=np.uint8)
    for b in range(w):
        out[:, b] = (word >> np.uint32(8 * b)).astype(np.uint8)
    return out.reshape(-1)


def unpack_control_words(buf: np.ndarray, n: int, max_rep: int, max_def: int):
    """Inverse of :func:`pack_control_words` -> (rep|None, def|None)."""
    w = control_word_width(max_rep, max_def)
    db = level_bits(max_def)
    rb = level_bits(max_rep)
    b = np.ascontiguousarray(buf[: n * w], dtype=np.uint8).reshape(n, w)
    word = np.zeros(n, dtype=np.uint32)
    for i in range(w):
        word |= b[:, i].astype(np.uint32) << np.uint32(8 * i)
    defs = (word & np.uint32((1 << db) - 1)).astype(np.uint8) if db else None
    rep = ((word >> np.uint32(db)) & np.uint32((1 << rb) - 1)).astype(np.uint8) if rb else None
    return rep, defs


def gather_le(buf: np.ndarray, pos: np.ndarray, width: int) -> np.ndarray:
    """Gather ``width``-byte little-endian ints at byte positions ``pos``.

    The row-parallel full-zip walk reads control words and length prefixes at
    many buffer positions per vectorized step; this is its one gather
    primitive.  Positions are clipped to the buffer so speculative reads past
    the end (an invalid trailing entry, a truncated scan window) return
    garbage instead of faulting — callers mask those lanes.
    """
    if len(pos) == 0 or width == 0 or len(buf) == 0:
        return np.zeros(len(pos), dtype=np.uint64)
    top = max(len(buf) - 1, 0)
    out = np.zeros(len(pos), dtype=np.uint64)
    p = np.asarray(pos, dtype=np.int64)
    for b in range(width):
        out |= buf[np.minimum(p + b, top)].astype(np.uint64) << np.uint64(8 * b)
    return out


def pack_levels(levels: np.ndarray, max_level: int) -> np.ndarray:
    """Bit-pack one level stream (mini-block buffers)."""
    return bitpack(levels.astype(np.uint64), level_bits(max_level))


def unpack_levels(buf: np.ndarray, n: int, max_level: int) -> np.ndarray:
    return bitunpack(buf, n, level_bits(max_level)).astype(np.uint8)
