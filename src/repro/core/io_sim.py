"""IOP accounting and storage-device modelling.

The container has no NVMe to benchmark, so results come in three tiers
(DESIGN.md §2.2):

1. **Counted** — every read issued by an encoding goes through an
   :class:`IOTracker`; we report exact IOPS, bytes fetched, dependency phases
   (sequential round-trips) and read amplification.
2. **Measured** — wall-clock decode/scan work on this CPU (real time).
3. **Modelled** — the counted trace priced with the paper's Fig. 1 device
   characteristics (Samsung 970 EVO Plus NVMe; S3 from [4]).

The TPU translation (DESIGN.md §2.1): an IOP ≙ one HBM→VMEM DMA of a
contiguous tile; ``HBM`` below models that regime for the serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Disk", "DiskView", "IOTracker", "IOStats", "DeviceModel", "Degradation",
    "TransientErrors", "Blackout", "CorrelatedFault",
    "NVME", "S3", "HBM", "DRAM", "model_time", "merge_phase_extents",
    "trace_stats",
]


class Disk:
    """An addressable byte store (the 'file').  In-memory by default; can be
    backed by a real file for benchmarks that want the OS in the loop."""

    def __init__(self, data: Optional[np.ndarray] = None, path: Optional[str] = None):
        if path is not None:
            self._f = open(path, "rb")
            self._mem = None
            self._size = self._f.seek(0, 2)
        else:
            self._f = None
            self._mem = np.asarray(data, dtype=np.uint8) if data is not None else np.zeros(0, np.uint8)
            self._size = len(self._mem)

    @staticmethod
    def from_bytes(b: bytes) -> "Disk":
        return Disk(np.frombuffer(b, dtype=np.uint8).copy())

    def __len__(self) -> int:
        return self._size

    def read(self, offset: int, size: int) -> np.ndarray:
        offset, size = int(offset), int(size)
        if size < 0:
            raise ValueError(f"negative read size {size}")
        if offset < 0 or offset + size > self._size:
            raise ValueError(
                f"read [{offset}, {offset + size}) out of bounds for "
                f"{self._size}-byte disk"
            )
        if self._f is not None:
            self._f.seek(offset)
            buf = self._f.read(size)
            if len(buf) != size:  # pragma: no cover - backing file shrank
                raise IOError(f"short read: wanted {size} bytes, got {len(buf)}")
            return np.frombuffer(buf, dtype=np.uint8).copy()
        # copy so callers can never alias (or mutate) the backing store
        return self._mem[offset : offset + size].copy()

    def write(self, offset: int, data) -> None:
        """Data-plane write (in-memory disks only): store ``data`` at
        ``offset``.  Durability is *not* implied — the tiered store's flush
        policy decides when the bytes count as persisted on the backing
        device (see ``repro.store.flush``)."""
        if self._f is not None:  # pragma: no cover - file-backed disks are RO
            raise IOError("file-backed disks are read-only")
        data = np.frombuffer(bytes(data), dtype=np.uint8) \
            if isinstance(data, (bytes, bytearray)) else np.asarray(data, np.uint8)
        offset = int(offset)
        if offset < 0 or offset + len(data) > self._size:
            raise ValueError(
                f"write [{offset}, {offset + len(data)}) out of bounds for "
                f"{self._size}-byte disk")
        self._mem[offset : offset + len(data)] = data

    def grow(self, nbytes: int) -> int:
        """Extend the address space by ``nbytes`` zero bytes (append path);
        returns the new size.  Existing views/readers stay valid — they hold
        the Disk object, not the buffer.  Capacity doubles geometrically (a
        logical ``_size`` over a larger backing array) so N appends cost
        amortized O(appended bytes), not O(total * N) reallocation."""
        nbytes = int(nbytes)
        if nbytes < 0:
            raise ValueError(f"cannot grow by {nbytes} bytes")
        if self._f is not None:  # pragma: no cover - file-backed disks are RO
            raise IOError("file-backed disks cannot grow")
        new_size = self._size + nbytes
        if new_size > len(self._mem):
            buf = np.zeros(max(new_size, 2 * len(self._mem), 4096), np.uint8)
            buf[: self._size] = self._mem[: self._size]
            self._mem = buf
        # bytes in [_size, new_size) are zero: writes are bounds-checked to
        # _size, so the spare capacity has never been touched
        self._size = new_size
        return self._size

    def zero(self, lo: int, hi: int) -> None:
        """Zero a byte range in place (the crash simulator's torn-write
        model: unflushed bytes vanish from the media)."""
        lo, hi = max(int(lo), 0), min(int(hi), self._size)
        if self._f is not None:  # pragma: no cover - file-backed disks are RO
            raise IOError("file-backed disks are read-only")
        if hi > lo:
            self._mem[lo:hi] = 0

    def read_gather(self, offsets, sizes) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized multi-extent read: one gather for N spans.

        Returns ``(data, out_offsets)`` where span ``k``'s bytes are
        ``data[out_offsets[k]:out_offsets[k + 1]]``.  Bounds are checked for
        every span; the in-memory path is a single fancy-index copy (no
        per-span Python loop), which is what makes the batched ``take``
        pipeline's chunk/index/span fetches cheap.
        """
        offsets = np.asarray(offsets, dtype=np.int64)
        sizes = np.asarray(sizes, dtype=np.int64)
        out_offs = np.zeros(len(sizes) + 1, dtype=np.int64)
        if len(sizes) == 0:
            return np.zeros(0, np.uint8), out_offs
        if (sizes < 0).any():
            raise ValueError("negative read size in gather")
        if int(offsets.min()) < 0 or int((offsets + sizes).max()) > self._size:
            raise ValueError(
                f"gather read out of bounds for {self._size}-byte disk"
            )
        np.cumsum(sizes, out=out_offs[1:])
        if self._f is not None:  # pragma: no cover - file-backed fallback
            parts = [self.read(int(o), int(s)) for o, s in zip(offsets, sizes)]
            data = np.concatenate(parts) if parts else np.zeros(0, np.uint8)
            return data, out_offs
        total = int(out_offs[-1])
        idx = np.repeat(offsets - out_offs[:-1], sizes) + np.arange(
            total, dtype=np.int64
        )
        return self._mem[idx], out_offs


class DiskView:
    """A length-bounded window into another :class:`Disk`.

    A multi-fragment dataset concatenates its files into one global address
    space (``repro.dataset``); each per-file reader parses its footer in
    file-local coordinates through a view while every scheduled read is
    priced at ``base + offset`` in the shared store — so cache block ids and
    sector alignment are consistent across files.
    """

    def __init__(self, disk: "Disk", base: int, size: int):
        base, size = int(base), int(size)
        if base < 0 or size < 0 or base + size > len(disk):
            raise ValueError(
                f"view [{base}, {base + size}) out of bounds for "
                f"{len(disk)}-byte disk"
            )
        self.disk = disk
        self.base = base
        self._size = size

    def __len__(self) -> int:
        return self._size

    def read(self, offset: int, size: int) -> np.ndarray:
        offset, size = int(offset), int(size)
        if size < 0:
            raise ValueError(f"negative read size {size}")
        if offset < 0 or offset + size > self._size:
            raise ValueError(
                f"read [{offset}, {offset + size}) out of bounds for "
                f"{self._size}-byte view"
            )
        return self.disk.read(self.base + offset, size)

    def read_gather(self, offsets, sizes) -> Tuple[np.ndarray, np.ndarray]:
        offsets = np.asarray(offsets, dtype=np.int64)
        sizes = np.asarray(sizes, dtype=np.int64)
        if len(sizes) and (
            (sizes < 0).any() or int(offsets.min()) < 0
            or int((offsets + sizes).max()) > self._size
        ):
            raise ValueError(
                f"gather read out of bounds for {self._size}-byte view"
            )
        return self.disk.read_gather(offsets + self.base, sizes)


@dataclasses.dataclass
class IOStats:
    n_iops: int = 0
    bytes_read: int = 0
    useful_bytes: int = 0
    max_phase: int = 0  # dependency depth: number of sequential round trips
    n_coalesced: int = 0  # IOPS after merging adjacent/overlapping requests

    @property
    def read_amplification(self) -> float:
        return self.bytes_read / self.useful_bytes if self.useful_bytes else float("nan")


def merge_phase_extents(
    ops: Sequence[Tuple[int, int, int]], gap: int = 0
) -> Dict[int, List[Tuple[int, int]]]:
    """Merge adjacent/overlapping byte ranges **within each dependency
    phase**.  Reads at phase p causally depend on reads at phases < p having
    returned, so cross-phase merging would fabricate requests no scheduler
    could have issued.  Returns ``{phase: [(lo, hi), ...]}`` sorted by lo;
    zero-length requests survive as ``(o, o)`` extents (they are still ops)."""
    by_phase: Dict[int, List[Tuple[int, int]]] = {}
    for o, sz, p in ops:
        by_phase.setdefault(int(p), []).append((int(o), int(o) + int(sz)))
    out: Dict[int, List[Tuple[int, int]]] = {}
    for p, ivs in by_phase.items():
        ivs.sort()
        merged: List[Tuple[int, int]] = []
        cur: Optional[Tuple[int, int]] = None
        for a, b in ivs:
            if cur is None or a > cur[1] + gap:
                if cur is not None:
                    merged.append(cur)
                cur = (a, b)
            else:
                cur = (cur[0], max(cur[1], b))
        if cur is not None:
            merged.append(cur)
        out[p] = merged
    return out


def trace_stats(
    ops: Sequence[Tuple[int, int, int]], useful_bytes: int = 0,
    coalesce_gap: int = 0,
) -> IOStats:
    """IOStats for a logical read trace; single source of truth shared by the
    legacy :class:`IOTracker` and the batched scheduler in ``repro.store``."""
    s = IOStats()
    s.n_iops = len(ops)
    s.bytes_read = sum(sz for _, sz, _ in ops)
    s.useful_bytes = int(useful_bytes)
    # an empty trace has depth 0; otherwise depth = deepest phase + 1
    s.max_phase = max((p for _, _, p in ops), default=-1) + 1
    s.n_coalesced = sum(len(v) for v in merge_phase_extents(ops, coalesce_gap).values())
    return s


class IOTracker:
    """Counts every read.  ``phase`` expresses dependencies: a read at phase p
    could only be issued after all reads at phases < p returned (the paper's
    'issued in 3 phases' for Arrow List<String>)."""

    def __init__(self, disk: Disk, sector: int = 4096):
        self.disk = disk
        self.sector = sector
        self.ops: List = []  # (offset, size, phase)

    def read(self, offset: int, size: int, phase: int = 0) -> np.ndarray:
        offset, size = int(offset), int(size)
        self.ops.append((offset, size, phase))
        return self.disk.read(offset, size)

    def note_useful(self, nbytes: int) -> None:
        self._useful = getattr(self, "_useful", 0) + int(nbytes)

    def reset(self) -> None:
        self.ops = []
        self._useful = 0

    def stats(self, coalesce_gap: int = 0) -> IOStats:
        return trace_stats(self.ops, getattr(self, "_useful", 0), coalesce_gap)


@dataclasses.dataclass(frozen=True)
class Degradation:
    """A time-varying fault on a device: between ``start`` and ``end``
    (virtual seconds) the device's round-trip latency is multiplied by
    ``latency_factor`` and its effective bandwidth by ``throughput_factor``
    (a throttled NVMe under thermal pressure, a saturated S3 prefix, a
    firmware stall).

    The fault plane lives strictly on the event-loop timing overlay
    (:mod:`repro.store.evloop`): the *priced* accounting —
    ``TierStats.model_time``, ``Job.serial_time``, logical IOPS/bytes —
    never consults the fault schedule, so every committed baseline stays
    bit-identical whether or not a device carries faults.  That asymmetry is
    the point: the live metrics plane has to *detect* a degradation the
    steady-state price model cannot see."""

    start: float
    end: float = float("inf")
    latency_factor: float = 1.0
    throughput_factor: float = 1.0

    def __post_init__(self):
        if self.latency_factor <= 0 or self.throughput_factor <= 0:
            raise ValueError("degradation factors must be positive")
        if self.end < self.start:
            raise ValueError("degradation window ends before it starts")

    def active(self, t: float) -> bool:
        return self.start <= t < self.end


_MASK64 = (1 << 64) - 1


def _splitmix_uniform(*keys: int) -> float:
    """Stateless uniform draw in [0, 1) from an integer key tuple
    (splitmix64 finalizer).  The fault plane's only randomness source: a
    draw is a pure function of its key, so two event-loop runs over the
    same jobs + fault schedule reproduce bit-identical failure sets —
    nothing is consumed from a shared stream whose position could drift."""
    x = 0x9E3779B97F4A7C15
    for k in keys:
        x = (x ^ (int(k) & _MASK64)) * 0xBF58476D1CE4E5B9 & _MASK64
        x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 31
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 30
    return (x >> 11) / float(1 << 53)


@dataclasses.dataclass(frozen=True)
class TransientErrors:
    """A transient-error window: between ``start`` and ``end`` (virtual
    seconds) each op on the device *independently* fails with probability
    ``error_prob`` — after consuming its round trip, the way a timed-out or
    errored NVMe command still occupied its queue slot.  Draws are pure
    functions of ``seed`` and the op's identity (unit, slot, attempt), so a
    run is exactly replayable and a lower ``error_prob`` fails a strict
    subset of the ops a higher one fails (same uniform, lower threshold).

    Like :class:`Degradation`, this is consulted only by the event-loop
    timing overlay: priced accounting and the logical trace never see it.
    """

    start: float
    end: float = float("inf")
    error_prob: float = 0.01
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.error_prob <= 1.0:
            raise ValueError("error_prob must be in [0, 1]")
        if self.end < self.start:
            raise ValueError("error window ends before it starts")

    def active(self, t: float) -> bool:
        return self.start <= t < self.end


@dataclasses.dataclass(frozen=True)
class Blackout:
    """A total outage: every op completing inside the window fails (a
    pulled cable, a crashed S3 prefix, an unmounted NVMe namespace).
    Equivalent to :class:`TransientErrors` at ``error_prob=1`` but kept as
    its own type so schedules read as what they model."""

    start: float
    end: float = float("inf")

    def __post_init__(self):
        if self.end < self.start:
            raise ValueError("blackout window ends before it starts")

    def active(self, t: float) -> bool:
        return self.start <= t < self.end


@dataclasses.dataclass(frozen=True)
class CorrelatedFault:
    """One fault window stamped onto several tiers at once (an availability
    zone brownout takes the NVMe cache *and* its S3 prefix down together —
    the correlated-failure shape independent per-tier schedules cannot
    express).  ``apply`` returns a new device list with ``fault`` appended
    to every named device, leaving the rest untouched."""

    fault: object  # Degradation | TransientErrors | Blackout
    devices: Tuple[str, ...]

    def apply(self, devices: Sequence["DeviceModel"]) -> List["DeviceModel"]:
        unknown = set(self.devices) - {d.name for d in devices}
        if unknown:
            raise ValueError(f"unknown device(s) {sorted(unknown)} in "
                             f"correlated fault")
        return [d.with_fault(self.fault) if d.name in self.devices else d
                for d in devices]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """First-order device model from the paper's Fig. 1 measurements."""

    name: str
    iops_4k: float  # peak random 4 KiB IOPS at full queue depth
    seq_bw: float  # bytes/s sequential
    latency: float  # per-round-trip latency (seconds)
    min_read: int  # reads below this size cost the same as this size
    # Fault-injection schedule, consulted only by the event-loop timing
    # overlay (see Degradation/TransientErrors/Blackout).  () = healthy,
    # the module constants below.
    faults: Tuple[object, ...] = ()

    def with_fault(self, fault) -> "DeviceModel":
        """A copy of this device carrying one more scheduled fault
        (:class:`Degradation`, :class:`TransientErrors` or
        :class:`Blackout`)."""
        return dataclasses.replace(self, faults=self.faults + (fault,))

    def latency_factor_at(self, t: float) -> float:
        """Round-trip latency multiplier at virtual time ``t`` (1.0 healthy;
        overlapping faults compound).  Error-type faults fail ops, they do
        not stretch them."""
        f = 1.0
        for d in self.faults:
            if d.active(t):
                f *= getattr(d, "latency_factor", 1.0)
        return f

    def bandwidth_factor_at(self, t: float) -> float:
        """Effective-bandwidth multiplier at virtual time ``t`` (1.0
        healthy, < 1.0 degraded; overlapping faults compound)."""
        f = 1.0
        for d in self.faults:
            if d.active(t):
                f *= getattr(d, "throughput_factor", 1.0)
        return f

    def fault_active_at(self, t: float) -> bool:
        """Is any scheduled fault window (degradation *or* error-type)
        open at virtual time ``t``?  Used by the store's fault-aware cache
        admission: a block fetched while its source tier is browned out is
        slow-path traffic, not working-set evidence, so it is not admitted.
        Like every fault consumer this reads only the schedule — priced
        accounting stays fault-blind (see the class docstring)."""
        return any(d.active(t) for d in self.faults)

    @property
    def has_error_faults(self) -> bool:
        """True if any scheduled fault can *fail* ops (vs merely slow
        them) — the event loop only allocates retry state for such tiers."""
        return any(isinstance(d, (TransientErrors, Blackout))
                   for d in self.faults)

    def op_fails_at(self, t: float, *keys: int) -> bool:
        """Does the op identified by ``keys`` fail if it completes at
        virtual time ``t``?  A pure function of (schedule, t, keys): a
        :class:`Blackout` fails everything in its window; each active
        :class:`TransientErrors` window contributes one independent
        seeded draw.  Window membership is judged at op-completion time —
        an op issued inside a window that completes after it has cleared
        the fault."""
        for d in self.faults:
            if isinstance(d, Blackout) and d.active(t):
                return True
            if isinstance(d, TransientErrors) and d.active(t) \
                    and d.error_prob > 0.0 \
                    and _splitmix_uniform(d.seed, *keys) < d.error_prob:
                return True
        return False


# Samsung 970 EVO Plus measured in the paper: 850K IOPS @4KiB, 3,400 MiB/s.
NVME = DeviceModel("nvme_970evo", 850_000, 3400 * (1 << 20), 90e-6, 4096)
# S3 (c7gn.8xlarge): tens of thousands of IOPS, no benefit < ~100KB reads.
S3 = DeviceModel("s3", 20_000, 10 * (1 << 30), 30e-3, 100 * 1024)
# TPU HBM: an "IOP" is a DMA tile; bandwidth 819 GB/s (v5e), ~1 us issue.
HBM = DeviceModel("tpu_hbm", 2_000_000, 819e9, 1e-6, 512)
# Host DRAM (the tiered store's RAM-hot tier): a cache-line-granular copy.
DRAM = DeviceModel("dram", 10_000_000, 25 * (1 << 30), 2e-7, 64)


def model_time(stats: IOStats, dev: DeviceModel, queue_depth: int = 256,
               use_coalesced: bool = False) -> float:
    """Price an IO trace on a device: throughput-limited term (max of IOPS
    limit scaled by request size, and bandwidth) plus dependency round trips
    amortized across the queue."""
    n = stats.n_coalesced if use_coalesced else stats.n_iops
    if n == 0:
        return 0.0
    avg = max(stats.bytes_read / n, 1.0)
    eff = max(avg, dev.min_read)
    iops_limit = min(dev.iops_4k, dev.seq_bw / eff)
    t_ops = n / iops_limit
    t_bw = stats.bytes_read / dev.seq_bw
    # dependency phases are sequential round trips; with a deep queue their
    # latency is paid once per phase, not per op
    return max(t_ops, t_bw) + stats.max_phase * dev.latency
