"""File writer/reader: the container format around the structural encodings.

Layout (one "disk page" per encoded leaf column, paper §2.1: Lance columns
may have multiple disk pages; we write one per leaf for clarity):

    [leaf payload 0][leaf payload 1]...[footer msgpack][footer_len u64]["LNC1"]

The footer holds the schema, per-leaf encoding metadata and payload offsets.
It is read once when the file is opened (not counted against per-take IOPS —
it is the search cache + file metadata of §2.3; its size is reported so the
0.1 % goal can be checked).

Encodings: ``lance`` (adaptive mini-block/full-zip, §4), ``lance-miniblock``
/ ``lance-fullzip`` (forced, for the ablations), ``parquet`` (§3.1),
``arrow`` (§3.2), ``packed`` (struct packing, §4.3).
"""

from __future__ import annotations

import struct as _struct
from typing import Dict, Iterable, List, Optional, Sequence

import msgpack
import numpy as np

from . import arrays as A
from . import types as T
from .adaptive import choose_encoding
from .arrow_like import ArrowReader, encode_arrow
from .encodings_base import EncodedColumn
from .fullzip import FullZipReader, encode_fullzip
from .io_sim import Disk
from .miniblock import MiniBlockReader, encode_miniblock
from .packing import PackedStructReader, encode_packed_struct
from .parquet_like import ParquetReader, encode_parquet
from .shred import ShreddedLeaf, leaf_paths, shred, unshred

MAGIC = b"LNC1"

__all__ = ["WriteOptions", "write_table", "FileReader", "read_footer",
           "type_to_dict", "type_from_dict"]


def read_footer(read, size: int):
    """Parse a Lance footer through ``read(offset, size) -> bytes-like``.

    The single source of the trailer format (``[footer][len u64][magic]``),
    shared by :class:`FileReader` (reading a Disk) and the dataset manifest
    (peeking raw fragment bytes).  Returns ``(meta, footer_len)``.
    """
    if size < 12:
        raise ValueError("not a Lance file (too short)")
    tail = bytes(read(size - 12, 12))
    if tail[-4:] != MAGIC:
        raise ValueError("not a Lance file (bad magic)")
    (flen,) = _struct.unpack("<Q", tail[:8])
    return unpack_meta(bytes(read(size - 12 - flen, flen))), flen


# ---------------------------------------------------------------------------
# schema serialization
# ---------------------------------------------------------------------------


def type_to_dict(t: T.DataType) -> Dict:
    if isinstance(t, T.Primitive):
        return {"k": "prim", "dtype": t.dtype, "null": t.nullable}
    if isinstance(t, T.Utf8):
        return {"k": "utf8", "null": t.nullable}
    if isinstance(t, T.Binary):
        return {"k": "bin", "null": t.nullable}
    if isinstance(t, T.FixedSizeList):
        return {"k": "fsl", "child": type_to_dict(t.child), "size": t.size, "null": t.nullable}
    if isinstance(t, T.List):
        return {"k": "list", "child": type_to_dict(t.child), "null": t.nullable}
    if isinstance(t, T.Struct):
        return {"k": "struct", "fields": [[n, type_to_dict(f)] for n, f in t.fields], "null": t.nullable}
    raise TypeError(t)


def type_from_dict(d: Dict) -> T.DataType:
    k = d["k"]
    if k == "prim":
        return T.Primitive(d["dtype"], d["null"])
    if k == "utf8":
        return T.Utf8(d["null"])
    if k == "bin":
        return T.Binary(d["null"])
    if k == "fsl":
        return T.FixedSizeList(type_from_dict(d["child"]), d["size"], d["null"])
    if k == "list":
        return T.List(type_from_dict(d["child"]), d["null"])
    if k == "struct":
        return T.Struct(tuple((n, type_from_dict(f)) for n, f in d["fields"]), d["null"])
    raise TypeError(d)


# msgpack with numpy support ------------------------------------------------


def _mp_default(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": True, "d": obj.dtype.str, "s": list(obj.shape), "b": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, tuple):
        return list(obj)
    raise TypeError(type(obj))


def _mp_hook(obj):
    if "__nd__" in obj:
        return np.frombuffer(obj["b"], dtype=np.dtype(obj["d"])).reshape(obj["s"]).copy()
    return obj


def pack_meta(meta) -> bytes:
    return msgpack.packb(meta, default=_mp_default, use_bin_type=True, strict_types=False)


def unpack_meta(blob: bytes):
    return msgpack.unpackb(blob, object_hook=_mp_hook, raw=False, strict_map_key=False)


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------


class WriteOptions:
    def __init__(
        self,
        encoding: str = "lance",  # lance | lance-miniblock | lance-fullzip | parquet | arrow
        page_bytes: int = 8 * 1024,  # parquet page target
        fixed_codec: Optional[str] = None,
        bytes_codec: Optional[str] = None,
        dict_encode: bool = False,  # parquet dictionary encoding
        arrow_compress: bool = False,
        packed_columns: Sequence[str] = (),  # struct columns to pack (4.3)
        decode: str = "numpy",  # default chunk decoder: numpy | pallas
    ):
        if decode not in ("numpy", "pallas"):
            raise ValueError(f"decode must be 'numpy'|'pallas', got {decode!r}")
        self.encoding = encoding
        self.page_bytes = page_bytes
        self.fixed_codec = fixed_codec
        self.bytes_codec = bytes_codec
        self.dict_encode = dict_encode
        self.arrow_compress = arrow_compress
        self.packed_columns = tuple(packed_columns)
        self.decode = decode


def _proto(leaf: ShreddedLeaf) -> ShreddedLeaf:
    """Strip data, keep static fields (stored in the footer)."""
    return ShreddedLeaf(
        path=leaf.path, type_path=leaf.type_path, leaf_type=leaf.leaf_type,
        rep=None, defs=None, values=None, n_entries=leaf.n_entries,
        max_rep=leaf.max_rep, max_def=leaf.max_def,
        def_meanings=leaf.def_meanings, null_item_code=leaf.null_item_code,
        n_rows=leaf.n_rows,
    )


def _encode_leaf(leaf: ShreddedLeaf, opts: WriteOptions) -> EncodedColumn:
    enc = opts.encoding
    if enc == "lance":
        enc = "lance-" + choose_encoding(leaf)
    if enc == "lance-miniblock":
        return encode_miniblock(
            leaf,
            fixed_codec=opts.fixed_codec,
            bytes_codec=opts.bytes_codec or "zstd_chunk",
        )
    if enc == "lance-fullzip":
        bc = opts.bytes_codec or "plain_bytes"
        from .compression import get_bytes_codec

        if not get_bytes_codec(bc).transparent:
            # full-zip requires transparent compression; opaque codecs are
            # applied per value instead (paper §2.2: "an opaque encoding can
            # be used in a transparent fashion if applied on a per-value
            # basis" — Lance's per-value LZ4)
            bc = "zstd_per_value"
        return encode_fullzip(
            leaf,
            fixed_codec=opts.fixed_codec or "plain",
            bytes_codec=bc,
        )
    if enc == "parquet":
        return encode_parquet(
            leaf,
            page_bytes=opts.page_bytes,
            fixed_codec=opts.fixed_codec,
            bytes_codec=opts.bytes_codec or "zstd_chunk",
            dict_encode=opts.dict_encode,
        )
    raise ValueError(enc)


def write_table(table: Dict[str, A.Array], opts: Optional[WriteOptions] = None) -> bytes:
    opts = opts or WriteOptions()
    payload = b""
    cols_meta: List[Dict] = []
    for name, arr in table.items():
        col: Dict = {"name": name, "type": type_to_dict(arr.type), "n_rows": len(arr)}
        if name in opts.packed_columns:
            ec = encode_packed_struct(arr)
            col["kind"] = "packed"
            col["leaves"] = [{
                "base": len(payload), "meta": ec.meta, "bytes": len(ec.payload),
                "search_cache": ec.search_cache_bytes,
            }]
            payload += ec.payload + b"\x00" * ((-len(ec.payload)) % 8)
        elif opts.encoding == "arrow":
            ec = encode_arrow(arr, compress=opts.arrow_compress)
            col["kind"] = "arrow"
            col["leaves"] = [{
                "base": len(payload), "meta": ec.meta, "bytes": len(ec.payload),
                "search_cache": ec.search_cache_bytes,
            }]
            payload += ec.payload + b"\x00" * ((-len(ec.payload)) % 8)
        else:
            col["kind"] = "shredded"
            leaves_meta = []
            for leaf in shred(arr):
                ec = _encode_leaf(leaf, opts)
                leaves_meta.append({
                    "base": len(payload), "meta": ec.meta, "bytes": len(ec.payload),
                    "search_cache": ec.search_cache_bytes,
                    "path": list(leaf.path),
                    "n_entries": leaf.n_entries,
                })
                payload += ec.payload + b"\x00" * ((-len(ec.payload)) % 8)
            col["leaves"] = leaves_meta
        cols_meta.append(col)
    footer = pack_meta({"columns": cols_meta,
                        "options": {"encoding": opts.encoding,
                                    "decode": opts.decode}})
    return payload + footer + _struct.pack("<Q", len(footer)) + MAGIC


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------


_READERS = {
    "miniblock": MiniBlockReader,
    "fullzip": FullZipReader,
    "parquet": ParquetReader,
}


class FileReader:
    """Reads a Lance-style file through the tiered storage subsystem.

    ``store`` selects the tier stack (see :func:`repro.store.make_store`):
    ``None``/"flat" prices every read on NVMe (seed behaviour), "flat-s3" is
    a cold object store, "tiered" an NVMe block cache over S3, "hot" RAM
    over NVMe over S3.  To customize capacities/policies pass a callable
    ``disk -> TieredStore``; a ready ``TieredStore`` instance is accepted
    only together with the ``Disk`` it wraps (bytes input always builds a
    fresh disk, so a pre-built store cannot match it).  Every
    ``take``/``scan`` runs as one scheduler :class:`~repro.store.ReadBatch`;
    random access is the batched decode-once pipeline (all needed
    chunks/index entries/spans submitted as phase-grouped ``read_many``
    batches, each span decoded exactly once, rows fanned out to request
    order by a single permutation).

    ``decode`` selects the device decode routes: ``"numpy"`` (host) or
    ``"pallas"`` (``repro.kernels``; interpret mode on CPU, Mosaic on TPU).
    Under ``"pallas"`` mini-block chunks batch-decode through the widened
    ``miniblock_decode`` kernel (bit-packed and FoR-bytepacked ints,
    multi-bit rep/def streams, fixed-size-list values) and fixed-stride
    full-zip takes fan out through the ``fullzip_gather`` block-table DMA
    gather.  ``None`` defers to the writer's ``WriteOptions(decode=...)``
    recorded in the footer.

    ``scheduler``/``base`` plug this file into a *shared* IO path (the
    multi-file dataset layer, ``repro.dataset``): instead of building its
    own store the reader enqueues every read — rebased by ``base`` into the
    scheduler's global address space — onto the injected
    :class:`~repro.store.IOScheduler`, so many files coalesce in one
    dispatch and share one cache budget.
    """

    def __init__(self, file_bytes_or_disk, dict_cached: bool = False,
                 store=None, queue_depth: int = 256, readahead="auto",
                 decode: Optional[str] = None, scheduler=None, base: int = 0,
                 tracer=None):
        from ..store import IOScheduler, make_store

        if isinstance(file_bytes_or_disk, (bytes, bytearray)):
            disk = Disk.from_bytes(bytes(file_bytes_or_disk))
        else:
            disk = file_bytes_or_disk
        self.disk = disk
        self.base = int(base)
        if scheduler is not None:
            if store is not None:
                raise ValueError("pass store or scheduler, not both")
            if tracer is not None:
                raise ValueError(
                    "the tracer is fixed by the injected scheduler")
            if queue_depth != 256 or readahead != "auto":
                raise ValueError(
                    "queue_depth/readahead are fixed by the injected "
                    "scheduler")
            if self.base < 0 or self.base + len(disk) > len(scheduler.store.disk):
                raise ValueError(
                    "file does not fit the shared store at base "
                    f"{self.base}")
            self.scheduler = scheduler
            self.store = scheduler.store
        else:
            if self.base:
                raise ValueError("base requires an injected scheduler")
            self.store = make_store(store, disk)
            self.scheduler = IOScheduler(self.store, queue_depth=queue_depth,
                                         readahead=readahead, tracer=tracer)
        self.tracer = self.scheduler.tracer
        self.meta, self.footer_bytes = read_footer(disk.read, len(disk))
        self.columns = {c["name"]: c for c in self.meta["columns"]}
        self.dict_cached = dict_cached
        if decode is None:
            decode = self.meta.get("options", {}).get("decode") or "numpy"
        if decode not in ("numpy", "pallas"):
            raise ValueError(f"decode must be 'numpy'|'pallas', got {decode!r}")
        self.decode = decode
        self._readers: Dict[str, list] = {}

    # -- reader construction ------------------------------------------------
    def _leaf_readers(self, name: str):
        if name in self._readers:
            return self._readers[name]
        col = self.columns[name]
        typ = type_from_dict(col["type"])
        out = []
        if col["kind"] == "arrow":
            lm = col["leaves"][0]
            out.append(ArrowReader(lm["meta"], lm["base"], typ))
        elif col["kind"] == "packed":
            lm = col["leaves"][0]
            out.append(PackedStructReader(lm["meta"], lm["base"], typ))
        else:
            protos = {tuple(p): tp for p, tp in leaf_paths(typ)}
            for lm in col["leaves"]:
                path = tuple(lm["path"])
                type_path = protos[path]
                proto = _proto_from(path, type_path, lm)
                enc = lm["meta"]["encoding"]
                cls = _READERS[enc]
                if enc == "parquet":
                    out.append(cls(lm["meta"], lm["base"], proto,
                                   dict_cached=self.dict_cached))
                elif enc in ("miniblock", "fullzip"):
                    out.append(cls(lm["meta"], lm["base"], proto,
                                   decode=self.decode))
                else:
                    out.append(cls(lm["meta"], lm["base"], proto))
        self._readers[name] = out
        return out

    # -- public API -----------------------------------------------------------
    def take(self, name: str, rows) -> A.Array:
        col = self.columns[name]
        rows = np.asarray(rows, dtype=np.int64)
        with self.tracer.span(f"take:{name}", cat="reader", n_rows=len(rows),
                              decode=self.decode):
            with self.scheduler.batch(f"take:{name}") as io:
                # the rows are the logical requests the drain's modeled cost
                # is attributed over (repro.obs.attrib); declared here — not
                # in take_leaves — so a dataset-wide take counts each row
                # once, not once per fragment
                io.note_requests(len(rows))
                res = self.take_leaves(name, rows, io)
            if col["kind"] in ("arrow", "packed"):
                return res
            return unshred(res, type_from_dict(col["type"]))

    def take_leaves(self, name: str, rows, io):
        """One take through an externally-owned batch handle.

        Returns the final :class:`~repro.core.arrays.Array` for
        arrow/packed columns, or the list of per-leaf ``ShreddedLeaf``
        slices (request order, duplicates materialized) for shredded ones —
        the dataset layer concatenates leaves across fragments before
        unshredding once.  Reads are rebased by this file's ``base`` so a
        shared batch prices them in the global address space.
        """
        rows = np.asarray(rows, dtype=np.int64)
        col = self.columns[name]
        readers = self._leaf_readers(name)
        io = io.at(self.base)
        if col["kind"] in ("arrow", "packed"):
            return readers[0].take(rows, io)
        return [r.take(rows, io) for r in readers]

    def scan(self, name: str, io_chunk: int = 8 << 20) -> A.Array:
        with self.tracer.span(f"scan:{name}", cat="reader",
                              decode=self.decode):
            with self.scheduler.batch(f"scan:{name}", prefetch=True) as io:
                return self.scan_into(name, io, io_chunk=io_chunk)

    def scan_into(self, name: str, io, io_chunk: int = 8 << 20) -> A.Array:
        """One full-column scan through an externally-owned batch handle."""
        col = self.columns[name]
        typ = type_from_dict(col["type"])
        readers = self._leaf_readers(name)
        io = io.at(self.base)
        if col["kind"] == "arrow":
            return readers[0].scan(io)
        if col["kind"] == "packed":
            return readers[0].scan(io, io_chunk=io_chunk)
        leaves = [r.scan(io, io_chunk=io_chunk) for r in readers]
        return unshred(leaves, typ)

    def scan_packed_field(self, name: str, fields) -> A.Array:
        readers = self._leaf_readers(name)
        with self.scheduler.batch(f"scan:{name}", prefetch=True) as io:
            return readers[0].scan(io.at(self.base), fields=fields)

    # -- accounting -------------------------------------------------------------
    def search_cache_bytes(self, name: Optional[str] = None) -> int:
        cols = [self.columns[name]] if name else self.meta["columns"]
        total = 0
        for c in cols:
            for lm in c["leaves"]:
                total += lm["search_cache"]
        return total

    def data_bytes(self, name: Optional[str] = None) -> int:
        cols = [self.columns[name]] if name else self.meta["columns"]
        return sum(lm["bytes"] for c in cols for lm in c["leaves"])

    def reset_io(self):
        """Zero the logical trace and tier counters.  Cache residency
        survives — warm tiers stay warm (use :meth:`drop_caches` for a
        cold restart)."""
        self.scheduler.reset()

    def io_stats(self, coalesce_gap: int = 0):
        return self.scheduler.stats(coalesce_gap)

    def tier_stats(self):
        """Per-tier dispatched-IO stats (fastest first, backing last)."""
        return self.store.tier_stats()

    def modelled_time(self, queue_depth: Optional[int] = None) -> float:
        """Modelled wall time of all IO since the last reset, priced on the
        configured tier stack."""
        return self.scheduler.model_time(queue_depth)

    def drop_caches(self):
        self.store.drop_caches()


def _proto_from(path, type_path, lm) -> ShreddedLeaf:
    from .shred import _def_codes

    codes, meanings, max_def, null_item = _def_codes(type_path)
    max_rep = sum(1 for t in type_path if isinstance(t, T.List))
    return ShreddedLeaf(
        path=path, type_path=tuple(type_path), leaf_type=type_path[-1],
        rep=None, defs=None, values=None,
        n_entries=lm.get("n_entries", 0), max_rep=max_rep, max_def=max_def,
        def_meanings=meanings, null_item_code=null_item,
        n_rows=lm["meta"].get("n_rows", 0),
    )
