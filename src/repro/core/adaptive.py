"""Adaptive structural-encoding selection (the paper's headline idea, §4).

Lance 2.1 alternates between two structural encodings based on data width:

* values >= 128 bytes  -> **full-zip** (cheap per-value access, no search
  cache, 1-2 IOPS random access);
* values <  128 bytes  -> **mini-block** (vectorized chunk decode, opaque
  compression, small search cache, chunk-sized read amplification).

The 128 B/value threshold is the paper's experimentally-derived constant
(§4.1).  The decision is per *leaf column* after shredding, using the same
average-size statistic the Lance writer uses.
"""

from __future__ import annotations

from .encodings_base import avg_value_bytes
from .shred import ShreddedLeaf

__all__ = ["FULLZIP_THRESHOLD_BYTES", "choose_encoding"]

FULLZIP_THRESHOLD_BYTES = 128


def choose_encoding(leaf: ShreddedLeaf) -> str:
    """'fullzip' for large values, 'miniblock' for small ones."""
    return "fullzip" if avg_value_bytes(leaf) >= FULLZIP_THRESHOLD_BYTES else "miniblock"
