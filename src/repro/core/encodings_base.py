"""Shared interface for structural encodings.

A structural encoding turns one :class:`~repro.core.shred.ShreddedLeaf` (or,
for the Arrow-style baseline, the original nested array) into a contiguous
byte payload ("column chunk" / Lance "disk page") plus metadata.  Readers
issue every read through the :class:`~repro.store.ReadBatch` handle the file
layer passes to ``take``/``scan``, so the batched IO scheduler owns
coalescing, tier classification and exact IOPS / read-amplification
accounting.

Readers return leaf *slices* as ``(rep, defs, values)`` aligned entry streams
for the requested rows; ``repro.core.shred.unshred`` turns those back into
nested arrays at the file layer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from . import arrays as A
from . import types as T
from .shred import ShreddedLeaf

__all__ = [
    "EncodedColumn",
    "ColumnReader",
    "align8",
    "pad_to",
    "leaf_slice",
    "avg_value_bytes",
    "reorder_leaf_rows",
    "concat_leaves",
    "empty_leaf",
    "empty_values",
    "value_bytes",
]


def align8(n: int) -> int:
    return (n + 7) & ~7


def pad_to(buf: bytes, align: int = 8) -> bytes:
    pad = (-len(buf)) % align
    return buf + b"\x00" * pad


@dataclasses.dataclass
class EncodedColumn:
    """Result of encoding one leaf column."""

    encoding: str
    payload: bytes  # contiguous bytes written to the data section
    meta: Dict  # column metadata (written to the footer)
    # RAM-resident bytes needed for warm random access (the paper's "search
    # cache"; sec. 2.3).  0.1% of data size is the stated goal.
    search_cache_bytes: int


class ColumnReader:
    """Random access + scan against an encoded column.

    ``base`` is the payload's offset inside the file; all reads go through
    the ``io`` handle (a :class:`~repro.store.ReadBatch`) supplied per
    operation by the file layer.
    """

    def __init__(self, meta: Dict, base: int, leaf_proto: ShreddedLeaf):
        self.meta = meta
        self.base = base
        self.proto = leaf_proto  # carries path/type_path/max levels, no data

    def take(self, rows: np.ndarray, io) -> ShreddedLeaf:
        raise NotImplementedError

    def scan(self, io) -> ShreddedLeaf:
        raise NotImplementedError


def leaf_slice(proto: ShreddedLeaf, rep, defs, values: A.Array, n_rows: int) -> ShreddedLeaf:
    """Build a ShreddedLeaf result with the prototype's static fields."""
    n = len(rep) if rep is not None else (len(defs) if defs is not None else len(values))
    return ShreddedLeaf(
        path=proto.path,
        type_path=proto.type_path,
        leaf_type=proto.leaf_type,
        rep=rep,
        defs=defs,
        values=values,
        n_entries=n,
        max_rep=proto.max_rep,
        max_def=proto.max_def,
        def_meanings=proto.def_meanings,
        null_item_code=proto.null_item_code,
        n_rows=n_rows,
    )


def avg_value_bytes(leaf: ShreddedLeaf) -> float:
    """Average bytes per leaf value — drives the adaptive encoding choice."""
    vals = leaf.values
    if isinstance(vals, A.VarBinaryArray):
        n = max(1, len(vals))
        return float(vals.offsets[-1]) / n
    if isinstance(vals, A.FixedSizeListArray):
        return float(vals.values.dtype.itemsize * vals.values.shape[1])
    return float(vals.values.dtype.itemsize)


def row_starts_from_rep(rep: Optional[np.ndarray], max_rep: int, n_entries: int) -> np.ndarray:
    """Boolean mask of entries that begin a new top-level row."""
    if max_rep == 0 or rep is None:
        return np.ones(n_entries, dtype=bool)
    return rep == max_rep


def reorder_leaf_rows(leaf: ShreddedLeaf, order: np.ndarray) -> ShreddedLeaf:
    """Gather a leaf's rows at ``order`` (any order, duplicates allowed).

    The take pipelines decode each needed row exactly once; this single
    segment-id permutation then fans the decoded rows back out to the request
    order.  Everything is one stable argsort-free pass: per-row entry spans
    come from one cumsum over row starts, the entry permutation from one
    ``np.repeat``/``arange`` expansion, and the (sparse) value gather from
    one cumsum over the validity mask — O(entries + output entries) total.
    """
    order = np.asarray(order, dtype=np.int64)
    starts = row_starts_from_rep(leaf.rep, leaf.max_rep, leaf.n_entries)
    seg = np.cumsum(starts) - 1
    n_src = int(seg[-1]) + 1 if len(seg) else 0
    row_lens = np.bincount(seg, minlength=n_src).astype(np.int64) if n_src else np.zeros(0, np.int64)
    row_offs = np.zeros(n_src + 1, dtype=np.int64)
    np.cumsum(row_lens, out=row_offs[1:])
    out_lens = row_lens[order]
    out_offs = np.zeros(len(order) + 1, dtype=np.int64)
    np.cumsum(out_lens, out=out_offs[1:])
    total = int(out_offs[-1])
    perm = np.repeat(row_offs[order] - out_offs[:-1], out_lens) + np.arange(
        total, dtype=np.int64
    )
    rep = leaf.rep[perm] if leaf.rep is not None else None
    defs = leaf.defs[perm] if leaf.defs is not None else None
    vmask = (leaf.defs == 0) if leaf.defs is not None else np.ones(leaf.n_entries, bool)
    vslot = np.cumsum(vmask) - 1
    sel = perm[vmask[perm]]
    vals = leaf.values.take(vslot[sel])
    return leaf_slice(leaf, rep, defs, vals, len(order))


def concat_leaves(leaves) -> ShreddedLeaf:
    """Concatenate leaf slices of one schema leaf, row-wise.

    The dataset layer takes each fragment's rows independently and stitches
    the per-fragment results back together before the final request-order
    permutation (:func:`reorder_leaf_rows`); rep/def streams and sparse
    values concatenate directly because every slice carries complete rows.
    """
    if len(leaves) == 1:
        return leaves[0]
    l0 = leaves[0]
    rep = (np.concatenate([l.rep for l in leaves])
           if l0.rep is not None else None)
    defs = (np.concatenate([l.defs for l in leaves])
            if l0.defs is not None else None)
    vals = A.concat([l.values for l in leaves])
    return leaf_slice(l0, rep, defs, vals, sum(l.n_rows for l in leaves))


def empty_leaf(proto: ShreddedLeaf) -> ShreddedLeaf:
    """A zero-row leaf slice with the prototype's static fields."""
    return leaf_slice(
        proto,
        np.zeros(0, np.uint8) if proto.max_rep > 0 else None,
        np.zeros(0, np.uint8) if proto.max_def > 0 else None,
        empty_values(proto.leaf_type), 0)


def empty_values(leaf_type: T.DataType) -> A.Array:
    """A zero-length values array of ``leaf_type`` (non-nullable)."""
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        return A.VarBinaryArray(
            leaf_type.with_nullable(False), np.ones(0, bool),
            np.zeros(1, np.int64), np.zeros(0, np.uint8)
        )
    if isinstance(leaf_type, T.FixedSizeList):
        return A.FixedSizeListArray(
            leaf_type.with_nullable(False),
            np.ones(0, bool),
            np.zeros((0, leaf_type.size), dtype=np.dtype(leaf_type.child.dtype)),
        )
    return A.PrimitiveArray(
        leaf_type.with_nullable(False), np.ones(0, bool),
        np.zeros(0, np.dtype(leaf_type.dtype))
    )


def value_bytes(vals: A.Array) -> int:
    """Payload bytes of a values array (the take paths' useful-bytes unit)."""
    if isinstance(vals, A.VarBinaryArray):
        return int(len(vals.data))
    return int(vals.values.nbytes)
