# The paper's primary contribution: adaptive structural encodings for
# columnar storage (Lance 2.1).  Shredding (Dremel rep/def levels), the
# mini-block and full-zip structural encodings, the Parquet-style and
# Arrow-style baselines, struct packing, and the file container with exact
# IOP accounting.

from . import types  # noqa: F401
from .adaptive import FULLZIP_THRESHOLD_BYTES, choose_encoding  # noqa: F401
from .arrays import (  # noqa: F401
    Array,
    FixedSizeListArray,
    ListArray,
    PrimitiveArray,
    StructArray,
    VarBinaryArray,
    from_pylist,
    to_pylist,
)
from .file import FileReader, WriteOptions, write_table  # noqa: F401
from .io_sim import DRAM, HBM, NVME, S3, Disk, IOTracker, model_time  # noqa: F401
from .shred import ShreddedLeaf, shred, unshred  # noqa: F401
