"""Parquet-style structural encoding (paper §3.1) — the primary baseline.

Leaf columns are stored as a sequence of **pages**; each page holds the
repetition levels, definition levels, and sparsely-stored values for a run of
complete top-level rows (Parquet pages begin on record boundaries).  A **page
offset index** — (offset, size, first row) per page — is the search cache
(20 in-memory bytes per page, the parquet-rs figure from §4.2.4); binary
search maps a row to exactly one page, so random access costs one IOP with
page-sized read amplification.

Dictionary encoding is modelled faithfully: the dictionary is a page at the
start of the column chunk, and a cold reader must fetch + decode it on every
take (the paper's "2% of ideal" pathology, §6.1.1) unless ``dict_cached``
(Lance-style search-cache placement) is set.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Optional

import numpy as np

from . import arrays as A
from . import types as T
from .compression import Encoded, bitpack, bitunpack, min_bits, get_bytes_codec, get_fixed_codec
from .encodings_base import (
    ColumnReader,
    EncodedColumn,
    empty_leaf,
    leaf_slice,
    pad_to,
    reorder_leaf_rows,
    value_bytes,
)
from .miniblock import _decode_chunk_values, _encode_chunk_values, _parse_chunk, _serialize_chunk, _empty_values
from .rdlevels import pack_levels, unpack_levels
from .shred import ShreddedLeaf

__all__ = ["encode_parquet", "ParquetReader", "PAGE_INDEX_BYTES_PER_PAGE"]

PAGE_INDEX_BYTES_PER_PAGE = 20  # parquet-rs in-memory page index entry


def encode_parquet(
    leaf: ShreddedLeaf,
    page_bytes: int = 8 * 1024,
    fixed_codec: Optional[str] = None,
    bytes_codec: str = "zstd_chunk",
    dict_encode: bool = False,
) -> EncodedColumn:
    n = leaf.n_entries
    valid_mask = (leaf.defs == 0) if leaf.defs is not None else np.ones(n, bool)
    value_slot = np.cumsum(valid_mask) - 1
    if leaf.max_rep > 0:
        row_start = leaf.rep == leaf.max_rep
    else:
        row_start = np.ones(n, dtype=bool)
    row_start_pos = np.nonzero(row_start)[0]
    n_rows = len(row_start_pos)

    # ---- optional dictionary over the whole column chunk -------------
    dict_page = b""
    dict_meta: Dict = {}
    codes = None
    if dict_encode:
        vals = leaf.values
        if isinstance(vals, A.VarBinaryArray):
            lens = vals.offsets[1:] - vals.offsets[:-1]
            keys = [vals.data[vals.offsets[i]: vals.offsets[i + 1]].tobytes() for i in range(len(vals))]
            uniq, codes = np.unique(np.array(keys, dtype=object), return_inverse=True)
            u_lens = np.array([len(u) for u in uniq], dtype=np.uint64)
            u_data = np.frombuffer(b"".join(uniq), dtype=np.uint8) if len(uniq) else np.zeros(0, np.uint8)
            lb = bitpack(u_lens, min_bits(u_lens))
            dict_page = pad_to(struct.pack("<II", len(uniq), len(lb))) + pad_to(lb.tobytes()) + pad_to(u_data.tobytes())
            dict_meta = {"kind": "var", "n": int(len(uniq)), "lbits": min_bits(u_lens)}
        else:
            flat = vals.values.reshape(len(vals), -1) if vals.values.ndim > 1 else vals.values
            uniq, codes = np.unique(flat, axis=0, return_inverse=True)
            dict_page = pad_to(np.ascontiguousarray(uniq).tobytes())
            dict_meta = {"kind": "fixed", "n": int(len(uniq)), "dtype": vals.values.dtype.name,
                         "shape1": 0 if vals.values.ndim == 1 else vals.values.shape[1]}
        codes = codes.astype(np.uint64)
        dict_meta["cbits"] = min_bits(codes)

    # ---- paginate on row boundaries -----------------------------------
    # estimate rows per page from average entry footprint
    pages: List[bytes] = []
    page_meta: List[Dict] = []
    offsets_in_payload: List[int] = []
    pos = len(dict_page)
    r = 0
    while r < n_rows or (n_rows == 0 and not pages):
        # grow the page until its *encoded* size crosses page_bytes
        lo_entry = row_start_pos[r] if n_rows else 0
        rows_here = max(1, n_rows - r) if n_rows else 0
        # binary grow: start from an estimate, double/halve on encode size
        guess = _estimate_rows(leaf, value_slot, valid_mask, row_start_pos, r, page_bytes)
        rows_here = min(max(1, guess), n_rows - r) if n_rows else 0
        while True:
            hi_entry = row_start_pos[r + rows_here] if r + rows_here < n_rows else n
            blob, meta = _encode_page(
                leaf, lo_entry, hi_entry, value_slot, valid_mask,
                fixed_codec, bytes_codec, codes,
            )
            if len(blob) <= page_bytes * 2 or rows_here <= 1:
                break
            rows_here = max(1, rows_here // 2)
        pages.append(blob)
        meta["first_row"] = r
        meta["n_rows"] = rows_here
        page_meta.append(meta)
        offsets_in_payload.append(pos)
        pos += len(blob)
        r += rows_here
        if n_rows == 0:
            break

    payload = dict_page + b"".join(pages)
    meta = {
        "encoding": "parquet",
        "fixed_codec": fixed_codec or "auto",
        "bytes_codec": bytes_codec,
        "dict": dict_meta if dict_encode else None,
        "dict_page_bytes": len(dict_page),
        "pages": page_meta,
        "page_offsets": offsets_in_payload,
        "n_rows": n_rows if n_rows else leaf.n_rows,
        "n_entries": n,
    }
    return EncodedColumn(
        "parquet", payload, meta,
        search_cache_bytes=PAGE_INDEX_BYTES_PER_PAGE * len(pages),
    )


def _estimate_rows(leaf, value_slot, valid_mask, row_start_pos, r, page_bytes) -> int:
    n_rows = len(row_start_pos)
    if n_rows == 0:
        return 0
    vals = leaf.values
    if isinstance(vals, A.VarBinaryArray):
        avg_v = float(vals.offsets[-1]) / max(1, len(vals))
    elif vals.values.ndim > 1:
        avg_v = vals.values.dtype.itemsize * vals.values.shape[1]
    else:
        avg_v = vals.values.dtype.itemsize
    entries_per_row = leaf.n_entries / n_rows
    per_row = entries_per_row * (avg_v * 0.6 + 0.4)  # assume mild compression
    return max(1, int(page_bytes / max(per_row, 1e-9)))


def _encode_page(leaf, lo, hi, value_slot, valid_mask, fixed_codec, bytes_codec, codes):
    vm = valid_mask[lo:hi]
    bufs: List[bytes] = []
    metas: List[Dict] = []
    if leaf.rep is not None:
        bufs.append(pack_levels(leaf.rep[lo:hi], leaf.max_rep).tobytes())
        metas.append({"stream": "rep"})
    if leaf.defs is not None:
        bufs.append(pack_levels(leaf.defs[lo:hi], leaf.max_def).tobytes())
        metas.append({"stream": "def"})
    if codes is not None:
        page_codes = codes[value_slot[lo:hi][vm]]
        cbits = min_bits(codes)
        bufs.append(bitpack(page_codes, cbits).tobytes())
        metas.append({"stream": "codes", "cbits": cbits})
    else:
        from .miniblock import _default_fixed_codec

        fc = fixed_codec or _default_fixed_codec(leaf.values)
        vals = leaf.values.take(value_slot[lo:hi][vm])
        for enc in _encode_chunk_values(leaf.leaf_type, vals, fc, bytes_codec):
            bufs.append(enc.data.tobytes())
            metas.append(enc.meta)
    blob = _serialize_page(bufs)
    return blob, {"n_entries": hi - lo, "n_values": int(vm.sum()), "bufmeta": metas,
                  "size": len(blob)}


def _serialize_page(buffers: List[bytes]) -> bytes:
    head = struct.pack("<I", len(buffers)) + b"".join(
        struct.pack("<I", len(b)) for b in buffers
    )
    out = pad_to(head)
    for b in buffers:
        out += pad_to(b)
    return out


def _parse_page(raw: np.ndarray) -> List[np.ndarray]:
    data = raw.tobytes()
    (nb,) = struct.unpack_from("<I", data, 0)
    sizes = struct.unpack_from(f"<{nb}I", data, 4)
    pos = (4 + 4 * nb + 7) & ~7
    bufs = []
    for s in sizes:
        bufs.append(raw[pos : pos + s])
        pos = (pos + s + 7) & ~7
    return bufs


class ParquetReader(ColumnReader):
    def __init__(self, meta, base, leaf_proto, dict_cached: bool = False):
        super().__init__(meta, base, leaf_proto)
        self.dict_cached = dict_cached
        self._dict_cache = None
        self._first_rows = np.array([p["first_row"] for p in meta["pages"]], dtype=np.int64)

    # -- dictionary -----------------------------------------------------
    def _load_dict(self, io, phase: int = 0):
        # Cold (non-cached) behavior is modelled by take() dropping the cache
        # at the start of each operation; within one operation the dictionary
        # is fetched once.
        if self._dict_cache is not None:
            return self._dict_cache
        dm = self.meta["dict"]
        raw = io.read(self.base, self.meta["dict_page_bytes"], phase=phase)
        if dm["kind"] == "var":
            n, lb_sz = struct.unpack_from("<II", raw.tobytes(), 0)
            pos = 8
            pos = (pos + 7) & ~7
            lens = bitunpack(raw[pos : pos + lb_sz], n, dm["lbits"]).astype(np.int64)
            pos = (pos + lb_sz + 7) & ~7
            offs = np.zeros(n + 1, np.int64)
            np.cumsum(lens, out=offs[1:])
            data = raw[pos : pos + int(offs[-1])]
            d = ("var", offs, np.asarray(data))
        else:
            dt = np.dtype(dm["dtype"])
            s1 = dm.get("shape1", 0)
            flat = np.frombuffer(raw.tobytes(), dtype=dt, count=dm["n"] * (s1 or 1))
            d = ("fixed", flat.reshape(dm["n"], s1) if s1 else flat)
        self._dict_cache = d
        return d

    def search_cache_bytes_effective(self) -> int:
        sc = PAGE_INDEX_BYTES_PER_PAGE * len(self.meta["pages"])
        if self.dict_cached and self.meta["dict"] is not None:
            sc += self.meta["dict_page_bytes"]
        return sc

    # -- decode ----------------------------------------------------------
    def _decode_page(self, pi: int, raw: np.ndarray, io):
        pm = self.meta["pages"][pi]
        bufs = _parse_page(raw)
        k = pm["n_entries"]
        bi = 0
        rep = defs = None
        if self.proto.max_rep > 0:
            rep = unpack_levels(bufs[bi], k, self.proto.max_rep)
            bi += 1
        if self.proto.max_def > 0:
            defs = unpack_levels(bufs[bi], k, self.proto.max_def)
            bi += 1
        if self.meta["dict"] is not None:
            codes = bitunpack(bufs[bi], pm["n_values"], pm["bufmeta"][bi]["cbits"]).astype(np.int64)
            d = self._load_dict(io, phase=0)
            if d[0] == "var":
                _, offs, data = d
                lens = (offs[1:] - offs[:-1])[codes]
                noffs = np.zeros(len(codes) + 1, np.int64)
                np.cumsum(lens, out=noffs[1:])
                out = np.zeros(int(noffs[-1]), np.uint8)
                src = np.repeat(offs[:-1][codes], lens) + (
                    np.arange(int(noffs[-1])) - np.repeat(noffs[:-1], lens)
                )
                out[:] = data[src]
                vals = A.VarBinaryArray(
                    self.proto.leaf_type.with_nullable(False),
                    np.ones(len(codes), bool), noffs, out,
                )
            else:
                flat = d[1][codes]
                if flat.ndim > 1:
                    vals = A.FixedSizeListArray(self.proto.leaf_type.with_nullable(False),
                                                np.ones(len(codes), bool), flat)
                else:
                    vals = A.PrimitiveArray(self.proto.leaf_type.with_nullable(False),
                                            np.ones(len(codes), bool), flat)
        else:
            vals = _decode_chunk_values(
                self.proto.leaf_type, bufs[bi:], pm["bufmeta"][bi:], pm["n_values"],
                self.meta["fixed_codec"], self.meta["bytes_codec"],
            )
        return rep, defs, vals

    # -- access ----------------------------------------------------------
    def take(self, rows: np.ndarray, io) -> ShreddedLeaf:
        """Batched random access, PR-2 style: one ``searchsorted`` maps all
        rows to pages, every needed page is fetched in a single phase-0
        ``read_many`` dispatch and decoded exactly once, row extraction is
        one vectorized segment-id pass over the concatenated entry streams,
        and a single :func:`reorder_leaf_rows` permutation fans the decoded
        rows out to request order (duplicates never re-extracted)."""
        rows = np.asarray(rows, dtype=np.int64)
        if len(rows) == 0:
            return empty_leaf(self.proto)
        if self.meta["dict"] is not None and not self.dict_cached:
            self._dict_cache = None  # cold: must refetch per take (parquet-rs behavior)
            self._load_dict(io, phase=0)
        pis = np.searchsorted(self._first_rows, rows, side="right") - 1
        needed = np.unique(pis)
        offs = np.asarray(self.meta["page_offsets"], dtype=np.int64)
        sizes = np.array([self.meta["pages"][p]["size"] for p in needed],
                         dtype=np.int64)
        data, doffs = io.read_many(self.base + offs[needed], sizes, phase=0)
        decoded = [
            self._decode_page(int(p), data[doffs[i]: doffs[i + 1]], io)
            for i, p in enumerate(needed)
        ]
        lens = np.array([self.meta["pages"][p]["n_entries"] for p in needed],
                        dtype=np.int64)
        reps = [d[0] for d in decoded]
        dfs = [d[1] for d in decoded]
        rep_all = np.concatenate(reps) if reps[0] is not None else None
        def_all = np.concatenate(dfs) if dfs[0] is not None else None
        vals_all = A.concat([d[2] for d in decoded])
        total = int(lens.sum())

        # global row id per entry (pages start on record boundaries, so each
        # page's cumsum of row starts is offset by its first_row)
        if self.proto.max_rep > 0:
            starts = rep_all == self.proto.max_rep
        else:
            starts = np.ones(total, dtype=bool)
        cs = np.cumsum(starts)
        page_off = np.zeros(len(needed) + 1, dtype=np.int64)
        np.cumsum(lens, out=page_off[1:])
        cs_pre = np.concatenate([[0], cs])[page_off[:-1]]
        first_rows = self._first_rows[needed]
        row_id = cs - 1 - np.repeat(cs_pre, lens) + np.repeat(first_rows, lens)

        # select the entries of all requested rows in one pass
        urows, inv = np.unique(rows, return_inverse=True)
        pos = np.searchsorted(urows, row_id)
        pos_c = np.minimum(pos, len(urows) - 1)
        sel = urows[pos_c] == row_id
        vmask = (def_all == 0) if def_all is not None else np.ones(total, bool)
        vslot = np.cumsum(vmask) - 1
        rep_sel = rep_all[sel] if rep_all is not None else None
        def_sel = def_all[sel] if def_all is not None else None
        val_sel = vals_all.take(vslot[sel & vmask])
        dec = leaf_slice(self.proto, rep_sel, def_sel, val_sel, len(urows))
        out = reorder_leaf_rows(dec, inv)
        # useful bytes over the *request* (duplicates included), identical to
        # the historical per-row extraction's accounting
        io.note_useful(value_bytes(out.values))
        return out

    def scan(self, io, io_chunk: int = 8 << 20) -> ShreddedLeaf:
        """Full scan in bounded-memory windows: pages are decoded as soon as
        their bytes are fully buffered and the consumed prefix is dropped,
        so peak raw-buffer RSS is O(window + max page) instead of O(column).
        The logical read sequence is unchanged."""
        if self.meta["dict"] is not None:
            self._load_dict(io, phase=0)
        offs = self.meta["page_offsets"]
        total = (offs[-1] + self.meta["pages"][-1]["size"]) if offs else 0
        start = self.meta["dict_page_bytes"]
        reps, dfs, vals = [], [], []
        buf = np.zeros(0, dtype=np.uint8)
        buf_start = start  # file offset of buf[0]
        pi = 0
        for p in range(start, total, io_chunk):
            part = io.read(self.base + p, min(io_chunk, total - p), phase=0)
            buf = np.concatenate([buf, part]) if len(buf) else part
            while pi < len(offs):
                off, sz = offs[pi], self.meta["pages"][pi]["size"]
                if off + sz > buf_start + len(buf):
                    break
                r, d, v = self._decode_page(
                    pi, buf[off - buf_start: off - buf_start + sz], io)
                reps.append(r)
                dfs.append(d)
                vals.append(v)
                pi += 1
            if pi < len(offs):  # drop bytes before the next undecoded page
                keep = offs[pi]
                buf = buf[keep - buf_start:]
                buf_start = keep
            else:
                buf = np.zeros(0, dtype=np.uint8)
                buf_start = p + len(part)
        rep = np.concatenate(reps) if reps and reps[0] is not None else None
        defs = np.concatenate(dfs) if dfs and dfs[0] is not None else None
        values = A.concat(vals) if vals else _empty_values(self.proto.leaf_type)
        return leaf_slice(self.proto, rep, defs, values, self.meta["n_rows"])
