"""The mini-block structural encoding (paper §4.2).

Small data types are chunked into compressed mini-blocks of 1–2 disk sectors
(4–8 KiB target, hard ceiling 32 KiB from the 12-bit word count), each chunk
holding bit-packed repetition levels, definition levels and value buffers.
Whole chunks are decoded at once, so opaque compression is allowed; random
access pays chunk-sized read amplification plus decode work — the trade the
paper accepts for small types.

Chunk rules implemented exactly as §4.2.1/4.2.2:
* power-of-two number of entries per chunk (last chunk may be ragged),
  at most 4096;
* chunk payload padded to 8-byte words; on-disk chunk meta is 2 bytes
  (12-bit word count, 4-bit log2(num values));
* chunk = [u16 n_buffers][u16 size x n_buffers][8-aligned buffers...];
* buffers: [rep][def][values...] (absent streams are skipped);
* a repetition index with N+1 = 2 counters per chunk supports one level of
  random access (§4.2.3), handling rows that split across chunks.

Search cache (§4.2.4): 24 in-memory bytes per chunk without a repetition
index, 41 with — we model exactly those numbers.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Optional

import numpy as np

from . import arrays as A
from . import types as T
from .compression import Encoded, get_bytes_codec, get_fixed_codec, min_bits
from .encodings_base import ColumnReader, EncodedColumn, leaf_slice, pad_to
from .rdlevels import level_bits, pack_levels, unpack_levels
from .shred import ShreddedLeaf

__all__ = ["encode_miniblock", "MiniBlockReader"]

MAX_CHUNK_VALUES = 4096
TARGET_CHUNK_BYTES = 8 * 1024  # 1-2 disk sectors compressed
MAX_CHUNK_WORDS = (1 << 12) - 1  # 12-bit word count
MIN_CHUNK_VALUES = 32

# in-memory search-cache cost model from the paper (sec 4.2.4)
CACHE_BYTES_PER_CHUNK = 24
CACHE_BYTES_PER_CHUNK_WITH_REP = 41


def _default_fixed_codec(values: A.Array) -> str:
    dt = values.values.dtype if not isinstance(values, A.VarBinaryArray) else None
    if dt is not None and dt.kind in ("i", "u"):
        return "bitpack"
    return "plain"


def _encode_chunk_values(
    leaf_type: T.DataType,
    values: A.Array,
    fixed_codec: str,
    bytes_codec: str,
) -> List[Encoded]:
    """Encode the (sparse) values of one chunk into 1-2 buffers."""
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        lengths = (values.offsets[1:] - values.offsets[:-1]).astype(np.uint64)
        bc = get_bytes_codec(bytes_codec)
        enc_data = bc.encode(lengths, values.data)
        stored = enc_data.out_lengths if enc_data.out_lengths is not None else lengths
        enc_lens = get_fixed_codec(fixed_codec if fixed_codec != "plain" else "bitpack").encode(
            np.asarray(stored, dtype=np.uint64)
        )
        return [enc_lens, enc_data]
    if isinstance(leaf_type, T.FixedSizeList):
        flat = values.values.reshape(-1)
        codec = get_fixed_codec("plain" if flat.dtype.kind == "f" else fixed_codec)
        enc = codec.encode(flat)
        enc.meta["fsl"] = leaf_type.size
        enc.meta["codec"] = codec.name
        return [enc]
    codec = get_fixed_codec("plain" if values.values.dtype.kind == "f" else fixed_codec)
    enc = codec.encode(values.values)
    enc.meta["codec"] = codec.name
    return [enc]


def _decode_chunk_values(
    leaf_type: T.DataType,
    bufs: List[np.ndarray],
    metas: List[Dict],
    n_values: int,
    fixed_codec: str,
    bytes_codec: str,
) -> A.Array:
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        lens_codec = get_fixed_codec(metas[0].get("codec", "bitpack"))
        stored = lens_codec.decode(Encoded(bufs[0], metas[0]), n_values).astype(np.int64)
        bc = get_bytes_codec(bytes_codec)
        out_lens, out_data = bc.decode(Encoded(bufs[1], metas[1]), stored)
        offsets = np.zeros(n_values + 1, dtype=np.int64)
        np.cumsum(out_lens, out=offsets[1:])
        return A.VarBinaryArray(
            leaf_type.with_nullable(False), np.ones(n_values, bool), offsets, out_data
        )
    codec = get_fixed_codec(metas[0]["codec"])
    if isinstance(leaf_type, T.FixedSizeList):
        flat = codec.decode(Encoded(bufs[0], metas[0]), n_values * leaf_type.size)
        return A.FixedSizeListArray(
            leaf_type.with_nullable(False),
            np.ones(n_values, bool),
            np.asarray(flat).reshape(n_values, leaf_type.size),
        )
    vals = codec.decode(Encoded(bufs[0], metas[0]), n_values)
    return A.PrimitiveArray(
        leaf_type.with_nullable(False), np.ones(n_values, bool), np.asarray(vals)
    )


def _serialize_chunk(buffers: List[bytes]) -> bytes:
    """[u16 n_buffers][u16 size each][8-aligned buffer bytes ...] padded to 8."""
    for b in buffers:
        if len(b) > 0xFFFF:
            raise ValueError("buffer exceeds u16 size field")
    head = struct.pack("<H", len(buffers)) + b"".join(
        struct.pack("<H", len(b)) for b in buffers
    )
    out = pad_to(head)
    for b in buffers:
        out += pad_to(b)
    return pad_to(out)


def _parse_chunk(raw: np.ndarray) -> List[np.ndarray]:
    data = raw.tobytes()
    (nb,) = struct.unpack_from("<H", data, 0)
    sizes = struct.unpack_from(f"<{nb}H", data, 2)
    pos = (2 + 2 * nb + 7) & ~7
    bufs = []
    for s in sizes:
        bufs.append(raw[pos : pos + s])
        pos = (pos + s + 7) & ~7
    return bufs


def encode_miniblock(
    leaf: ShreddedLeaf,
    fixed_codec: Optional[str] = None,
    bytes_codec: str = "zstd_chunk",
) -> EncodedColumn:
    fixed_codec = fixed_codec or _default_fixed_codec(leaf.values)
    n_entries = leaf.n_entries

    # map each entry to its value slot (sparse values: def==0 entries only)
    valid_mask = (leaf.defs == 0) if leaf.defs is not None else np.ones(n_entries, bool)
    value_slot = np.cumsum(valid_mask) - 1

    # rows: entries that start a top-level row
    if leaf.max_rep > 0:
        row_start = leaf.rep == leaf.max_rep
    else:
        row_start = np.ones(n_entries, dtype=bool)

    chunks: List[bytes] = []
    chunk_meta: List[Dict] = []
    rep_index: List[tuple] = []  # (rows_started_before_chunk, first_entry_is_row_start)
    payload_offsets: List[int] = []
    pos = 0
    start = 0
    rows_before = 0
    while start < n_entries or (n_entries == 0 and not chunks):
        k = min(MAX_CHUNK_VALUES, n_entries - start) if n_entries else 0
        if k > 0:
            # round down to power of two unless it's the ragged tail
            if start + k < n_entries:
                k = 1 << (k.bit_length() - 1)
        while True:
            end = start + k
            e_rep = leaf.rep[start:end] if leaf.rep is not None else None
            e_def = leaf.defs[start:end] if leaf.defs is not None else None
            vm = valid_mask[start:end]
            vals = leaf.values.take(value_slot[start:end][vm])
            bufs: List[bytes] = []
            metas: List[Dict] = []
            if e_rep is not None:
                bufs.append(pack_levels(e_rep, leaf.max_rep).tobytes())
                metas.append({"stream": "rep"})
            if e_def is not None:
                bufs.append(pack_levels(e_def, leaf.max_def).tobytes())
                metas.append({"stream": "def"})
            encs = _encode_chunk_values(leaf.leaf_type, vals, fixed_codec, bytes_codec)
            for enc in encs:
                bufs.append(enc.data.tobytes())
                metas.append(enc.meta)
            try:
                blob = _serialize_chunk(bufs)
            except ValueError:
                blob = None
            if (
                blob is not None
                and (len(blob) <= TARGET_CHUNK_BYTES or k <= MIN_CHUNK_VALUES)
                and len(blob) // 8 <= MAX_CHUNK_WORDS
            ):
                break
            if k <= 1:
                raise ValueError("single value exceeds miniblock limits; "
                                 "use full-zip for large types")
            k = max(1, k // 2)
        n_vals = int(vm.sum())
        chunks.append(blob)
        chunk_meta.append(
            {
                "n_entries": k,
                "n_values": n_vals,
                "words": len(blob) // 8,
                "bufmeta": metas,
            }
        )
        rep_index.append((rows_before, bool(row_start[start]) if k else True))
        rows_before += int(row_start[start:end].sum())
        payload_offsets.append(pos)
        pos += len(blob)
        start = end
        if n_entries == 0:
            break

    payload = b"".join(chunks)
    has_rep = leaf.max_rep > 0
    per_chunk = CACHE_BYTES_PER_CHUNK_WITH_REP if has_rep else CACHE_BYTES_PER_CHUNK
    meta = {
        "encoding": "miniblock",
        "fixed_codec": fixed_codec,
        "bytes_codec": bytes_codec,
        "chunks": chunk_meta,
        "chunk_offsets": payload_offsets,
        "rep_index": rep_index,
        "n_rows": leaf.n_rows,
        "n_entries": n_entries,
    }
    return EncodedColumn(
        encoding="miniblock",
        payload=payload,
        meta=meta,
        search_cache_bytes=per_chunk * len(chunks),
    )


class MiniBlockReader(ColumnReader):
    def _decode_chunk(self, ci: int, raw: np.ndarray):
        cm = self.meta["chunks"][ci]
        bufs = _parse_chunk(raw)
        k = cm["n_entries"]
        bi = 0
        rep = defs = None
        if self.proto.max_rep > 0:
            rep = unpack_levels(bufs[bi], k, self.proto.max_rep)
            bi += 1
        if self.proto.max_def > 0:
            defs = unpack_levels(bufs[bi], k, self.proto.max_def)
            bi += 1
        vals = _decode_chunk_values(
            self.proto.leaf_type,
            bufs[bi:],
            cm["bufmeta"][bi:],
            cm["n_values"],
            self.meta["fixed_codec"],
            self.meta["bytes_codec"],
        )
        return rep, defs, vals

    # ------------------------------------------------------------------
    def _chunks_for_rows(self, rows: np.ndarray) -> Dict[int, np.ndarray]:
        """Map sorted unique row ids -> list of chunk indices to fetch."""
        ri = self.meta["rep_index"]
        rows_before = np.array([r[0] for r in ri], dtype=np.int64)
        first_is_start = np.array([r[1] for r in ri], dtype=bool)
        n_chunks = len(ri)
        need: Dict[int, list] = {}
        for r in rows:
            c0 = int(np.searchsorted(rows_before, r, side="right")) - 1
            # find chunk where row r+1 starts
            c1 = int(np.searchsorted(rows_before, r + 1, side="right")) - 1
            if c1 > c0 and rows_before[c1] == r + 1 and first_is_start[c1]:
                c1 -= 1
            need[int(r)] = list(range(c0, min(c1, n_chunks - 1) + 1))
        return need

    def take(self, rows: np.ndarray, io) -> ShreddedLeaf:
        rows = np.asarray(rows, dtype=np.int64)
        order = np.argsort(rows, kind="stable")
        srows = rows[order]
        need = self._chunks_for_rows(srows)
        all_chunks = sorted({c for cs in need.values() for c in cs})
        offs = self.meta["chunk_offsets"]
        sizes = [self.meta["chunks"][c]["words"] * 8 for c in all_chunks]
        raws = {}
        for c, sz in zip(all_chunks, sizes):
            raws[c] = io.read(self.base + offs[c], sz, phase=0)
        decoded = {c: self._decode_chunk(c, raws[c]) for c in all_chunks}

        rep_parts, def_parts, val_parts, nrows = [], [], [], 0
        ri = self.meta["rep_index"]
        for r in srows:
            cs = need[int(r)]
            # concatenate entry streams of the involved chunks, then select
            # the entries belonging to row r
            reps = [decoded[c][0] for c in cs]
            dfs = [decoded[c][1] for c in cs]
            vls = [decoded[c][2] for c in cs]
            rep = np.concatenate(reps) if reps[0] is not None else None
            dfs = np.concatenate(dfs) if dfs[0] is not None else None
            vals = A.concat(vls) if len(vls) > 1 else vls[0]
            if self.proto.max_rep > 0:
                starts = rep == self.proto.max_rep
            else:
                starts = np.ones(len(dfs) if dfs is not None else len(vals), bool)
            # rows started before chunk cs[0] is ri[cs[0]][0]; entries before
            # the first start in the group belong to row (rows_before - 1),
            # which cumsum handles naturally (segment id -1 + rows_before).
            row_of_entry = np.cumsum(starts) - 1 + ri[cs[0]][0]
            sel = row_of_entry == r
            valid_sel = sel & ((dfs == 0) if dfs is not None else True)
            vmask = (dfs == 0) if dfs is not None else np.ones(len(sel), bool)
            vslot = np.cumsum(vmask) - 1
            rep_parts.append(rep[sel] if rep is not None else None)
            def_parts.append(dfs[sel] if dfs is not None else None)
            val_parts.append(vals.take(vslot[valid_sel]))
            nrows += 1
        rep = np.concatenate(rep_parts) if rep_parts and rep_parts[0] is not None else None
        defs = np.concatenate(def_parts) if def_parts and def_parts[0] is not None else None
        vals = A.concat(val_parts)
        io.note_useful(int(sum(len(v.data) if isinstance(v, A.VarBinaryArray) else v.values.nbytes for v in val_parts)))
        out = leaf_slice(self.proto, rep, defs, vals, len(rows))
        return _reorder_rows(out, np.argsort(order, kind="stable"))

    def scan(self, io, io_chunk: int = 8 << 20) -> ShreddedLeaf:
        offs = self.meta["chunk_offsets"]
        total = (offs[-1] + self.meta["chunks"][-1]["words"] * 8) if offs else 0
        raw_parts = []
        for p in range(0, total, io_chunk):
            raw_parts.append(io.read(self.base + p, min(io_chunk, total - p), phase=0))
        raw = np.concatenate(raw_parts) if raw_parts else np.zeros(0, np.uint8)
        reps, dfs, vals = [], [], []
        for ci, off in enumerate(offs):
            sz = self.meta["chunks"][ci]["words"] * 8
            r, d, v = self._decode_chunk(ci, raw[off : off + sz])
            reps.append(r)
            dfs.append(d)
            vals.append(v)
        rep = np.concatenate(reps) if reps and reps[0] is not None else None
        defs = np.concatenate(dfs) if dfs and dfs[0] is not None else None
        if vals:
            values = A.concat(vals)
        else:
            values = _empty_values(self.proto.leaf_type)
        return leaf_slice(self.proto, rep, defs, values, self.meta["n_rows"])


def _empty_values(leaf_type: T.DataType) -> A.Array:
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        return A.VarBinaryArray(
            leaf_type.with_nullable(False), np.ones(0, bool), np.zeros(1, np.int64), np.zeros(0, np.uint8)
        )
    if isinstance(leaf_type, T.FixedSizeList):
        return A.FixedSizeListArray(
            leaf_type.with_nullable(False),
            np.ones(0, bool),
            np.zeros((0, leaf_type.size), dtype=np.dtype(leaf_type.child.dtype)),
        )
    return A.PrimitiveArray(
        leaf_type.with_nullable(False), np.ones(0, bool), np.zeros(0, np.dtype(leaf_type.dtype))
    )


def _reorder_rows(leaf: ShreddedLeaf, order: np.ndarray) -> ShreddedLeaf:
    """Reorder a leaf's rows (take() must honor the request order)."""
    if leaf.max_rep == 0:
        rep = None
        defs = leaf.defs[order] if leaf.defs is not None else None
        vmask = (leaf.defs == 0) if leaf.defs is not None else np.ones(leaf.n_entries, bool)
        vslot = np.cumsum(vmask) - 1
        sel = order[vmask[order]]
        vals = leaf.values.take(vslot[sel])
        return leaf_slice(leaf, rep, defs, vals, leaf.n_rows)
    # general case: segment the entry stream by row starts, permute segments
    starts = leaf.rep == leaf.max_rep
    seg = np.cumsum(starts) - 1
    idx_by_row = [np.nonzero(seg == r)[0] for r in range(int(seg[-1]) + 1 if len(seg) else 0)]
    perm = np.concatenate([idx_by_row[r] for r in order]) if len(order) else np.zeros(0, np.int64)
    rep = leaf.rep[perm]
    defs = leaf.defs[perm] if leaf.defs is not None else None
    vmask = (leaf.defs == 0) if leaf.defs is not None else np.ones(leaf.n_entries, bool)
    vslot = np.cumsum(vmask) - 1
    vperm = vslot[perm[vmask[perm]]]
    vals = leaf.values.take(vperm)
    return leaf_slice(leaf, rep, defs, vals, leaf.n_rows)
