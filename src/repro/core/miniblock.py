"""The mini-block structural encoding (paper §4.2).

Small data types are chunked into compressed mini-blocks of 1–2 disk sectors
(4–8 KiB target, hard ceiling 32 KiB from the 12-bit word count), each chunk
holding bit-packed repetition levels, definition levels and value buffers.
Whole chunks are decoded at once, so opaque compression is allowed; random
access pays chunk-sized read amplification plus decode work — the trade the
paper accepts for small types.

Chunk rules implemented exactly as §4.2.1/4.2.2:
* power-of-two number of entries per chunk (last chunk may be ragged),
  at most 4096;
* chunk payload padded to 8-byte words; on-disk chunk meta is 2 bytes
  (12-bit word count, 4-bit log2(num values));
* chunk = [u16 n_buffers][u16 size x n_buffers][8-aligned buffers...];
* buffers: [rep][def][values...] (absent streams are skipped);
* a repetition index with N+1 = 2 counters per chunk supports one level of
  random access (§4.2.3), handling rows that split across chunks.

Search cache (§4.2.4): 24 in-memory bytes per chunk without a repetition
index, 41 with — we model exactly those numbers.

Random access runs as a batched decode-once pipeline (see
:class:`MiniBlockReader`): one vectorized repetition-index lookup for all
rows, one phase-grouped ``read_many`` IO dispatch, each chunk decoded
exactly once (optionally on-device via the ``decode='pallas'`` knob — the
power-of-two/8-aligned chunk rules make the kernel's static BlockSpec
tiling possible), and a single segment-id permutation back to request
order.  The logical IOPS/byte trace is identical to the historical per-row
reader.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Optional

import numpy as np

from . import arrays as A
from . import types as T
from .compression import Encoded, get_bytes_codec, get_fixed_codec, min_bits
from .encodings_base import (
    ColumnReader,
    EncodedColumn,
    empty_leaf,
    empty_values,
    leaf_slice,
    pad_to,
    reorder_leaf_rows,
    value_bytes,
)
from .rdlevels import level_bits, pack_levels, unpack_levels
from .shred import ShreddedLeaf

__all__ = ["encode_miniblock", "MiniBlockReader"]

MAX_CHUNK_VALUES = 4096
TARGET_CHUNK_BYTES = 8 * 1024  # 1-2 disk sectors compressed
MAX_CHUNK_WORDS = (1 << 12) - 1  # 12-bit word count
MIN_CHUNK_VALUES = 32

# in-memory search-cache cost model from the paper (sec 4.2.4)
CACHE_BYTES_PER_CHUNK = 24
CACHE_BYTES_PER_CHUNK_WITH_REP = 41


def _default_fixed_codec(values: A.Array) -> str:
    dt = values.values.dtype if not isinstance(values, A.VarBinaryArray) else None
    if dt is not None and dt.kind in ("i", "u"):
        return "bitpack"
    return "plain"


def _encode_chunk_values(
    leaf_type: T.DataType,
    values: A.Array,
    fixed_codec: str,
    bytes_codec: str,
) -> List[Encoded]:
    """Encode the (sparse) values of one chunk into 1-2 buffers."""
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        lengths = (values.offsets[1:] - values.offsets[:-1]).astype(np.uint64)
        bc = get_bytes_codec(bytes_codec)
        enc_data = bc.encode(lengths, values.data)
        stored = enc_data.out_lengths if enc_data.out_lengths is not None else lengths
        enc_lens = get_fixed_codec(fixed_codec if fixed_codec != "plain" else "bitpack").encode(
            np.asarray(stored, dtype=np.uint64)
        )
        return [enc_lens, enc_data]
    if isinstance(leaf_type, T.FixedSizeList):
        flat = values.values.reshape(-1)
        codec = get_fixed_codec("plain" if flat.dtype.kind == "f" else fixed_codec)
        enc = codec.encode(flat)
        enc.meta["fsl"] = leaf_type.size
        enc.meta["codec"] = codec.name
        return [enc]
    codec = get_fixed_codec("plain" if values.values.dtype.kind == "f" else fixed_codec)
    enc = codec.encode(values.values)
    enc.meta["codec"] = codec.name
    return [enc]


def _decode_chunk_values(
    leaf_type: T.DataType,
    bufs: List[np.ndarray],
    metas: List[Dict],
    n_values: int,
    fixed_codec: str,
    bytes_codec: str,
) -> A.Array:
    if isinstance(leaf_type, (T.Utf8, T.Binary)):
        lens_codec = get_fixed_codec(metas[0].get("codec", "bitpack"))
        stored = lens_codec.decode(Encoded(bufs[0], metas[0]), n_values).astype(np.int64)
        bc = get_bytes_codec(bytes_codec)
        out_lens, out_data = bc.decode(Encoded(bufs[1], metas[1]), stored)
        offsets = np.zeros(n_values + 1, dtype=np.int64)
        np.cumsum(out_lens, out=offsets[1:])
        return A.VarBinaryArray(
            leaf_type.with_nullable(False), np.ones(n_values, bool), offsets, out_data
        )
    codec = get_fixed_codec(metas[0]["codec"])
    if isinstance(leaf_type, T.FixedSizeList):
        flat = codec.decode(Encoded(bufs[0], metas[0]), n_values * leaf_type.size)
        return A.FixedSizeListArray(
            leaf_type.with_nullable(False),
            np.ones(n_values, bool),
            np.asarray(flat).reshape(n_values, leaf_type.size),
        )
    vals = codec.decode(Encoded(bufs[0], metas[0]), n_values)
    return A.PrimitiveArray(
        leaf_type.with_nullable(False), np.ones(n_values, bool), np.asarray(vals)
    )


def _serialize_chunk(buffers: List[bytes]) -> bytes:
    """[u16 n_buffers][u16 size each][8-aligned buffer bytes ...] padded to 8."""
    for b in buffers:
        if len(b) > 0xFFFF:
            raise ValueError("buffer exceeds u16 size field")
    head = struct.pack("<H", len(buffers)) + b"".join(
        struct.pack("<H", len(b)) for b in buffers
    )
    out = pad_to(head)
    for b in buffers:
        out += pad_to(b)
    return pad_to(out)


def _parse_chunk(raw: np.ndarray) -> List[np.ndarray]:
    data = raw.tobytes()
    (nb,) = struct.unpack_from("<H", data, 0)
    sizes = struct.unpack_from(f"<{nb}H", data, 2)
    pos = (2 + 2 * nb + 7) & ~7
    bufs = []
    for s in sizes:
        bufs.append(raw[pos : pos + s])
        pos = (pos + s + 7) & ~7
    return bufs


def encode_miniblock(
    leaf: ShreddedLeaf,
    fixed_codec: Optional[str] = None,
    bytes_codec: str = "zstd_chunk",
) -> EncodedColumn:
    fixed_codec = fixed_codec or _default_fixed_codec(leaf.values)
    n_entries = leaf.n_entries

    # map each entry to its value slot (sparse values: def==0 entries only)
    valid_mask = (leaf.defs == 0) if leaf.defs is not None else np.ones(n_entries, bool)
    value_slot = np.cumsum(valid_mask) - 1

    # rows: entries that start a top-level row
    if leaf.max_rep > 0:
        row_start = leaf.rep == leaf.max_rep
    else:
        row_start = np.ones(n_entries, dtype=bool)

    chunks: List[bytes] = []
    chunk_meta: List[Dict] = []
    rep_index: List[tuple] = []  # (rows_started_before_chunk, first_entry_is_row_start)
    payload_offsets: List[int] = []
    pos = 0
    start = 0
    rows_before = 0
    while start < n_entries or (n_entries == 0 and not chunks):
        k = min(MAX_CHUNK_VALUES, n_entries - start) if n_entries else 0
        if k > 0:
            # round down to power of two unless it's the ragged tail
            if start + k < n_entries:
                k = 1 << (k.bit_length() - 1)
        while True:
            end = start + k
            e_rep = leaf.rep[start:end] if leaf.rep is not None else None
            e_def = leaf.defs[start:end] if leaf.defs is not None else None
            vm = valid_mask[start:end]
            vals = leaf.values.take(value_slot[start:end][vm])
            bufs: List[bytes] = []
            metas: List[Dict] = []
            if e_rep is not None:
                bufs.append(pack_levels(e_rep, leaf.max_rep).tobytes())
                metas.append({"stream": "rep"})
            if e_def is not None:
                bufs.append(pack_levels(e_def, leaf.max_def).tobytes())
                metas.append({"stream": "def"})
            encs = _encode_chunk_values(leaf.leaf_type, vals, fixed_codec, bytes_codec)
            for enc in encs:
                bufs.append(enc.data.tobytes())
                metas.append(enc.meta)
            try:
                blob = _serialize_chunk(bufs)
            except ValueError:
                blob = None
            if (
                blob is not None
                and (len(blob) <= TARGET_CHUNK_BYTES or k <= MIN_CHUNK_VALUES)
                and len(blob) // 8 <= MAX_CHUNK_WORDS
            ):
                break
            if k <= 1:
                raise ValueError("single value exceeds miniblock limits; "
                                 "use full-zip for large types")
            k = max(1, k // 2)
        n_vals = int(vm.sum())
        chunks.append(blob)
        chunk_meta.append(
            {
                "n_entries": k,
                "n_values": n_vals,
                "words": len(blob) // 8,
                "bufmeta": metas,
            }
        )
        rep_index.append((rows_before, bool(row_start[start]) if k else True))
        rows_before += int(row_start[start:end].sum())
        payload_offsets.append(pos)
        pos += len(blob)
        start = end
        if n_entries == 0:
            break

    payload = b"".join(chunks)
    has_rep = leaf.max_rep > 0
    per_chunk = CACHE_BYTES_PER_CHUNK_WITH_REP if has_rep else CACHE_BYTES_PER_CHUNK
    meta = {
        "encoding": "miniblock",
        "fixed_codec": fixed_codec,
        "bytes_codec": bytes_codec,
        "chunks": chunk_meta,
        "chunk_offsets": payload_offsets,
        "rep_index": rep_index,
        "n_rows": leaf.n_rows,
        "n_entries": n_entries,
    }
    return EncodedColumn(
        encoding="miniblock",
        payload=payload,
        meta=meta,
        search_cache_bytes=per_chunk * len(chunks),
    )


class MiniBlockReader(ColumnReader):
    """Mini-block random access + scan.

    ``take`` runs as a batched, decode-once pipeline: one vectorized
    ``searchsorted`` maps all requested rows to chunk ranges, every needed
    chunk is fetched in a single phase-0 :meth:`~repro.store.ReadBatch.read_many`
    dispatch and decoded exactly once, row extraction is a single
    segment-id/gather permutation over the concatenated entry streams, and
    the result is fanned back out to request order with one
    :func:`~repro.core.encodings_base.reorder_leaf_rows` pass.

    ``decode`` selects the chunk decoder: ``"numpy"`` (host) or ``"pallas"``
    (the `repro.kernels` mini-block kernel; bit-packed flat integer chunks
    are batch-decoded in one ``pallas_call``, other codecs fall back to
    numpy per chunk).
    """

    def __init__(self, meta: Dict, base: int, leaf_proto: ShreddedLeaf,
                 decode: str = "numpy"):
        super().__init__(meta, base, leaf_proto)
        if decode not in ("numpy", "pallas"):
            raise ValueError(f"decode must be 'numpy'|'pallas', got {decode!r}")
        self.decode = decode

    def _decode_chunk(self, ci: int, raw: np.ndarray):
        cm = self.meta["chunks"][ci]
        bufs = _parse_chunk(raw)
        k = cm["n_entries"]
        bi = 0
        rep = defs = None
        if self.proto.max_rep > 0:
            rep = unpack_levels(bufs[bi], k, self.proto.max_rep)
            bi += 1
        if self.proto.max_def > 0:
            defs = unpack_levels(bufs[bi], k, self.proto.max_def)
            bi += 1
        vals = _decode_chunk_values(
            self.proto.leaf_type,
            bufs[bi:],
            cm["bufmeta"][bi:],
            cm["n_values"],
            self.meta["fixed_codec"],
            self.meta["bytes_codec"],
        )
        return rep, defs, vals

    # ------------------------------------------------------------------
    def _chunk_ranges_for_rows(self, urows: np.ndarray):
        """Vectorized §4.2.3 repetition-index lookup: sorted unique row ids ->
        per-row inclusive chunk ranges ``(c0, c1)``, one ``searchsorted``
        over all rows instead of one per row."""
        ri = self.meta["rep_index"]
        rows_before = np.array([r[0] for r in ri], dtype=np.int64)
        first_is_start = np.array([r[1] for r in ri], dtype=bool)
        n_chunks = len(ri)
        c0 = np.searchsorted(rows_before, urows, side="right") - 1
        # chunk where row r+1 starts; if that chunk *begins* with row r+1,
        # row r ends in the previous chunk
        c1 = np.searchsorted(rows_before, urows + 1, side="right") - 1
        back = (c1 > c0) & (rows_before[c1] == urows + 1) & first_is_start[c1]
        c1 = np.minimum(c1 - back, n_chunks - 1)
        return c0, c1, rows_before

    def take(self, rows: np.ndarray, io) -> ShreddedLeaf:
        rows = np.asarray(rows, dtype=np.int64)
        if len(rows) == 0:
            return empty_leaf(self.proto)
        urows, inv = np.unique(rows, return_inverse=True)
        if urows[0] < 0 or urows[-1] >= self.meta["n_rows"]:
            raise IndexError(
                f"take rows out of bounds for {self.meta['n_rows']}-row column"
            )
        c0, c1, rows_before = self._chunk_ranges_for_rows(urows)
        n_chunks = len(rows_before)
        # union of the [c0, c1] ranges via a coverage diff (O(chunks + rows))
        cover = np.zeros(n_chunks + 1, dtype=np.int64)
        np.add.at(cover, c0, 1)
        np.add.at(cover, c1 + 1, -1)
        needed = np.nonzero(np.cumsum(cover[:-1]) > 0)[0]

        # IO: every needed chunk exactly once, one phase-0 batch dispatch
        offs = np.asarray(self.meta["chunk_offsets"], dtype=np.int64)
        sizes = np.array([self.meta["chunks"][c]["words"] * 8 for c in needed],
                         dtype=np.int64)
        data, doffs = io.read_many(self.base + offs[needed], sizes, phase=0)
        raws = [data[doffs[i]: doffs[i + 1]] for i in range(len(needed))]

        # decode each chunk exactly once (numpy or batched pallas)
        decoded = self._decode_chunks(needed, raws,
                                      tracer=getattr(io, "tracer", None))
        lens = np.array([self.meta["chunks"][c]["n_entries"] for c in needed],
                        dtype=np.int64)
        reps = [d[0] for d in decoded]
        dfs = [d[1] for d in decoded]
        rep_all = np.concatenate(reps) if reps and reps[0] is not None else None
        def_all = np.concatenate(dfs) if dfs and dfs[0] is not None else None
        vals_all = A.concat([d[2] for d in decoded])
        total = int(lens.sum())

        # global row id per entry: per-chunk cumsum over row starts, offset by
        # the repetition index's rows-started-before counter (entries before a
        # chunk's first start continue row rows_before - 1)
        if self.proto.max_rep > 0:
            starts = rep_all == self.proto.max_rep
        else:
            starts = np.ones(total, dtype=bool)
        cs = np.cumsum(starts)
        chunk_off = np.zeros(len(needed) + 1, dtype=np.int64)
        np.cumsum(lens, out=chunk_off[1:])
        cs_pre = np.concatenate([[0], cs])[chunk_off[:-1]]
        row_id = cs - 1 - np.repeat(cs_pre, lens) + np.repeat(rows_before[needed], lens)

        # select the entries of all requested rows in one pass
        pos = np.searchsorted(urows, row_id)
        pos_c = np.minimum(pos, len(urows) - 1)
        sel = urows[pos_c] == row_id
        vmask = (def_all == 0) if def_all is not None else np.ones(total, bool)
        vslot = np.cumsum(vmask) - 1
        rep_sel = rep_all[sel] if rep_all is not None else None
        def_sel = def_all[sel] if def_all is not None else None
        val_sel = vals_all.take(vslot[sel & vmask])
        dec = leaf_slice(self.proto, rep_sel, def_sel, val_sel, len(urows))
        # useful bytes are counted over *unique* rows: duplicates are served
        # from the decoded result, not re-read, so amplification stays >= 1
        io.note_useful(value_bytes(dec.values))
        return reorder_leaf_rows(dec, inv)  # fan out to request order

    # ------------------------------------------------------------------
    def _decode_chunks(self, chunk_ids, raws, tracer=None) -> List[tuple]:
        """Decode chunks ``chunk_ids`` (raw payloads in ``raws``) exactly
        once each.  Under ``decode='pallas'``, integer chunks (bit-packed or
        FoR byte-packed values; flat, nested or fixed-size-list; any
        rep/def level width) are batch-decoded by one ``pallas_call``; the
        rest fall back to the numpy path per chunk.  ``tracer`` (the IO
        path's, via the batch handle) receives a structured fallback-reason
        event for every chunk that routes back to numpy."""
        if self.decode == "pallas":
            routed = self._decode_chunks_pallas(chunk_ids, raws, tracer)
            if routed is not None:
                return routed
        return [self._decode_chunk(c, raw) for c, raw in zip(chunk_ids, raws)]

    _PALLAS_MAX_TILE_VALUES = 1 << 17  # VMEM cap on tile_entries * vpe

    def _pallas_eligible(self) -> bool:
        """Column-level kernel coverage: integer primitives and fixed-size
        lists of integers, with any (column-constant) rep/def level widths.
        Per-chunk value codecs are checked in :meth:`_chunk_kernel_params`.
        """
        return self._pallas_ineligible_reason() is None

    def _pallas_ineligible_reason(self) -> Optional[str]:
        """Column-level fallback reason (None = eligible).  The slugs are the
        stable vocabulary the ROADMAP's "close the fallback shapes" item
        tracks: ``variable-width-leaf`` (utf8/binary/list offsets),
        ``float-values``, ``non-integer-values``, ``tile-over-vmem``."""
        lt = self.proto.leaf_type
        if isinstance(lt, T.Primitive):
            vpe = 1
            kind = np.dtype(lt.dtype).kind
        elif isinstance(lt, T.FixedSizeList):
            vpe = lt.size
            kind = np.dtype(lt.child.dtype).kind
        else:
            return "variable-width-leaf"
        if kind == "f":
            return "float-values"
        if kind not in "iu":
            return "non-integer-values"
        if MAX_CHUNK_VALUES * vpe > self._PALLAS_MAX_TILE_VALUES:
            return "tile-over-vmem"
        return None

    @staticmethod
    def _chunk_kernel_params(bufmeta: Dict) -> Optional[tuple]:
        """Per-chunk value-codec eligibility: ``(bits, ref)`` when the
        kernel's int32 extract covers this chunk, else None.  ``bitpack`` is
        a dense bit stream (ref 0); ``bytepack`` is byte-aligned FoR whose
        reference must keep the int32 arithmetic exact."""
        codec = bufmeta.get("codec")
        if codec == "bitpack":
            return (bufmeta["bits"], 0) if bufmeta["bits"] <= 31 else None
        if codec == "bytepack":
            ref = bufmeta.get("ref")
            if ref is None:  # float payload stored as raw bytes
                return None
            bits = 8 * bufmeta["width"]
            if bits > 31:
                return None
            if ref < -(1 << 31) or ref + (1 << bits) - 1 > (1 << 31) - 1:
                return None
            return (bits, ref)
        return None

    @staticmethod
    def _chunk_fallback_reason(bufmeta: Dict) -> str:
        """Why :meth:`_chunk_kernel_params` rejected this chunk's value
        codec (only called when it did)."""
        codec = bufmeta.get("codec")
        if codec == "bitpack":
            return ">31-bit"
        if codec == "bytepack":
            if bufmeta.get("ref") is None:
                return "float-bytes"
            if 8 * bufmeta["width"] > 31:
                return ">31-bit"
            return "ref-overflow"
        return f"opaque-codec:{codec}"

    def _decode_chunks_pallas(self, chunk_ids, raws,
                              tracer=None) -> Optional[List[tuple]]:
        note = tracer is not None and tracer.enabled
        col_reason = self._pallas_ineligible_reason()
        if col_reason is not None:
            if note:
                tracer.fallback("miniblock", col_reason,
                                n_chunks=len(chunk_ids))
            return None
        from ..kernels import ops  # lazy: keep numpy-only readers jax-free

        lt = self.proto.leaf_type
        fsl = isinstance(lt, T.FixedSizeList)
        vpe = lt.size if fsl else 1
        dt = np.dtype(lt.child.dtype if fsl else lt.dtype)
        rep_bits = level_bits(self.proto.max_rep)
        def_bits = level_bits(self.proto.max_def)
        vbi = (1 if rep_bits else 0) + (1 if def_bits else 0)
        metas = [self.meta["chunks"][c] for c in chunk_ids]
        # metadata-only eligibility check first: chunks are parsed at most
        # once, and an all-ineligible batch costs no parse work at all
        kp = [self._chunk_kernel_params(cm["bufmeta"][vbi]) for cm in metas]
        if note:
            reasons: Dict[str, int] = {}
            for cm, p in zip(metas, kp):
                if p is None:
                    r = self._chunk_fallback_reason(cm["bufmeta"][vbi])
                    reasons[r] = reasons.get(r, 0) + 1
            for r in sorted(reasons):
                tracer.fallback("miniblock", r, n_chunks=reasons[r])
        if not any(p is not None for p in kp):
            return None
        sel = [i for i, p in enumerate(kp) if p is not None]
        parsed = {i: _parse_chunk(raws[i]) for i in sel}
        tile = -(-max(metas[i]["n_entries"] for i in sel) // 128) * 128
        params = np.zeros((len(sel), 3), dtype=np.int32)
        streams = []  # (rep_words, def_words, val_words) ragged rows
        for j, i in enumerate(sel):
            cm, bufs = metas[i], parsed[i]
            rw = ops.pack_words(bufs[0], pad_words=1) if rep_bits else None
            dw = (ops.pack_words(bufs[1 if rep_bits else 0], pad_words=1)
                  if def_bits else None)
            vw = ops.pack_words(bufs[vbi], pad_words=1)
            streams.append((rw, dw, vw))
            params[j] = (cm["n_entries"], kp[i][0], kp[i][1])

        def stack(rows, active):
            if not active:
                return np.zeros((len(rows), 1), dtype=np.uint32)
            width = max(len(r) for r in rows)
            out = np.zeros((len(rows), width), dtype=np.uint32)
            for j, r in enumerate(rows):
                out[j, : len(r)] = r
            return out

        rep_np, def_np, vals_np = (np.asarray(a) for a in ops.miniblock_decode(
            stack([s[0] for s in streams], rep_bits),
            stack([s[1] for s in streams], def_bits),
            stack([s[2] for s in streams], True),
            params, rep_bits=rep_bits, def_bits=def_bits, vpe=vpe,
            tile_entries=tile, fill=0))

        out: List[tuple] = [None] * len(chunk_ids)
        for j, i in enumerate(sel):
            k = metas[i]["n_entries"]
            rep = rep_np[j, :k].astype(np.uint8) if rep_bits else None
            defs = def_np[j, :k].astype(np.uint8) if def_bits else None
            valid = (defs == 0) if defs is not None else np.ones(k, bool)
            n_valid = int(valid.sum())
            dense = vals_np[j, : k * vpe]
            if fsl:
                vals = A.FixedSizeListArray(
                    lt.with_nullable(False), np.ones(n_valid, bool),
                    dense.reshape(k, vpe)[valid].astype(dt),
                )
            else:
                vals = A.PrimitiveArray(
                    lt.with_nullable(False), np.ones(n_valid, bool),
                    dense[:k][valid].astype(dt),
                )
            out[i] = (rep, defs, vals)
        for i, p in enumerate(kp):
            if p is None:
                out[i] = self._decode_chunk(chunk_ids[i], raws[i])
        return out

    def scan(self, io, io_chunk: int = 8 << 20) -> ShreddedLeaf:
        offs = self.meta["chunk_offsets"]
        total = (offs[-1] + self.meta["chunks"][-1]["words"] * 8) if offs else 0
        raw_parts = []
        for p in range(0, total, io_chunk):
            raw_parts.append(io.read(self.base + p, min(io_chunk, total - p), phase=0))
        raw = np.concatenate(raw_parts) if raw_parts else np.zeros(0, np.uint8)
        n_chunks = len(offs)
        raws = [
            raw[offs[ci]: offs[ci] + self.meta["chunks"][ci]["words"] * 8]
            for ci in range(n_chunks)
        ]
        decoded = self._decode_chunks(np.arange(n_chunks), raws,
                                      tracer=getattr(io, "tracer", None))
        reps = [d[0] for d in decoded]
        dfs = [d[1] for d in decoded]
        vals = [d[2] for d in decoded]
        rep = np.concatenate(reps) if reps and reps[0] is not None else None
        defs = np.concatenate(dfs) if dfs and dfs[0] is not None else None
        if vals:
            values = A.concat(vals)
        else:
            values = empty_values(self.proto.leaf_type)
        return leaf_slice(self.proto, rep, defs, values, self.meta["n_rows"])


# retained as the historical entry points; the implementations are the shared
# helpers in encodings_base
_reorder_rows = reorder_leaf_rows
_empty_values = empty_values
