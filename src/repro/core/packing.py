"""Struct packing (paper §4.3).

A packed struct stores all fields of a struct in one column: each child is
compressed *individually* (vectorized columnar compression), then the
compressed child values are zipped row-major.  Random access fetches every
field of a row in one IOP; projecting a single field during a scan must read
(and discard) the whole struct — the trade-off measured in Fig. 18.

Fixed-width structs (all children fixed width) produce a fixed row stride:
``[validity byte?][f0 bytes][f1 bytes]...``.  If any child is variable width
the whole struct becomes variable width with a repetition-index-style row
offset table (the paper's 'packing the entire record' row-format extreme).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from . import arrays as A
from . import types as T
from .compression import Encoded, get_fixed_codec
from .encodings_base import EncodedColumn

__all__ = ["encode_packed_struct", "PackedStructReader"]


def encode_packed_struct(arr: A.StructArray, fixed_codec: str = "plain") -> EncodedColumn:
    n = len(arr)
    child_meta: List[Dict] = []
    mats: List[np.ndarray] = []
    has_validity = arr.type.nullable or any(c.type.nullable for _, c in arr.children)
    if has_validity:
        vbyte = arr.validity.astype(np.uint8)
        bit = 1
        for _, c in arr.children:
            if c.type.nullable:
                vbyte = vbyte | (c.validity.astype(np.uint8) << bit)
                bit += 1
            if bit > 7:
                raise ValueError("packed struct supports <= 7 nullable children")
        mats.append(vbyte.reshape(n, 1))
        child_meta.append({"name": "__validity__", "width": 1})
    for name, c in arr.children:
        fc = get_fixed_codec("plain" if (not hasattr(c, "values") or c.values.dtype.kind == "f") else fixed_codec)
        if isinstance(c, (A.PrimitiveArray, A.FixedSizeListArray)):
            enc = fc.encode(c.values)
            w = fc.encoded_width(enc)
            if w is None:
                raise ValueError("packed struct children need transparent fixed codecs")
            mats.append(enc.data.reshape(n, w))
            child_meta.append({"name": name, "width": w, "codec": fc.name, "codec_meta": enc.meta})
        else:
            raise NotImplementedError("variable-width packed structs: pack at file level")
    stride = sum(m.shape[1] for m in mats)
    out = np.concatenate(mats, axis=1) if mats else np.zeros((n, 0), np.uint8)
    meta = {
        "encoding": "packed_struct",
        "stride": stride,
        "n_rows": n,
        "children": child_meta,
        "has_validity": has_validity,
    }
    return EncodedColumn("packed_struct", np.ascontiguousarray(out).tobytes(), meta, 0)


class PackedStructReader:
    def __init__(self, meta: Dict, base: int, typ: T.Struct):
        self.meta = meta
        self.base = base
        self.type = typ

    def _decode_rows(self, raw: np.ndarray, n: int, fields=None) -> A.StructArray:
        mat = raw[: n * self.meta["stride"]].reshape(n, self.meta["stride"])
        pos = 0
        validity = np.ones(n, bool)
        child_validity: Dict[str, np.ndarray] = {}
        children = []
        bit = 1
        for cm in self.meta["children"]:
            w = cm["width"]
            block = mat[:, pos : pos + w]
            pos += w
            if cm["name"] == "__validity__":
                vb = block[:, 0]
                if self.type.nullable:
                    validity = (vb & 1).astype(bool)
                for fname, ft in self.type.fields:
                    if ft.nullable:
                        child_validity[fname] = ((vb >> bit) & 1).astype(bool)
                        bit += 1
                continue
            if fields is not None and cm["name"] not in fields:
                continue
            ft = self.type.field(cm["name"])
            fc = get_fixed_codec(cm["codec"])
            flat = fc.decode(Encoded(np.ascontiguousarray(block).reshape(-1), cm["codec_meta"]), n)
            cv = child_validity.get(cm["name"], np.ones(n, bool))
            if isinstance(ft, T.FixedSizeList):
                children.append((cm["name"], A.FixedSizeListArray(ft, cv, np.asarray(flat).reshape(n, ft.size))))
            else:
                children.append((cm["name"], A.PrimitiveArray(ft, cv, np.asarray(flat))))
        typ = self.type if fields is None else T.Struct(
            tuple((nm, ft) for nm, ft in self.type.fields if nm in fields), self.type.nullable
        )
        return A.StructArray(typ, validity, tuple(children))

    def take(self, rows: np.ndarray, io) -> A.StructArray:
        """Batched random access: unique rows are fetched in one phase-0
        ``read_many`` dispatch, decoded in a single pass, and gathered back
        to request order (duplicates never re-read)."""
        rows = np.asarray(rows, dtype=np.int64)
        stride = self.meta["stride"]
        if len(rows) == 0:
            return self._decode_rows(np.zeros(0, np.uint8), 0)
        urows, inv = np.unique(rows, return_inverse=True)
        if urows[0] < 0 or urows[-1] >= self.meta["n_rows"]:
            raise IndexError(
                f"take rows out of bounds for {self.meta['n_rows']}-row column"
            )
        data, _ = io.read_many(
            self.base + urows * stride,
            np.full(len(urows), stride, dtype=np.int64), phase=0)
        # useful bytes over *unique* rows (duplicates are never re-read)
        io.note_useful(stride * len(urows))
        return self._decode_rows(data, len(urows)).take(inv)

    def scan(self, io, fields=None, io_chunk: int = 8 << 20) -> A.StructArray:
        n = self.meta["n_rows"]
        total = n * self.meta["stride"]
        parts = []
        for p in range(0, total, io_chunk):
            parts.append(io.read(self.base + p, min(io_chunk, total - p), phase=0))
        raw = np.concatenate(parts) if parts else np.zeros(0, np.uint8)
        return self._decode_rows(raw, n, fields=fields)
