"""Host (numpy-backed) array model, Arrow-flavoured.

Arrays carry their logical :mod:`repro.core.types` type, a validity mask
(boolean, ``True`` = valid) and type-specific buffers.  This is the in-memory
interchange representation: the structural encodings in ``miniblock.py`` /
``fullzip.py`` / ``parquet_like.py`` / ``arrow_like.py`` consume and produce
these arrays.

Validity is stored as an unpacked boolean numpy array for convenience; the
*encodings* decide how validity is physically represented (rep/def levels,
bitmaps, control words...).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from . import types as T

__all__ = [
    "Array",
    "PrimitiveArray",
    "FixedSizeListArray",
    "ListArray",
    "VarBinaryArray",
    "StructArray",
    "from_pylist",
    "to_pylist",
    "concat",
    "ragged_indices",
]


def ragged_indices(starts: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Flat source indices of ragged segments: ``[starts[k], starts[k] +
    lengths[k])`` for every segment, concatenated.

    The one repeat/arange idiom behind every vectorized ragged gather in the
    repo (var-binary/list takes, zipped value-byte slicing, arrow span
    extraction): ``out[cum[k] + i] = starts[k] + i``.
    """
    lengths = np.asarray(lengths, dtype=np.int64)
    offs = np.zeros(len(lengths) + 1, dtype=np.int64)
    np.cumsum(lengths, out=offs[1:])
    total = int(offs[-1])
    return np.repeat(np.asarray(starts, dtype=np.int64) - offs[:-1], lengths) + np.arange(
        total, dtype=np.int64
    )


def _as_validity(validity, n: int) -> np.ndarray:
    if validity is None:
        return np.ones(n, dtype=bool)
    v = np.asarray(validity, dtype=bool)
    assert v.shape == (n,), (v.shape, n)
    return v


@dataclasses.dataclass
class Array:
    """Base class; concrete arrays define buffers."""

    type: T.DataType
    validity: np.ndarray  # bool[n], True = valid

    def __len__(self) -> int:
        return int(self.validity.shape[0])

    # Subclasses implement take/slice/equality helpers.
    def take(self, indices: np.ndarray) -> "Array":
        raise NotImplementedError

    def slice(self, start: int, stop: int) -> "Array":
        return self.take(np.arange(start, stop, dtype=np.int64))


@dataclasses.dataclass
class PrimitiveArray(Array):
    values: np.ndarray = None  # dtype matches type.dtype; garbage where invalid

    @staticmethod
    def build(values, validity=None, nullable: bool = True) -> "PrimitiveArray":
        values = np.asarray(values)
        v = _as_validity(validity, len(values))
        return PrimitiveArray(
            T.Primitive(values.dtype.name, nullable), v, values
        )

    def take(self, indices: np.ndarray) -> "PrimitiveArray":
        idx = np.asarray(indices, dtype=np.int64)
        return PrimitiveArray(self.type, self.validity[idx], self.values[idx])


@dataclasses.dataclass
class FixedSizeListArray(Array):
    # values has shape (n, size) flattened child values (child non-nullable)
    values: np.ndarray = None

    @staticmethod
    def build(values, validity=None, nullable: bool = True) -> "FixedSizeListArray":
        values = np.asarray(values)
        assert values.ndim == 2
        v = _as_validity(validity, len(values))
        child = T.Primitive(values.dtype.name, nullable=False)
        return FixedSizeListArray(
            T.FixedSizeList(child, int(values.shape[1]), nullable), v, values
        )

    def take(self, indices: np.ndarray) -> "FixedSizeListArray":
        idx = np.asarray(indices, dtype=np.int64)
        return FixedSizeListArray(self.type, self.validity[idx], self.values[idx])


@dataclasses.dataclass
class VarBinaryArray(Array):
    """Utf8 or Binary: offsets[n+1] int64 + data uint8."""

    offsets: np.ndarray = None
    data: np.ndarray = None

    @staticmethod
    def build(values: Sequence[Optional[bytes]], utf8: bool = False, nullable: bool = True) -> "VarBinaryArray":
        n = len(values)
        validity = np.array([v is not None for v in values], dtype=bool)
        lengths = np.array([0 if v is None else len(v) for v in values], dtype=np.int64)
        offsets = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        data = np.frombuffer(
            b"".join(v for v in values if v is not None), dtype=np.uint8
        ).copy() if n else np.zeros(0, dtype=np.uint8)
        typ = T.Utf8(nullable) if utf8 else T.Binary(nullable)
        return VarBinaryArray(typ, validity, offsets, data)

    def value(self, i: int) -> Optional[bytes]:
        if not self.validity[i]:
            return None
        return self.data[self.offsets[i] : self.offsets[i + 1]].tobytes()

    def take(self, indices: np.ndarray) -> "VarBinaryArray":
        idx = np.asarray(indices, dtype=np.int64)
        lengths = (self.offsets[1:] - self.offsets[:-1])[idx]
        new_off = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lengths, out=new_off[1:])
        # one repeat/arange gather instead of a per-value copy loop
        src = ragged_indices(self.offsets[:-1][idx], lengths)
        out = self.data[src] if len(src) else np.zeros(0, dtype=np.uint8)
        return VarBinaryArray(self.type, self.validity[idx], new_off, out)


@dataclasses.dataclass
class ListArray(Array):
    offsets: np.ndarray = None  # int64[n+1]
    child: Array = None

    @staticmethod
    def build(child: Array, offsets, validity=None, nullable: bool = True) -> "ListArray":
        offsets = np.asarray(offsets, dtype=np.int64)
        v = _as_validity(validity, len(offsets) - 1)
        return ListArray(T.List(child.type, nullable), v, offsets, child)

    def take(self, indices: np.ndarray) -> "ListArray":
        idx = np.asarray(indices, dtype=np.int64)
        lengths = (self.offsets[1:] - self.offsets[:-1])[idx]
        new_off = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lengths, out=new_off[1:])
        child_idx = ragged_indices(self.offsets[:-1][idx], lengths)
        return ListArray(self.type, self.validity[idx], new_off, self.child.take(child_idx))


@dataclasses.dataclass
class StructArray(Array):
    children: tuple = ()  # tuple[(name, Array), ...]

    @staticmethod
    def build(children, validity=None, nullable: bool = True) -> "StructArray":
        children = tuple(children)
        n = len(children[0][1])
        for _, c in children:
            assert len(c) == n
        v = _as_validity(validity, n)
        typ = T.Struct(tuple((nm, c.type) for nm, c in children), nullable)
        return StructArray(typ, v, children)

    def field(self, name: str) -> Array:
        for n, c in self.children:
            if n == name:
                return c
        raise KeyError(name)

    def take(self, indices: np.ndarray) -> "StructArray":
        idx = np.asarray(indices, dtype=np.int64)
        return StructArray(
            self.type,
            self.validity[idx],
            tuple((n, c.take(idx)) for n, c in self.children),
        )


# ---------------------------------------------------------------------------
# Python interchange (used by tests & the hypothesis strategies)
# ---------------------------------------------------------------------------

def from_pylist(pyvals, typ: T.DataType) -> Array:
    """Build an Array of ``typ`` from nested python values (None = null)."""
    n = len(pyvals)
    validity = np.array([v is not None for v in pyvals], dtype=bool)
    if isinstance(typ, T.Primitive):
        dt = np.dtype(typ.dtype)
        vals = np.array([v if v is not None else 0 for v in pyvals], dtype=dt)
        return PrimitiveArray(typ, validity, vals)
    if isinstance(typ, (T.Utf8, T.Binary)):
        bs = [None if v is None else (v.encode() if isinstance(v, str) else bytes(v)) for v in pyvals]
        arr = VarBinaryArray.build(bs, utf8=isinstance(typ, T.Utf8), nullable=typ.nullable)
        return dataclasses.replace(arr, type=typ)
    if isinstance(typ, T.FixedSizeList):
        dt = np.dtype(typ.child.dtype)
        vals = np.zeros((n, typ.size), dtype=dt)
        for i, v in enumerate(pyvals):
            if v is not None:
                vals[i] = np.asarray(v, dtype=dt)
        return FixedSizeListArray(typ, validity, vals)
    if isinstance(typ, T.List):
        lengths = np.array([0 if v is None else len(v) for v in pyvals], dtype=np.int64)
        offsets = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        flat = []
        for v in pyvals:
            if v is not None:
                flat.extend(v)
        child = from_pylist(flat, typ.child)
        return ListArray(typ, validity, offsets, child)
    if isinstance(typ, T.Struct):
        children = []
        for name, ftyp in typ.fields:
            fvals = [None if v is None else v.get(name) for v in pyvals]
            children.append((name, from_pylist(fvals, ftyp)))
        return StructArray(typ, validity, tuple(children))
    raise TypeError(typ)


def to_pylist(arr: Array):
    """Inverse of :func:`from_pylist` (numpy scalars converted to python)."""
    typ = arr.type
    out = []
    if isinstance(typ, T.Primitive):
        for i in range(len(arr)):
            out.append(arr.values[i].item() if arr.validity[i] else None)
        return out
    if isinstance(typ, (T.Utf8, T.Binary)):
        for i in range(len(arr)):
            v = arr.value(i)
            if v is None:
                out.append(None)
            else:
                out.append(v.decode() if isinstance(typ, T.Utf8) else v)
        return out
    if isinstance(typ, T.FixedSizeList):
        for i in range(len(arr)):
            out.append(list(arr.values[i].tolist()) if arr.validity[i] else None)
        return out
    if isinstance(typ, T.List):
        child = to_pylist(arr.child)
        for i in range(len(arr)):
            if not arr.validity[i]:
                out.append(None)
            else:
                out.append(child[arr.offsets[i] : arr.offsets[i + 1]])
        return out
    if isinstance(typ, T.Struct):
        kids = {n: to_pylist(c) for n, c in arr.children}
        for i in range(len(arr)):
            if not arr.validity[i]:
                out.append(None)
            else:
                out.append({n: kids[n][i] for n, _ in arr.children})
        return out
    raise TypeError(typ)


def concat(arrays: Sequence[Array]) -> Array:
    """Concatenate arrays of identical type (used by the scan paths)."""
    assert arrays
    if len(arrays) == 1:
        return arrays[0]
    # Cheap generic path via python interchange would be slow; implement the
    # common cases directly.
    a0 = arrays[0]
    validity = np.concatenate([a.validity for a in arrays])
    if isinstance(a0, PrimitiveArray):
        return PrimitiveArray(a0.type, validity, np.concatenate([a.values for a in arrays]))
    if isinstance(a0, FixedSizeListArray):
        return FixedSizeListArray(a0.type, validity, np.concatenate([a.values for a in arrays]))
    if isinstance(a0, VarBinaryArray):
        datas = np.concatenate([a.data for a in arrays])
        offs = [arrays[0].offsets]
        base = arrays[0].offsets[-1]
        for a in arrays[1:]:
            offs.append(a.offsets[1:] + base)
            base = base + a.offsets[-1]
        return VarBinaryArray(a0.type, validity, np.concatenate(offs), datas)
    if isinstance(a0, ListArray):
        child = concat([a.child for a in arrays])
        offs = [arrays[0].offsets]
        base = arrays[0].offsets[-1]
        for a in arrays[1:]:
            offs.append(a.offsets[1:] + base)
            base = base + a.offsets[-1]
        return ListArray(a0.type, validity, np.concatenate(offs), child)
    if isinstance(a0, StructArray):
        children = []
        for k, (name, _) in enumerate(a0.children):
            children.append((name, concat([a.children[k][1] for a in arrays])))
        return StructArray(a0.type, validity, tuple(children))
    raise TypeError(type(a0))
