"""Logical data types for the columnar core.

These mirror the Arrow type system closely enough to express every data type
used in the paper's experiments (scalar, string, scalar-list, string-list,
vector = FixedSizeList<f32>, vector-list, image = Binary, image-list) plus
arbitrary Struct/List nesting for the property tests.

A type is *fixed width* when every value occupies the same number of bytes
(primitives and FixedSizeLists of fixed-width children).  Fixed-width-ness is
what the adaptive structural encoding keys off (together with the average
value size) -- see ``repro.core.adaptive``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "DataType",
    "Primitive",
    "FixedSizeList",
    "List",
    "Struct",
    "Utf8",
    "Binary",
    "uint8",
    "int8",
    "int16",
    "int32",
    "int64",
    "uint32",
    "uint64",
    "float16",
    "float32",
    "float64",
    "utf8",
    "binary",
]


class DataType:
    """Base class for logical types."""

    nullable: bool

    def fixed_width(self) -> Optional[int]:
        """Bytes per value if the type is fixed width, else ``None``."""
        raise NotImplementedError

    # -- Dremel bookkeeping -------------------------------------------------
    def num_list_levels(self) -> int:
        """Number of (variable-size) List levels contained in this type path.

        FixedSizeList does NOT count: the paper treats primitive FSL arrays as
        primitive types (sec. 4.2) so it contributes no repetition.
        """
        raise NotImplementedError

    def with_nullable(self, nullable: bool) -> "DataType":
        return dataclasses.replace(self, nullable=nullable)


@dataclasses.dataclass(frozen=True)
class Primitive(DataType):
    dtype: str  # numpy dtype string, e.g. "int64", "float32"
    nullable: bool = True

    def fixed_width(self) -> Optional[int]:
        return int(np.dtype(self.dtype).itemsize)

    def num_list_levels(self) -> int:
        return 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{self.dtype}{'?' if self.nullable else ''}"


@dataclasses.dataclass(frozen=True)
class Utf8(DataType):
    nullable: bool = True

    def fixed_width(self) -> Optional[int]:
        return None

    def num_list_levels(self) -> int:
        return 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"utf8{'?' if self.nullable else ''}"


@dataclasses.dataclass(frozen=True)
class Binary(DataType):
    nullable: bool = True

    def fixed_width(self) -> Optional[int]:
        return None

    def num_list_levels(self) -> int:
        return 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"binary{'?' if self.nullable else ''}"


@dataclasses.dataclass(frozen=True)
class FixedSizeList(DataType):
    child: DataType = dataclasses.field(default_factory=lambda: Primitive("float32", nullable=False))
    size: int = 1
    nullable: bool = True

    def __post_init__(self) -> None:
        if self.child.fixed_width() is None:
            raise ValueError("FixedSizeList child must be fixed width")
        if self.child.nullable:
            # The paper treats FSL as a primitive: child validity is not part
            # of rep/def.  We require non-nullable children for simplicity.
            raise ValueError("FixedSizeList child must be non-nullable")

    def fixed_width(self) -> Optional[int]:
        return self.child.fixed_width() * self.size

    def num_list_levels(self) -> int:
        return 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"fsl<{self.child!r},{self.size}>{'?' if self.nullable else ''}"


@dataclasses.dataclass(frozen=True)
class List(DataType):
    child: DataType = dataclasses.field(default_factory=lambda: Primitive("int64"))
    nullable: bool = True

    def fixed_width(self) -> Optional[int]:
        return None

    def num_list_levels(self) -> int:
        return 1 + self.child.num_list_levels()

    def __repr__(self) -> str:  # pragma: no cover
        return f"list<{self.child!r}>{'?' if self.nullable else ''}"


@dataclasses.dataclass(frozen=True)
class Struct(DataType):
    fields: tuple = ()  # tuple[(name, DataType), ...]
    nullable: bool = True

    def fixed_width(self) -> Optional[int]:
        total = 0
        for _, f in self.fields:
            w = f.fixed_width()
            if w is None or f.nullable:
                return None
            total += w
        return total

    def num_list_levels(self) -> int:
        return max((f.num_list_levels() for _, f in self.fields), default=0)

    def field(self, name: str) -> DataType:
        for n, f in self.fields:
            if n == name:
                return f
        raise KeyError(name)

    def __repr__(self) -> str:  # pragma: no cover
        inner = ", ".join(f"{n}: {f!r}" for n, f in self.fields)
        return f"struct<{inner}>{'?' if self.nullable else ''}"


def uint8(nullable: bool = True) -> Primitive:
    return Primitive("uint8", nullable)


def int8(nullable: bool = True) -> Primitive:
    return Primitive("int8", nullable)


def int16(nullable: bool = True) -> Primitive:
    return Primitive("int16", nullable)


def int32(nullable: bool = True) -> Primitive:
    return Primitive("int32", nullable)


def int64(nullable: bool = True) -> Primitive:
    return Primitive("int64", nullable)


def uint32(nullable: bool = True) -> Primitive:
    return Primitive("uint32", nullable)


def uint64(nullable: bool = True) -> Primitive:
    return Primitive("uint64", nullable)


def float16(nullable: bool = True) -> Primitive:
    return Primitive("float16", nullable)


def float32(nullable: bool = True) -> Primitive:
    return Primitive("float32", nullable)


def float64(nullable: bool = True) -> Primitive:
    return Primitive("float64", nullable)


def utf8(nullable: bool = True) -> Utf8:
    return Utf8(nullable)


def binary(nullable: bool = True) -> Binary:
    return Binary(nullable)
