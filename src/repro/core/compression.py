"""Compressive encodings.

The paper (sec. 2.2) splits codecs into **transparent** (a single value can be
sliced out of the compressed buffer given its position/length: bit-packing,
FSST, dictionary, per-value LZ4) and **opaque** (values depend on each other:
delta encodings, block compressors).  The structural encodings constrain which
family is usable: full-zip requires transparent codecs; mini-block and
parquet-like pages may use opaque codecs because a whole chunk is always
decoded.

All codecs work on host numpy arrays (encode runs in the writer / input
pipeline).  Decode paths used on the accelerator have jnp/Pallas twins in
``repro.kernels`` validated against these implementations.

zstd (installed) stands in for the paper's LZ4/Snappy class of
general-purpose byte codecs -- recorded in DESIGN.md §8.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

try:
    import zstandard as _zstd

    _ZSTD_C = _zstd.ZstdCompressor(level=1)
    _ZSTD_D = _zstd.ZstdDecompressor()
except Exception:
    # no zstandard on this interpreter: stdlib zlib stands in (same opaque
    # block-compressor class; only the ratio/speed constants differ)
    import zlib as _zlib

    _zstd = None

    class _ZlibCompressor:
        def compress(self, b: bytes) -> bytes:
            return _zlib.compress(b, 1)

    class _ZlibDecompressor:
        def decompress(self, b: bytes) -> bytes:
            return _zlib.decompress(b)

    _ZSTD_C = _ZlibCompressor()
    _ZSTD_D = _ZlibDecompressor()

__all__ = [
    "Encoded",
    "bitpack",
    "bitunpack",
    "min_bits",
    "FIXED_CODECS",
    "BYTES_CODECS",
    "FixedCodec",
    "BytesCodec",
    "get_fixed_codec",
    "get_bytes_codec",
]


@dataclasses.dataclass
class Encoded:
    """A compressed buffer plus the (small) metadata needed to decode it.

    ``meta`` travels in the column metadata / search cache, never inline in
    the data stream, mirroring the paper's recommendation that dictionaries
    and symbol tables live in the search cache (sec. 6.1.1).
    """

    data: np.ndarray  # uint8
    meta: Dict
    # per-value byte lengths AFTER compression; only set by transparent
    # bytes codecs (needed by full-zip to zip values)
    out_lengths: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# bit packing primitives
# ---------------------------------------------------------------------------


def min_bits(values: np.ndarray) -> int:
    """Bits needed for the max value (>=1 so zero-width buffers never occur)."""
    if len(values) == 0:
        return 1
    m = int(values.max())
    assert int(values.min()) >= 0, "bitpack requires non-negative values"
    return max(1, int(m).bit_length())


def bitpack(values: np.ndarray, bits: int) -> np.ndarray:
    """Pack non-negative ints into a dense little-endian bit stream."""
    v = np.ascontiguousarray(values, dtype=np.uint64)
    n = len(v)
    if n == 0:
        return np.zeros(0, dtype=np.uint8)
    shifts = np.arange(bits, dtype=np.uint64)
    bit_mat = ((v[:, None] >> shifts[None, :]) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bit_mat.reshape(-1), bitorder="little")


def bitunpack(buf: np.ndarray, n: int, bits: int) -> np.ndarray:
    """Inverse of :func:`bitpack`; returns uint64[n]."""
    if n == 0:
        return np.zeros(0, dtype=np.uint64)
    raw = np.unpackbits(np.ascontiguousarray(buf, dtype=np.uint8), bitorder="little")
    bit_mat = raw[: n * bits].reshape(n, bits).astype(np.uint64)
    weights = (np.uint64(1) << np.arange(bits, dtype=np.uint64))
    return bit_mat @ weights


def bytepack(values: np.ndarray, width: int) -> np.ndarray:
    """Pack non-negative ints into ``width`` little-endian bytes per value
    (byte-aligned bit packing: the transparent variant used by full-zip)."""
    v = np.ascontiguousarray(values, dtype=np.uint64)
    shifts = (np.arange(width, dtype=np.uint64) * np.uint64(8))
    out = ((v[:, None] >> shifts[None, :]) & np.uint64(0xFF)).astype(np.uint8)
    return out.reshape(-1)


def byteunpack(buf: np.ndarray, n: int, width: int) -> np.ndarray:
    b = np.ascontiguousarray(buf[: n * width], dtype=np.uint8).reshape(n, width)
    shifts = (np.arange(width, dtype=np.uint64) * np.uint64(8))
    return (b.astype(np.uint64) << shifts[None, :]).sum(axis=1, dtype=np.uint64)


def _zigzag(v: np.ndarray) -> np.ndarray:
    s = v.astype(np.int64)
    return ((s << 1) ^ (s >> 63)).astype(np.uint64)


def _unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64)
    return ((u >> np.uint64(1)).astype(np.int64)) ^ -(u & np.uint64(1)).astype(np.int64)


# ---------------------------------------------------------------------------
# Fixed-width codecs
# ---------------------------------------------------------------------------


class FixedCodec:
    """Codec for a 1-D fixed-width numeric array."""

    name: str
    transparent: bool

    def encode(self, values: np.ndarray) -> Encoded:
        raise NotImplementedError

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        raise NotImplementedError

    def encoded_width(self, enc: Encoded) -> Optional[int]:
        """Bytes per value when transparent & fixed width, else None."""
        return None


class PlainFixed(FixedCodec):
    name = "plain"
    transparent = True

    def encode(self, values: np.ndarray) -> Encoded:
        return Encoded(
            np.frombuffer(np.ascontiguousarray(values).tobytes(), dtype=np.uint8).copy(),
            {"dtype": values.dtype.name, "shape1": 0 if values.ndim == 1 else values.shape[1]},
        )

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        dt = np.dtype(enc.meta["dtype"])
        flat = np.frombuffer(enc.data.tobytes(), dtype=dt)
        s1 = enc.meta.get("shape1", 0)
        return flat.reshape(n, s1) if s1 else flat[:n]

    def encoded_width(self, enc: Encoded) -> Optional[int]:
        dt = np.dtype(enc.meta["dtype"])
        s1 = enc.meta.get("shape1", 0) or 1
        return dt.itemsize * s1


class BitPackFixed(FixedCodec):
    """Dense (non-byte-aligned) bit packing of non-negative ints.

    Transparent in the paper's sense (value ``i`` lives at bit ``i * bits``)
    but not byte-addressable; used inside mini-block chunks.
    """

    name = "bitpack"
    transparent = True

    def encode(self, values: np.ndarray) -> Encoded:
        bits = min_bits(values)
        return Encoded(bitpack(values, bits), {"bits": bits, "dtype": values.dtype.name})

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        out = bitunpack(enc.data, n, enc.meta["bits"])
        return out.astype(np.dtype(enc.meta["dtype"]))


class BytePackFixed(FixedCodec):
    """Byte-aligned packing (frame-of-reference against the column min).

    The full-zip transparent integer codec: value ``i`` occupies bytes
    ``[i*W, (i+1)*W)`` with W in the metadata.
    """

    name = "bytepack"
    transparent = True

    def encode(self, values: np.ndarray) -> Encoded:
        v = np.ascontiguousarray(values)
        if v.dtype.kind in "iu" and len(v):
            ref = int(v.min())
            shifted = (v.astype(np.int64) - ref).astype(np.uint64)
            width = max(1, (min_bits(shifted) + 7) // 8)
            return Encoded(
                bytepack(shifted, width),
                {"width": width, "ref": ref, "dtype": v.dtype.name},
            )
        # floats: plain bytes per value
        raw = np.frombuffer(v.tobytes(), dtype=np.uint8).copy()
        return Encoded(raw, {"width": v.dtype.itemsize, "ref": None, "dtype": v.dtype.name})

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        dt = np.dtype(enc.meta["dtype"])
        if enc.meta["ref"] is None:
            return np.frombuffer(enc.data.tobytes(), dtype=dt)[:n]
        u = byteunpack(enc.data, n, enc.meta["width"])
        return (u.astype(np.int64) + enc.meta["ref"]).astype(dt)

    def encoded_width(self, enc: Encoded) -> Optional[int]:
        return enc.meta["width"]


class DeltaBitPack(FixedCodec):
    """Opaque: delta + zigzag + bitpack (Parquet's delta-binary-packed kin)."""

    name = "delta_bitpack"
    transparent = False

    def encode(self, values: np.ndarray) -> Encoded:
        v = values.astype(np.int64)
        deltas = np.diff(v, prepend=v[:1] if len(v) else np.zeros(1, np.int64))
        if len(v):
            deltas[0] = v[0]
        zz = _zigzag(deltas)
        bits = min_bits(zz)
        return Encoded(bitpack(zz, bits), {"bits": bits, "dtype": values.dtype.name})

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        zz = bitunpack(enc.data, n, enc.meta["bits"])
        deltas = _unzigzag(zz)
        return np.cumsum(deltas).astype(np.dtype(enc.meta["dtype"]))


class RLEFixed(FixedCodec):
    """Opaque: run-length encoding (value, run) with bit-packed columns."""

    name = "rle"
    transparent = False

    def encode(self, values: np.ndarray) -> Encoded:
        v = np.asarray(values)
        if len(v) == 0:
            return Encoded(np.zeros(0, np.uint8), {"runs": 0, "dtype": v.dtype.name,
                                                   "vbits": 1, "rbits": 1})
        change = np.empty(len(v), dtype=bool)
        change[0] = True
        np.not_equal(v[1:], v[:-1], out=change[1:])
        starts = np.nonzero(change)[0]
        run_vals = v[starts].astype(np.int64)
        run_lens = np.diff(np.append(starts, len(v))).astype(np.uint64)
        zz = _zigzag(run_vals)
        vbits, rbits = min_bits(zz), min_bits(run_lens)
        a, b = bitpack(zz, vbits), bitpack(run_lens, rbits)
        return Encoded(
            np.concatenate([a, b]),
            {"runs": len(starts), "split": len(a), "vbits": vbits, "rbits": rbits,
             "dtype": v.dtype.name},
        )

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        r = enc.meta["runs"]
        if r == 0:
            return np.zeros(0, dtype=np.dtype(enc.meta["dtype"]))
        s = enc.meta["split"]
        vals = _unzigzag(bitunpack(enc.data[:s], r, enc.meta["vbits"]))
        lens = bitunpack(enc.data[s:], r, enc.meta["rbits"]).astype(np.int64)
        return np.repeat(vals, lens).astype(np.dtype(enc.meta["dtype"]))[:n]


class DictFixed(FixedCodec):
    """Dictionary over fixed-width values; codes bit-packed, dictionary in the
    metadata (=> the search cache, as the paper recommends for Lance)."""

    name = "dict"
    transparent = True  # given the dictionary is cached

    def encode(self, values: np.ndarray) -> Encoded:
        uniq, codes = np.unique(np.asarray(values), return_inverse=True)
        bits = min_bits(codes.astype(np.uint64))
        return Encoded(
            bitpack(codes.astype(np.uint64), bits),
            {"bits": bits, "dict": uniq, "dtype": values.dtype.name},
        )

    def decode(self, enc: Encoded, n: int) -> np.ndarray:
        codes = bitunpack(enc.data, n, enc.meta["bits"]).astype(np.int64)
        return enc.meta["dict"][codes]


# ---------------------------------------------------------------------------
# Bytes (variable-width) codecs
# ---------------------------------------------------------------------------


class BytesCodec:
    """Codec for a stream of variable-width byte values."""

    name: str
    transparent: bool

    def encode(self, lengths: np.ndarray, data: np.ndarray) -> Encoded:
        raise NotImplementedError

    def decode(self, enc: Encoded, lengths: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (out_lengths, out_data): decompressed per-value bytes.

        ``lengths`` are the *stored* (compressed) per-value lengths for
        transparent codecs; for opaque codecs they are ignored and the
        original lengths come out of the blob.
        """
        raise NotImplementedError


class PlainBytes(BytesCodec):
    name = "plain_bytes"
    transparent = True

    def encode(self, lengths: np.ndarray, data: np.ndarray) -> Encoded:
        return Encoded(np.asarray(data, np.uint8), {}, out_lengths=np.asarray(lengths, np.int64))

    def decode(self, enc: Encoded, lengths: np.ndarray):
        return np.asarray(lengths, np.int64), np.asarray(enc.data, np.uint8)


class ZstdPerValue(BytesCodec):
    """Opaque codec applied per value => transparent usage (paper sec. 2.2:
    'Lance will apply LZ4 compression on a per-value basis')."""

    name = "zstd_per_value"
    transparent = True

    def encode(self, lengths: np.ndarray, data: np.ndarray) -> Encoded:
        raw = data.tobytes()
        offs = np.zeros(len(lengths) + 1, dtype=np.int64)
        np.cumsum(lengths, out=offs[1:])
        frames = [_ZSTD_C.compress(raw[offs[i]: offs[i + 1]]) for i in range(len(lengths))]
        out_lens = np.array([len(f) for f in frames], dtype=np.int64)
        blob = np.frombuffer(b"".join(frames), dtype=np.uint8).copy() if frames else np.zeros(0, np.uint8)
        return Encoded(blob, {}, out_lengths=out_lens)

    def decode(self, enc: Encoded, lengths: np.ndarray):
        raw = enc.data.tobytes()
        offs = np.zeros(len(lengths) + 1, dtype=np.int64)
        np.cumsum(lengths, out=offs[1:])
        vals = [_ZSTD_D.decompress(raw[offs[i]: offs[i + 1]]) for i in range(len(lengths))]
        out_lens = np.array([len(v) for v in vals], dtype=np.int64)
        blob = np.frombuffer(b"".join(vals), dtype=np.uint8).copy() if vals else np.zeros(0, np.uint8)
        return out_lens, blob


class ZstdChunk(BytesCodec):
    """Opaque whole-buffer compression (mini-block / parquet pages only)."""

    name = "zstd_chunk"
    transparent = False

    def encode(self, lengths: np.ndarray, data: np.ndarray) -> Encoded:
        blob = _ZSTD_C.compress(data.tobytes())
        return Encoded(
            np.frombuffer(blob, dtype=np.uint8).copy(),
            {"lengths_inline": np.asarray(lengths, np.int64)},
        )

    def decode(self, enc: Encoded, lengths: np.ndarray):
        raw = _ZSTD_D.decompress(enc.data.tobytes())
        out_lens = enc.meta["lengths_inline"]
        return np.asarray(out_lens, np.int64), np.frombuffer(raw, dtype=np.uint8).copy()


class FSSTLite(BytesCodec):
    """Simplified FSST: a static table of 1- and 2-byte symbols mapped to
    1-byte codes; 0xFF escapes a literal byte.  Transparent: every value is
    encoded independently, so a value can be sliced and decoded alone given
    the symbol table (which lives in the search cache)."""

    name = "fsst_lite"
    transparent = True
    MAX_SYMS = 254  # codes 0..253; 254 unused; 255 = escape
    ESC = 255

    def _train(self, data: np.ndarray) -> List[bytes]:
        sample = data[: 1 << 16]
        if len(sample) < 2:
            return []
        pairs = sample[:-1].astype(np.uint16) | (sample[1:].astype(np.uint16) << 8)
        pc = np.bincount(pairs, minlength=1 << 16)
        singles = np.bincount(sample, minlength=256)
        # savings: pair used saves 1 byte/occurrence; single saves 1 byte ONLY
        # vs escaped literal; prefer pairs, then frequent singles.
        n_pairs = min(128, int((pc > 4).sum()))
        top_pairs = np.argsort(pc)[::-1][:n_pairs]
        top_pairs = [int(p) for p in top_pairs if pc[p] > 4]
        n_single = self.MAX_SYMS - len(top_pairs)
        top_singles = [int(s) for s in np.argsort(singles)[::-1][:n_single] if singles[s] > 0]
        syms = [bytes([p & 0xFF, p >> 8]) for p in top_pairs]
        syms += [bytes([s]) for s in top_singles]
        return syms[: self.MAX_SYMS]

    def encode(self, lengths: np.ndarray, data: np.ndarray) -> Encoded:
        data = np.asarray(data, np.uint8)
        syms = self._train(data)
        pair_code = {}
        single_code = {}
        for c, s in enumerate(syms):
            if len(s) == 2:
                pair_code[s[0] | (s[1] << 8)] = c
            else:
                single_code[s[0]] = c
        n = len(data)
        if n == 0:
            return Encoded(np.zeros(0, np.uint8), {"syms": syms},
                           out_lengths=np.zeros(len(lengths), np.int64))
        # value boundaries: pairs must not straddle values
        offs = np.zeros(len(lengths) + 1, dtype=np.int64)
        np.cumsum(lengths, out=offs[1:])
        boundary = np.zeros(n + 1, dtype=bool)
        boundary[offs[offs <= n]] = True

        pair_lut = np.full(1 << 16, -1, dtype=np.int16)
        for p, c in pair_code.items():
            pair_lut[p] = c
        single_lut = np.full(256, -1, dtype=np.int16)
        for s, c in single_code.items():
            single_lut[s] = c

        pairs = np.zeros(n, dtype=np.uint16)
        if n > 1:
            pairs[:-1] = data[:-1].astype(np.uint16) | (data[1:].astype(np.uint16) << 8)
        cand = np.zeros(n, dtype=bool)
        if n > 1:
            cand[:-1] = pair_lut[pairs[:-1]] >= 0
            cand[:-1] &= ~boundary[1:n]  # pair (i, i+1) must not cross a boundary
        # greedy left-to-right non-overlap == take even offsets within runs
        run_start = cand & ~np.concatenate([[False], cand[:-1]])
        run_id = np.cumsum(run_start)
        pos_in_run = np.arange(n) - np.maximum.accumulate(
            np.where(run_start, np.arange(n), -1)
        )
        sel = cand & ((pos_in_run & 1) == 0)
        # a selected pair at i consumes i+1; i+1 cannot also be selected (it
        # would be odd position in the run) -- holds by parity.
        consumed = np.zeros(n, dtype=bool)
        consumed[1:] = sel[:-1]
        single_pos = ~sel & ~consumed
        # emit: selected pair -> 1 code byte; single in table -> 1 code byte;
        # else escape + literal (2 bytes)
        out_len_at = np.zeros(n, dtype=np.int64)
        out_len_at[sel] = 1
        s_in = single_pos & (single_lut[data] >= 0)
        s_esc = single_pos & ~s_in
        out_len_at[s_in] = 1
        out_len_at[s_esc] = 2
        out_pos = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(out_len_at, out=out_pos[1:])
        total = int(out_pos[-1])
        out = np.zeros(total, dtype=np.uint8)
        out[out_pos[:-1][sel]] = pair_lut[pairs[sel]].astype(np.uint8)
        out[out_pos[:-1][s_in]] = single_lut[data[s_in]].astype(np.uint8)
        out[out_pos[:-1][s_esc]] = self.ESC
        out[out_pos[:-1][s_esc] + 1] = data[s_esc]
        out_lengths = out_pos[offs[1:]] - out_pos[offs[:-1]]
        return Encoded(out, {"syms": syms}, out_lengths=out_lengths.astype(np.int64))

    def decode(self, enc: Encoded, lengths: np.ndarray):
        syms: List[bytes] = enc.meta["syms"]
        data = np.asarray(enc.data, np.uint8)
        n = len(data)
        if n == 0:
            return np.zeros(len(lengths), np.int64), np.zeros(0, np.uint8)
        sym_len = np.ones(256, dtype=np.int64)  # escape handled separately
        sym_b0 = np.arange(256, dtype=np.uint8)
        sym_b1 = np.zeros(256, dtype=np.uint8)
        for c, s in enumerate(syms):
            sym_len[c] = len(s)
            sym_b0[c] = s[0]
            sym_b1[c] = s[1] if len(s) == 2 else 0
        is_code_start = np.ones(n, dtype=bool)
        # escape consumes 2 input bytes; compute starts via scan on escapes:
        # a byte is a start iff previous start wasn't an escape consuming it.
        esc = data == self.ESC
        # sequential dependency only through escape chains; escapes cannot be
        # produced by code emission, so: start[i] = not (start[i-1] and esc[i-1])
        start = np.ones(n, dtype=bool)
        i = 0
        # vectorized: runs of consecutive escapes alternate; find via parity
        esc_run_start = esc & ~np.concatenate([[False], esc[:-1]])
        pos_in_esc_run = np.arange(n) - np.maximum.accumulate(
            np.where(esc_run_start, np.arange(n), -1)
        )
        # within an escape run starting at a start position, escapes at even
        # offsets are code starts (escape), odd offsets are literals.
        consumed_by_esc = np.zeros(n, dtype=bool)
        consumed_by_esc[1:] = esc[:-1] & ((pos_in_esc_run[:-1] & 1) == 0)
        # note: a literal byte equal to ESC inside an escape pair is consumed;
        # runs handle chains of escaped-escapes correctly by parity.
        start = ~consumed_by_esc
        starts_idx = np.nonzero(start)[0]
        codes = data[starts_idx]
        is_esc = codes == self.ESC
        lit = np.zeros(len(codes), dtype=np.uint8)
        lit_idx = starts_idx[is_esc] + 1
        lit[is_esc] = data[np.minimum(lit_idx, n - 1)]
        out_len = np.where(is_esc, 1, sym_len[codes])
        out_pos = np.zeros(len(codes) + 1, dtype=np.int64)
        np.cumsum(out_len, out=out_pos[1:])
        out = np.zeros(int(out_pos[-1]), dtype=np.uint8)
        p = out_pos[:-1]
        out[p[is_esc]] = lit[is_esc]
        one = ~is_esc & (sym_len[codes] == 1)
        two = ~is_esc & (sym_len[codes] == 2)
        out[p[one]] = sym_b0[codes[one]]
        out[p[two]] = sym_b0[codes[two]]
        out[p[two] + 1] = sym_b1[codes[two]]
        # per-value output lengths: map stored lengths (compressed) to input
        # positions, then to output positions
        in_offs = np.zeros(len(lengths) + 1, dtype=np.int64)
        np.cumsum(lengths, out=in_offs[1:])
        # output offset at each input byte position
        out_at = np.zeros(n + 1, dtype=np.int64)
        out_at[starts_idx] = out_pos[:-1]
        # forward-fill non-start positions, then append total
        np.maximum.accumulate(out_at[:-1], out=out_at[:-1])
        out_at[n] = out_pos[-1]
        out_lengths = out_at[in_offs[1:]] - out_at[in_offs[:-1]]
        return out_lengths.astype(np.int64), out


FIXED_CODECS: Dict[str, FixedCodec] = {
    c.name: c for c in [PlainFixed(), BitPackFixed(), BytePackFixed(), DeltaBitPack(), RLEFixed(), DictFixed()]
}
BYTES_CODECS: Dict[str, BytesCodec] = {
    c.name: c for c in [PlainBytes(), ZstdPerValue(), ZstdChunk(), FSSTLite()]
}


def get_fixed_codec(name: str) -> FixedCodec:
    return FIXED_CODECS[name]


def get_bytes_codec(name: str) -> BytesCodec:
    return BYTES_CODECS[name]
