"""Pallas TPU kernel: mini-block chunk decode.

One grid step decodes one mini-block chunk (§4.2): unpack the bit-packed
repetition / definition level streams, unpack the (frame-of-reference)
bit-packed or byte-packed values, and scatter them densely (fill at nulls).
Per-chunk parameters (entry count, value bit width, FoR reference) arrive via
scalar prefetch; chunk payloads are padded to a common word count so the
BlockSpec tiling is static — the mini-block format's power-of-two/8-byte-
aligned chunk rules (§4.2.1) exist precisely to make this tiling possible.

Coverage (static per call, constant per column):

* ``rep_bits``/``def_bits``: 0 (stream absent) or any width — multi-bit
  definition streams of nested/struct columns decode on device, not just the
  1-bit flat bitmap.
* ``vpe`` (values per entry): 1 for primitives, the list size for
  fixed-size-list chunks — each valid entry owns ``vpe`` consecutive values.
* values: dense little-endian bit stream at any per-chunk width <= 31 bits
  (``bitpack``), or byte-aligned FoR (``bytepack``, width*8 bits) with the
  per-chunk reference added back.

VMEM budget: a chunk is <=32 KiB by construction (12-bit word count), plus
the ``(tile_entries * vpe,)`` int32 output tile — the reader caps
``tile_entries * vpe`` so this stays comfortably inside the ~16 MiB VMEM of
a TPU core even with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["miniblock_decode_pallas", "MAX_ENTRIES"]

MAX_ENTRIES = 4096  # the format's per-chunk value ceiling (sec 4.2.1)


def _iota(n: int) -> jax.Array:
    """1-D uint32 iota via a 2-D broadcasted iota (TPU needs >=2-D)."""
    return (
        jax.lax.broadcasted_iota(jnp.uint32, (n // 128, 128), 0) * 128
        + jax.lax.broadcasted_iota(jnp.uint32, (n // 128, 128), 1)
    ).reshape(-1)


def _extract(words, bitpos, bits, mask):
    """Little-endian ``bits``-wide field at ``bitpos`` of a uint32 stream."""
    w = (bitpos // 32).astype(jnp.int32)
    sh = bitpos % 32
    w0 = jnp.take(words, w, axis=0)
    w1 = jnp.take(words, jnp.minimum(w + 1, words.shape[0] - 1), axis=0)
    hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
    hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
    return ((w0 >> sh) | hi) & mask


def _kernel(params_ref, rep_ref, def_ref, val_ref,
            out_rep_ref, out_def_ref, out_val_ref,
            *, rep_bits: int, def_bits: int, vpe: int, tile: int, fill: int):
    c = pl.program_id(0)
    n = params_ref[c, 0]
    bits = params_ref[c, 1].astype(jnp.uint32)
    ref = params_ref[c, 2]

    j = _iota(tile)
    in_range = j < n.astype(jnp.uint32)
    if rep_bits:
        rep = _extract(rep_ref[0, :], j * rep_bits,
                       jnp.uint32(rep_bits), jnp.uint32((1 << rep_bits) - 1))
        out_rep_ref[...] = jnp.where(in_range, rep.astype(jnp.int32), 0).reshape(
            tile // 128, 128)
    else:
        out_rep_ref[...] = jnp.zeros((tile // 128, 128), jnp.int32)
    if def_bits:
        d = _extract(def_ref[0, :], j * def_bits,
                     jnp.uint32(def_bits), jnp.uint32((1 << def_bits) - 1))
        valid = (d == 0) & in_range
        out_def_ref[...] = jnp.where(in_range, d.astype(jnp.int32), 0).reshape(
            tile // 128, 128)
    else:
        valid = in_range
        out_def_ref[...] = jnp.zeros((tile // 128, 128), jnp.int32)
    # value slot of each entry: cumsum over the validity mask
    vidx = (jnp.cumsum(valid.astype(jnp.int32)) - 1).astype(jnp.uint32)

    # each valid entry owns vpe consecutive values in the dense stream
    k = _iota(tile * vpe)
    e = (k // jnp.uint32(vpe)).astype(jnp.int32)
    valid_k = jnp.take(valid, e, axis=0)
    slot = jnp.take(vidx, e, axis=0) * jnp.uint32(vpe) + k % jnp.uint32(vpe)
    bitpos = jnp.where(valid_k, slot, 0) * bits
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bits) - jnp.uint32(1))
    vals = _extract(val_ref[0, :], bitpos, bits, mask)
    out = jnp.where(valid_k, vals.astype(jnp.int32) + ref, fill)
    out_val_ref[...] = out.reshape(tile * vpe // 128, 128)


@functools.partial(
    jax.jit,
    static_argnames=("rep_bits", "def_bits", "vpe", "tile_entries", "fill",
                     "interpret"))
def miniblock_decode_pallas(
    rep_words: jax.Array,  # (C, RW) uint32 (dummy (C, 1) when rep_bits == 0)
    def_words: jax.Array,  # (C, DW) uint32 (dummy (C, 1) when def_bits == 0)
    val_words: jax.Array,  # (C, VW) uint32
    params: jax.Array,  # (C, 3) int32: [n_entries, vbits, ref]
    *,
    rep_bits: int,
    def_bits: int,
    vpe: int = 1,
    tile_entries: int = MAX_ENTRIES,
    fill: int = 0,
    interpret: bool = True,
):
    """Decode C chunks -> (rep, defs, vals) int32 tiles.

    ``rep``/``defs`` are ``(C, tile_entries)`` level streams (zero where the
    stream is absent or past ``n_entries``); ``vals`` is the dense
    ``(C, tile_entries * vpe)`` value tile with ``fill`` at nulls.
    """
    assert tile_entries % 128 == 0 and (tile_entries * vpe) % 128 == 0
    C = params.shape[0]
    RW, DW, VW = rep_words.shape[1], def_words.shape[1], val_words.shape[1]
    R = tile_entries // 128
    RV = tile_entries * vpe // 128
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, RW), lambda c, p: (c, 0)),
            pl.BlockSpec((1, DW), lambda c, p: (c, 0)),
            pl.BlockSpec((1, VW), lambda c, p: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((R, 128), lambda c, p: (c, 0)),
            pl.BlockSpec((R, 128), lambda c, p: (c, 0)),
            pl.BlockSpec((RV, 128), lambda c, p: (c, 0)),
        ],
    )
    rep, defs, vals = pl.pallas_call(
        functools.partial(_kernel, rep_bits=rep_bits, def_bits=def_bits,
                          vpe=vpe, tile=tile_entries, fill=fill),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C * R, 128), jnp.int32),
            jax.ShapeDtypeStruct((C * R, 128), jnp.int32),
            jax.ShapeDtypeStruct((C * RV, 128), jnp.int32),
        ],
        interpret=interpret,
    )(params, rep_words, def_words, val_words)
    return (rep.reshape(C, tile_entries), defs.reshape(C, tile_entries),
            vals.reshape(C, tile_entries * vpe))
