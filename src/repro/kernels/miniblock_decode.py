"""Pallas TPU kernel: mini-block chunk decode.

One grid step decodes one mini-block chunk (§4.2): unpack the 1-bit
definition bitmap, unpack the frame-of-reference bit-packed values, and
scatter them densely (fill at nulls).  Chunk parameters (entry count, value
bit width, FoR reference) vary per chunk and arrive via scalar prefetch; the
chunk payloads are padded to a common word count so the BlockSpec tiling is
static — the mini-block format's power-of-two/8-byte-aligned chunk rules
(§4.2.1) exist precisely to make this kind of tiling possible.

VMEM budget: a chunk is ≤32 KiB by construction (12-bit word count), plus
the (4096,)-value output tile — comfortably inside the ~16 MiB VMEM of a
TPU core even with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["miniblock_decode_pallas", "MAX_ENTRIES"]

MAX_ENTRIES = 4096  # the format's per-chunk value ceiling (sec 4.2.1)


def _kernel(params_ref, def_ref, val_ref, out_vals_ref, out_valid_ref, *, nullable: bool, fill: int):
    c = pl.program_id(0)
    n = params_ref[c, 0]
    bits = params_ref[c, 1].astype(jnp.uint32)
    ref = params_ref[c, 2]

    j = (
        jax.lax.broadcasted_iota(jnp.uint32, (MAX_ENTRIES // 128, 128), 0) * 128
        + jax.lax.broadcasted_iota(jnp.uint32, (MAX_ENTRIES // 128, 128), 1)
    ).reshape(-1)
    in_range = j < n.astype(jnp.uint32)
    if nullable:
        dw = def_ref[0, :]
        w = (j // 32).astype(jnp.int32)
        d = (jnp.take(dw, w, axis=0) >> (j % 32)) & jnp.uint32(1)
        valid = (d == 0) & in_range
    else:
        valid = in_range
    vidx = (jnp.cumsum(valid.astype(jnp.int32)) - 1).astype(jnp.uint32)
    bitpos = jnp.where(valid, vidx, 0) * bits
    w = (bitpos // 32).astype(jnp.int32)
    sh = bitpos % 32
    vw = val_ref[0, :]
    w0 = jnp.take(vw, w, axis=0)
    w1 = jnp.take(vw, jnp.minimum(w + 1, vw.shape[0] - 1), axis=0)
    hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
    hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
    mask = jnp.where(bits >= 32, jnp.uint32(0xFFFFFFFF), (jnp.uint32(1) << bits) - jnp.uint32(1))
    vals = ((w0 >> sh) | hi) & mask
    out = jnp.where(valid, vals.astype(jnp.int32) + ref, fill)
    out_vals_ref[...] = out.reshape(MAX_ENTRIES // 128, 128)
    out_valid_ref[...] = valid.reshape(MAX_ENTRIES // 128, 128)


@functools.partial(jax.jit, static_argnames=("nullable", "fill", "interpret"))
def miniblock_decode_pallas(
    def_words: jax.Array,  # (C, DW) uint32
    val_words: jax.Array,  # (C, VW) uint32
    params: jax.Array,  # (C, 3) int32: [n_entries, vbits, ref]
    *,
    nullable: bool,
    fill: int = 0,
    interpret: bool = True,
):
    C, DW = def_words.shape
    VW = val_words.shape[1]
    R = MAX_ENTRIES // 128
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, DW), lambda c, p: (c, 0)),
            pl.BlockSpec((1, VW), lambda c, p: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((R, 128), lambda c, p: (c, 0)),
            pl.BlockSpec((R, 128), lambda c, p: (c, 0)),
        ],
    )
    vals, valid = pl.pallas_call(
        functools.partial(_kernel, nullable=nullable, fill=fill),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C * R, 128), jnp.int32),
            jax.ShapeDtypeStruct((C * R, 128), jnp.bool_),
        ],
        interpret=interpret,
    )(params, def_words, val_words)
    return vals.reshape(C, MAX_ENTRIES), valid.reshape(C, MAX_ENTRIES)
