"""Pallas TPU kernel: full-zip random-access gather ("take").

The paper's full-zip random access is: look up a row's byte range (repetition
index / fixed stride) and issue one IOP for the zipped bytes (§4.1.4).  The
TPU-native translation is a **block-table-driven DMA gather**: row offsets are
scalar-prefetched and consumed by the input BlockSpec's index_map, so each
grid step DMAs exactly one zipped row from HBM into VMEM — one "IOP" per row,
no gather instructions inside the kernel body.  (This is the same mechanism
paged-attention KV fetch uses; the repetition index plays the block table.)

Wired into :meth:`repro.core.fullzip.FullZipReader.take` behind the
``decode="pallas"`` knob: the unique fetched rows are gathered straight into
request order (``rows`` = the request's inverse permutation, duplicates
included), replacing the host fan-out permutation with one device gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fullzip_gather_pallas"]


def _kernel(idx_ref, zipped_ref, out_ref):
    # the BlockSpec index_map already DMA'd the selected row block; copy out.
    out_ref[...] = zipped_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fullzip_gather_pallas(
    zipped: jax.Array,  # (n_rows, stride) uint8 (stride: control word + value)
    rows: jax.Array,  # (n_take,) int32 row ids (from the repetition index)
    *,
    interpret: bool = True,
) -> jax.Array:
    n_take = rows.shape[0]
    stride = zipped.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_take,),
        in_specs=[pl.BlockSpec((1, stride), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, stride), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_take, stride), zipped.dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), zipped)
