"""Pallas TPU kernel: k-bit little-endian unpack -> int32/uint32.

Bit-unpacking ends every transparent integer codec in the paper (control
words §4.1.1, mini-block values §4.2, repetition indexes §4.1.4), so it is
the innermost decode hot-spot.  TPU adaptation: the packed stream is viewed
as uint32 words; each grid step unpacks VALS_PER_BLOCK = 8*128*8 values
(a (64, 128) tile, lane-aligned for the VPU).  Because
``VALS_PER_BLOCK * bits`` is a multiple of 32 for every bits<=32, value
blocks never straddle word-block boundaries, so the input BlockSpec tiles
exactly ``32 * bits`` words per step with no halo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitunpack_pallas", "VALS_PER_BLOCK"]

SUBLANES, LANES = 64, 128
VALS_PER_BLOCK = SUBLANES * LANES  # 8192 values / grid step
# words consumed per block = VALS_PER_BLOCK * bits / 32 = 256 * bits


def _kernel(words_ref, out_ref, *, bits: int):
    j = (
        jax.lax.broadcasted_iota(jnp.uint32, (SUBLANES, LANES), 0) * LANES
        + jax.lax.broadcasted_iota(jnp.uint32, (SUBLANES, LANES), 1)
    )
    bitpos = j * jnp.uint32(bits)
    w = (bitpos // 32).astype(jnp.int32)
    sh = bitpos % 32
    words = words_ref[...]
    w0 = jnp.take(words, w, axis=0)
    w1 = jnp.take(words, jnp.minimum(w + 1, words.shape[0] - 1), axis=0)
    hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
    hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
    mask = jnp.uint32((1 << bits) - 1) if bits < 32 else jnp.uint32(0xFFFFFFFF)
    out_ref[...] = ((w0 >> sh) | hi) & mask


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bitunpack_pallas(words: jax.Array, bits: int, *, interpret: bool = True) -> jax.Array:
    """Unpack a uint32 word stream into (n_blocks*8192,) uint32 values.

    ``words`` must hold at least ``ceil(n_values*bits/32)`` words padded up to
    a multiple of ``256*bits`` (the per-block word count); callers slice the
    result to their true length.
    """
    wpb = VALS_PER_BLOCK * bits // 32
    assert words.shape[0] % wpb == 0, (words.shape, wpb)
    n_blocks = words.shape[0] // wpb
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((wpb,), lambda b: (b,))],
        out_specs=pl.BlockSpec((SUBLANES, LANES), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * SUBLANES, LANES), jnp.uint32),
        interpret=interpret,
    )(words)
    return out.reshape(-1)
