"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (shape/dtype
sweeps in ``tests/test_kernels.py``) and the fallback implementation on
backends without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bitunpack_ref", "miniblock_decode_ref", "fullzip_gather_ref",
           "ivf_topk_ref", "IVF_ID_SENTINEL"]

# Padding / exhaustion marker for ivf_topk: never a valid row id (global row
# ids are dispatch-checked to fit in 31 bits), and maximal so the
# min-id tie-break never prefers it over a real candidate.
IVF_ID_SENTINEL = (1 << 31) - 1


def bitunpack_ref(words: jax.Array, n: int, bits: int) -> jax.Array:
    """Unpack ``n`` little-endian ``bits``-wide values from uint32 words."""
    j = jnp.arange(n, dtype=jnp.uint32)
    bitpos = j * jnp.uint32(bits)
    w = (bitpos // 32).astype(jnp.int32)
    sh = bitpos % 32
    w0 = words[w]
    w1 = words[jnp.minimum(w + 1, words.shape[0] - 1)]
    hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
    hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
    mask = jnp.uint32((1 << bits) - 1) if bits < 32 else jnp.uint32(0xFFFFFFFF)
    return ((w0 >> sh) | hi) & mask


def _extract_ref(words: jax.Array, bitpos: jax.Array, bits, mask) -> jax.Array:
    """Little-endian dynamic-width field extraction from uint32 words."""
    w = (bitpos // 32).astype(jnp.int32)
    sh = bitpos % 32
    w0 = words[w]
    w1 = words[jnp.minimum(w + 1, words.shape[0] - 1)]
    hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
    hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
    return ((w0 >> sh) | hi) & mask


def miniblock_decode_ref(
    rep_words: jax.Array,  # (C, RW) uint32 bit-packed rep levels (dummy if absent)
    def_words: jax.Array,  # (C, DW) uint32 bit-packed def levels (dummy if absent)
    val_words: jax.Array,  # (C, VW) uint32 bit/byte-packed FoR values
    n_entries: jax.Array,  # (C,) int32 valid entries per chunk
    vbits: jax.Array,  # (C,) int32 value bit width per chunk
    refs: jax.Array,  # (C,) int32 frame-of-reference per chunk
    max_entries: int,
    rep_bits: int,
    def_bits: int,
    vpe: int = 1,
    fill: int = 0,
):
    """Decode C mini-block chunks -> ``(rep, defs, vals)`` int32 tiles.

    Models the §4.2 decode for integer chunks: per chunk, unpack the rep/def
    level streams (widths are column constants; 0 = stream absent), unpack
    the sparse packed values (``vpe`` consecutive values per valid entry —
    fixed-size lists set ``vpe`` to the list size) and scatter them densely
    with ``fill`` at nulls.  Ground truth for the Pallas kernel.
    """

    def one(rw, dw, vw, n, bits, ref):
        j = jnp.arange(max_entries, dtype=jnp.uint32)
        in_range = j < n.astype(jnp.uint32)
        if rep_bits:
            rep = _extract_ref(rw, j * jnp.uint32(rep_bits),
                               jnp.uint32(rep_bits),
                               jnp.uint32((1 << rep_bits) - 1))
            rep = jnp.where(in_range, rep.astype(jnp.int32), 0)
        else:
            rep = jnp.zeros(max_entries, jnp.int32)
        if def_bits:
            d = _extract_ref(dw, j * jnp.uint32(def_bits),
                             jnp.uint32(def_bits),
                             jnp.uint32((1 << def_bits) - 1))
            valid = (d == 0) & in_range
            d = jnp.where(in_range, d.astype(jnp.int32), 0)
        else:
            valid = in_range
            d = jnp.zeros(max_entries, jnp.int32)
        vidx = (jnp.cumsum(valid.astype(jnp.int32)) - 1).astype(jnp.uint32)
        k = jnp.arange(max_entries * vpe, dtype=jnp.uint32)
        e = (k // jnp.uint32(vpe)).astype(jnp.int32)
        valid_k = valid[e]
        slot = vidx[e] * jnp.uint32(vpe) + k % jnp.uint32(vpe)
        bitpos = jnp.where(valid_k, slot, 0) * bits.astype(jnp.uint32)
        mask = jnp.where(
            bits >= 32, jnp.uint32(0xFFFFFFFF),
            (jnp.uint32(1) << bits.astype(jnp.uint32)) - 1)
        vals = _extract_ref(vw, bitpos, bits, mask)
        out = jnp.where(valid_k, vals.astype(jnp.int32) + ref, fill)
        return rep, d, out

    return jax.vmap(one)(rep_words, def_words, val_words, n_entries, vbits, refs)


def ivf_topk_ref(queries: jax.Array, cands: jax.Array, ids: jax.Array,
                 mask: jax.Array, k: int, kp: int = 128):
    """Batched squared-L2 distance + deterministic top-k selection.

    ``queries``: (Q, D) float; ``cands``: (N, D) float; ``ids``: (1, N)
    int32 candidate row ids (``IVF_ID_SENTINEL`` in padding); ``mask``:
    (Q, N) int32 — 1 where candidate n is eligible for query q (IVF probes
    different partitions per query over one shared candidate matrix), 0
    where it is not (and in padding columns).

    Returns ``(dists, winners)`` of shape (Q, kp): entry j is the j-th
    nearest eligible candidate, ties broken toward the *lowest row id*
    (bit-reproducible regardless of candidate order); entries past the
    eligible count — and columns >= k — hold ``(inf, IVF_ID_SENTINEL)``.
    Ground truth for the Pallas kernel: same op sequence, validated
    bit-identical in interpret mode.
    """
    acc = queries.dtype
    qq = jnp.sum(queries * queries, axis=1, keepdims=True)        # (Q, 1)
    cc = jnp.sum(cands * cands, axis=1, keepdims=True).T          # (1, N)
    dot = jnp.dot(queries, cands.T, preferred_element_type=acc)   # (Q, N)
    d = qq - 2.0 * dot + cc
    eligible = mask != 0
    d = jnp.where(eligible, d, jnp.inf).astype(acc)
    # the kernel route is int32-only; the ref also backs the >31-bit-id
    # fallback, where the sentinel has to stay maximal in the wider dtype
    sent = IVF_ID_SENTINEL if ids.dtype == jnp.int32 \
        else jnp.iinfo(ids.dtype).max
    idrow = jnp.where(eligible, ids, sent)                        # (Q, N)
    colk = jax.lax.broadcasted_iota(jnp.int32, (queries.shape[0], kp), 1)
    out_d = jnp.full((queries.shape[0], kp), jnp.inf, acc)
    out_i = jnp.full((queries.shape[0], kp), sent, ids.dtype)
    for j in range(k):
        m = jnp.min(d, axis=1, keepdims=True)                     # (Q, 1)
        tie = jnp.where(d == m, idrow, sent)
        wid = jnp.min(tie, axis=1, keepdims=True)                 # (Q, 1)
        out_d = jnp.where(colk == j, m, out_d)
        out_i = jnp.where(colk == j, wid, out_i)
        sel = (d == m) & (idrow == wid)
        d = jnp.where(sel, jnp.inf, d)
        idrow = jnp.where(sel, sent, idrow)
    return out_d, out_i


def fullzip_gather_ref(zipped: jax.Array, rows: jax.Array) -> jax.Array:
    """Random-access take on a fixed-stride full-zip buffer.

    ``zipped``: (n_rows, stride) uint8 — each row is [control word | value
    bytes].  ``rows``: (n_take,) int32.  One gathered row ≙ the paper's
    "1 IOP for fixed-width random access"; on TPU it is one HBM→VMEM DMA per
    row, which the Pallas kernel drives through its BlockSpec index_map
    (the repetition index acting as a block table).
    """
    return zipped[rows]
