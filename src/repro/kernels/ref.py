"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (shape/dtype
sweeps in ``tests/test_kernels.py``) and the fallback implementation on
backends without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bitunpack_ref", "miniblock_decode_ref", "fullzip_gather_ref"]


def bitunpack_ref(words: jax.Array, n: int, bits: int) -> jax.Array:
    """Unpack ``n`` little-endian ``bits``-wide values from uint32 words."""
    j = jnp.arange(n, dtype=jnp.uint32)
    bitpos = j * jnp.uint32(bits)
    w = (bitpos // 32).astype(jnp.int32)
    sh = bitpos % 32
    w0 = words[w]
    w1 = words[jnp.minimum(w + 1, words.shape[0] - 1)]
    hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
    hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
    mask = jnp.uint32((1 << bits) - 1) if bits < 32 else jnp.uint32(0xFFFFFFFF)
    return ((w0 >> sh) | hi) & mask


def miniblock_decode_ref(
    def_words: jax.Array,  # (C, DW) uint32 bit-packed 1-bit def levels
    val_words: jax.Array,  # (C, VW) uint32 bit-packed FoR values
    n_entries: jax.Array,  # (C,) int32 valid entries per chunk
    vbits: jax.Array,  # (C,) int32 value bit width per chunk
    refs: jax.Array,  # (C,) int32 frame-of-reference per chunk
    max_entries: int,
    nullable: bool,
    fill: int = 0,
):
    """Decode C mini-block chunks -> dense (C, max_entries) int32 + validity.

    Models the §4.2 scan path for flat integer columns (the training-token
    pipeline): per chunk, unpack the definition bitmap, unpack the sparse
    bit-packed values, and scatter them densely with ``fill`` at nulls.
    """

    def one(dw, vw, n, bits, ref):
        j = jnp.arange(max_entries, dtype=jnp.uint32)
        in_range = j < n.astype(jnp.uint32)
        if nullable:
            d = bitunpack_ref(dw, max_entries, 1)
            valid = (d == 0) & in_range
        else:
            valid = in_range
        vidx = jnp.cumsum(valid.astype(jnp.int32)) - 1
        # dynamic bit width unpack
        bitpos = jnp.where(valid, vidx, 0).astype(jnp.uint32) * bits.astype(jnp.uint32)
        w = (bitpos // 32).astype(jnp.int32)
        sh = bitpos % 32
        w0 = vw[w]
        w1 = vw[jnp.minimum(w + 1, vw.shape[0] - 1)]
        hi_shift = (jnp.uint32(32) - sh) & jnp.uint32(31)
        hi = jnp.where(sh > 0, w1 << hi_shift, jnp.uint32(0))
        mask = jnp.where(
            bits >= 32, jnp.uint32(0xFFFFFFFF), (jnp.uint32(1) << bits.astype(jnp.uint32)) - 1
        )
        vals = ((w0 >> sh) | hi) & mask
        out = jnp.where(valid, vals.astype(jnp.int32) + ref, fill)
        return out, valid

    return jax.vmap(one)(def_words, val_words, n_entries, vbits, refs)


def fullzip_gather_ref(zipped: jax.Array, rows: jax.Array) -> jax.Array:
    """Random-access take on a fixed-stride full-zip buffer.

    ``zipped``: (n_rows, stride) uint8 — each row is [control word | value
    bytes].  ``rows``: (n_take,) int32.  One gathered row ≙ the paper's
    "1 IOP for fixed-width random access"; on TPU it is one HBM→VMEM DMA per
    row, which the Pallas kernel drives through its BlockSpec index_map
    (the repetition index acting as a block table).
    """
    return zipped[rows]
