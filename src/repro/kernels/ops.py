"""Public jit'd kernel entry points.

Each op dispatches to the Pallas TPU kernel (interpret=True on CPU so the
kernel *body* is what executes) or to the pure-jnp oracle in ``ref.py``.
On a real TPU backend ``interpret`` flips to False and the same code lowers
to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitunpack import VALS_PER_BLOCK, bitunpack_pallas
from .fullzip_gather import fullzip_gather_pallas
from .miniblock_decode import MAX_ENTRIES, miniblock_decode_pallas

__all__ = [
    "bitunpack",
    "miniblock_decode",
    "fullzip_gather",
    "pack_words",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_words(buf: np.ndarray, pad_words: int = 1) -> np.ndarray:
    """uint8 packed stream -> uint32 little-endian words (host helper)."""
    b = np.asarray(buf, np.uint8)
    pad = (-len(b)) % 4
    b = np.pad(b, (0, pad))
    w = b.view(np.uint32)
    if pad_words:
        w = np.pad(w, (0, pad_words))
    return w


def bitunpack(words: jax.Array, n: int, bits: int, *, use_pallas: bool = True) -> jax.Array:
    """Unpack ``n`` ``bits``-wide values from a uint32 word stream."""
    if not use_pallas:
        return ref.bitunpack_ref(words, n, bits)
    wpb = VALS_PER_BLOCK * bits // 32
    n_blocks = max(1, -(-n // VALS_PER_BLOCK))
    need = n_blocks * wpb
    w = jnp.pad(words, (0, max(0, need - words.shape[0])))[:need]
    out = bitunpack_pallas(w, bits, interpret=not on_tpu())
    return out[:n]


def miniblock_decode(
    rep_words: jax.Array,
    def_words: jax.Array,
    val_words: jax.Array,
    params: jax.Array,
    *,
    rep_bits: int,
    def_bits: int,
    vpe: int = 1,
    tile_entries: int = MAX_ENTRIES,
    fill: int = 0,
    use_pallas: bool = True,
):
    """Decode C mini-block chunks -> ``(rep, defs, vals)`` int32 tiles.

    ``rep``/``defs`` are ``(C, tile_entries)``; ``vals`` is the dense
    ``(C, tile_entries * vpe)`` tile (``vpe`` values per valid entry —
    fixed-size-list chunks set it to the list size).  Entries past a chunk's
    ``n_entries`` and null value slots read as 0 / ``fill``.
    """
    if not use_pallas:
        return ref.miniblock_decode_ref(
            rep_words, def_words, val_words,
            params[:, 0], params[:, 1], params[:, 2],
            tile_entries, rep_bits, def_bits, vpe, fill,
        )
    return miniblock_decode_pallas(
        rep_words, def_words, val_words, params,
        rep_bits=rep_bits, def_bits=def_bits, vpe=vpe,
        tile_entries=tile_entries, fill=fill,
        interpret=not on_tpu(),
    )


def fullzip_gather(zipped: jax.Array, rows: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Gather zipped fixed-stride rows (the §4.1 take path)."""
    if not use_pallas:
        return ref.fullzip_gather_ref(zipped, rows)
    return fullzip_gather_pallas(zipped, rows, interpret=not on_tpu())
