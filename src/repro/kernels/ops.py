"""Public jit'd kernel entry points.

Each op dispatches to the Pallas TPU kernel (interpret=True on CPU so the
kernel *body* is what executes) or to the pure-jnp oracle in ``ref.py``.
On a real TPU backend ``interpret`` flips to False and the same code lowers
to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitunpack import VALS_PER_BLOCK, bitunpack_pallas
from .fullzip_gather import fullzip_gather_pallas
from .ivf_topk import K_PAD, QUERY_TILE, ivf_topk_pallas
from .miniblock_decode import MAX_ENTRIES, miniblock_decode_pallas
from .ref import IVF_ID_SENTINEL

__all__ = [
    "bitunpack",
    "miniblock_decode",
    "fullzip_gather",
    "ivf_topk",
    "pack_words",
    "on_tpu",
    "IVF_ID_SENTINEL",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_words(buf: np.ndarray, pad_words: int = 1) -> np.ndarray:
    """uint8 packed stream -> uint32 little-endian words (host helper)."""
    b = np.asarray(buf, np.uint8)
    pad = (-len(b)) % 4
    b = np.pad(b, (0, pad))
    w = b.view(np.uint32)
    if pad_words:
        w = np.pad(w, (0, pad_words))
    return w


def bitunpack(words: jax.Array, n: int, bits: int, *, use_pallas: bool = True) -> jax.Array:
    """Unpack ``n`` ``bits``-wide values from a uint32 word stream."""
    if not use_pallas:
        return ref.bitunpack_ref(words, n, bits)
    wpb = VALS_PER_BLOCK * bits // 32
    n_blocks = max(1, -(-n // VALS_PER_BLOCK))
    need = n_blocks * wpb
    w = jnp.pad(words, (0, max(0, need - words.shape[0])))[:need]
    out = bitunpack_pallas(w, bits, interpret=not on_tpu())
    return out[:n]


def miniblock_decode(
    rep_words: jax.Array,
    def_words: jax.Array,
    val_words: jax.Array,
    params: jax.Array,
    *,
    rep_bits: int,
    def_bits: int,
    vpe: int = 1,
    tile_entries: int = MAX_ENTRIES,
    fill: int = 0,
    use_pallas: bool = True,
):
    """Decode C mini-block chunks -> ``(rep, defs, vals)`` int32 tiles.

    ``rep``/``defs`` are ``(C, tile_entries)``; ``vals`` is the dense
    ``(C, tile_entries * vpe)`` tile (``vpe`` values per valid entry —
    fixed-size-list chunks set it to the list size).  Entries past a chunk's
    ``n_entries`` and null value slots read as 0 / ``fill``.
    """
    if not use_pallas:
        return ref.miniblock_decode_ref(
            rep_words, def_words, val_words,
            params[:, 0], params[:, 1], params[:, 2],
            tile_entries, rep_bits, def_bits, vpe, fill,
        )
    return miniblock_decode_pallas(
        rep_words, def_words, val_words, params,
        rep_bits=rep_bits, def_bits=def_bits, vpe=vpe,
        tile_entries=tile_entries, fill=fill,
        interpret=not on_tpu(),
    )


def fullzip_gather(zipped: jax.Array, rows: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Gather zipped fixed-stride rows (the §4.1 take path)."""
    if not use_pallas:
        return ref.fullzip_gather_ref(zipped, rows)
    return fullzip_gather_pallas(zipped, rows, interpret=not on_tpu())


def _ivf_pad(queries, cands, ids, mask):
    """Pad (queries, cands, ids, mask) to the kernel's static tiling:
    query rows to a multiple of 8, candidates to a multiple of 128, dims
    to a multiple of 128.  Zero dim-padding is L2-exact; padded candidate
    columns are masked out and carry the id sentinel."""
    q2 = np.atleast_2d(np.asarray(queries))
    c2 = np.atleast_2d(np.asarray(cands))
    qn, d = q2.shape
    n = c2.shape[0]
    qp = -(-max(qn, 1) // QUERY_TILE) * QUERY_TILE
    np_ = -(-max(n, 1) // 128) * 128
    dp = -(-max(d, 1) // 128) * 128
    qpad = np.zeros((qp, dp), q2.dtype)
    qpad[:qn, :d] = q2
    cpad = np.zeros((np_, dp), c2.dtype)
    cpad[:n, :d] = c2
    idp = np.full((1, np_), IVF_ID_SENTINEL,
                  np.asarray(ids).dtype if np.asarray(ids).size else np.int32)
    idp[0, :n] = np.asarray(ids).reshape(-1)
    mpad = np.zeros((qp, np_), np.int32)
    if mask is None:
        mpad[:qn, :n] = 1
    else:
        mpad[:qn, :n] = np.asarray(mask, np.int32).reshape(qn, n)
    return q2, qpad, cpad, idp, mpad


def ivf_topk(queries, cands, ids, k: int, mask=None, *,
             use_pallas: bool = True, tracer=None):
    """Batched squared-L2 distance + deterministic top-k over one shared
    candidate matrix (the IVF search hot loop).

    ``queries``: (Q, D) or (D,); ``cands``: (N, D); ``ids``: (N,)
    candidate row ids; ``mask``: optional (Q, N) per-query eligibility
    (1 = candidate in one of this query's probed partitions).  Returns
    ``(dists, winners)`` of shape (Q, k) — ties break toward the lowest
    row id, entries past a query's eligible count hold
    ``(inf, IVF_ID_SENTINEL)``.

    Dispatches to the Pallas kernel when eligible (float32 vectors, ids
    within 31 bits, k <= 128, at least one candidate); otherwise falls
    back to the jnp oracle and reports the structured reason through
    ``tracer`` as a ``decode.fallback.ivf.<reason>`` counter — the same
    no-silent-fallback contract as the decode kernels.
    """
    k = int(k)
    if k < 1:
        raise ValueError("k must be positive")
    q2 = np.atleast_2d(np.asarray(queries))
    c2 = np.atleast_2d(np.asarray(cands))
    ids_arr = np.asarray(ids).reshape(-1)
    qn, n = q2.shape[0], c2.shape[0]
    reason = None
    if q2.dtype != np.float32 or c2.dtype != np.float32:
        reason = "non-float32"
    elif n == 0:
        reason = "no-candidates"
    elif k > K_PAD:
        reason = f">{K_PAD}-k"
    elif ids_arr.size and int(ids_arr.max()) >= IVF_ID_SENTINEL:
        reason = ">31-bit-ids"
    wide = reason == ">31-bit-ids"
    if wide:
        # jnp is int32 on CPU: select over *positions* of the candidates
        # sorted by id (position tie-break == id tie-break) and map back
        order = np.argsort(ids_arr, kind="stable")
        c2 = c2[order]
        if mask is not None:
            mask = np.atleast_2d(np.asarray(mask))[:, order]
        ids_sorted, ids_run = ids_arr[order], np.arange(n, dtype=np.int32)
    else:
        ids_run = ids_arr if ids_arr.dtype == np.int32 \
            else ids_arr.astype(np.int32)
    _, qpad, cpad, idp, mpad = _ivf_pad(q2, c2, ids_run, mask)
    if not use_pallas or reason is not None:
        if use_pallas and tracer is not None:
            tracer.fallback("ivf", reason, n_queries=qn, n_candidates=n, k=k)
        d, w = ref.ivf_topk_ref(jnp.asarray(qpad), jnp.asarray(cpad),
                                jnp.asarray(idp), jnp.asarray(mpad),
                                k, kp=max(K_PAD, k))
    else:
        d, w = ivf_topk_pallas(jnp.asarray(qpad), jnp.asarray(cpad),
                               jnp.asarray(idp), jnp.asarray(mpad),
                               k=k, interpret=not on_tpu())
    d, w = d[:qn, :k], w[:qn, :k]
    if wide:
        wnp = np.asarray(w)
        w = np.where(wnp == IVF_ID_SENTINEL, np.int64(IVF_ID_SENTINEL),
                     ids_sorted[np.minimum(wnp, n - 1)])
    return d, w
