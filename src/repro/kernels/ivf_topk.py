"""Pallas TPU kernel: batched IVF distance + top-k selection.

One grid step scores one 8-row query tile against the full candidate matrix
(the posting lists of every probed partition, concatenated by the search
path): squared-L2 distances via one MXU matmul, then ``k`` masked-argmin
selection sweeps with a deterministic tie-break toward the lowest candidate
row id — so the winner set is bit-reproducible no matter how the posting
lists happened to be ordered on disk.

The per-query eligibility ``mask`` is what makes one shared candidate
matrix serve a *batch* of IVF queries: each query probes its own
``nprobe`` partitions, so a candidate fetched for query A may be out of
scope for query B; masked (and padding) entries score ``+inf`` and carry
the id sentinel, which the selection sweep can never prefer.

Inputs are pre-padded by :func:`repro.kernels.ops.ivf_topk` (queries to a
multiple of 8 rows, candidates to a multiple of 128, dims to a multiple of
128 — the f32 VMEM tile) so the BlockSpec tiling is static.  VMEM budget:
the candidate matrix rides whole into every grid step, so callers keep
``N * D * 4`` bytes (plus the (8, N) distance tile) comfortably under a
core's ~16 MiB — the search path's per-probe candidate counts are far
below that.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import IVF_ID_SENTINEL

__all__ = ["ivf_topk_pallas", "QUERY_TILE", "K_PAD"]

QUERY_TILE = 8   # f32 min sublane tile: one grid step scores 8 queries
K_PAD = 128      # output lane width; k <= K_PAD, columns >= k are sentinel


def _kernel(q_ref, c_ref, id_ref, m_ref, out_d_ref, out_i_ref, *, k: int):
    q = q_ref[...]                     # (QUERY_TILE, Dp) f32
    c = c_ref[...]                     # (Np, Dp) f32
    ids = id_ref[...]                  # (1, Np) int32
    mask = m_ref[...]                  # (QUERY_TILE, Np) int32
    qq = jnp.sum(q * q, axis=1, keepdims=True)                      # (QT, 1)
    cc = jnp.sum(c * c, axis=1, keepdims=True).T                    # (1, Np)
    dot = jnp.dot(q, c.T, preferred_element_type=jnp.float32)       # (QT, Np)
    d = qq - 2.0 * dot + cc
    eligible = mask != 0
    d = jnp.where(eligible, d, jnp.inf).astype(jnp.float32)
    idrow = jnp.where(eligible, ids, IVF_ID_SENTINEL)               # (QT, Np)
    colk = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], K_PAD), 1)
    out_d = jnp.full((q.shape[0], K_PAD), jnp.inf, jnp.float32)
    out_i = jnp.full((q.shape[0], K_PAD), IVF_ID_SENTINEL, jnp.int32)
    for j in range(k):
        m = jnp.min(d, axis=1, keepdims=True)                       # (QT, 1)
        tie = jnp.where(d == m, idrow, IVF_ID_SENTINEL)
        wid = jnp.min(tie, axis=1, keepdims=True)                   # (QT, 1)
        out_d = jnp.where(colk == j, m, out_d)
        out_i = jnp.where(colk == j, wid, out_i)
        sel = (d == m) & (idrow == wid)
        d = jnp.where(sel, jnp.inf, d)
        idrow = jnp.where(sel, IVF_ID_SENTINEL, idrow)
    out_d_ref[...] = out_d
    out_i_ref[...] = out_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ivf_topk_pallas(queries: jax.Array, cands: jax.Array, ids: jax.Array,
                    mask: jax.Array, *, k: int, interpret: bool = True):
    """(Qp, Dp) f32 queries x (Np, Dp) f32 candidates -> top-k per query.

    ``ids`` is (1, Np) int32, ``mask`` (Qp, Np) int32; all shapes
    pre-padded (Qp % 8 == Np % 128 == Dp % 128 == 0, sentinel/zero in the
    padding).  Returns ``(dists, winners)`` of shape (Qp, K_PAD) — see
    :func:`repro.kernels.ref.ivf_topk_ref` for the exact selection
    semantics the kernel reproduces bit-identically.
    """
    qp, dp = queries.shape
    np_, _ = cands.shape
    assert qp % QUERY_TILE == 0 and dp % 128 == 0 and np_ % 128 == 0
    assert 1 <= k <= K_PAD
    n_tiles = qp // QUERY_TILE
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((QUERY_TILE, dp), lambda i: (i, 0)),
            pl.BlockSpec((np_, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, np_), lambda i: (0, 0)),
            pl.BlockSpec((QUERY_TILE, np_), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_TILE, K_PAD), lambda i: (i, 0)),
            pl.BlockSpec((QUERY_TILE, K_PAD), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qp, K_PAD), jnp.float32),
            jax.ShapeDtypeStruct((qp, K_PAD), jnp.int32),
        ],
        interpret=interpret,
    )(queries, cands, ids, mask)
