"""jax API compatibility: the repo targets the jax>=0.6 surface
(``jax.shard_map``, ``jax.set_mesh``, ``check_vma``); older 0.4.x releases
spell these ``jax.experimental.shard_map.shard_map`` / ``check_rep`` and
have no ambient-mesh setter.  Import from here instead of feature-testing
at every call site."""

from __future__ import annotations

import contextlib

import jax

__all__ = ["shard_map", "set_mesh", "axis_size"]


if hasattr(jax.lax, "axis_size"):
    def axis_size(name) -> int:
        return jax.lax.axis_size(name)
else:
    def axis_size(name) -> int:
        # on 0.4.x, psum of a python scalar constant-folds to a static int
        # inside shard_map, so it is usable in shape computations
        return jax.lax.psum(1, name)


if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):
    set_mesh = jax.sharding.use_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh):
        # pre-ambient-mesh jax: shard_map / jit carry the mesh explicitly,
        # so there is nothing to install
        yield
