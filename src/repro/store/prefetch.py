"""Sequential / strided readahead policy for the scan path.

Watches the stream of coalesced extents a batch dispatches and, once it sees
``min_run`` consecutive reads advancing forward by a (near-)constant step,
asks the scheduler to pull the next ``window_bytes`` into the cache ahead of
demand.  Readahead never re-requests a region it already covered
(``_ra_until`` high-water mark), so a steady scan issues one window-sized
backing read per window instead of one per logical read.
"""

from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["SequentialReadahead"]


class SequentialReadahead:
    def __init__(self, window_bytes: int = 1 << 20, min_run: int = 2,
                 max_gap: int = 1 << 16):
        if window_bytes <= 0:
            raise ValueError("window_bytes must be positive")
        self.window_bytes = int(window_bytes)
        self.min_run = int(min_run)
        self.max_gap = int(max_gap)
        self.reset()

    def reset(self) -> None:
        self._last_lo: Optional[int] = None
        self._last_end: Optional[int] = None
        self._stride: Optional[int] = None
        self._run = 0
        self._ra_until = 0

    def observe(self, lo: int, hi: int) -> Optional[Tuple[int, int]]:
        """Feed one demand extent; returns a (lo, hi) region to prefetch, or
        None if the pattern is not (yet) sequential/strided."""
        lo, hi = int(lo), int(hi)
        seq = (
            self._last_end is not None
            and 0 <= lo - self._last_end <= self.max_gap
        )
        stride = lo - self._last_lo if self._last_lo is not None else None
        strided = (
            stride is not None and stride > 0 and stride == self._stride
        )
        if seq or strided:
            self._run += 1
        else:
            self._run = 1
            self._ra_until = 0
        self._stride = stride
        self._last_lo, self._last_end = lo, hi
        if self._run < self.min_run:
            return None
        start = max(hi, self._ra_until)
        end = hi + self.window_bytes
        if start >= end:
            return None  # window already covered by an earlier prefetch
        self._ra_until = end
        return start, end
