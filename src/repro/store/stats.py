"""Unified per-tier IO accounting for the tiered store.

Every tier (the backing device and each cache level) carries one
:class:`TierStats`: dispatched IOPS and bytes (sector-aligned, i.e. what the
device actually serves), block-granular cache hit/miss/eviction counters, and
per-phase op counts so queue-depth-limited round trips can be priced.

The write path (PR 5) adds the ingest-side counters: ``write_iops`` /
``bytes_written`` are dispatched device writes (absorbed dirty extents on a
cache tier, write-through or flush extents on the backing tier);
``flush_iops`` / ``flush_bytes`` are the subset issued by the flusher;
``dirty_bytes`` is the tier's resident not-yet-durable footprint (folded in
from the cache at query time); ``lost_bytes`` counts dirty bytes a simulated
crash discarded — the durability side of the write-back latency trade.

This replaces the ad-hoc accounting that used to live in benchmark call
sites: ``model_time`` here is the same first-order device model as
:func:`repro.core.io_sim.model_time`, extended with a queue-depth term —
a phase with more outstanding requests than the device queue can hold pays
one round-trip latency per queue drain, not one per phase.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..core.io_sim import DeviceModel

__all__ = ["TierStats", "DrainRecord"]


@dataclasses.dataclass
class DrainRecord:
    """One completed queue drain across the whole store.

    Appended by ``TieredStore.end_batch``: ``tiers`` maps tier index
    (fastest level first, backing device last — the ``tier_stats()`` order)
    to the ``(phase_ops, phase_bytes)`` buckets that drain archived.
    ``n_requests`` is the logical request count the batch carried (rows of a
    ``take``; 0 for scans/flushes) — the denominator per-request latency
    attribution (:mod:`repro.obs.attrib`) divides each drain's cost by.
    """

    label: str
    n_requests: int
    tiers: Dict[int, Tuple[Dict[int, int], Dict[int, int]]]


@dataclasses.dataclass
class TierStats:
    """Dispatched-IO counters for one storage tier.

    Dependency round trips are tracked **per batch**: each ``take``/``scan``
    is its own queue drain, so two sequential batches pay two sets of phase
    latencies even though their ops share phase numbers.  ``phase_ops`` is
    the open batch; :meth:`end_batch` archives it into ``batch_phases``.
    """

    name: str
    n_iops: int = 0          # dispatched device requests (incl. prefetch)
    bytes_read: int = 0      # sector-aligned bytes served (incl. prefetch)
    hits: int = 0            # block lookups served by this tier's cache
    misses: int = 0          # block lookups that fell through this tier
    evictions: int = 0       # blocks evicted from this tier's cache
    prefetch_iops: int = 0   # subset of n_iops issued by readahead
    prefetch_bytes: int = 0  # subset of bytes_read issued by readahead
    write_iops: int = 0      # dispatched device write requests
    bytes_written: int = 0   # sector-aligned bytes written to this tier
    flush_iops: int = 0      # subset of write_iops issued by the flusher
    flush_bytes: int = 0     # subset of bytes_written issued by the flusher
    rmw_iops: int = 0        # read-modify-write merge reads (sub-sector
                             # write edges not resident in any cache tier);
                             # subset of n_iops — see TieredStore.price_rmw
    rmw_bytes: int = 0       # subset of bytes_read issued by RMW merges
    dirty_bytes: int = 0     # resident dirty bytes (folded in at query time)
    lost_bytes: int = 0      # dirty bytes discarded by a simulated crash
    max_phase: int = 0       # deepest dependency phase seen (+1)
    phase_ops: Dict[int, int] = dataclasses.field(default_factory=dict)
    phase_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    batch_phases: List[Dict[int, int]] = dataclasses.field(default_factory=list)

    @property
    def hit_rate(self) -> Optional[float]:
        """Block-lookup hit rate, or ``None`` before any lookup — never NaN
        (NaN here used to leak non-standard tokens into BENCH_*.json)."""
        n = self.hits + self.misses
        return self.hits / n if n else None

    def add_op(self, nbytes: int, phase: int, prefetch: bool = False) -> None:
        self.n_iops += 1
        self.bytes_read += int(nbytes)
        self.phase_ops[int(phase)] = self.phase_ops.get(int(phase), 0) + 1
        self.phase_bytes[int(phase)] = (
            self.phase_bytes.get(int(phase), 0) + int(nbytes))
        self.max_phase = max(self.max_phase, int(phase) + 1)
        if prefetch:
            self.prefetch_iops += 1
            self.prefetch_bytes += int(nbytes)

    def add_write_op(self, nbytes: int, phase: int, flush: bool = False) -> None:
        """One dispatched device *write* (an absorbed dirty extent on a cache
        tier, a write-through or flush extent on the backing tier).  Writes
        share the per-phase op buckets with reads, so a drain's round-trip
        pricing covers both directions of traffic."""
        self.write_iops += 1
        self.bytes_written += int(nbytes)
        self.phase_ops[int(phase)] = self.phase_ops.get(int(phase), 0) + 1
        self.phase_bytes[int(phase)] = (
            self.phase_bytes.get(int(phase), 0) + int(nbytes))
        self.max_phase = max(self.max_phase, int(phase) + 1)
        if flush:
            self.flush_iops += 1
            self.flush_bytes += int(nbytes)

    def end_batch(self) -> Optional[Tuple[Dict[int, int], Dict[int, int]]]:
        """Close the open batch: its phases become one archived queue drain.
        Returns the drained ``(phase_ops, phase_bytes)`` buckets (``None`` if
        the batch touched nothing on this tier) so the store can log the
        drain for per-request attribution."""
        if self.phase_ops:
            drained = (self.phase_ops, self.phase_bytes)
            self.batch_phases.append(self.phase_ops)
            self.phase_ops = {}
            self.phase_bytes = {}
            return drained
        return None

    def model_time(self, dev: DeviceModel, queue_depth: int = 256) -> float:
        """Price this tier's dispatched trace on ``dev``: throughput-limited
        term plus queue-depth-limited dependency round trips, one drain per
        (batch, phase).  Reads and writes share the device's throughput and
        queue (first-order full-duplex-less model, matching the paper's
        Fig-1 single-direction measurements)."""
        total_ops = self.n_iops + self.write_iops
        if total_ops == 0:
            return 0.0
        total_bytes = self.bytes_read + self.bytes_written
        avg = max(total_bytes / total_ops, 1.0)
        eff = max(avg, dev.min_read)
        iops_limit = min(dev.iops_4k, dev.seq_bw / eff)
        t = max(total_ops / iops_limit, total_bytes / dev.seq_bw)
        qd = max(1, queue_depth)
        for phases in self.batch_phases + [self.phase_ops]:
            for ops in phases.values():
                t += math.ceil(ops / qd) * dev.latency
        return t

    def snapshot(self) -> "TierStats":
        """Detached copy — safe to hold across a later ``reset()``."""
        return dataclasses.replace(
            self, phase_ops=dict(self.phase_ops),
            phase_bytes=dict(self.phase_bytes),
            batch_phases=[dict(p) for p in self.batch_phases],
        )

    def reset(self) -> None:
        self.n_iops = self.bytes_read = 0
        self.hits = self.misses = self.evictions = 0
        self.prefetch_iops = self.prefetch_bytes = 0
        self.write_iops = self.bytes_written = 0
        self.flush_iops = self.flush_bytes = 0
        self.rmw_iops = self.rmw_bytes = 0
        self.dirty_bytes = self.lost_bytes = 0
        self.max_phase = 0
        self.phase_ops = {}
        self.phase_bytes = {}
        self.batch_phases = []
