"""Batched IO scheduler + tiered store: the layer between the structural
encodings and the raw :class:`~repro.core.io_sim.Disk`.

The read path no longer talks to a device directly.  `FileReader` opens a
:class:`ReadBatch` per ``take``/``scan`` and hands it to the encoding
readers; every logical read goes through :meth:`ReadBatch.read`, which serves
bytes synchronously (the data plane is the simulated disk) and records the
request.  When the batch closes, the scheduler:

1. **coalesces** the batch's requests per dependency phase (the paper's
   'issued in N phases'), subsuming the post-hoc merging that used to be
   buried in ``IOTracker.stats``;
2. **aligns** each coalesced extent to device sectors;
3. **classifies** each sector against the cache hierarchy (RAM-hot →
   NVMe-warm → S3-cold) and dispatches per-tier, per-phase ops with
   queue-depth-limited round-trip pricing;
4. optionally runs **readahead** (scan batches) to pull upcoming sectors
   into the cache ahead of demand.

Accounting is two-plane by design: :meth:`IOScheduler.stats` reports the
*logical* trace (identical numbers to the legacy ``IOTracker``, so no
experiment regresses), while :meth:`TieredStore.tier_stats` reports what
each *device* actually served (aligned bytes, hits/misses, prefetch).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.io_sim import (
    DRAM,
    NVME,
    S3,
    DeviceModel,
    Disk,
    IOStats,
    merge_phase_extents,
    trace_stats,
)
from ..obs.timeseries import NULL_PLANE, MetricsPlane
from ..obs.trace import NULL_TRACER
from .cache import BlockCache
from .evloop import (JobCompletion, QoS, RetryPolicy, ServiceWindow,
                     build_job)
from .flush import FlushPolicy
from .prefetch import SequentialReadahead
from .stats import DrainRecord, TierStats
from .workload import WorkloadStats

__all__ = ["CacheTier", "TieredStore", "ReadBatch", "WriteBatch",
           "IOScheduler", "make_store"]

DEFAULT_SECTOR = 4096
DEFAULT_CACHE_BYTES = 64 << 20


class CacheTier:
    """One cache level: a fast device pricing blocks resident in ``cache``."""

    def __init__(self, device: DeviceModel, cache: BlockCache, name: Optional[str] = None):
        self.device = device
        self.cache = cache
        self.stats = TierStats(name or device.name)


class TieredStore:
    """A stack of cache tiers (fastest first) over one backing device.

    The store prices reads; bytes always come from ``disk``.  A block served
    by tier i is admitted into every faster tier (inclusive promotion); a
    block missing everywhere is read from the backing device and admitted
    into all tiers.
    """

    def __init__(
        self,
        disk: Disk,
        backing: DeviceModel = NVME,
        levels: Sequence[CacheTier] = (),
        sector: int = DEFAULT_SECTOR,
    ):
        self.disk = disk
        self.backing = backing
        self.backing_stats = TierStats(backing.name)
        self.levels: List[CacheTier] = list(levels)
        self.sector = int(sector)
        self.flush_policy: Optional[FlushPolicy] = None
        # Observability: drain_log records every completed queue drain (for
        # per-request attribution, always on — it is pure bookkeeping and
        # never feeds back into pricing); tracer is the span sink threaded
        # down from the IOScheduler (NULL_TRACER = disabled, zero-cost).
        self.drain_log: List[DrainRecord] = []
        self.tracer = NULL_TRACER
        # Fault-aware admission: when the *source* tier of a fetch has an
        # open fault window at the current virtual time, the block is
        # served but NOT admitted into faster tiers — brownout traffic is
        # slow-path evidence, not working-set evidence, and admitting it
        # evicts genuinely hot blocks.  ``fault_clock`` is installed by the
        # IOScheduler (window arrival time inside a service window, the
        # virtual clock otherwise); ``None`` means no clock — admission is
        # gated only when a device actually carries faults, so stores whose
        # devices are healthy (every committed baseline) are bit-identical.
        self.fault_clock = None
        self.admission_fault_skips = 0
        for lvl in self.levels:
            if lvl.cache.block_bytes != self.sector:
                raise ValueError("cache block size must equal the store sector")

    def _admission_gated(self, source: DeviceModel) -> bool:
        """True when ``source`` is inside a fault window right now (skip
        admission).  Zero-cost on healthy devices: the faults tuple is
        empty and the clock is never consulted."""
        if not source.faults:
            return False
        t = self.fault_clock() if self.fault_clock is not None else 0.0
        if source.fault_active_at(t):
            self.admission_fault_skips += 1
            return True
        return False

    # -- constructors -------------------------------------------------------
    @classmethod
    def flat(cls, disk: Disk, device: DeviceModel = NVME,
             sector: int = DEFAULT_SECTOR) -> "TieredStore":
        """Single-tier store: every read priced on ``device`` (the seed
        repo's behaviour)."""
        return cls(disk, backing=device, levels=(), sector=sector)

    @classmethod
    def cached(
        cls,
        disk: Disk,
        backing: DeviceModel = S3,
        cache_device: DeviceModel = NVME,
        cache_bytes: int = DEFAULT_CACHE_BYTES,
        sector: int = DEFAULT_SECTOR,
        policy: str = "clock",
        admission: str = "always",
        cache: Optional[BlockCache] = None,
    ) -> "TieredStore":
        """The paper's deployment shape: an NVMe block cache over S3.

        Pass an existing ``cache`` to share one block cache (one NVMe
        budget) across several stores — valid only when the stores price
        reads over the same address space (the same :class:`Disk`, or a
        dataset's concatenated global disk), since block ids are plain
        sector numbers."""
        if cache is None:
            cache = BlockCache(cache_bytes, block_bytes=sector, policy=policy,
                               admission=admission)
        return cls(disk, backing=backing,
                   levels=(CacheTier(cache_device, cache),), sector=sector)

    @classmethod
    def hot(
        cls,
        disk: Disk,
        backing: DeviceModel = S3,
        ram_bytes: int = 8 << 20,
        nvme_bytes: int = DEFAULT_CACHE_BYTES,
        sector: int = DEFAULT_SECTOR,
    ) -> "TieredStore":
        """Three tiers: RAM-hot over NVMe-warm over S3-cold."""
        ram = BlockCache(ram_bytes, block_bytes=sector, policy="lru")
        nvme = BlockCache(nvme_bytes, block_bytes=sector, policy="clock")
        return cls(disk, backing=backing,
                   levels=(CacheTier(DRAM, ram), CacheTier(NVME, nvme)),
                   sector=sector)

    # -- dispatch ------------------------------------------------------------
    def dispatch_extent(self, lo: int, hi: int, phase: int,
                        prefetch: bool = False) -> None:
        """Price one coalesced extent: sector-align, classify each block
        against the hierarchy, dispatch contiguous same-tier runs."""
        lo, hi = int(lo), int(hi)
        if hi <= lo:
            return
        b0 = lo // self.sector
        b1 = (hi + self.sector - 1) // self.sector
        if not self.levels:
            self.backing_stats.add_op((b1 - b0) * self.sector, phase, prefetch)
            return
        # classify each block: index into levels, or len(levels) for backing
        run_tier: Optional[int] = None
        run_blocks = 0

        def flush() -> None:
            if run_blocks == 0:
                return
            nbytes = run_blocks * self.sector
            if run_tier == len(self.levels):
                self.backing_stats.add_op(nbytes, phase, prefetch)
            else:
                self.levels[run_tier].stats.add_op(nbytes, phase, prefetch)

        for bid in range(b0, b1):
            if prefetch:
                # readahead only fills holes; resident blocks are skipped
                # without touching hit/miss counters, and a fill is billed
                # to the backing tier only if the admission policy actually
                # kept it (the scheduler consults admission before issuing)
                if any(bid in lvl.cache for lvl in self.levels):
                    tier = None
                elif self._admission_gated(self.backing):
                    # a browned-out backing tier gets no speculative fills
                    tier = None
                else:
                    resident = False
                    for lvl in self.levels:
                        resident |= lvl.cache.admit(bid)
                    tier = len(self.levels) if resident else None
            else:
                tier = len(self.levels)
                for li, lvl in enumerate(self.levels):
                    if lvl.cache.lookup(bid):
                        tier = li
                        break
                # fill every tier faster than the one that served (on a
                # backing miss that is all of them) — unless the serving
                # tier is inside a fault window (fault-aware admission)
                source = self.levels[tier].device if tier < len(self.levels) \
                    else self.backing
                if tier > 0 and not self._admission_gated(source):
                    for li in range(min(tier, len(self.levels))):
                        self.levels[li].cache.admit(bid)
            if tier != run_tier:
                flush()
                run_tier, run_blocks = tier, 0
            if tier is not None:
                run_blocks += 1
        flush()

    def end_batch(self, label: str = "io", n_requests: int = 0) -> None:
        """Archive every tier's open batch as one completed queue drain and
        log which (tier, phase) buckets it drained — the substrate
        :func:`repro.obs.attribute` decomposes ``model_time`` over.
        ``n_requests`` is the logical request count the batch carried (rows
        of a ``take``); 0 means "unattributed" (scans, flushes)."""
        tiers: Dict[int, Tuple[Dict[int, int], Dict[int, int]]] = {}
        for idx, lvl in enumerate(self.levels):
            drained = lvl.stats.end_batch()
            if drained is not None:
                tiers[idx] = drained
        drained = self.backing_stats.end_batch()
        if drained is not None:
            tiers[len(self.levels)] = drained
        if tiers:
            self.drain_log.append(DrainRecord(label, int(n_requests), tiers))

    # -- write path ----------------------------------------------------------
    def set_flush_policy(self, policy: Optional[FlushPolicy]) -> None:
        """Attach the write-path policy (see :mod:`repro.store.flush`) and
        wire the fastest tier's eviction hook so dirty victims are written
        back before their slot is reused (flush-on-evict, always on)."""
        self.flush_policy = policy
        if self.levels:
            if policy is None:
                self.levels[0].cache.on_evict = None
            else:
                self.levels[0].cache.on_evict = (
                    lambda bid, dirty: policy.on_evict(self, bid, dirty))

    def dispatch_write_extent(self, lo: int, hi: int, phase: int = 0,
                              flush: bool = False) -> None:
        """Price one sector-aligned write on the backing device and fill the
        written blocks clean into the cache tiers (a write-through fill:
        subsequent reads are warm; the fill bypasses the admission filter —
        admission polices *reads*, and these are the writer's own freshest
        bytes).  The flush path skips the fill (its blocks are already
        resident dirty)."""
        lo, hi = int(lo), int(hi)
        if hi <= lo:
            return
        b0 = lo // self.sector
        b1 = (hi + self.sector - 1) // self.sector
        if not flush:
            self.price_rmw(lo, hi, phase)
        self.backing_stats.add_write_op((b1 - b0) * self.sector, phase, flush)
        if not flush:
            for bid in range(b0, b1):
                for lvl in self.levels:
                    lvl.cache.fill(bid)

    def price_rmw(self, lo: int, hi: int, phase: int = 0) -> None:
        """Sub-sector write edges pay read-modify-write.

        A write extent that starts or ends mid-sector shares its edge
        sector with bytes already on media (the previous append's tail in
        the 8-aligned append-only layout); a sector-granular device cannot
        write part of a sector, so the merge needs the rest of the sector
        first.  If the edge block is resident in any cache tier (clean or
        dirty) the merge happens in cache for free — that is exactly why
        write-through fills and write-back dirty residency suppress repeat
        RMW on a hot append point.  Otherwise one sector-sized read is
        priced on the backing tier (it is a miss everywhere) and counted in
        ``rmw_iops``/``rmw_bytes``.  The read lands in the same phase
        bucket as the write it unblocks, so drains, ``model_time``,
        attribution and the event loop all see it; the *logical* trace
        never does — RMW is a device artifact, not a request."""
        lo, hi = int(lo), int(hi)
        edges = []
        if lo % self.sector:
            edges.append(lo // self.sector)
        if hi % self.sector and hi < len(self.disk):
            bid = hi // self.sector
            if bid not in edges:
                edges.append(bid)
        for bid in edges:
            if any(bid in lvl.cache for lvl in self.levels):
                continue
            self.backing_stats.add_op(self.sector, phase)
            self.backing_stats.rmw_iops += 1
            self.backing_stats.rmw_bytes += self.sector

    def flush_all(self) -> int:
        """Commit barrier: make every dirty block durable (no-op without a
        write-back policy)."""
        if self.flush_policy is None:
            return 0
        return self.flush_policy.flush_all(self)

    def dirty_extents(self) -> List[Tuple[int, int]]:
        """Contiguous byte extents of the not-yet-durable blocks."""
        out: List[Tuple[int, int]] = []
        for lvl in self.levels:
            blocks = lvl.cache.dirty_blocks
            if not blocks:
                continue
            run_lo = prev = blocks[0]
            for b in blocks[1:]:
                if b != prev + 1:
                    out.append((run_lo * self.sector, (prev + 1) * self.sector))
                    run_lo = b
                prev = b
            out.append((run_lo * self.sector, (prev + 1) * self.sector))
        return out

    def discard_dirty(self) -> List[Tuple[int, int]]:
        """Simulated crash: every dirty block's unflushed bytes are lost.
        Drops the blocks from the cache (their contents are no longer
        trustworthy), counts ``lost_bytes`` per tier, clears flush-policy
        state, and returns the lost byte extents so the caller can tear the
        corresponding media ranges."""
        extents = self.dirty_extents()
        for lvl in self.levels:
            blocks = lvl.cache.dirty_blocks
            lvl.stats.lost_bytes += len(blocks) * self.sector
            for bid in blocks:
                lvl.cache.invalidate(bid)
                if self.flush_policy is not None:
                    self.flush_policy.drop_block(bid)
        return extents

    # -- reporting -----------------------------------------------------------
    def tier_stats(self) -> List[TierStats]:
        """Per-tier stats, fastest first, backing device last.  Cache
        hit/miss/eviction counters are folded in from each level's cache.
        Returns detached snapshots — safe to hold across a later reset."""
        out: List[TierStats] = []
        for lvl in self.levels:
            s = lvl.stats
            s.hits = lvl.cache.hits
            s.misses = lvl.cache.misses
            s.evictions = lvl.cache.evictions
            s.dirty_bytes = lvl.cache.dirty_bytes
            out.append(s.snapshot())
        out.append(self.backing_stats.snapshot())
        return out

    def model_time(self, queue_depth: int = 256) -> float:
        """Modelled wall time: each tier serves its share; tiers on the miss
        path are serial, so the total is the sum of per-tier times."""
        t = self.backing_stats.model_time(self.backing, queue_depth)
        for lvl in self.levels:
            t += lvl.stats.model_time(lvl.device, queue_depth)
        return t

    def reset_stats(self) -> None:
        """Zero all counters; cache *contents* survive (warm tiers stay
        warm — resetting residency is :meth:`drop_caches`)."""
        self.backing_stats.reset()
        for lvl in self.levels:
            lvl.stats.reset()
            lvl.cache.reset_stats()
        self.drain_log = []
        self.admission_fault_skips = 0

    def drop_caches(self) -> None:
        for lvl in self.levels:
            lvl.cache.drop()


class ReadBatch:
    """Handle for one ``take``/``scan``'s reads.  Serves bytes synchronously
    and records the logical trace; dispatch happens when the batch closes."""

    def __init__(self, scheduler: "IOScheduler", label: str = "io",
                 prefetch: bool = False):
        self.scheduler = scheduler
        self.label = label
        self.prefetch = prefetch
        self.request: Optional[str] = None  # stamped by IOScheduler.batch
        self.ops: List[Tuple[int, int, int]] = []
        self._useful = 0
        self.n_requests = 0
        self._closed = False

    @property
    def tracer(self):
        """The IO path's tracer — encoding readers reach it through the
        batch handle to emit decode-route (pallas fallback) events."""
        return self.scheduler.tracer

    def read(self, offset: int, size: int, phase: int = 0) -> np.ndarray:
        if self._closed:
            raise RuntimeError("read on a closed ReadBatch")
        offset, size = int(offset), int(size)
        self.ops.append((offset, size, phase))
        return self.scheduler.store.disk.read(offset, size)

    def read_many(self, offsets, sizes, phase: int = 0):
        """Submit one phase-grouped batch of spans in a single dispatch.

        Records one logical op per span (accounting identical to N
        :meth:`read` calls) but serves all bytes with one vectorized gather.
        Returns ``(data, out_offsets)``: span ``k`` is
        ``data[out_offsets[k]:out_offsets[k + 1]]``.  This is the batched
        ``take`` pipeline's entry point — cross-row coalescing happens once
        per phase at batch close instead of N times.
        """
        if self._closed:
            raise RuntimeError("read on a closed ReadBatch")
        offsets = np.asarray(offsets, dtype=np.int64)
        sizes = np.asarray(sizes, dtype=np.int64)
        phase = int(phase)
        self.ops.extend(
            (o, s, phase) for o, s in zip(offsets.tolist(), sizes.tolist())
        )
        return self.scheduler.store.disk.read_gather(offsets, sizes)

    def note_useful(self, nbytes: int) -> None:
        self._useful += int(nbytes)

    def note_requests(self, n: int) -> None:
        """Declare how many logical requests (rows) this batch serves; the
        drain's modeled cost is attributed across them
        (:func:`repro.obs.attribute`).  Purely observational — never feeds
        back into coalescing or pricing."""
        self.n_requests += int(n)

    def at(self, base: int):
        """A view of this batch translated by ``base`` bytes.

        Encoding readers always issue file-local offsets; when several files
        share one scheduler (``repro.dataset``) each file's reads are
        rebased into the dataset's global address space through this view,
        so spans from different files coalesce in the same per-phase pass
        and hit the same cache block ids."""
        return self if not base else _OffsetBatch(self, int(base))

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.scheduler._finish(self)

    def __enter__(self) -> "ReadBatch":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _OffsetBatch:
    """Thin rebasing proxy over a :class:`ReadBatch` (see its ``at``)."""

    __slots__ = ("_batch", "base")

    def __init__(self, batch, base: int):
        self._batch = batch
        self.base = base

    def read(self, offset: int, size: int, phase: int = 0) -> np.ndarray:
        return self._batch.read(self.base + int(offset), size, phase)

    def read_many(self, offsets, sizes, phase: int = 0):
        offsets = np.asarray(offsets, dtype=np.int64) + self.base
        return self._batch.read_many(offsets, sizes, phase)

    def note_useful(self, nbytes: int) -> None:
        self._batch.note_useful(nbytes)

    def note_requests(self, n: int) -> None:
        self._batch.note_requests(n)

    @property
    def tracer(self):
        return self._batch.tracer

    def at(self, base: int):
        return self._batch.at(self.base + int(base))


class WriteBatch:
    """Handle for one append/ingest operation's writes.  Mirrors
    :class:`ReadBatch`: bytes land on the simulated disk synchronously (the
    data plane), accounting and durability are decided when the batch closes
    — the scheduler coalesces the write extents per phase and hands them to
    the store's :class:`~repro.store.FlushPolicy` (write-through dispatch or
    dirty absorption; no policy attached behaves as write-through)."""

    def __init__(self, scheduler: "IOScheduler", label: str = "write"):
        self.scheduler = scheduler
        self.label = label
        self.request: Optional[str] = None  # stamped by write_batch
        self.ops: List[Tuple[int, int, int]] = []
        self._closed = False

    def write(self, offset: int, data, phase: int = 0) -> None:
        if self._closed:
            raise RuntimeError("write on a closed WriteBatch")
        offset = int(offset)
        self.scheduler.store.disk.write(offset, data)
        self.ops.append((offset, len(data), phase))

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.scheduler._finish_write(self)

    def __enter__(self) -> "WriteBatch":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class IOScheduler:
    """Accepts whole read batches, coalesces per phase, dispatches through
    the tiered store, and keeps the legacy logical-trace accounting."""

    def __init__(
        self,
        store: TieredStore,
        queue_depth: int = 256,
        readahead: Union[str, None, SequentialReadahead] = "auto",
        tracer=None,
        queue_depths: Optional[Dict[str, int]] = None,
        plane: MetricsPlane = NULL_PLANE,
        retry_policy: Optional[RetryPolicy] = RetryPolicy(),
    ):
        self.store = store
        self.queue_depth = int(queue_depth)
        # per-device-name depth overrides (e.g. {"nvme": 64, "s3": 8});
        # unnamed devices fall back to the shared queue_depth.  Used by
        # serial pricing here and inherited by ServiceWindow.run().
        self.queue_depths = dict(queue_depths) if queue_depths else None
        # Recovery policy inherited by ServiceWindow.run(): compiled in by
        # default, but only ever consulted on tiers whose fault schedule
        # can fail ops, so healthy-path pricing stays bit-identical.
        self.retry_policy = retry_policy
        # live metrics plane: store-side gauges (cache hit rate, dirty
        # bytes, admission state) sampled at batch close on the virtual
        # clock.  NULL_PLANE (the default) collects nothing.
        self.plane = plane if plane is not None else NULL_PLANE
        if readahead == "auto":
            readahead = SequentialReadahead() if store.levels else None
        self.readahead = readahead or None
        # One tracer per IO path: passing one here threads it through the
        # store (flush-policy spans) and every reader sharing this
        # scheduler.  Default is the store's (NULL_TRACER unless set) so
        # injected-scheduler readers inherit the path's tracer.
        if tracer is not None:
            store.tracer = tracer
        self.tracer = store.tracer
        self.workload = WorkloadStats()
        self.ops: List[Tuple[int, int, int]] = []
        self.write_ops: List[Tuple[int, int, int]] = []
        self._useful = 0
        self.n_batches = 0
        self.n_write_batches = 0
        # Event-loop serving plane (pure timing overlay — never feeds back
        # into classification or pricing).  Outside a service window every
        # drain completes immediately at its serial price on the virtual
        # clock; inside one, drains become Jobs the window simulates.
        self.vclock = 0.0
        self.completions: List[JobCompletion] = []
        self._window: Optional[ServiceWindow] = None
        self._request_seq = 0
        self._job_seq = 0
        # fault-aware admission reads the serving plane's notion of "now":
        # the current request's arrival inside a service window, the
        # virtual clock outside one
        store.fault_clock = self._fault_now

    def _fault_now(self) -> float:
        win = self._window
        if win is not None and getattr(win, "_arrival", None) is not None:
            return win._arrival
        return self.vclock

    def batch(self, label: str = "io", prefetch: bool = False) -> ReadBatch:
        rb = ReadBatch(self, label, prefetch=prefetch)
        self._request_seq += 1
        rb.request = f"{label}#{self._request_seq}"
        win = self._window
        if win is not None and win._cur is not None and win._cur.request:
            rb.request = win._cur.request
        return rb

    def write_batch(self, label: str = "write") -> WriteBatch:
        wb = WriteBatch(self, label)
        self._request_seq += 1
        wb.request = f"{label}#{self._request_seq}"
        return wb

    def service_window(self, qos: Optional[QoS] = None) -> ServiceWindow:
        """Open a multi-request serving window: drains completed inside it
        are captured as event-loop jobs (tagged per request via
        ``window.request(tenant=..., at=...)``) and priced together by
        ``window.run("interleaved")`` / ``run("serial")`` — the same
        executed workload under both dispatch models."""
        return ServiceWindow(self, qos)

    def _devices(self) -> List[DeviceModel]:
        """Tier devices in drain-record index order (levels, then backing)."""
        return [lvl.device for lvl in self.store.levels] + [self.store.backing]

    def flush_barrier(self) -> int:
        """Commit-barrier flush routed through the serving plane.

        ``TieredStore.flush_all`` records its drains but runs outside any
        batch close, so calling it directly would leave the barrier's write
        runs invisible to the virtual clock and to an open service window.
        This wrapper lifts them like every other drain — inside a window
        the flush becomes one more job sharing the device queues with the
        in-flight reads, which is exactly the read/flush interleaving the
        event loop prices."""
        n0 = len(self.store.drain_log)
        n = self.store.flush_all()
        self._ingest_drains(n0, request="flush:barrier")
        return n

    def _ingest_drains(self, n0: int, request: Optional[str] = None) -> None:
        """Lift every drain the closing batch appended (its own, plus any
        flush drains its close triggered) into the serving plane."""
        log = self.store.drain_log
        if len(log) <= n0:
            return
        win = self._window
        for rec in log[n0:]:
            self._job_seq += 1
            job = build_job(rec, self._devices(), request=request,
                            seq=self._job_seq, submit=self.vclock)
            if win is not None:
                win._submit(job)
            else:
                done = self.vclock + job.serial_time(self.queue_depth,
                                                     self.queue_depths)
                self.completions.append(JobCompletion(
                    rec.label, job.tenant, request, rec.n_requests,
                    self.vclock, done))
                self.vclock = done

    def _finish_write(self, batch: WriteBatch) -> None:
        tr = self.tracer
        n0 = len(self.store.drain_log)
        # every batch gets its own Perfetto track so concurrent requests
        # render as separate lanes instead of one flat span stream
        tid = tr.track(batch.request) if tr.enabled else None
        with tr.span(f"write:{batch.label}", cat="scheduler", tid=tid,
                     n_ops=len(batch.ops), request=batch.request,
                     bytes=sum(sz for _, sz, _ in batch.ops)):
            self.write_ops.extend(batch.ops)
            self.n_write_batches += 1
            extents = merge_phase_extents(batch.ops, gap=0)
            policy = self.store.flush_policy
            if policy is None:
                # unattached stores behave write-through: durable at batch
                # close
                with tr.span("dispatch:write-through", cat="scheduler",
                             tid=tid):
                    for phase in sorted(extents):
                        for lo, hi in extents[phase]:
                            self.store.dispatch_write_extent(lo, hi, phase)
            else:
                with tr.span("absorb", cat="flush", tid=tid):
                    policy.absorb(self.store, extents)
            self.store.end_batch(batch.label)
            if policy is not None:
                policy.on_batch_end(self.store)
            self._ingest_drains(n0, request=batch.request)
        if tr.enabled:
            self._sample_counters()
        if self.plane.enabled:
            self._sample_plane()

    def _finish(self, batch: ReadBatch) -> None:
        tr = self.tracer
        n0 = len(self.store.drain_log)
        logical_bytes = sum(sz for _, sz, _ in batch.ops)
        # per-request track id: concurrent takers get separate Perfetto
        # lanes (the request id is also stamped into args for filtering)
        tid = tr.track(batch.request) if tr.enabled else None
        with tr.span(f"drain:{batch.label}", cat="scheduler", tid=tid,
                     n_ops=len(batch.ops), bytes=logical_bytes,
                     n_requests=batch.n_requests, prefetch=batch.prefetch,
                     request=batch.request):
            self.ops.extend(batch.ops)
            self._useful += batch._useful
            self.n_batches += 1
            # Admission auto-select: fold this batch into the scan/take mix
            # and re-point any auto cache level *before* the batch
            # dispatches, so a scan arriving at a take-warmed cache is
            # already policed.
            self.workload.note_batch(batch.label, batch.prefetch,
                                     len(batch.ops), logical_bytes)
            policy = self.workload.preferred_admission()
            for lvl in self.store.levels:
                if lvl.cache.admission == "auto":
                    before = lvl.cache.active_admission
                    lvl.cache.set_active_admission(policy)
                    if tr.enabled and lvl.cache.active_admission != before:
                        tr.instant("admission_flip", cat="cache",
                                   tier=lvl.stats.name, to=policy,
                                   flips=lvl.cache.admission_flips)
            # Readahead watches the *raw request stream in arrival order* —
            # what a streaming scheduler sees as the reader issues its
            # chunks — and its fills land in the cache ahead of the demand
            # drain, so the demand extents below hit the warm tier instead
            # of the backing one.
            if (batch.prefetch and self.readahead is not None
                    and self.store.levels):
                with tr.span("readahead", cat="scheduler", tid=tid):
                    disk_len = len(self.store.disk)
                    for o, sz, p in batch.ops:
                        if sz <= 0:
                            continue
                        pf = self.readahead.observe(o, o + sz)
                        if pf is not None:
                            plo, phi = pf[0], min(pf[1], disk_len)
                            if phi > plo:
                                self.store.dispatch_extent(plo, phi, p,
                                                           prefetch=True)
            with tr.span("coalesce", cat="scheduler", tid=tid) as csp:
                extents = merge_phase_extents(batch.ops, gap=0)
                csp.set(n_phases=len(extents),
                        n_extents=sum(len(v) for v in extents.values()))
            for phase in sorted(extents):
                with tr.span(f"dispatch:p{phase}", cat="scheduler", tid=tid,
                             n_extents=len(extents[phase])):
                    for lo, hi in extents[phase]:
                        self.store.dispatch_extent(lo, hi, phase)
            # each batch is its own queue drain: later batches pay their own
            # dependency round trips even though phase numbers restart at 0
            self.store.end_batch(batch.label, batch.n_requests)
            # the flush deadline is measured in batches; tick it for read
            # batches too so dirty data ages out under read-heavy mixes
            if self.store.flush_policy is not None:
                self.store.flush_policy.on_batch_end(self.store)
            self._ingest_drains(n0, request=batch.request)
        if tr.enabled:
            self._sample_counters()
        if self.plane.enabled:
            self._sample_plane()

    def _sample_counters(self) -> None:
        """One sample per counter track at batch close (traced runs only)."""
        tr = self.tracer
        for lvl in self.store.levels:
            cache = lvl.cache
            looked = cache.hits + cache.misses
            tr.counter(f"cache:{lvl.stats.name}", {
                "hit_rate": cache.hits / looked if looked else 0.0,
                "dirty_bytes": cache.dirty_bytes,
                "evictions": cache.evictions,
            })
        tr.counter("scheduler", {
            "n_batches": self.n_batches,
            "n_write_batches": self.n_write_batches,
            "drains": len(self.store.drain_log),
        })

    def _sample_plane(self) -> None:
        """Store-side gauges into the live metrics plane at batch close,
        timestamped on the virtual clock (inside an open service window the
        store's vclock does not advance, so the window's latest arrival
        time stands in — the batch closed while that request was being
        served)."""
        win = self._window
        t = win._arrival if win is not None else self.vclock
        plane = self.plane
        for lvl in self.store.levels:
            for key, v in lvl.cache.gauges().items():
                plane.sample(f"cache.{lvl.stats.name}.{key}", t, v)
        plane.sample("scheduler.drains", t, len(self.store.drain_log))

    # -- accounting ----------------------------------------------------------
    def stats(self, coalesce_gap: int = 0) -> IOStats:
        """Logical-trace stats, bit-identical to the legacy ``IOTracker``.
        Reads only — the write trace is :meth:`write_stats`."""
        return trace_stats(self.ops, self._useful, coalesce_gap)

    def write_stats(self, coalesce_gap: int = 0) -> IOStats:
        """Logical *write* trace (ingest side), same accounting shape."""
        return trace_stats(self.write_ops, 0, coalesce_gap)

    def tier_stats(self) -> List[TierStats]:
        return self.store.tier_stats()

    def model_time(self, queue_depth: Optional[int] = None) -> float:
        if queue_depth is None:
            queue_depth = self.queue_depth
        return self.store.model_time(queue_depth)

    def reset(self) -> None:
        self.ops = []
        self.write_ops = []
        self._useful = 0
        self.n_batches = 0
        self.n_write_batches = 0
        self.vclock = 0.0
        self.completions = []
        self._request_seq = 0
        self._job_seq = 0
        self.store.reset_stats()
        self.workload.reset()
        if self.readahead is not None:
            self.readahead.reset()


def make_store(spec, disk: Disk) -> TieredStore:
    """Resolve a store spec: None/'flat' (NVMe, seed behaviour), 'flat-s3'
    (cold object store), 'tiered' (NVMe cache over S3), 'tiered-auto' (same
    with workload-driven admission), 'hot' (RAM over NVMe over S3), a
    callable ``disk -> TieredStore``, or a ready instance (which must have
    been built over the same ``Disk`` so cache block ids stay meaningful —
    sharing one store across readers of the same disk is how they share one
    NVMe budget)."""
    if spec is None or spec == "flat":
        return TieredStore.flat(disk)
    if spec == "flat-s3":
        return TieredStore.flat(disk, device=S3)
    if spec == "tiered":
        return TieredStore.cached(disk)
    if spec == "tiered-auto":
        return TieredStore.cached(disk, admission="auto")
    if spec == "hot":
        return TieredStore.hot(disk)
    if isinstance(spec, TieredStore):
        if spec.disk is not disk:
            raise ValueError("store was built over a different disk")
        return spec
    if callable(spec):
        store = spec(disk)
        if not isinstance(store, TieredStore):
            raise TypeError("store factory must return a TieredStore")
        return store
    raise ValueError(f"unknown store spec {spec!r}")
