"""Flush policies: when written blocks become durable on the backing device.

The read side of the tiered store decides what a read *costs*; this module
is its write-side dual — it decides when a write *persists*.  Every write
batch (``IOScheduler.write_batch``) closes into the store's attached
:class:`FlushPolicy`:

* ``write-through`` — every sector-aligned write extent is dispatched to the
  backing device at batch close (and admitted clean into the cache tiers so
  subsequent reads are NVMe-warm).  Durable immediately; every append pays a
  backing-device queue drain.
* ``write-back`` — extents are absorbed into the fastest cache tier as
  *dirty* blocks (priced as cache-device writes) and flushed to the backing
  device later: when the dirty footprint crosses ``high_watermark`` of the
  cache capacity (drained down to ``low_watermark``, oldest first), when a
  dirty block's age exceeds ``deadline_batches`` scheduler batches, when a
  dirty block is evicted (flush-on-evict, always on), or at an explicit
  :meth:`flush_all` barrier (the dataset writer's commit fence).
* ``flush-on-evict`` — the lazy extreme: dirty blocks persist only on
  eviction or an explicit barrier.  Maximum write coalescing, maximum
  bytes-at-risk.

Flush batches are dispatched **through the same accounting path as reads**:
contiguous dirty runs become sector-aligned backing write ops in
:class:`~repro.store.TierStats` phase buckets, so write-back IOPS are priced
against the same queue-depth model as the read traffic they compete with.

Durability model: dirty = would be lost on crash.  ``TieredStore.
discard_dirty`` simulates the crash (drops dirty residency, counts
``lost_bytes``, returns the lost extents so the dataset writer can tear the
unflushed media bytes).  Tests inject ``fail_after`` to interrupt a flush
after N dispatched extents and prove any prefix of the flush+commit sequence
leaves every committed manifest version readable.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["FlushPolicy", "SimulatedCrash"]

MODES = ("write-through", "write-back", "flush-on-evict")


class SimulatedCrash(RuntimeError):
    """Raised by a fault-injected flush after ``fail_after`` extents; the
    blocks already dispatched are durable, the rest are still dirty."""


class FlushPolicy:
    """Write-path policy attached to a :class:`~repro.store.TieredStore` via
    ``store.set_flush_policy`` (done by :func:`repro.store.make_store` specs
    and the dataset writer)."""

    def __init__(
        self,
        mode: str = "write-back",
        high_watermark: float = 0.5,
        low_watermark: float = 0.25,
        deadline_batches: int = 8,
    ):
        if mode not in MODES:
            raise ValueError(f"unknown flush mode {mode!r} (want one of {MODES})")
        if not 0.0 < high_watermark <= 1.0:
            raise ValueError("high_watermark must be in (0, 1]")
        if not 0.0 <= low_watermark <= high_watermark:
            raise ValueError("low_watermark must be in [0, high_watermark]")
        if deadline_batches <= 0:
            raise ValueError("deadline_batches must be positive")
        self.mode = mode
        self.high_watermark = float(high_watermark)
        self.low_watermark = float(low_watermark)
        self.deadline_batches = int(deadline_batches)
        self._born: Dict[int, int] = {}  # dirty block id -> batch tick
        self._tick = 0
        self.n_flush_events = 0       # watermark/deadline/evict/barrier drains
        self.fail_after: Optional[int] = None  # fault injection (tests)

    # -- ingest ---------------------------------------------------------------
    def absorb(self, store, extents: Dict[int, List[Tuple[int, int]]]) -> None:
        """One closed write batch's per-phase coalesced extents.

        Write-through (also any store without a cache level) dispatches to
        the backing tier immediately; write-back/flush-on-evict absorb the
        blocks dirty into the fastest tier.
        """
        if self.mode == "write-through" or not store.levels:
            for phase in sorted(extents):
                for lo, hi in extents[phase]:
                    store.dispatch_write_extent(lo, hi, phase)
            return
        lvl = store.levels[0]
        sector = store.sector
        for phase in sorted(extents):
            for lo, hi in extents[phase]:
                if hi <= lo:
                    continue
                b0, b1 = lo // sector, (hi + sector - 1) // sector
                # a sub-sector edge whose block is not resident anywhere
                # needs the rest of the sector read from backing before the
                # dirty block is whole (read-modify-write); resident blocks
                # merge in cache for free
                store.price_rmw(lo, hi, phase)
                lvl.stats.add_write_op((b1 - b0) * sector, phase)
                for bid in range(b0, b1):
                    # birth = the clean->dirty transition: a block re-dirtied
                    # while still dirty keeps aging from its first write, but
                    # one whose dirty state was dropped elsewhere (drop_caches)
                    # must not inherit a stale tick and flush prematurely
                    if not lvl.cache.is_dirty(bid):
                        self._born[bid] = self._tick
                    lvl.cache.mark_dirty(bid)

    # -- triggers -------------------------------------------------------------
    def on_evict(self, store, block_id: int, was_dirty: bool) -> None:
        """Cache eviction hook: a dirty victim is written back before its
        slot is reused (one single-block backing write, part of the current
        open drain)."""
        if not was_dirty:
            return
        store.backing_stats.add_write_op(store.sector, phase=0, flush=True)
        self._born.pop(block_id, None)
        self.n_flush_events += 1
        store.tracer.instant("flush_on_evict", cat="flush", block=block_id)

    def on_batch_end(self, store) -> None:
        """Scheduler tick (one per closed read/write batch): age-out dirty
        blocks past the deadline, then enforce the high watermark."""
        self._tick += 1
        if self.mode != "write-back" or not store.levels:
            return
        cache = store.levels[0].cache
        # prune entries whose dirty state was dropped behind our back
        # (drop_caches, invalidate) so _born cannot grow without bound
        stale = [b for b in self._born if not cache.is_dirty(b)]
        for b in stale:
            del self._born[b]
        expired = [b for b, t in self._born.items()
                   if self._tick - t >= self.deadline_batches]
        if expired:
            self.flush(store, expired, reason="deadline")
        cap = cache.capacity_blocks * cache.block_bytes
        if cache.dirty_bytes > self.high_watermark * cap:
            excess = cache.dirty_bytes - int(self.low_watermark * cap)
            oldest = sorted(self._born, key=self._born.get)
            victims = [b for b in oldest if cache.is_dirty(b)]
            self.flush(store, victims[: max(excess // cache.block_bytes, 1)],
                       reason="watermark")

    # -- flushing -------------------------------------------------------------
    def flush(self, store, blocks: Sequence[int],
              reason: str = "barrier") -> int:
        """Write a set of dirty blocks back to the backing device: contiguous
        runs become one sector-aligned backing write op each, dispatched into
        the store's open drain and closed as one queue drain.  ``reason``
        names the trigger (``deadline``/``watermark``/``barrier``) — it
        labels the drain record and the trace span, so flush stalls are
        attributable.  Returns the number of blocks made durable.
        ``fail_after`` (fault injection) crashes the flush after that many
        dispatched extents."""
        blocks = sorted(b for b in blocks)
        if not blocks:
            return 0
        label = f"flush:{reason}"
        cache = store.levels[0].cache if store.levels else None
        sector = store.sector
        runs: List[Tuple[int, int]] = []
        run_lo = prev = blocks[0]
        for b in blocks[1:]:
            if b != prev + 1:
                runs.append((run_lo, prev + 1))
                run_lo = b
            prev = b
        runs.append((run_lo, prev + 1))
        done = 0
        with store.tracer.span(label, cat="flush", n_blocks=len(blocks),
                               n_runs=len(runs)):
            for i, (b0, b1) in enumerate(runs):
                if self.fail_after is not None and i >= self.fail_after:
                    store.end_batch(label)
                    raise SimulatedCrash(
                        f"flush interrupted after {i} of {len(runs)} extents")
                store.backing_stats.add_write_op((b1 - b0) * sector, phase=0,
                                                 flush=True)
                for bid in range(b0, b1):
                    if cache is not None:
                        cache.clean(bid)
                    self._born.pop(bid, None)
                    done += 1
            store.end_batch(label)  # a flush is its own queue drain
        self.n_flush_events += 1
        return done

    def flush_all(self, store) -> int:
        """The commit barrier: make every dirty block durable now."""
        if not store.levels:
            return 0
        return self.flush(store, store.levels[0].cache.dirty_blocks,
                          reason="barrier")

    def drop_block(self, block_id: int) -> None:
        """Forget policy state for a discarded (crashed/invalidated) block."""
        self._born.pop(block_id, None)
