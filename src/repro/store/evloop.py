"""Event-loop dispatch simulation over the tiered store's drain log.

The scheduler's accounting model (PR 6) archives every completed queue drain
as a :class:`~repro.store.stats.DrainRecord`: per tier, per dependency phase,
the op and byte buckets the drain moved.  Until now those drains were priced
*serially* — each batch paid its full queue-depth-limited round-trip cost
before the next batch started, so flushes stalled between read batches and
concurrent takers queued end to end.  This module replaces that timing model
with an event-loop simulation while leaving the accounting plane untouched:

* every drain record becomes a :class:`Job` — an ordered chain of per
  (phase, tier) *units*, each carrying its latency rounds (``ceil(ops/qd)``
  round trips) and its share of the tier's throughput-pipe time;
* each tier keeps an **outstanding-request table** bounded by the queue
  depth: when a tier starts a round it packs up to ``queue_depth`` ops from
  *all* pending units — read batches from many concurrent requests and
  ``FlushPolicy`` write runs share the same queue, so round-trip latency
  amortizes across jobs exactly the way the paper's deep-queue NVMe argument
  says it should;
* a **virtual-clock completion heap** orders round completions, pipe drains
  and job arrivals; completions are naturally *reordered* — a small warm job
  submitted after a large cold one can finish first;
* **QoS knobs** (:class:`QoS`): per-tenant weighted queue admission
  (weighted-fair round packing by served-ops/weight), strict priority
  classes, and a starvation guard that front-runs any unit that has been
  overtaken by later-arriving work for ``starvation_rounds`` rounds.

Hard contract — *lone-job degeneration*: a job simulated alone completes in
exactly its serial-drain price, i.e. the same per-(batch, phase) arithmetic
as :meth:`TierStats.model_time <repro.store.stats.TierStats.model_time>`
applied to that one drain.  The per-tier throughput term is split across the
job's phase units byte-proportionally with exact remainder assignment (the
same scheme as :func:`repro.obs.attribute`), so the unit chain telescopes
back to ``tp + sum(ceil(ops/qd) * latency)`` per tier.  With no concurrency
the event loop *is* the old serial drain; concurrency only shares rounds, it
never invents bandwidth (the pipe is FCFS and work-conserving).

Nothing here feeds back into pricing or classification: the event loop is a
timing overlay over drains that already happened, which is what keeps the
logical trace and the per-tier accounting bit-identical whether or not a
service window is open.

PR 8 additions, all on the interleaved path and all observational or
explicitly opted into:

* **per-tier queue depths** — ``queue_depths={"nvme": 64, "s3": 8}``
  overrides the shared depth per device name (serial pricing and the
  lone-job degeneration contract hold per tier);
* **live metrics plane** — pass a :class:`~repro.obs.MetricsPlane` and the
  loop samples per-tier utilization / outstanding-window occupancy /
  pipe-backlog gauges at round boundaries and ``jobs.in_flight`` at
  arrival/completion, all on the virtual clock.  Sampling is read-only:
  completions are bit-identical with the plane on or off (tested);
* **SLO hook** — pass a :class:`~repro.obs.SLOMonitor` and every job
  completion feeds its tenant's burn-rate windows as it lands;
* **fault injection** — :class:`~repro.core.io_sim.Degradation` entries on
  a tier's :class:`DeviceModel` stretch that tier's round latency and pipe
  drain while active.  Only the interleaved loop consults the fault
  schedule; serial pricing and the accounting plane never see it, so
  committed baselines stay bit-identical while the serve bench degrades
  NVMe mid-run and gates the SLO alert;
* **closed-loop arrivals** — a job with ``after=<job>`` is held until its
  dependency completes, then arrives ``think`` virtual seconds later
  (the :class:`ServiceWindow` wires per-client chains; see
  ``repro.serve.workload`` for the coordinated-omission caveat).

PR 9 adds the failure/recovery layer, again interleaved-only and again
invisible on the healthy path:

* **transient errors** — :class:`~repro.core.io_sim.TransientErrors` /
  :class:`~repro.core.io_sim.Blackout` entries on a tier's fault schedule
  fail individual ops *after they consume their round trip* (window
  membership judged at round-completion time, draws deterministic in the
  fault seed);
* **retry / timeout / backoff** — a unit whose round loses ops re-queues
  the failed slots and re-arms after a deterministic exponential backoff
  with seeded jitter (heap kind 3), bounded by
  :class:`RetryPolicy.max_retries` and a per-unit deadline of
  ``timeout_k ×`` its healthy expected service time;
* **tier failover** — a unit that exhausts retries against a faulted tier
  is re-dispatched against the next (slower) tier, re-priced at that
  tier's device model for the surviving slots; a unit that exhausts on
  the last tier (or with failover disabled) fails its whole job, which
  surfaces as a :class:`JobCompletion` with ``error`` set — never an
  exception;
* **load shedding** — an :class:`~repro.obs.slo.Shedder` consulted at
  arrival can reject a job outright (``error="shed"``), trading the
  lowest-priority tenants' admissions for the protected tenants' burn
  rate;
* **counters** — ``retry.<dev>``, ``failover.<dev>``, ``error.<tenant>``,
  ``shed.<tenant>`` land in :attr:`ServiceResult.counters` (and on the
  metrics plane when attached).

The retry machinery only allocates state on tiers whose device schedule
*can* fail ops (``DeviceModel.has_error_faults``): with no error faults —
including ``TransientErrors(error_prob=0)`` — every run is bit-identical
to the pre-recovery loop, which is what keeps all committed baselines
pinned with the recovery layer compiled in.
"""

from __future__ import annotations

import contextlib
import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.io_sim import DeviceModel, _splitmix_uniform
from ..obs.metrics import percentile
from ..obs.timeseries import NULL_PLANE, MetricsPlane
from .stats import DrainRecord

__all__ = ["QoS", "RetryPolicy", "Job", "JobCompletion", "ServiceResult",
           "ServiceWindow", "EventLoop", "build_job", "latency_percentiles"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Recovery knobs for the interleaved loop.

    A unit whose round loses ops to an error fault re-queues the failed
    slots and re-arms after ``backoff_base * backoff_factor**k`` seconds
    (k = completed backoffs), stretched by up to ``jitter`` relative
    seeded jitter — the delay is priced purely as virtual-clock time, it
    occupies no queue slot.  The unit gives up when it has burned
    ``max_retries`` backoffs *or* blows its deadline of ``timeout_k ×``
    its healthy expected service time (``ceil(ops/qd)·latency + pipe``),
    whichever comes first; deadlines are only ever checked when a failure
    actually occurred, so they cannot perturb healthy runs.  On give-up,
    ``failover=True`` re-dispatches the surviving slots against the next
    (slower) tier, re-priced at that tier's model; otherwise — or when
    already on the last tier — the whole job fails with a per-request
    ``error``.  All draws key off ``seed``: same policy + same fault
    schedule ⇒ bit-identical replay."""

    max_retries: int = 4
    backoff_base: float = 1e-3
    backoff_factor: float = 2.0
    jitter: float = 0.5
    timeout_k: float = 8.0
    failover: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base < 0 or self.backoff_factor < 1.0:
            raise ValueError("need backoff_base >= 0 and backoff_factor >= 1")
        if self.jitter < 0:
            raise ValueError("jitter must be >= 0")
        if self.timeout_k <= 0:
            raise ValueError("timeout_k must be positive")


@dataclasses.dataclass
class QoS:
    """Fairness/priority knobs for interleaved round packing.

    ``weights`` biases the weighted-fair share (a tenant with weight 4 gets
    ~4x the round slots of a weight-1 tenant under contention); ``priority``
    maps tenants to strict classes (higher served first — a lower class only
    gets slots the higher classes left free); ``starvation_rounds`` bounds
    how long strict priority can starve anyone: a unit *overtaken by
    later-arriving work* for that many rounds jumps the whole order
    (waiting behind earlier arrivals is ordinary queueing and does not
    age)."""

    weights: Dict[str, float] = dataclasses.field(default_factory=dict)
    priority: Dict[str, int] = dataclasses.field(default_factory=dict)
    default_weight: float = 1.0
    starvation_rounds: int = 16

    def weight_for(self, tenant: str) -> float:
        w = float(self.weights.get(tenant, self.default_weight))
        return w if w > 0.0 else 1e-9

    def priority_for(self, tenant: str) -> int:
        return int(self.priority.get(tenant, 0))


class _Unit:
    """One (phase, tier) slice of a job: ``ops`` queue slots to win plus a
    ``pipe`` share of the tier's throughput term."""

    __slots__ = ("job", "tier", "phase", "dev", "ops", "nbytes", "pipe",
                 "seq", "ops_left", "wait_rounds", "retry_q", "backoffs",
                 "deadline")

    def __init__(self, job: "Job", tier: int, phase: int, dev: DeviceModel,
                 ops: int, nbytes: int, pipe: float):
        self.job = job
        self.tier = tier
        self.phase = phase
        self.dev = dev
        self.ops = int(ops)
        self.nbytes = int(nbytes)
        self.pipe = float(pipe)
        self.seq = 0          # global arrival order, assigned at run time
        self.ops_left = 0     # per-run state (reset by EventLoop.run)
        self.wait_rounds = 0
        # recovery state, allocated only on error-faulted tiers:
        # (slot, attempt) pairs still owed, backoffs burned, give-up time
        self.retry_q: Optional[List[Tuple[int, int]]] = None
        self.backoffs = 0
        self.deadline: Optional[float] = None


class Job:
    """One drain record lifted into the event loop: an ordered unit chain
    (phase-major, fastest tier first within a phase) plus serving metadata."""

    __slots__ = ("label", "tenant", "weight", "request", "n_requests",
                 "submit", "seq", "units", "_next", "after", "think")

    def __init__(self, label: str, tenant: str = "default",
                 weight: Optional[float] = None,
                 request: Optional[str] = None, n_requests: int = 0,
                 submit: float = 0.0, seq: int = 0,
                 after: Optional["Job"] = None, think: float = 0.0):
        self.label = label
        self.tenant = tenant
        self.weight = weight
        self.request = request
        self.n_requests = int(n_requests)
        self.submit = float(submit)
        self.seq = int(seq)
        self.units: List[_Unit] = []
        self._next = 0
        # closed-loop dependency: this job arrives `think` virtual seconds
        # after `after` completes (if `after` is in the same run), instead
        # of at its nominal `submit` time.
        self.after = after
        self.think = float(think)

    def serial_time(self, queue_depth: int,
                    queue_depths: Optional[Dict[str, int]] = None) -> float:
        """The job's old-world price: every unit strictly sequential —
        ``sum(ceil(ops/qd) * latency + pipe)`` over the chain, which is
        exactly ``TierStats.model_time`` restricted to this one drain.
        ``queue_depths`` overrides the shared depth per device name, the
        same per-tier fallback rule as the event loop."""
        qd0 = max(1, int(queue_depth))
        t = 0.0
        # accumulate per tier in chain order so the float summation order
        # matches model_time's (tp first, then the phase latency terms)
        per_tier: Dict[int, Tuple[float, float]] = {}
        for u in self.units:
            qd = qd0
            if queue_depths:
                qd = max(1, int(queue_depths.get(u.dev.name, qd0)))
            tp, lat = per_tier.get(u.tier, (0.0, 0.0))
            per_tier[u.tier] = (tp + u.pipe,
                                lat + math.ceil(u.ops / qd) * u.dev.latency)
        for tp, lat in per_tier.values():
            t += tp + lat
        return t


@dataclasses.dataclass
class JobCompletion:
    """One job's completion record on the virtual clock.  ``error`` is
    ``None`` for a served request, ``"shed"`` for an admission rejection,
    or ``"io:<device>"`` when retries + failover were exhausted — failures
    are data, never exceptions."""

    label: str
    tenant: str
    request: Optional[str]
    n_requests: int
    submit: float
    done: float
    error: Optional[str] = None

    @property
    def latency(self) -> float:
        return self.done - self.submit

    @property
    def ok(self) -> bool:
        return self.error is None


def build_job(
    record: DrainRecord,
    devices: Sequence[DeviceModel],
    *,
    tenant: str = "default",
    weight: Optional[float] = None,
    request: Optional[str] = None,
    submit: float = 0.0,
    seq: int = 0,
) -> Job:
    """Lift one drain record into a :class:`Job`.

    ``devices`` is the store's tier order (fastest level first, backing
    last) — the same indexing the record's ``tiers`` dict uses.  Per tier,
    the throughput term is computed with the *identical* arithmetic as
    ``TierStats.model_time`` over that tier's slice of the drain (average op
    size clamped to ``min_read``, IOPS- or bandwidth-limited, whichever
    binds) and then split across the tier's phase units byte-proportionally
    with the remainder assigned exactly to the last unit, so the per-tier
    pipe shares sum to the tier's throughput term bit-for-bit."""
    job = Job(record.label, tenant=tenant, weight=weight, request=request,
              n_requests=record.n_requests, submit=submit, seq=seq)
    staged: List[Tuple[int, int, _Unit]] = []
    for tier in sorted(record.tiers):
        phase_ops, phase_bytes = record.tiers[tier]
        dev = devices[tier]
        total_ops = sum(phase_ops.values())
        if total_ops == 0:
            continue
        total_bytes = sum(phase_bytes.get(p, 0) for p in phase_ops)
        avg = max(total_bytes / total_ops, 1.0)
        eff = max(avg, dev.min_read)
        iops_limit = min(dev.iops_4k, dev.seq_bw / eff)
        tp = max(total_ops / iops_limit, total_bytes / dev.seq_bw)
        phases = sorted(phase_ops)
        assigned = 0.0
        for k, p in enumerate(phases):
            nb = phase_bytes.get(p, 0)
            if k == len(phases) - 1:
                pipe = tp - assigned  # exact remainder: shares sum to tp
            elif total_bytes:
                pipe = tp * (nb / total_bytes)
                assigned += pipe
            else:
                pipe = tp * (phase_ops[p] / total_ops)
                assigned += pipe
            staged.append((p, tier, _Unit(job, tier, p, dev,
                                          phase_ops[p], nb, pipe)))
    # phase-major chain: phase p on every tier completes before phase p+1
    # starts (the dependency the phases encode), fastest tier first within a
    # phase (the classify order).
    staged.sort(key=lambda t: (t[0], t[1]))
    job.units = [u for _, _, u in staged]
    return job


@dataclasses.dataclass
class ServiceResult:
    """One event-loop (or serial-baseline) run over a set of jobs.

    ``counters`` carries the recovery layer's tallies (``retry.<dev>``,
    ``failover.<dev>``, ``error.<tenant>``, ``shed.<tenant>``) — empty on
    healthy runs and in serial mode."""

    mode: str
    completions: List[JobCompletion]
    tiers: Dict[str, Dict[str, int]]
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return max((c.done for c in self.completions), default=0.0)

    @property
    def errors(self) -> List[JobCompletion]:
        """Failed completions (shed and io-exhausted), submission order."""
        return [c for c in self.completions if c.error is not None]

    def availability(self, tenant: Optional[str] = None) -> float:
        """Served fraction — completions without an error over all
        completions (shed rejections count against availability), overall
        or for one tenant.  1.0 when the filter matches nothing."""
        tot = ok = 0
        for c in self.completions:
            if tenant is not None and c.tenant != tenant:
                continue
            tot += 1
            ok += c.error is None
        return ok / tot if tot else 1.0

    def percentiles(self, tenant: Optional[str] = None,
                    label_prefix: Optional[str] = None) -> Optional[Dict]:
        """Nearest-rank per-request latency summary (seconds) over *served*
        completions (errors excluded — an error is not a latency),
        optionally filtered by tenant and/or drain-label prefix."""
        lats = [c.latency for c in self.completions
                if c.error is None
                and (tenant is None or c.tenant == tenant)
                and (label_prefix is None or c.label.startswith(label_prefix))]
        return latency_percentiles(lats)


def latency_percentiles(latencies: Sequence[float]) -> Optional[Dict]:
    """count/mean/p50/p99/p999/max over a latency population (nearest-rank,
    the same estimator as :mod:`repro.obs.metrics`); ``None`` when empty."""
    lats = sorted(float(x) for x in latencies)
    if not lats:
        return None
    return {
        "count": len(lats),
        "mean": sum(lats) / len(lats),
        "p50": percentile(lats, 50.0),
        "p99": percentile(lats, 99.0),
        "p999": percentile(lats, 99.9),
        "max": lats[-1],
    }


class _TierState:
    """Per-tier run state: the outstanding-request table and the FCFS
    bandwidth pipe."""

    __slots__ = ("dev", "pending", "in_round", "granted", "granted_slots",
                 "busy", "pipe_free", "rounds", "max_outstanding", "served",
                 "busy_time", "round_start", "last_t", "last_busy")

    def __init__(self, dev: DeviceModel):
        self.dev = dev
        self.pending: List[_Unit] = []
        self.in_round: List[_Unit] = []
        self.granted: Dict[int, int] = {}   # unit seq -> ops in this round
        # unit seq -> (slot, attempt) pairs in this round; only populated
        # on error-faulted tiers under a RetryPolicy
        self.granted_slots: Dict[int, List[Tuple[int, int]]] = {}
        self.busy = False
        self.pipe_free = 0.0
        self.rounds = 0
        self.max_outstanding = 0
        self.served: Dict[str, int] = {}    # tenant -> ops served (for WFQ)
        self.busy_time = 0.0                # cumulative round-in-flight time
        self.round_start = 0.0
        self.last_t = 0.0                   # utilization-sampling anchors
        self.last_busy = 0.0


class EventLoop:
    """Virtual-clock simulation of interleaved dispatch across the tiers.

    ``run(jobs, mode="interleaved")`` shares each tier's latency rounds
    across all pending jobs (bounded by the queue depth) and drains bytes
    through a work-conserving FCFS pipe; ``mode="serial"`` prices the same
    job list the old way — one batch fully drained before the next starts —
    which is the baseline the serving benchmark's p99 gate compares against.
    Both modes are pure functions of (jobs, queue_depth, qos): they mutate
    no accounting state and can be re-run on the same job list."""

    def __init__(self, devices: Sequence[DeviceModel], queue_depth: int = 256,
                 qos: Optional[QoS] = None,
                 queue_depths: Optional[Dict[str, int]] = None,
                 plane: MetricsPlane = NULL_PLANE, slo=None,
                 retry: Optional[RetryPolicy] = None, shedder=None):
        self.devices = list(devices)
        self.queue_depth = max(1, int(queue_depth))
        self.qos = qos or QoS()
        # per-device-name depth overrides; any device not named falls back
        # to the shared queue_depth
        self.queue_depths = ({name: max(1, int(v))
                              for name, v in queue_depths.items()}
                             if queue_depths else None)
        self.plane = plane if plane is not None else NULL_PLANE
        self.slo = slo
        # recovery knobs; only consulted on tiers whose fault schedule can
        # fail ops, so a policy on a healthy device list costs nothing
        self.retry = retry
        self.shedder = shedder

    def qd_for(self, dev: DeviceModel) -> int:
        if self.queue_depths:
            return self.queue_depths.get(dev.name, self.queue_depth)
        return self.queue_depth

    # -- public entry points --------------------------------------------------
    def run(self, jobs: Sequence[Job], mode: str = "interleaved") -> ServiceResult:
        if mode == "serial":
            return self._run_serial(jobs)
        if mode != "interleaved":
            raise ValueError(f"unknown event-loop mode {mode!r}")
        return self._run_interleaved(jobs)

    # -- serial baseline ------------------------------------------------------
    def _run_serial(self, jobs: Sequence[Job]) -> ServiceResult:
        """The old drain-the-whole-batch-then-return world: jobs run FIFO in
        (submit, seq) order, each paying its full serial-drain price.

        Deliberately blind to device fault schedules and to the metrics
        plane: serial pricing is the accounting baseline the bench gate
        pins, so it must stay bit-identical regardless of injected
        degradations or sampling.  Closed-loop dependencies are honoured
        (the dependent issues when its dependency completes plus think
        time) so both modes price the same arrival process."""
        clock = 0.0
        completions: List[JobCompletion] = []
        ordered = sorted(jobs, key=lambda j: (j.submit, j.seq))
        ids = {id(j) for j in ordered}
        done: Dict[int, float] = {}
        for job in ordered:
            submit = job.submit
            if job.after is not None and id(job.after) in ids:
                # the window submits dependencies before dependents, so the
                # dependency sorts first and its completion time is known
                submit = max(submit, done.get(id(job.after), 0.0) + job.think)
            start = max(clock, submit)
            clock = start + job.serial_time(self.queue_depth,
                                            self.queue_depths)
            done[id(job)] = clock
            completions.append(JobCompletion(
                job.label, job.tenant, job.request, job.n_requests,
                submit, clock))
        return ServiceResult("serial", completions, {})

    # -- interleaved event loop -----------------------------------------------
    def _run_interleaved(self, jobs: Sequence[Job]) -> ServiceResult:
        tiers = [_TierState(dev) for dev in self.devices]
        heap: List[Tuple[float, int, int, object]] = []
        eseq = 0  # heap tie-break: deterministic FIFO among equal timestamps
        plane, slo = self.plane, self.slo
        policy, shedder = self.retry, self.shedder
        counters: Dict[str, int] = {}

        def push(t: float, kind: int, payload) -> None:
            nonlocal eseq
            eseq += 1
            heapq.heappush(heap, (t, kind, eseq, payload))

        def bump(key: str, n: int = 1) -> None:
            counters[key] = counters.get(key, 0) + n
            if plane.enabled:
                plane.counter(key).inc(n)

        ordered = sorted(jobs, key=lambda j: (j.submit, j.seq))
        ids = {id(j) for j in ordered}
        # closed-loop dependents wait for their dependency's completion
        # instead of arriving at their nominal submit time
        deps: Dict[int, List[Job]] = {}
        # effective issue time per job (dependents: dep completion + think);
        # kept out of Job.submit so repeated runs stay pure
        esub: Dict[int, float] = {}
        useq = 0
        for job in ordered:
            job._next = 0
            for u in job.units:
                useq += 1
                u.seq = useq
                u.ops_left = u.ops
                u.wait_rounds = 0
                u.retry_q = None       # recovery state is strictly per-run:
                u.backoffs = 0         # resetting it keeps repeated runs
                u.deadline = None      # over the same jobs pure
            if job.after is not None and id(job.after) in ids:
                deps.setdefault(id(job.after), []).append(job)
            else:
                esub[id(job)] = job.submit
                push(job.submit, 0, job)  # kind 0: arrival

        completions: List[JobCompletion] = []
        in_flight = 0

        def complete(job: Job, t: float, error: Optional[str] = None) -> None:
            nonlocal in_flight
            submit = esub[id(job)]
            completions.append(JobCompletion(
                job.label, job.tenant, job.request, job.n_requests,
                submit, t, error))
            in_flight -= 1
            plane.sample("jobs.in_flight", t, in_flight)
            if error is None:
                plane.observe_latency(f"latency.{job.tenant}", t, t - submit)
                if slo is not None:
                    slo.observe(job.tenant, t, t - submit)
            else:
                bump(f"error.{job.tenant}")
                if slo is not None:
                    # a failure consumes error budget whatever its latency
                    slo.observe(job.tenant, t, t - submit, error=True)
            for d in deps.pop(id(job), ()):
                at = esub[id(d)] = max(d.submit, t + d.think)
                push(at, 0, d)

        def activate(unit: _Unit, t: float) -> None:
            ts = tiers[unit.tier]
            if policy is not None and unit.retry_q is None \
                    and ts.dev.has_error_faults:
                # first dispatch against an error-faulted tier: materialize
                # the slot queue and stamp the give-up deadline off the
                # unit's *healthy* expected service time
                unit.retry_q = [(s, 0) for s in range(unit.ops)]
                qd = self.qd_for(ts.dev)
                unit.deadline = t + policy.timeout_k * (
                    math.ceil(unit.ops / qd) * ts.dev.latency + unit.pipe)
            ts.pending.append(unit)
            if not ts.busy:
                start_round(ts, t)

        def exhaust(unit: _Unit, ts: _TierState, t: float) -> None:
            """Retries/deadline exhausted on this tier: fail over the
            surviving slots to the next (slower) tier, re-priced at that
            tier's model — or fail the whole job if there is nowhere left
            to go."""
            nonlocal useq
            job = unit.job
            nxt = unit.tier + 1
            if policy.failover and nxt < len(self.devices):
                bump(f"failover.{ts.dev.name}")
                r = len(unit.retry_q)
                dev2 = self.devices[nxt]
                # prorate the unit's bytes over the surviving slots and
                # price them with the target tier's model arithmetic (the
                # same formula as build_job); cache admission is implicitly
                # skipped — this is a timing re-dispatch, the accounting
                # plane never sees it
                nb = int(round(unit.nbytes * (r / unit.ops))) \
                    if unit.ops else 0
                avg = max(nb / r, 1.0)
                eff = max(avg, dev2.min_read)
                iops_limit = min(dev2.iops_4k, dev2.seq_bw / eff)
                tp = max(r / iops_limit, nb / dev2.seq_bw)
                v = _Unit(job, nxt, unit.phase, dev2, r, nb, tp)
                useq += 1
                v.seq = useq
                v.ops_left = r
                # v substitutes for `unit` positionally: when it drains,
                # finish_unit advances job._next past the abandoned unit.
                # If the target tier is itself error-faulted, activate()
                # arms fresh retry state there (cascading failover).
                activate(v, t)
            else:
                complete(job, t, error=f"io:{ts.dev.name}")

        def order_key(ts: _TierState):
            qos = self.qos

            def key(u: _Unit):
                tenant = u.job.tenant
                w = u.job.weight if u.job.weight is not None \
                    else qos.weight_for(tenant)
                starved = 0 if u.wait_rounds >= qos.starvation_rounds else 1
                return (starved, -qos.priority_for(tenant),
                        ts.served.get(tenant, 0) / max(w, 1e-9), u.seq)
            return key

        def start_round(ts: _TierState, t: float) -> None:
            """Pack the next outstanding window: up to the tier's queue
            depth ops drawn from all pending units in QoS order."""
            if not ts.pending:
                ts.busy = False
                return
            order = sorted(ts.pending, key=order_key(ts))
            qd = self.qd_for(ts.dev)
            err = policy is not None and ts.dev.has_error_faults
            slots = qd
            chosen: List[_Unit] = []
            passed: List[_Unit] = []
            granted: Dict[int, int] = {}
            for u in order:
                if slots <= 0:
                    passed.append(u)
                    continue
                g = min(u.ops_left, slots)
                granted[u.seq] = g
                if err and g:
                    # remember which (slot, attempt) pairs ride this round
                    # so finish_round can judge each op individually
                    ts.granted_slots[u.seq] = u.retry_q[:g]
                    del u.retry_q[:g]
                u.ops_left -= g
                u.wait_rounds = 0
                slots -= g
                ts.served[u.job.tenant] = ts.served.get(u.job.tenant, 0) + g
                chosen.append(u)
            # aging: a passed-over unit only moves toward the starvation
            # threshold when *later-arriving* work jumped ahead of it.
            # Waiting behind earlier arrivals is plain FIFO queueing; being
            # overtaken is what strict priority classes inflict, and that is
            # what the guard bounds — under a sustained high-class flood
            # every victim would otherwise cross the threshold in lockstep
            # with the flood itself and priority would just re-decide.
            max_seq = max((u.seq for u in chosen), default=0)
            for u in passed:
                if u.seq < max_seq:
                    u.wait_rounds += 1
            ts.pending = [u for u in ts.pending if u.seq not in granted]
            ts.in_round = chosen
            ts.granted = granted
            ts.busy = True
            ts.rounds += 1
            outstanding = qd - slots
            ts.max_outstanding = max(ts.max_outstanding, outstanding)
            ts.round_start = t
            # fault schedule: an active degradation stretches this round's
            # trip time; healthy devices take the branch-free path
            lat = ts.dev.latency
            if ts.dev.faults:
                lat *= ts.dev.latency_factor_at(t)
            if plane.enabled:
                plane.sample(f"tier.{ts.dev.name}.outstanding", t,
                             outstanding)
            push(t + lat, 1, ts)  # kind 1: round completion

        def finish_round(ts: _TierState, t: float) -> None:
            ts.busy_time += t - ts.round_start
            faulted = bool(ts.dev.faults)
            err = policy is not None and ts.dev.has_error_faults
            for u in ts.in_round:
                if err:
                    # judge each op that rode this round at its completion
                    # time: window membership + an independent seeded draw
                    # per (tier, unit, slot, attempt)
                    failed = [(s, a)
                              for s, a in ts.granted_slots.get(u.seq, ())
                              if ts.dev.op_fails_at(t, u.tier, u.seq, s, a)]
                    if failed:
                        u.retry_q.extend((s, a + 1) for s, a in failed)
                        u.ops_left = len(u.retry_q)
                        if u.backoffs >= policy.max_retries \
                                or t >= u.deadline:
                            exhaust(u, ts, t)
                        else:
                            bump(f"retry.{ts.dev.name}", len(failed))
                            u.backoffs += 1
                            jit = 1.0 + policy.jitter * _splitmix_uniform(
                                policy.seed, u.tier, u.seq, u.backoffs)
                            delay = (policy.backoff_base
                                     * policy.backoff_factor
                                     ** (u.backoffs - 1) * jit)
                            push(t + delay, 3, u)  # kind 3: backoff re-arm
                        continue
                if u.ops_left == 0:
                    # all this unit's ops have completed their round trips;
                    # its bytes drain through the FCFS bandwidth pipe
                    pipe = u.pipe
                    if faulted:
                        pipe /= ts.dev.bandwidth_factor_at(t)
                    ts.pipe_free = max(ts.pipe_free, t) + pipe
                    push(ts.pipe_free, 2, u)  # kind 2: unit completion
                else:
                    ts.pending.append(u)
            ts.in_round = []
            ts.granted = {}
            if err:
                ts.granted_slots = {}
            ts.busy = False
            if plane.enabled:
                # utilization = fraction of virtual time this tier had a
                # round in flight since the last sample; pipe backlog is the
                # queued-bytes drain horizon in virtual seconds
                dt = t - ts.last_t
                if dt > 0:
                    plane.sample(f"tier.{ts.dev.name}.utilization", t,
                                 min(1.0, (ts.busy_time - ts.last_busy) / dt))
                    ts.last_t = t
                    ts.last_busy = ts.busy_time
                plane.sample(f"tier.{ts.dev.name}.pipe_backlog", t,
                             max(0.0, ts.pipe_free - t))
            if ts.pending:
                start_round(ts, t)

        def finish_unit(unit: _Unit, t: float) -> None:
            job = unit.job
            job._next += 1
            if job._next < len(job.units):
                activate(job.units[job._next], t)
            else:
                complete(job, t)

        while heap:
            t, kind, _, payload = heapq.heappop(heap)
            if kind == 0:
                job = payload
                if shedder is not None and not shedder.admit(job.tenant, t):
                    # admission rejection: the job completes immediately as
                    # shed, consumes no queue slot, and is not fed to the
                    # SLO monitor (rejections are the policy's output, not
                    # evidence about the protected tenants' service);
                    # closed-loop dependents still release — a real client
                    # retries or moves on after a 429
                    bump(f"shed.{job.tenant}")
                    completions.append(JobCompletion(
                        job.label, job.tenant, job.request, job.n_requests,
                        esub[id(job)], t, "shed"))
                    for d in deps.pop(id(job), ()):
                        at = esub[id(d)] = max(d.submit, t + d.think)
                        push(at, 0, d)
                    continue
                in_flight += 1
                plane.sample("jobs.in_flight", t, in_flight)
                if job.units:
                    activate(job.units[0], t)
                else:
                    complete(job, t)
            elif kind == 1:
                finish_round(payload, t)
            elif kind == 2:
                finish_unit(payload, t)
            else:
                activate(payload, t)  # kind 3: backoff elapsed, re-queue

        report = {ts.dev.name: {"rounds": ts.rounds,
                                "max_outstanding": ts.max_outstanding}
                  for ts in tiers if ts.rounds}
        return ServiceResult("interleaved", completions, report, counters)


@dataclasses.dataclass
class _RequestCtx:
    tenant: str
    at: Optional[float]
    weight: Optional[float]
    request: Optional[str]
    client: Optional[str] = None
    think: float = 0.0
    dep: Optional[Job] = None  # the client's previous request's last job


class ServiceWindow:
    """Collects the drains of many concurrent requests for one shared
    event-loop run.

    Opened via ``IOScheduler.service_window()``.  While the window is open,
    every completed drain (read batches, write batches, and the flush runs
    they trigger) is lifted into a :class:`Job` instead of advancing the
    scheduler's immediate virtual clock; :meth:`request` tags the jobs a
    block of calls produces with a tenant, an arrival time and an optional
    weight.  ``run("interleaved")`` and ``run("serial")`` then price the
    *same executed workload* under both dispatch models — cache state and
    accounting are identical by construction, only the timing differs."""

    def __init__(self, scheduler, qos: Optional[QoS] = None):
        self.scheduler = scheduler
        self.qos = qos
        self.jobs: List[Job] = []
        self._cur: Optional[_RequestCtx] = None
        self._arrival = 0.0  # default submit time for untagged drains
        self._last_by_client: Dict[str, Job] = {}  # closed-loop chain heads

    def __enter__(self) -> "ServiceWindow":
        if self.scheduler._window is not None:
            raise RuntimeError("service windows do not nest")
        self.scheduler._window = self
        return self

    def __exit__(self, *exc) -> None:
        self.scheduler._window = None

    @contextlib.contextmanager
    def request(self, tenant: str = "default", at: Optional[float] = None,
                weight: Optional[float] = None,
                request: Optional[str] = None,
                client: Optional[str] = None, think: float = 0.0):
        """Tag every drain produced inside the block as one tenant request
        arriving at virtual time ``at`` (defaults to the latest arrival seen,
        so untimed requests land back to back).

        ``client`` opts the request into the closed-loop arrival model: its
        jobs depend on the *last job of the same client's previous request*
        and arrive ``think`` virtual seconds after that job completes (a
        client issues its next request only after the previous response
        lands — the chain approximates request completion by its last
        submitted drain).  Open-loop requests just set ``at``."""
        if at is not None:
            self._arrival = float(at)
        prev = self._cur
        dep = self._last_by_client.get(client) if client else None
        self._cur = _RequestCtx(tenant, self._arrival, weight, request,
                                client=client, think=float(think), dep=dep)
        try:
            yield
        finally:
            self._cur = prev

    def _submit(self, job: Job) -> None:
        ctx = self._cur
        if ctx is not None:
            job.tenant = ctx.tenant
            job.weight = ctx.weight
            job.submit = ctx.at if ctx.at is not None else self._arrival
            if ctx.request is not None:
                job.request = ctx.request
            if ctx.client is not None:
                job.after = ctx.dep
                job.think = ctx.think
                self._last_by_client[ctx.client] = job
        else:
            job.submit = self._arrival
        self.jobs.append(job)

    def run(self, mode: str = "interleaved", qos: Optional[QoS] = None,
            queue_depth: Optional[int] = None,
            queue_depths: Optional[Dict[str, int]] = None,
            plane: MetricsPlane = NULL_PLANE, slo=None,
            retry: Optional[RetryPolicy] = None, shedder=None,
            devices: Optional[Sequence[DeviceModel]] = None) -> ServiceResult:
        """Price the captured jobs; pure — callable repeatedly, with either
        mode, without touching scheduler or store state.  ``plane``/``slo``
        attach the live metrics plane and SLO monitor to the interleaved
        run; ``queue_depths`` overrides depth per device name (defaulting
        to the scheduler's per-tier map, if it has one); ``retry`` falls
        back to the scheduler's ``retry_policy``; ``devices`` substitutes a
        (possibly fault-injected) device list for the scheduler's — the
        chaos bench re-prices one captured workload under many fault
        schedules this way.  A ``shedder`` carries hysteresis state across
        a run: reset or rebuild it between runs to keep them pure."""
        loop = EventLoop(devices if devices is not None
                         else self.scheduler._devices(),
                         queue_depth or self.scheduler.queue_depth,
                         qos or self.qos,
                         queue_depths=(queue_depths if queue_depths is not None
                                       else getattr(self.scheduler,
                                                    "queue_depths", None)),
                         plane=plane, slo=slo,
                         retry=(retry if retry is not None
                                else getattr(self.scheduler,
                                             "retry_policy", None)),
                         shedder=shedder)
        return loop.run(self.jobs, mode=mode)
