"""Workload-mix observer for the batched IO scheduler.

Admission tuning (ROADMAP): ``second_touch`` protects a cache from
single-pass scan flooding but delays residency for the take-heavy serving
workload the paper optimizes.  Neither is right for every trace, so the
scheduler feeds every finished batch into a :class:`WorkloadStats` and any
cache level configured ``admission="auto"`` follows the observed mix:

* **scan-heavy** (scan batches moved more logical bytes than take batches)
  → ``second_touch``: streams must touch a block twice to earn a slot;
* **take-heavy** → ``always``: the hot rows are admitted on first miss.

Classification is by batch intent, not size: a batch opened with
``prefetch=True`` (or labelled ``scan:*``) is a scan, everything else is a
take.  The decision is re-evaluated *before* each batch dispatches, so a
scan arriving at a take-warmed cache is already policed by ``second_touch``
and cannot flush the working set first.

The decision carries **hysteresis**: near the scan/take byte-mix boundary a
naive majority test flips the admission policy on every batch (each flip
resets second-touch ghost state, so thrashing is not free).  The preference
is therefore stateful — it only moves to ``second_touch`` once scan bytes
exceed take bytes by the ``hysteresis`` margin, and only moves back once
they fall short by the same margin; inside the band the previous decision
sticks.
"""

from __future__ import annotations

__all__ = ["WorkloadStats"]


class WorkloadStats:
    def __init__(self, scan_bias: float = 1.0, hysteresis: float = 0.1):
        # scan_bias scales scan bytes in the comparison: > 1 flips to
        # second_touch earlier, < 1 later.  1.0 = plain byte majority.
        # hysteresis is the dead band around the boundary: the preference
        # flips only once the biased scan bytes cross take bytes by this
        # relative margin (0 restores the memoryless majority test).
        self.scan_bias = float(scan_bias)
        if hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")
        self.hysteresis = float(hysteresis)
        self.reset()

    def reset(self) -> None:
        self.n_scan_batches = 0
        self.n_take_batches = 0
        self.scan_ops = 0
        self.take_ops = 0
        self.scan_bytes = 0
        self.take_bytes = 0
        self._pref = "always"  # cold-start default; sticky inside the band

    # -- ingest --------------------------------------------------------------
    def note_batch(self, label: str, prefetch: bool, n_ops: int,
                   nbytes: int) -> None:
        """Record one finished :class:`~repro.store.ReadBatch`."""
        if prefetch or str(label).startswith("scan"):
            self.n_scan_batches += 1
            self.scan_ops += int(n_ops)
            self.scan_bytes += int(nbytes)
        else:
            self.n_take_batches += 1
            self.take_ops += int(n_ops)
            self.take_bytes += int(nbytes)

    # -- decision ------------------------------------------------------------
    @property
    def scan_fraction(self):
        """Scan share of the logical byte stream, or ``None`` before any
        batch — never NaN (NaN leaked into BENCH_*.json artifacts)."""
        total = self.scan_bytes + self.take_bytes
        return self.scan_bytes / total if total else None

    def preferred_admission(self) -> str:
        """``second_touch`` when scans dominate the byte stream, else
        ``always`` (also the cold-start default).  Stateful: inside the
        hysteresis band the previous preference is returned unchanged, so
        an alternating workload sitting on the boundary cannot thrash the
        admission policy batch to batch."""
        scan = self.scan_bytes * self.scan_bias
        if scan > (1.0 + self.hysteresis) * self.take_bytes:
            self._pref = "second_touch"
        elif scan < self.take_bytes / (1.0 + self.hysteresis):
            self._pref = "always"
        return self._pref

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"WorkloadStats(scan={self.n_scan_batches}b/{self.scan_bytes}B, "
            f"take={self.n_take_batches}b/{self.take_bytes}B, "
            f"prefer={self.preferred_admission()})"
        )
