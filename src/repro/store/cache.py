"""Sector-granular block cache with pluggable eviction + admission.

The cache fronts a slow backing device (S3) with a fast one (NVMe, RAM).
It tracks *residency only* — block ids over the backing address space, at
``block_bytes`` (one device sector by default) granularity; actual bytes
always come from the simulated :class:`~repro.core.io_sim.Disk`, the cache
decides which tier a block's read is priced on.

Eviction policies:

* ``clock`` — second-chance ring (one ref bit per slot); constant-time and
  scan-resistant enough for the paper's take-heavy workloads.
* ``lru`` — classic recency order, for comparison.

Admission policies:

* ``always`` — admit every missed block (default).
* ``second_touch`` — admit a block only on its second miss within the ghost
  window (a bounded FIFO of recently-seen block ids, 8x the cache's slot
  count).  Protects the cache from single-pass scan flooding.
* ``auto`` — start as ``always`` and let an observer of the workload (the
  scheduler's :class:`~repro.store.WorkloadStats`) flip the *active* policy
  between ``always`` (take-heavy mixes: admit the hot rows immediately) and
  ``second_touch`` (scan-heavy mixes: keep single-pass streams from
  flooding the cache) via :meth:`BlockCache.set_active_admission`.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List

__all__ = ["BlockCache"]


class BlockCache:
    def __init__(
        self,
        capacity_bytes: int,
        block_bytes: int = 4096,
        policy: str = "clock",
        admission: str = "always",
    ):
        if block_bytes <= 0:
            raise ValueError("block_bytes must be positive")
        if capacity_bytes < block_bytes:
            raise ValueError("cache smaller than one block")
        if policy not in ("clock", "lru"):
            raise ValueError(f"unknown eviction policy {policy!r}")
        if admission not in ("always", "second_touch", "auto"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.block_bytes = int(block_bytes)
        self.capacity_blocks = int(capacity_bytes) // self.block_bytes
        self.policy = policy
        self.admission = admission  # configured policy ("auto" stays "auto")
        self._active = "always" if admission == "auto" else admission
        self.admission_flips = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # lru state
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # clock state
        self._slot_of: Dict[int, int] = {}
        self._blocks: List[int] = []
        self._ref: List[int] = []
        self._hand = 0
        # second-touch ghost list (ids seen once, not yet admitted)
        self._ghost: "OrderedDict[int, None]" = OrderedDict()
        self._ghost_cap = 8 * self.capacity_blocks

    # -- residency ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self._lru) if self.policy == "lru" else len(self._slot_of)

    def __contains__(self, block_id: int) -> bool:
        return block_id in (self._lru if self.policy == "lru" else self._slot_of)

    @property
    def resident_bytes(self) -> int:
        return len(self) * self.block_bytes

    # -- access ------------------------------------------------------------
    def lookup(self, block_id: int) -> bool:
        """Hit test; updates recency/ref state and hit/miss counters."""
        if self.policy == "lru":
            if block_id in self._lru:
                self._lru.move_to_end(block_id)
                self.hits += 1
                return True
        else:
            slot = self._slot_of.get(block_id)
            if slot is not None:
                self._ref[slot] = 1
                self.hits += 1
                return True
        self.misses += 1
        return False

    @property
    def active_admission(self) -> str:
        """The policy actually applied to admits (resolves ``auto``)."""
        return self._active

    def set_active_admission(self, policy: str) -> None:
        """Flip the active policy of an ``auto`` cache.  No-op unless the
        cache was configured ``admission="auto"`` — explicit policies are
        pinned by construction."""
        if policy not in ("always", "second_touch"):
            raise ValueError(f"unknown admission policy {policy!r}")
        if self.admission != "auto" or policy == self._active:
            return
        self._active = policy
        self.admission_flips += 1

    def admit(self, block_id: int) -> bool:
        """Maybe-insert a block after a miss; returns True if now resident."""
        if block_id in self:
            return True
        if self._active == "second_touch":
            if block_id not in self._ghost:
                self._ghost[block_id] = None
                while len(self._ghost) > self._ghost_cap:
                    self._ghost.popitem(last=False)
                return False
            del self._ghost[block_id]
        if self.policy == "lru":
            if len(self._lru) >= self.capacity_blocks:
                self._lru.popitem(last=False)
                self.evictions += 1
            self._lru[block_id] = None
            return True
        # clock: insert with a clear ref bit — only a subsequent lookup
        # earns the block its second chance
        if len(self._blocks) < self.capacity_blocks:
            self._slot_of[block_id] = len(self._blocks)
            self._blocks.append(block_id)
            self._ref.append(0)
            return True
        while self._ref[self._hand]:
            self._ref[self._hand] = 0
            self._hand = (self._hand + 1) % self.capacity_blocks
        victim = self._blocks[self._hand]
        del self._slot_of[victim]
        self.evictions += 1
        self._blocks[self._hand] = block_id
        self._slot_of[block_id] = self._hand
        self._ref[self._hand] = 0
        self._hand = (self._hand + 1) % self.capacity_blocks
        return True

    # -- management ---------------------------------------------------------
    def drop(self) -> None:
        """Discard all resident blocks (counters are kept)."""
        self._lru.clear()
        self._slot_of.clear()
        self._blocks = []
        self._ref = []
        self._hand = 0
        self._ghost.clear()

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
