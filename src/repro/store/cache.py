"""Sector-granular block cache with pluggable eviction + admission.

The cache fronts a slow backing device (S3) with a fast one (NVMe, RAM).
It tracks *residency only* — block ids over the backing address space, at
``block_bytes`` (one device sector by default) granularity; actual bytes
always come from the simulated :class:`~repro.core.io_sim.Disk`, the cache
decides which tier a block's read is priced on.

Eviction policies:

* ``clock`` — second-chance ring (one ref bit per slot); constant-time and
  scan-resistant enough for the paper's take-heavy workloads.
* ``lru`` — classic recency order, for comparison.

Admission policies:

* ``always`` — admit every missed block (default).
* ``second_touch`` — admit a block only on its second miss within the ghost
  window (a bounded FIFO of recently-seen block ids, 8x the cache's slot
  count).  Protects the cache from single-pass scan flooding.
* ``auto`` — start as ``always`` and let an observer of the workload (the
  scheduler's :class:`~repro.store.WorkloadStats`) flip the *active* policy
  between ``always`` (take-heavy mixes: admit the hot rows immediately) and
  ``second_touch`` (scan-heavy mixes: keep single-pass streams from
  flooding the cache) via :meth:`BlockCache.set_active_admission`.

Write-back state (the ingest path, ``repro.store.flush``): the cache
additionally tracks which resident blocks are **dirty** — written but not
yet flushed to the backing device.  ``mark_dirty`` force-inserts (dirty data
must occupy a slot, bypassing the admission filter), ``clean`` marks a block
flushed, and evicting a dirty block notifies ``on_evict`` so the flush
policy can write it back before the slot is reused (flush-on-evict).
``invalidate`` drops a block outright (compaction retargeting / crash
discard) without counting a capacity eviction.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Set

__all__ = ["BlockCache"]

_MISSING = object()


class BlockCache:
    def __init__(
        self,
        capacity_bytes: int,
        block_bytes: int = 4096,
        policy: str = "clock",
        admission: str = "always",
    ):
        if block_bytes <= 0:
            raise ValueError("block_bytes must be positive")
        if capacity_bytes < block_bytes:
            raise ValueError("cache smaller than one block")
        if policy not in ("clock", "lru"):
            raise ValueError(f"unknown eviction policy {policy!r}")
        if admission not in ("always", "second_touch", "auto"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.block_bytes = int(block_bytes)
        self.capacity_blocks = int(capacity_bytes) // self.block_bytes
        self.policy = policy
        self.admission = admission  # configured policy ("auto" stays "auto")
        self._active = "always" if admission == "auto" else admission
        self.admission_flips = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # lru state
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # clock state
        self._slot_of: Dict[int, int] = {}
        self._blocks: List[int] = []
        self._ref: List[int] = []
        self._hand = 0
        self._free: List[int] = []  # tombstoned clock slots (invalidate)
        # second-touch ghost list (ids seen once, not yet admitted)
        self._ghost: "OrderedDict[int, None]" = OrderedDict()
        self._ghost_cap = 8 * self.capacity_blocks
        # write-back state: dirty (written, unflushed) resident blocks
        self._dirty: Set[int] = set()
        # eviction hook (block_id, was_dirty); the flush policy uses it to
        # write back dirty victims before their slot is reused
        self.on_evict: Optional[Callable[[int, bool], None]] = None

    # -- residency ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self._lru) if self.policy == "lru" else len(self._slot_of)

    def __contains__(self, block_id: int) -> bool:
        return block_id in (self._lru if self.policy == "lru" else self._slot_of)

    @property
    def resident_bytes(self) -> int:
        return len(self) * self.block_bytes

    # -- access ------------------------------------------------------------
    def lookup(self, block_id: int) -> bool:
        """Hit test; updates recency/ref state and hit/miss counters."""
        if self.policy == "lru":
            if block_id in self._lru:
                self._lru.move_to_end(block_id)
                self.hits += 1
                return True
        else:
            slot = self._slot_of.get(block_id)
            if slot is not None:
                self._ref[slot] = 1
                self.hits += 1
                return True
        self.misses += 1
        return False

    @property
    def active_admission(self) -> str:
        """The policy actually applied to admits (resolves ``auto``)."""
        return self._active

    def gauges(self) -> "Dict[str, float]":
        """Instantaneous gauge snapshot for the live metrics plane.

        ``hit_rate`` is lifetime hits / lookups (0.0 before any lookup —
        a gauge needs a number, and the windowed view comes from sampling
        this repeatedly, not from NaN); ``admission_second_touch`` encodes
        the active policy as 0/1 so a policy flip shows as a step on the
        counter track."""
        looked = self.hits + self.misses
        return {
            "hit_rate": self.hits / looked if looked else 0.0,
            "dirty_bytes": float(self.dirty_bytes),
            "resident_bytes": float(self.resident_bytes),
            "evictions": float(self.evictions),
            "admission_second_touch":
                1.0 if self._active == "second_touch" else 0.0,
        }

    def set_active_admission(self, policy: str) -> None:
        """Flip the active policy of an ``auto`` cache.  No-op unless the
        cache was configured ``admission="auto"`` — explicit policies are
        pinned by construction."""
        if policy not in ("always", "second_touch"):
            raise ValueError(f"unknown admission policy {policy!r}")
        if self.admission != "auto" or policy == self._active:
            return
        self._active = policy
        self.admission_flips += 1

    def admit(self, block_id: int) -> bool:
        """Maybe-insert a block after a miss; returns True if now resident."""
        if block_id in self:
            return True
        if self._active == "second_touch":
            if block_id not in self._ghost:
                self._ghost[block_id] = None
                while len(self._ghost) > self._ghost_cap:
                    self._ghost.popitem(last=False)
                return False
            del self._ghost[block_id]
        self._insert(block_id)
        return True

    def _evicted(self, victim: int) -> None:
        self.evictions += 1
        was_dirty = victim in self._dirty
        self._dirty.discard(victim)
        if self.on_evict is not None:
            self.on_evict(victim, was_dirty)

    def _insert(self, block_id: int) -> None:
        """Unconditional insert (evicting as needed); no admission filter."""
        if self.policy == "lru":
            if len(self._lru) >= self.capacity_blocks:
                victim, _ = self._lru.popitem(last=False)
                self._evicted(victim)
            self._lru[block_id] = None
            return
        # clock: insert with a clear ref bit — only a subsequent lookup
        # earns the block its second chance
        if self._free:
            slot = self._free.pop()
            self._slot_of[block_id] = slot
            self._blocks[slot] = block_id
            self._ref[slot] = 0
            return
        if len(self._blocks) < self.capacity_blocks:
            self._slot_of[block_id] = len(self._blocks)
            self._blocks.append(block_id)
            self._ref.append(0)
            return
        while self._ref[self._hand]:
            self._ref[self._hand] = 0
            self._hand = (self._hand + 1) % self.capacity_blocks
        victim = self._blocks[self._hand]
        del self._slot_of[victim]
        self._evicted(victim)
        self._blocks[self._hand] = block_id
        self._slot_of[block_id] = self._hand
        self._ref[self._hand] = 0
        self._hand = (self._hand + 1) % self.capacity_blocks

    # -- write-back state ----------------------------------------------------
    def fill(self, block_id: int) -> None:
        """Write-path *clean* fill: force-insert resident, bypassing the
        admission filter.  A write-through store just put these bytes on the
        backing device — they are the freshest data there is, so the ghost
        list's scan protection does not apply (admission polices reads, not
        the writer's own fills)."""
        if block_id not in self:
            self._ghost.pop(block_id, None)
            self._insert(block_id)

    def mark_dirty(self, block_id: int) -> None:
        """Write-path insert: make the block resident — bypassing the
        admission filter, dirty data must hold a slot — and mark it dirty.
        Evicting it later notifies ``on_evict`` with ``was_dirty=True`` so
        the flush policy can write it back first."""
        if block_id not in self:
            self._ghost.pop(block_id, None)
            self._insert(block_id)
        elif self.policy == "lru":
            self._lru.move_to_end(block_id)
        else:
            self._ref[self._slot_of[block_id]] = 1
        self._dirty.add(block_id)

    def clean(self, block_id: int) -> None:
        """Mark a block flushed (durable); residency is unchanged."""
        self._dirty.discard(block_id)

    def is_dirty(self, block_id: int) -> bool:
        return block_id in self._dirty

    @property
    def dirty_blocks(self) -> List[int]:
        return sorted(self._dirty)

    @property
    def dirty_bytes(self) -> int:
        return len(self._dirty) * self.block_bytes

    def invalidate(self, block_id: int) -> bool:
        """Drop a block without a capacity eviction (no ``on_evict``, no
        eviction counter): compaction retargeting and crash discard.  Any
        dirty state is discarded with it."""
        self._dirty.discard(block_id)
        if self.policy == "lru":
            return self._lru.pop(block_id, _MISSING) is not _MISSING
        slot = self._slot_of.pop(block_id, None)
        if slot is None:
            return False
        self._blocks[slot] = -1  # tombstone; reused before any eviction
        self._ref[slot] = 0
        self._free.append(slot)
        return True

    # -- management ---------------------------------------------------------
    def drop(self) -> None:
        """Discard all resident blocks (counters are kept).  Dirty state is
        discarded silently — callers that care about durability flush before
        dropping (``TieredStore.discard_dirty`` is the accounted path)."""
        self._lru.clear()
        self._slot_of.clear()
        self._blocks = []
        self._ref = []
        self._hand = 0
        self._free = []
        self._ghost.clear()
        self._dirty.clear()

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
