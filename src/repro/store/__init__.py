# Tiered storage: NVMe block cache over S3 with an async batched IO
# scheduler.  Sits between the structural encodings and the raw Disk —
# FileReader opens a ReadBatch per take/scan, the scheduler coalesces per
# dependency phase, sector-aligns, classifies against the cache hierarchy
# and prices each tier with the paper's Fig-1 device models.  The ingest
# path mirrors it: WriteBatch absorbs appends, FlushPolicy decides when
# dirty blocks become durable on the backing device (write-through /
# write-back with deadline+watermark / flush-on-evict).

from .cache import BlockCache  # noqa: F401
from .evloop import (  # noqa: F401
    EventLoop,
    Job,
    JobCompletion,
    QoS,
    RetryPolicy,
    ServiceResult,
    ServiceWindow,
    build_job,
    latency_percentiles,
)
from .flush import FlushPolicy, SimulatedCrash  # noqa: F401
from .prefetch import SequentialReadahead  # noqa: F401
from .scheduler import (  # noqa: F401
    CacheTier,
    IOScheduler,
    ReadBatch,
    TieredStore,
    WriteBatch,
    make_store,
)
from .stats import DrainRecord, TierStats  # noqa: F401
from .workload import WorkloadStats  # noqa: F401
