"""Shared model building blocks.

Parameters are plain nested dicts of jnp arrays; every ``init_*`` function
returns ``(params, specs)`` where ``specs`` is a structurally identical tree
of :class:`jax.sharding.PartitionSpec`.  Sharding axis conventions
(DESIGN.md §5):

* ``"dp"`` placeholder resolves to ``("pod", "data")`` (or ``("data",)`` on a
  single pod) — data parallel / FSDP.
* ``"tp"`` resolves to ``"model"`` — tensor parallel.

Weights shard their *flattened feature* dimensions (e.g. ``n_heads *
head_dim``), which are divisible by the 16-wide model axis for every
assigned architecture even when the head count itself is not (GSPMD pads
uneven intermediate shardings, but argument shardings must divide evenly).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "DP", "TP", "dense_init", "rmsnorm_init", "embed_init",
    "rmsnorm", "rope_freqs", "apply_rope", "dtype_of", "stack_layers",
]

# logical axis tokens resolved by repro.dist.sharding.resolve_spec
DP = "__dp__"
TP = "__tp__"


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               in_axis=None, out_axis=TP, scale: Optional[float] = None):
    """Linear layer params + specs.  Default: column parallel (out on TP)."""
    scale = scale if scale is not None else (1.0 / (d_in ** 0.5))
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    params = {"w": w.astype(dtype)}
    specs = {"w": P(in_axis, out_axis)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype=dtype)
        specs["b"] = P(out_axis)
    return params, specs


def rmsnorm_init(d: int, dtype) -> Tuple[Dict, Dict]:
    return {"scale": jnp.ones((d,), dtype=dtype)}, {"scale": P(None)}


def embed_init(key, vocab: int, d: int, dtype) -> Tuple[Dict, Dict]:
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"w": w.astype(dtype)}, {"w": P(TP, None)}


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., H, head_dim); cos/sin broadcastable to (..., 1, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def stack_layers(init_one, key, n_layers: int):
    """vmap an init function over layer keys -> stacked (L, ...) params.

    Returns (params, specs) where specs gain a leading None axis.
    """
    keys = jax.random.split(key, n_layers)
    _, specs = init_one(keys[0])
    params = jax.vmap(init_one_params(init_one))(keys)
    specs = jax.tree.map(
        lambda s: P(None, *s), specs, is_leaf=lambda s: isinstance(s, P)
    )
    return params, specs


def init_one_params(init_one):
    def f(k):
        p, _ = init_one(k)
        return p

    return f
