"""Architecture registry: config -> model builder, input specs, cache specs.

``input_specs`` returns ``ShapeDtypeStruct`` stand-ins (no allocation) plus
activation PartitionSpecs for every model input of a given (arch, shape)
cell — the dry-run lowers against these.  Modality frontends are stubs per
the task spec: the VLM receives precomputed patch embeddings, the audio
model precomputed frame embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCfg
from .attention import gqa_cache_spec, mla_cache_spec
from .common import DP, TP
from .ssm import mamba2_cache_spec
from .transformer import LMModel

__all__ = ["build_model", "input_specs", "cache_specs", "supports_shape", "model_flops"]


def build_model(cfg: ModelConfig, mesh=None, batch_axes=("data",),
                data_size: int = 16, use_sharded_moe: bool = False) -> LMModel:
    return LMModel(cfg, data_size=data_size, use_sharded_moe=use_sharded_moe,
                   batch_axes=tuple(batch_axes), mesh=mesh)


def supports_shape(cfg: ModelConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic sequence mixing (task spec)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skipped: pure full-attention arch at 500K context (DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Tuple[jax.ShapeDtypeStruct, P]]:
    B, S = shape.global_batch, shape.seq_len
    dp = P(DP)
    out: Dict[str, Tuple[jax.ShapeDtypeStruct, P]] = {}
    if shape.kind == "train":
        out["tokens"] = (jax.ShapeDtypeStruct((B, S + 1), jnp.int32), P(DP, None))
    elif shape.kind == "prefill":
        out["tokens"] = (jax.ShapeDtypeStruct((B, S), jnp.int32), P(DP, None))
    else:  # decode: one new token against a cache of length S
        out["tokens"] = (jax.ShapeDtypeStruct((B, 1), jnp.int32), P(DP, None))
    if cfg.family == "vlm" and shape.kind != "decode":
        out["vision_embeds"] = (
            jax.ShapeDtypeStruct((B, cfg.n_vision_tokens, cfg.d_vision), jnp.dtype(cfg.dtype)),
            P(DP, None, None),
        )
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = (
            jax.ShapeDtypeStruct((B, S, cfg.d_audio), jnp.dtype(cfg.dtype)),
            P(DP, None, None),
        )
    return out


# ---------------------------------------------------------------------------
# caches (for decode dry-runs and the serving engine)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def cache_specs(cfg: ModelConfig, shape: ShapeCfg, dp_total: int):
    """Returns (ShapeDtypeStruct tree, PartitionSpec tree) for the decode cache.

    ``dp_total``: number of chips on the batch axes — batches smaller than it
    flip the cache to sequence sharding (SP / flash-decoding combine).
    """
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    dt = cfg.dtype
    batch_sharded = B >= dp_total and B % dp_total == 0

    def seq_or_batch(spec_batch: P, spec_seq: P) -> P:
        return spec_batch if batch_sharded else spec_seq

    if cfg.family in ("dense", "moe"):
        if cfg.mla is not None:
            m = cfg.mla
            shapes = {"layers": {"ckv": _sds((L, B, S, m.kv_lora_rank), dt),
                                 "kpe": _sds((L, B, S, m.qk_rope_dim), dt)},
                      "length": _sds((), jnp.int32)}
            lspec = mla_cache_spec(cfg, batch_sharded)
            specs = {"layers": {k: P(None, *v) for k, v in lspec.items()},
                     "length": P()}
            return shapes, specs
        f = cfg.n_kv_heads * cfg.head_dim
        shapes = {"layers": {"k": _sds((L, B, S, f), dt), "v": _sds((L, B, S, f), dt)},
                  "length": _sds((), jnp.int32)}
        lspec = gqa_cache_spec(cfg, batch_sharded)
        specs = {"layers": {k: P(None, *v) for k, v in lspec.items()}, "length": P()}
        return shapes, specs

    if cfg.family == "ssm":
        s = cfg.ssm
        H, Pd, N = cfg.ssm_heads, s.head_dim, s.d_state
        di, GN = cfg.d_inner, s.n_groups * s.d_state
        shapes = {"layers": {
            "state": _sds((L, B, H, N, Pd), jnp.float32),
            "conv": {"x": _sds((L, B, s.d_conv - 1, di), dt),
                     "B": _sds((L, B, s.d_conv - 1, GN), dt),
                     "C": _sds((L, B, s.d_conv - 1, GN), dt)}},
            "length": _sds((), jnp.int32)}
        lspec = mamba2_cache_spec(cfg, batch_sharded)
        specs = {"layers": jax.tree.map(lambda v: P(None, *v), lspec,
                                        is_leaf=lambda v: isinstance(v, P)),
                 "length": P()}
        return shapes, specs

    if cfg.family == "hybrid":
        s = cfg.ssm
        H, Pd, N = cfg.ssm_heads, s.head_dim, s.d_state
        di, GN = cfg.d_inner, s.n_groups * s.d_state
        n_shared = cfg.n_layers // cfg.shared_attn_every
        f = cfg.n_kv_heads * cfg.head_dim
        shapes = {
            "mamba": {"state": _sds((L, B, H, N, Pd), jnp.float32),
                      "conv": {"x": _sds((L, B, s.d_conv - 1, di), dt),
                               "B": _sds((L, B, s.d_conv - 1, GN), dt),
                               "C": _sds((L, B, s.d_conv - 1, GN), dt)}},
            "shared": {"k": _sds((n_shared, B, S, f), dt),
                       "v": _sds((n_shared, B, S, f), dt)},
            "length": _sds((), jnp.int32),
        }
        mspec = mamba2_cache_spec(cfg, batch_sharded)
        aspec = gqa_cache_spec(cfg, batch_sharded)
        specs = {
            "mamba": jax.tree.map(lambda v: P(None, *v), mspec,
                                  is_leaf=lambda v: isinstance(v, P)),
            "shared": {k: P(None, *v) for k, v in aspec.items()},
            "length": P(),
        }
        return shapes, specs

    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        f = cfg.n_kv_heads * cfg.head_dim
        Nv = cfg.n_vision_tokens
        aspec = gqa_cache_spec(cfg, batch_sharded)
        shapes = {
            "self": {"k": _sds((n_cross, per, B, S, f), dt),
                     "v": _sds((n_cross, per, B, S, f), dt)},
            "cross": {"k": _sds((n_cross, B, Nv, f), dt),
                      "v": _sds((n_cross, B, Nv, f), dt)},
            "length": _sds((), jnp.int32),
        }
        specs = {
            "self": {k: P(None, None, *v) for k, v in aspec.items()},
            "cross": {k: P(None, *v) for k, v in aspec.items()},
            "length": P(),
        }
        return shapes, specs

    if cfg.family == "audio":
        L = cfg.n_dec_layers
        f = cfg.n_kv_heads * cfg.head_dim
        aspec = gqa_cache_spec(cfg, batch_sharded)
        shapes = {
            "self": {"k": _sds((L, B, S, f), dt), "v": _sds((L, B, S, f), dt)},
            "cross": {"k": _sds((L, B, S, f), dt), "v": _sds((L, B, S, f), dt)},
            "length": _sds((), jnp.int32),
        }
        specs = {
            "self": {k: P(None, *v) for k, v in aspec.items()},
            "cross": {k: P(None, *v) for k, v in aspec.items()},
            "length": P(),
        }
        return shapes, specs

    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# analytic parameter / FLOP counts (for §Roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------


def param_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(total_params, active_params) — active differs for MoE."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    embed = V * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.mla is not None:
            m = cfg.mla
            return (d * H * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim)
                    + H * m.v_head_dim * d)
        return d * H * hd * 2 + d * Hkv * hd * 2

    def mlp_params(f=ff):
        return 3 * d * f

    def mamba_params():
        s = cfg.ssm
        di, GN, Hs = cfg.d_inner, s.n_groups * s.d_state, cfg.ssm_heads
        return 2 * d * di + 2 * d * GN + d * Hs + di * d + s.d_conv * (di + 2 * GN)

    total = active = embed
    fam = cfg.family
    if fam == "dense":
        per = attn_params() + mlp_params()
        total += cfg.n_layers * per
        active = total
    elif fam == "moe":
        m = cfg.moe
        routed = 3 * d * m.d_ff_expert
        shared = 3 * d * (m.d_ff_shared or 0) * m.n_shared if m.n_shared else 0
        per_total = attn_params() + m.n_experts * routed + shared + d * m.n_experts
        per_active = attn_params() + m.top_k * routed + shared + d * m.n_experts
        total += cfg.n_layers * per_total
        active += cfg.n_layers * per_active
    elif fam == "ssm":
        total += cfg.n_layers * mamba_params()
        active = total
    elif fam == "hybrid":
        total += cfg.n_layers * mamba_params()
        total += attn_params() + mlp_params()  # shared block counted once
        # but APPLIED n_shared times: active compute counts applications
        n_shared = cfg.n_layers // cfg.shared_attn_every
        active = embed + cfg.n_layers * mamba_params() + n_shared * (attn_params() + mlp_params())
    elif fam == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        total += n_self * (attn_params() + mlp_params())
        total += n_cross * (attn_params() + mlp_params())
        total += cfg.d_vision * d
        active = total
    elif fam == "audio":
        total += cfg.n_enc_layers * (attn_params() + mlp_params())
        total += cfg.n_dec_layers * (2 * attn_params() + mlp_params())
        total += cfg.d_audio * d
        active = total
    return int(total), int(active)


def model_flops(cfg: ModelConfig, shape: ShapeCfg) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train;
    2·N·D for prefill; 2·N_active per token for decode."""
    total, active = param_counts(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per request
