"""Mamba2 (SSD — state-space duality) sequence mixer.

Implements the chunked SSD algorithm (arXiv:2405.21060): within chunks of
length Q the computation is an attention-like quadratic form with decay
mask; across chunks a linear recurrence carries the (H, P, N) state.  Decode
is a single-step state update — the "KV cache" of an SSM is its fixed-width
state, which is why ``long_500k`` runs on SSM/hybrid archs only (DESIGN.md).

Projections are split (z/x/B/C/dt) rather than fused so every sharded
feature dim (d_inner, heads) divides the 16-wide model axis cleanly.
f32 internals for the cumulative decays.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import DP, TP, dense_init

__all__ = ["init_mamba2", "mamba2_apply", "mamba2_cache_spec"]


def init_mamba2(key, cfg, dtype):
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.ssm_heads
    GN = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    pz, sz = dense_init(ks[0], d, di, dtype, in_axis=DP)
    px, sx = dense_init(ks[1], d, di, dtype, in_axis=DP)
    pB, sB = dense_init(ks[2], d, GN, dtype, in_axis=DP, out_axis=None)
    pC, sC = dense_init(ks[3], d, GN, dtype, in_axis=DP, out_axis=None)
    pdt, sdt = dense_init(ks[4], d, H, dtype, in_axis=DP, out_axis=TP)
    po, so = dense_init(ks[5], di, d, dtype, in_axis=TP, out_axis=DP)
    params = {
        "in_z": pz, "in_x": px, "in_B": pB, "in_C": pC, "in_dt": pdt,
        "out": po,
        "conv_x": {"w": (jax.random.normal(ks[6], (s.d_conv, di), jnp.float32) * 0.1).astype(dtype),
                   "b": jnp.zeros((di,), dtype)},
        "conv_B": {"w": (jax.random.normal(ks[7], (s.d_conv, GN), jnp.float32) * 0.1).astype(dtype),
                   "b": jnp.zeros((GN,), dtype)},
        "conv_C": {"w": (jax.random.normal(jax.random.fold_in(ks[7], 1), (s.d_conv, GN), jnp.float32) * 0.1).astype(dtype),
                   "b": jnp.zeros((GN,), dtype)},
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
    }
    specs = {
        "in_z": sz, "in_x": sx, "in_B": sB, "in_C": sC, "in_dt": sdt,
        "out": so,
        "conv_x": {"w": P(None, TP), "b": P(TP)},
        "conv_B": {"w": P(None, None), "b": P(None)},
        "conv_C": {"w": P(None, None), "b": P(None)},
        "A_log": P(TP), "D": P(TP), "dt_bias": P(TP),
        "norm": {"scale": P(TP)},
    }
    return params, specs


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along S.  x (B,S,C); w (K,C).  Returns (y, new
    state (B,K-1,C)) when a state is provided (decode), else y only."""
    K = w.shape[0]
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)  # (B, K-1+S, C)
        new_state = xin[:, -(K - 1):, :]
    else:
        xin = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = xin[:, -(K - 1):, :]
    y = sum(xin[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(y + b), new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh (B,S,H,P); dt (B,S,H) f32 post-softplus; A (H,) f32 negative;
    Bm/Cm (B,S,G,N).  Heads map to groups h -> h % G... (G divides H; heads
    share B/C within a group).  Returns y (B,S,H,P) and final state
    (B,H,P,N) f32.
    """
    B_, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad with dt=0 entries: they contribute nothing to the state
        # (x·dt = 0) and decay exp(0)=1, so the scan is exact.
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    rep = H // G
    dtA = dt * A[None, None, :]  # (B,S,H) negative
    xdt = (xh.astype(jnp.float32) * dt[..., None])

    xc = xdt.reshape(B_, nc, Q, H, Pd)
    dc = dtA.reshape(B_, nc, Q, H)
    Bc = Bm.reshape(B_, nc, Q, G, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, nc, Q, G, N).astype(jnp.float32)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    cs = jnp.cumsum(dc, axis=2)  # (B,nc,Q,H) cumulative log-decay
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    iq = jnp.arange(Q)
    causal = iq[:, None] >= iq[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * L
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

    # chunk-final states: S_c = sum_j exp(cs_end - cs_j) B_j (x dt)_j
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bh, decay_to_end, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B,nc,H)

    def body(carry, t):
        S_prev = carry  # (B,H,N,P)
        S_new = S_prev * chunk_decay[:, t][:, :, None, None] + S_c[:, t]
        return S_new, S_prev

    S0 = jnp.zeros((B_, H, N, Pd), jnp.float32)
    S_last, S_prevs = jax.lax.scan(body, S0, jnp.arange(nc))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcihn,bcih,bchnp->bcihp", Ch, jnp.exp(cs), S_prevs)

    y = (y_intra + y_inter).reshape(B_, S, H, Pd)[:, :S_orig]
    return y, S_last


def mamba2_apply(params, cfg, x, mode: str, cache: Optional[Dict] = None):
    s = cfg.ssm
    B, S, d = x.shape
    di, H, Pd = cfg.d_inner, cfg.ssm_heads, s.head_dim
    G, N = s.n_groups, s.d_state

    z = jnp.einsum("bsd,df->bsf", x, params["in_z"]["w"])
    xr = jnp.einsum("bsd,df->bsf", x, params["in_x"]["w"])
    Braw = jnp.einsum("bsd,df->bsf", x, params["in_B"]["w"])
    Craw = jnp.einsum("bsd,df->bsf", x, params["in_C"]["w"])
    dt_raw = jnp.einsum("bsd,df->bsf", x, params["in_dt"]["w"])

    conv_cache = cache.get("conv") if cache else None
    if mode == "decode":
        xr, cx = _causal_conv(xr, params["conv_x"]["w"], params["conv_x"]["b"], conv_cache["x"])
        Braw, cB = _causal_conv(Braw, params["conv_B"]["w"], params["conv_B"]["b"], conv_cache["B"])
        Craw, cC = _causal_conv(Craw, params["conv_C"]["w"], params["conv_C"]["b"], conv_cache["C"])
    else:
        xr, cx = _causal_conv(xr, params["conv_x"]["w"], params["conv_x"]["b"])
        Braw, cB = _causal_conv(Braw, params["conv_B"]["w"], params["conv_B"]["b"])
        Craw, cC = _causal_conv(Craw, params["conv_C"]["w"], params["conv_C"]["b"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xr.reshape(B, S, H, Pd)
    Bm = Braw.reshape(B, S, G, N)
    Cm = Craw.reshape(B, S, G, N)

    if mode == "decode":
        assert S == 1
        state = cache["state"]  # (B,H,N,P) f32
        dtA = jnp.exp(dt[:, 0] * A[None, :])  # (B,H)
        Bh = jnp.repeat(Bm[:, 0].astype(jnp.float32), H // G, axis=1)  # (B,H,N)
        Ch = jnp.repeat(Cm[:, 0].astype(jnp.float32), H // G, axis=1)
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None]  # (B,H,P)
        state = state * dtA[:, :, None, None] + jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
        y = jnp.einsum("bhn,bhnp->bhp", Ch, state)[:, None]  # (B,1,H,P)
        new_cache = {"state": state, "conv": {"x": cx, "B": cB, "C": cC}}
    else:
        y, S_last = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
        new_cache = (
            {"state": S_last, "conv": {"x": cx, "B": cB, "C": cC}}
            if mode == "prefill"
            else None
        )

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMSNorm (mamba2)
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype) * params["norm"]["scale"]
    out = jnp.einsum("bsf,fd->bsd", g, params["out"]["w"])
    return out, new_cache


def mamba2_cache_spec(cfg, batch_sharded: bool):
    bs = DP if batch_sharded else None
    return {
        "state": P(bs, TP, None, None),
        "conv": {"x": P(bs, None, TP), "B": P(bs, None, None), "C": P(bs, None, None)},
    }
