"""Attention variants: GQA (+QKV bias), MLA (DeepSeek kv-LoRA), gated
cross-attention (VLM), plus a memory-bounded blockwise ("flash") attention
used for long prefills and a KV-cache decode path.

Decode KV caches are stored with the head/feature dims flattened
(``Hkv*head_dim``) so their sharded dimension is divisible by the 16-wide
model axis even when ``n_kv_heads`` is not (e.g. kv=8 or kv=5).
For ``long_500k`` (batch 1) the cache shards its *sequence* dimension over
the data axis; the softmax reductions below then lower to the
flash-decoding partial-softmax combine via GSPMD (all-reduce of max/sum).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import DP, TP, apply_rope, dense_init, rope_freqs

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# blockwise attention (training / prefill)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, Dv)
    *,
    causal: bool,
    q_chunk: int = 2048,
    k_chunk: int = 2048,
    scale: Optional[float] = None,
    kv_len: Optional[jax.Array] = None,  # mask k positions >= kv_len
) -> jax.Array:
    """Online-softmax blockwise attention (O(S) memory, exact).

    The causal mask is applied inside each (q-block, k-block) tile; fully
    masked tiles still compute (static shapes) — trimming them is a §Perf
    hillclimb item tracked in EXPERIMENTS.md.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // k_chunk
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0

    qr = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kr = k.reshape(B, nk, k_chunk, Hkv, D)
    vr = v.reshape(B, nk, k_chunk, Hkv, Dv)

    def per_q(qi, qblk):  # qblk (B, qc, Hkv, G, D)
        m0 = jnp.full((B, q_chunk, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, Hkv, G, Dv), jnp.float32)

        def body(carry, ki):
            m, l, acc = carry
            kblk = kr[:, ki]
            vblk = vr[:, ki]
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            ik = ki * k_chunk + jnp.arange(k_chunk)
            if causal:
                # additive 2-D bias instead of a 6-D select: XLA hoisted the
                # broadcast pred array out of the scan (GiB-scale HBM traffic,
                # EXPERIMENTS.md §Perf A1); the (qc, kc) f32 bias fuses.
                iq = qi * q_chunk + jnp.arange(q_chunk)
                bias = jnp.where(iq[:, None] >= ik[None, :], 0.0, NEG_INF)
                s = s + bias[None, :, None, None, :]
            if kv_len is not None:
                s = s + jnp.where(ik < kv_len, 0.0, NEG_INF)[None, None, None, None, :]
            mn = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - mn[..., None])
            corr = jnp.exp(m - mn)
            l2 = l * corr + p.sum(axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(v.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (mn, l2, acc2), None

        # nested remat: without it, AD of the scan saves every block's f32
        # score/probability tensors as residuals (TiB-scale HBM traffic at
        # 32K context — §Perf A1).  Recomputing s/p per block in backward is
        # the flash-attention backward.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                      jnp.arange(nk))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = jax.lax.map(lambda args: per_q(*args),
                      (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # out: (nq, B, qc, Hkv, G, Dv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dv)
    return out


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, Dv)
    length: jax.Array,  # (B,) valid cache lengths
    scale: Optional[float] = None,
) -> jax.Array:
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(S)[None, :] < length[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    # softmax over (possibly seq-sharded) S: GSPMD lowers the max/sum
    # reductions to the flash-decoding combine when S is sharded.
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, v_cache.shape[3]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg, dtype):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pq, sq = dense_init(ks[0], d, H * hd, dtype, bias=cfg.qkv_bias, in_axis=DP)
    pk, sk = dense_init(ks[1], d, Hkv * hd, dtype, bias=cfg.qkv_bias, in_axis=DP)
    pv, sv = dense_init(ks[2], d, Hkv * hd, dtype, bias=cfg.qkv_bias, in_axis=DP)
    po, so = dense_init(ks[3], H * hd, d, dtype, in_axis=TP, out_axis=DP)
    return (
        {"q": pq, "k": pk, "v": pv, "o": po},
        {"q": sq, "k": sk, "v": sv, "o": so},
    )


def _proj(p, x):
    y = jnp.einsum("bsd,df->bsf", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def gqa_apply(
    params,
    cfg,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    mode: str,  # train | prefill | decode
    cache: Optional[Dict] = None,
):
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(params["q"], x).reshape(B, S, H, hd)
    k = _proj(params["k"], x).reshape(B, S, Hkv, hd)
    v = _proj(params["v"], x).reshape(B, S, Hkv, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)  # (B,S,hd/2)
    q = apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
    k = apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])

    new_cache = None
    if mode == "decode":
        assert cache is not None and S == 1
        kc = cache["k"].reshape(B, -1, Hkv, hd)
        vc = cache["v"].reshape(B, -1, Hkv, hd)
        idx = cache["length"]  # scalar (global decode position)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, idx, axis=1)
        o = decode_attention(q, kc, vc, jnp.full((B,), idx + 1))
        new_cache = {
            "k": kc.reshape(B, -1, Hkv * hd),
            "v": vc.reshape(B, -1, Hkv * hd),
        }
    else:
        # mode "encode" (enc-dec encoder) is bidirectional
        o = flash_attention(q, k, v, causal=(mode != "encode"))
        if mode == "prefill":
            new_cache = {
                "k": k.reshape(B, S, Hkv * hd),
                "v": v.reshape(B, S, Hkv * hd),
            }
    out = jnp.einsum("bsf,fd->bsd", o.reshape(B, S, H * hd), params["o"]["w"])
    return out, new_cache


def gqa_cache_spec(cfg, batch_sharded: bool):
    """PartitionSpec for the per-layer KV cache (stacked later)."""
    if batch_sharded:
        bs = P(DP, None, TP)
    else:  # long-context single-request: shard the sequence dim (SP)
        bs = P(None, "data", TP)
    return {"k": bs, "v": bs}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    ks = jax.random.split(key, 5)
    pq, sq = dense_init(ks[0], d, H * (dn + dr), dtype, in_axis=DP)
    pdkv, sdkv = dense_init(ks[1], d, r + dr, dtype, in_axis=DP, out_axis=None)
    puk, suk = dense_init(ks[2], r, H * dn, dtype, in_axis=None, out_axis=TP)
    puv, suv = dense_init(ks[3], r, H * dv, dtype, in_axis=None, out_axis=TP)
    po, so = dense_init(ks[4], H * dv, d, dtype, in_axis=TP, out_axis=DP)
    return (
        {"q": pq, "dkv": pdkv, "uk": puk, "uv": puv, "o": po},
        {"q": sq, "dkv": sdkv, "uk": suk, "uv": suv, "o": so},
    )


def mla_apply(params, cfg, x, positions, mode, cache=None):
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    q = _proj(params["q"], x).reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    ckv_pe = _proj(params["dkv"], x)  # (B, S, r + dr)
    ckv, kpe = ckv_pe[..., :r], ckv_pe[..., r:]
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    qr = apply_rope(qr, cos[:, :, None, :], sin[:, :, None, :])
    kpe = apply_rope(kpe[:, :, None, :], cos[:, :, None, :], sin[:, :, None, :])[:, :, 0]

    wuk = params["uk"]["w"].reshape(r, H, dn)
    wuv = params["uv"]["w"].reshape(r, H, dv)
    scale = (dn + dr) ** -0.5

    if mode == "decode":
        assert cache is not None and S == 1
        idx = cache["length"]
        ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, idx, axis=1)
        kpe_c = jax.lax.dynamic_update_slice_in_dim(cache["kpe"], kpe, idx, axis=1)
        Sc = ckv_c.shape[1]
        # absorbed form: score = (qn . Wuk) . ckv + qr . kpe
        q_abs = jnp.einsum("bhn,rhn->bhr", qn[:, 0], wuk,
                           preferred_element_type=jnp.float32)
        s = (
            jnp.einsum("bhr,bsr->bhs", q_abs, ckv_c.astype(jnp.float32))
            + jnp.einsum("bhe,bse->bhs", qr[:, 0].astype(jnp.float32),
                         kpe_c.astype(jnp.float32))
        ) * scale
        mask = jnp.arange(Sc)[None, :] < (idx + 1)
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_c = jnp.einsum("bhs,bsr->bhr", p, ckv_c.astype(jnp.float32))
        o = jnp.einsum("bhr,rhv->bhv", o_c, wuv.astype(jnp.float32))
        o = o.reshape(B, 1, H * dv).astype(x.dtype)
        new_cache = {"ckv": ckv_c, "kpe": kpe_c}
    else:
        kn = jnp.einsum("bsr,rhn->bshn", ckv, wuk)
        vv = jnp.einsum("bsr,rhv->bshv", ckv, wuv)
        qcat = jnp.concatenate([qn, qr], axis=-1)
        kcat = jnp.concatenate([kn, jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, dr))], axis=-1)
        o = flash_attention(qcat, kcat, vv, causal=(mode != "encode"), scale=scale)
        o = o.reshape(B, S, H * dv)
        new_cache = {"ckv": ckv, "kpe": kpe} if mode == "prefill" else None
    out = jnp.einsum("bsf,fd->bsd", o, params["o"]["w"])
    return out, new_cache


def mla_cache_spec(cfg, batch_sharded: bool):
    if batch_sharded:
        return {"ckv": P(DP, None, None), "kpe": P(DP, None, None)}
    return {"ckv": P(None, "data", None), "kpe": P(None, "data", None)}


# ---------------------------------------------------------------------------
# Gated cross-attention (Llama-3.2-Vision style)
# ---------------------------------------------------------------------------


def init_cross_attn(key, cfg, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    Hkv = cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    pq, sq = dense_init(ks[0], d, H * hd, dtype, in_axis=DP)
    pk, sk = dense_init(ks[1], d, Hkv * hd, dtype, in_axis=DP)
    pv, sv = dense_init(ks[2], d, Hkv * hd, dtype, in_axis=DP)
    po, so = dense_init(ks[3], H * hd, d, dtype, in_axis=TP, out_axis=DP)
    params = {"q": pq, "k": pk, "v": pv, "o": po,
              "gate": jnp.zeros((), dtype=jnp.float32)}
    specs = {"q": sq, "k": sk, "v": sv, "o": so, "gate": P()}
    return params, specs


def cross_attn_apply(params, cfg, x, vis_tokens, mode, cache=None):
    """x: (B, S, d) text; vis_tokens: (B, Nv, d) projected vision embeddings."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(params["q"], x).reshape(B, S, H, hd)
    if cache is not None and mode == "decode":
        k = cache["k"].reshape(B, -1, Hkv, hd)
        v = cache["v"].reshape(B, -1, Hkv, hd)
        new_cache = cache
    else:
        k = _proj(params["k"], vis_tokens).reshape(B, -1, Hkv, hd)
        v = _proj(params["v"], vis_tokens).reshape(B, -1, Hkv, hd)
        Nv = k.shape[1]
        new_cache = {"k": k.reshape(B, Nv, Hkv * hd), "v": v.reshape(B, Nv, Hkv * hd)}
    # pad the (1601-ish) vision token axis up to a tile multiple and mask
    Nv = k.shape[1]
    pad = (-Nv) % 128
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    o = flash_attention(q, k, v, causal=False, q_chunk=2048,
                        k_chunk=128, kv_len=jnp.int32(Nv))
    o = jnp.einsum("bsf,fd->bsd", o.reshape(B, S, H * hd), params["o"]["w"])
    gate = jnp.tanh(params["gate"]).astype(x.dtype)
    return o * gate, new_cache
