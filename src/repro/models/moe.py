"""Mixture-of-Experts FFN with expert parallelism (GShard-style a2a).

Design (DESIGN.md §5): experts live on the **data** axis — tokens are already
batch-sharded there, so dispatch is one ``all_to_all`` hop each way.  The
expert dimension is physically padded to the data-axis size when the logical
expert count is smaller (grok-1: 8 experts on a 16-wide axis → each expert
stored twice, halving its routed load); when larger, each shard owns
``E / data`` experts (deepseek: 64/16 = 4 per shard).

Capacity-based dispatch: per source shard, each expert-slot receives at most
``C = ceil(T_local * top_k * capacity_factor / n_slots)`` tokens; overflow is
dropped (standard Switch/GShard semantics) and counted in the aux metrics.
The FLOP count therefore tracks *active* parameters (6·N_active·D), which is
what §Roofline's MODEL_FLOPS expects for MoE.

Runs inside ``jax.shard_map`` over the full mesh; the TP (model) axis shards
each expert's FFN width, with a psum to complete the row-parallel second
matmul.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import DP, TP, dense_init

__all__ = ["init_moe", "moe_apply_sharded", "moe_apply_reference", "expert_slots"]


def expert_slots(n_experts: int, data_size: int) -> int:
    """Physical expert slots = lcm-style padding up to the data axis size."""
    if n_experts >= data_size:
        assert n_experts % data_size == 0
        return n_experts
    assert data_size % n_experts == 0
    return data_size


def init_moe(key, cfg, dtype, data_size: int = 16):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    slots = expert_slots(m.n_experts, data_size)
    reps = slots // m.n_experts
    ks = jax.random.split(key, 5)

    def ew(k, d_in, d_out):
        w = jax.random.normal(k, (m.n_experts, d_in, d_out), jnp.float32) / (d_in ** 0.5)
        w = jnp.tile(w, (reps, 1, 1))  # physical replication of experts
        return w.astype(dtype)

    params = {
        "router": {"w": (jax.random.normal(ks[0], (d, m.n_experts), jnp.float32) * 0.02).astype(jnp.float32)},
        "wi": ew(ks[1], d, f),
        "wg": ew(ks[2], d, f),
        "wo": ew(ks[3], f, d),
    }
    specs = {
        "router": {"w": P(None, None)},
        "wi": P("data", None, TP),
        "wg": P("data", None, TP),
        "wo": P("data", TP, None),
    }
    if m.n_shared:
        fs = m.d_ff_shared or m.d_ff_expert
        pi, si = dense_init(ks[4], d, m.n_shared * fs, dtype, in_axis=DP)
        k2 = jax.random.split(ks[4], 3)
        pg, sg = dense_init(k2[0], d, m.n_shared * fs, dtype, in_axis=DP)
        po, so = dense_init(k2[1], m.n_shared * fs, d, dtype, in_axis=TP, out_axis=DP)
        params["shared"] = {"wi": pi, "wg": pg, "wo": po}
        specs["shared"] = {"wi": si, "wg": sg, "wo": so}
    return params, specs


def _routing(x2d, router_w, n_experts: int, top_k: int):
    """x2d (T, d) -> (top-k expert ids (T,k), gates (T,k), aux loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    me = probs.mean(axis=0)
    ce = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0) / ids.size
    aux = n_experts * jnp.sum(me * ce)
    return ids, gates, aux


def moe_apply_sharded(params, cfg, x, mesh_axes=("data", "model")):
    """Expert-parallel MoE for use inside shard_map over the mesh.

    ``x``: the *local* activation shard (B_l, S_l, d).  Collectives:
    all_to_all over ``data`` (dispatch / return), psum over ``model``
    (row-parallel wo).
    """
    from ..compat import axis_size

    m = cfg.moe
    data_axis, model_axis = mesh_axes
    data_size = axis_size(data_axis)
    slots = expert_slots(m.n_experts, data_size)
    reps = slots // m.n_experts
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    ids, gates, aux = _routing(x2, params["router"]["w"], m.n_experts, m.top_k)

    # map expert -> physical slot (spread over replicas by token parity)
    tok = jnp.arange(T, dtype=jnp.int32)[:, None]
    slot = ids * reps + (tok % reps)

    C = int(max(1, -(-T * m.top_k * m.capacity_factor // slots)))
    # per (token, k) -> position within its slot's send buffer
    onehot = jax.nn.one_hot(slot.reshape(-1), slots, dtype=jnp.int32)  # (T*k, slots)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # (T*k, slots)
    my_pos = (pos * onehot).sum(-1)  # (T*k,)
    keep = my_pos < C
    dropped = 1.0 - keep.mean()

    # build send buffer (slots, C, d)
    send = jnp.zeros((slots, C, d), x.dtype)
    flat_slot = slot.reshape(-1)
    src_tok = jnp.broadcast_to(tok, (T, m.top_k)).reshape(-1)
    send = send.at[flat_slot, jnp.where(keep, my_pos, 0)].add(
        jnp.where(keep[:, None], x2[src_tok], 0)
    )
    # dispatch: each shard keeps slot block s for itself -> a2a over data
    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0, tiled=True)
    # recv: (data_size * (slots/data_size), C, d) == (slots, C, d) where the
    # leading axis now enumerates source shards for MY slot(s)
    slots_local = slots // data_size  # == 1 when slots == data_size
    h = recv.reshape(data_size * slots_local, C, d)

    # local expert compute (my slots' experts), TP on ff width, row-parallel
    # out; params arrive shard_map-sliced: (slots_local, d, f_local)
    wi, wg, wo = params["wi"], params["wg"], params["wo"]
    hh = h.reshape(data_size, slots_local, C, d).transpose(1, 0, 2, 3).reshape(slots_local, data_size * C, d)
    a = jnp.einsum("etd,edf->etf", hh, wi)
    g = jnp.einsum("etd,edf->etf", hh, wg)
    o = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * a, wo)
    # §Perf C2: complete the row-parallel second matmul with a
    # REDUCE-SCATTER along d instead of an all-reduce, carry only the d/16
    # slice through the return all-to-all and combine, then all-gather once.
    # Collective payload per layer: RS(1/16) + a2a(1/16) + AG(1) ≈ 0.3x the
    # [AR(1) + a2a(1)] baseline.
    model_size = axis_size(model_axis)
    ds = d // model_size
    o = jax.lax.psum_scatter(o.astype(x.dtype), model_axis,
                             scatter_dimension=2, tiled=True)
    o = o.reshape(slots_local, data_size, C, ds).transpose(1, 0, 2, 3).reshape(slots, C, ds)

    # return trip (d-sliced)
    back = jax.lax.all_to_all(o, data_axis, split_axis=0, concat_axis=0, tiled=True)
    # combine: gather each token's k slot outputs, weight by gates
    out_tok = back[flat_slot, jnp.where(keep, my_pos, 0)]
    out_tok = jnp.where(keep[:, None], out_tok, 0)
    combined = jnp.zeros((T, ds), jnp.float32).at[src_tok].add(
        out_tok.astype(jnp.float32) * gates.reshape(-1)[:, None]
    )
    out = jax.lax.all_gather(combined.astype(x.dtype), model_axis,
                             axis=1, tiled=True)
    out = out.reshape(B, S, d)

    if "shared" in params:
        # shared experts: plain TP FFN (wi/wg column-, wo row-parallel)
        sh = params["shared"]
        a = jnp.einsum("bsd,df->bsf", x, sh["wi"]["w"])
        g = jnp.einsum("bsd,df->bsf", x, sh["wg"]["w"])
        so = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * a, sh["wo"]["w"])
        out = out + jax.lax.psum(so, model_axis)
    return out, {"aux": aux, "dropped": dropped}


def moe_apply_reference(params, cfg, x):
    """Single-device oracle: exact top-k dense routing (no capacity drop).

    Used by unit tests to validate the sharded path (up to capacity drops)
    and by CPU smoke tests.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    ids, gates, aux = _routing(x2, params["router"]["w"], m.n_experts, m.top_k)
    wi, wg, wo = params["wi"], params["wg"], params["wo"]
    out = jnp.zeros((T, d), jnp.float32)
    for k in range(m.top_k):
        e = ids[:, k]
        a = jnp.einsum("td,tdf->tf", x2, wi[e])
        g = jnp.einsum("td,tdf->tf", x2, wg[e])
        o = jnp.einsum("tf,tfd->td", jax.nn.silu(g) * a, wo[e])
        out = out + o.astype(jnp.float32) * gates[:, k][:, None]
    out = out.astype(x.dtype).reshape(B, S, d)
    if "shared" in params:
        sh = params["shared"]
        a = jnp.einsum("bsd,df->bsf", x, sh["wi"]["w"])
        g = jnp.einsum("bsd,df->bsf", x, sh["wg"]["w"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * a, sh["wo"]["w"])
    return out, {"aux": aux, "dropped": jnp.float32(0)}
