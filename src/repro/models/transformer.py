"""Model assembly for every assigned architecture family.

One generic LM covering dense / MoE / MLA attention, Mamba2 (SSM),
Zamba2-style hybrid (mamba backbone + weight-tied shared attention block),
Llama-3.2-Vision-style gated cross-attention layers, and a Seamless-style
encoder-decoder.  Layers are stacked with ``lax.scan`` (keeps HLO size O(1)
in depth — critical for 80-100 layer dry-runs) and rematerialized per layer
according to ``cfg.remat``.

Caches: per-layer tensors are stacked on a leading layer axis and carried as
scan xs/ys; the decode position lives in a single global ``length`` scalar
injected into each layer's view inside the scan body.

API (used by launch/dryrun, launch/train, serve/engine):

* ``init(key)``                       -> (params, specs)
* ``loss_fn(params, batch)``          -> (loss, metrics)
* ``prefill(params, batch)``          -> (last_logits, cache)
* ``decode_step(params, cache, tok)`` -> (last_logits, cache)
* ``input_specs(shape)`` / ``cache_specs(shape)`` live in registry.py
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .attention import (
    cross_attn_apply,
    gqa_apply,
    init_cross_attn,
    init_gqa,
    init_mla,
    mla_apply,
)
from .common import DP, TP, dense_init, dtype_of, embed_init, rmsnorm, rmsnorm_init
from .moe import init_moe, moe_apply_reference, moe_apply_sharded
from .ssm import init_mamba2, mamba2_apply

__all__ = ["LMModel", "init_mlp", "mlp_apply"]


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, dtype):
    ks = jax.random.split(key, 3)
    pi, si = dense_init(ks[0], d, ff, dtype, in_axis=DP)
    pg, sg = dense_init(ks[1], d, ff, dtype, in_axis=DP)
    po, so = dense_init(ks[2], ff, d, dtype, in_axis=TP, out_axis=DP)
    return {"wi": pi, "wg": pg, "wo": po}, {"wi": si, "wg": sg, "wo": so}


def mlp_apply(p, x):
    a = jnp.einsum("bsd,df->bsf", x, p["wi"]["w"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"]["w"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * a, p["wo"]["w"])


# ---------------------------------------------------------------------------
# layer inits
# ---------------------------------------------------------------------------


def _init_dense_layer(key, cfg, dtype, cross: bool = False, use_mla: bool = False):
    ks = jax.random.split(key, 2)
    n1, s1 = rmsnorm_init(cfg.d_model, dtype)
    n2, s2 = rmsnorm_init(cfg.d_model, dtype)
    if cross:
        pa, sa = init_cross_attn(ks[0], cfg, dtype)
    elif use_mla:
        pa, sa = init_mla(ks[0], cfg, dtype)
    else:
        pa, sa = init_gqa(ks[0], cfg, dtype)
    pm, sm = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return (
        {"n1": n1, "attn": pa, "n2": n2, "mlp": pm},
        {"n1": s1, "attn": sa, "n2": s2, "mlp": sm},
    )


def _init_moe_layer(key, cfg, dtype, data_size: int):
    ks = jax.random.split(key, 2)
    n1, s1 = rmsnorm_init(cfg.d_model, dtype)
    n2, s2 = rmsnorm_init(cfg.d_model, dtype)
    use_mla = cfg.mla is not None
    pa, sa = init_mla(ks[0], cfg, dtype) if use_mla else init_gqa(ks[0], cfg, dtype)
    pm, sm = init_moe(ks[1], cfg, dtype, data_size)
    return (
        {"n1": n1, "attn": pa, "n2": n2, "moe": pm},
        {"n1": s1, "attn": sa, "n2": s2, "moe": sm},
    )


def _init_ssm_layer(key, cfg, dtype):
    n1, s1 = rmsnorm_init(cfg.d_model, dtype)
    pm, sm = init_mamba2(key, cfg, dtype)
    return {"n1": n1, "mamba": pm}, {"n1": s1, "mamba": sm}


def _stack(init_one, key, n):
    keys = jax.random.split(key, n)
    _, sp = init_one(keys[0])
    ps = jax.vmap(lambda k: init_one(k)[0])(keys)
    sp = jax.tree.map(lambda s: P(None, *s), sp, is_leaf=lambda s: isinstance(s, P))
    return ps, sp


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LMModel:
    cfg: ModelConfig
    data_size: int = 16  # data-axis extent (for MoE expert slotting)
    use_sharded_moe: bool = False  # shard_map EP; False = reference (CPU tests)
    batch_axes: Tuple[str, ...] = ("data",)
    mesh: Optional[object] = None

    # ------------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        dtype = dtype_of(cfg)
        ks = jax.random.split(key, 8)
        params: Dict = {}
        specs: Dict = {}
        pe, se = embed_init(ks[0], cfg.vocab, cfg.d_model, dtype)
        params["embed"], specs["embed"] = pe, se
        if not cfg.tie_embeddings:
            pu, su = dense_init(ks[1], cfg.d_model, cfg.vocab, dtype, in_axis=DP)
            params["unembed"], specs["unembed"] = pu, su
        nf, sf = rmsnorm_init(cfg.d_model, dtype)
        params["final_norm"], specs["final_norm"] = nf, sf

        fam = cfg.family
        if fam in ("dense", "moe"):
            use_mla = cfg.mla is not None
            if fam == "moe":
                init_one = lambda k: _init_moe_layer(k, cfg, dtype, self.data_size)
            else:
                init_one = lambda k: _init_dense_layer(k, cfg, dtype, use_mla=use_mla)
            params["layers"], specs["layers"] = _stack(init_one, ks[2], cfg.n_layers)
        elif fam == "ssm":
            params["layers"], specs["layers"] = _stack(
                lambda k: _init_ssm_layer(k, cfg, dtype), ks[2], cfg.n_layers)
        elif fam == "hybrid":
            params["layers"], specs["layers"] = _stack(
                lambda k: _init_ssm_layer(k, cfg, dtype), ks[2], cfg.n_layers)
            params["shared"], specs["shared"] = _init_dense_layer(ks[3], cfg, dtype)
        elif fam == "vlm":
            period = cfg.cross_attn_every
            n_cross = cfg.n_layers // period
            n_self_per = period - 1
            p_self, s_self = _stack(lambda k: _init_dense_layer(k, cfg, dtype),
                                    ks[2], n_cross * n_self_per)
            params["self_layers"] = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]), p_self)
            specs["self_layers"] = jax.tree.map(
                lambda s: P(None, *s), s_self, is_leaf=lambda s: isinstance(s, P))
            params["cross_layers"], specs["cross_layers"] = _stack(
                lambda k: _init_dense_layer(k, cfg, dtype, cross=True), ks[3], n_cross)
            pv, sv = dense_init(ks[4], cfg.d_vision, cfg.d_model, dtype,
                                in_axis=None, out_axis=None)
            params["vis_proj"], specs["vis_proj"] = pv, sv
        elif fam == "audio":
            params["enc_layers"], specs["enc_layers"] = _stack(
                lambda k: _init_dense_layer(k, cfg, dtype), ks[2], cfg.n_enc_layers)

            def init_dec(k):
                k1, k2 = jax.random.split(k)
                p1, s1 = _init_dense_layer(k1, cfg, dtype)
                pc, sc = init_cross_attn(k2, cfg, dtype)
                nc, snc = rmsnorm_init(cfg.d_model, dtype)
                p1["cross"], s1["cross"] = pc, sc
                p1["nc"], s1["nc"] = nc, snc
                return p1, s1

            params["dec_layers"], specs["dec_layers"] = _stack(init_dec, ks[3], cfg.n_dec_layers)
            pa, sa = dense_init(ks[4], cfg.d_audio, cfg.d_model, dtype,
                                in_axis=None, out_axis=None)
            params["audio_proj"], specs["audio_proj"] = pa, sa
        else:  # pragma: no cover
            raise ValueError(fam)
        return params, specs

    # ------------------------------------------------------------------
    # building blocks
    # ------------------------------------------------------------------
    def _attn(self, p, x, positions, mode, cache):
        if self.cfg.mla is not None:
            return mla_apply(p, self.cfg, x, positions, mode, cache)
        return gqa_apply(p, self.cfg, x, positions, mode, cache)

    def _moe_ffn(self, p, x):
        if not self.use_sharded_moe:
            return moe_apply_reference(p, self.cfg, x)
        bspec = P(self.batch_axes, None, None)
        pspec = {
            "router": {"w": P(None, None)},
            "wi": P("data", None, "model"),
            "wg": P("data", None, "model"),
            "wo": P("data", "model", None),
        }
        if "shared" in p:
            pspec["shared"] = {
                "wi": {"w": P(None, "model")},
                "wg": {"w": P(None, "model")},
                "wo": {"w": P("model", None)},
            }
        from ..compat import shard_map

        return shard_map(
            lambda pp, xx: moe_apply_sharded(pp, self.cfg, xx),
            mesh=self.mesh,
            in_specs=(pspec, bspec),
            out_specs=(bspec, {"aux": P(), "dropped": P()}),
        )(p, x)

    def _dense_layer_apply(self, p, x, positions, mode, cache):
        cfg = self.cfg
        h, nc = self._attn(p["attn"], rmsnorm(x, p["n1"]["scale"], cfg.norm_eps),
                           positions, mode, cache)
        x = x + h
        x = x + mlp_apply(p["mlp"], rmsnorm(x, p["n2"]["scale"], cfg.norm_eps))
        return x, nc, jnp.float32(0)

    def _moe_layer_apply(self, p, x, positions, mode, cache):
        cfg = self.cfg
        h, nc = self._attn(p["attn"], rmsnorm(x, p["n1"]["scale"], cfg.norm_eps),
                           positions, mode, cache)
        x = x + h
        m, aux = self._moe_ffn(p["moe"], rmsnorm(x, p["n2"]["scale"], cfg.norm_eps))
        return x + m, nc, aux["aux"]

    def _ssm_layer_apply(self, p, x, mode, cache):
        cfg = self.cfg
        h, nc = mamba2_apply(p["mamba"], cfg,
                             rmsnorm(x, p["n1"]["scale"], cfg.norm_eps), mode, cache)
        return x + h, nc

    def _remat(self, fn, mode):
        if mode == "train" and self.cfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.cfg.remat == "dots" else None)
            return jax.checkpoint(fn, policy=policy)
        return fn

    def _embed(self, params, tokens):
        y = jnp.take(params["embed"]["w"], tokens, axis=0)
        if self.mesh is not None:
            y = jax.lax.with_sharding_constraint(y, P(self.batch_axes, None, None))
        return y

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"])
        return jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"])

    # scan cache helpers ---------------------------------------------------
    @staticmethod
    def _with_len(lc, glen):
        """Inject the global decode position into a per-layer cache view."""
        if lc is None or glen is None:
            return lc
        out = dict(lc)
        out["length"] = glen
        return out

    def _xs_caches(self, caches_layers, n_layers, mode):
        if mode in ("train", "encode") or caches_layers is None:
            return jnp.zeros((n_layers, 1), jnp.int32)  # dummy xs
        return caches_layers

    # ------------------------------------------------------------------
    # backbones
    # ------------------------------------------------------------------
    def _decoder_stack(self, params_layers, x, positions, mode, caches, apply3):
        """Homogeneous scan.  caches: {"layers": stacked, "length": scalar}|None.
        apply3(p, x, positions, mode, cache) -> (x, new_cache, aux)."""
        cfg = self.cfg
        glen = caches["length"] if (caches is not None and mode == "decode") else None
        n_layers = jax.tree.leaves(params_layers)[0].shape[0]

        def body(carry, xs):
            lp, lc = xs
            cache_in = self._with_len(lc, glen) if mode == "decode" else None
            fn = self._remat(
                lambda q, qp, qc: apply3(qp, q, positions, mode, qc), mode)
            xx, nc, aux = fn(carry, lp, cache_in)
            if nc is None:
                nc = jnp.int32(0)  # dummy ys
            return xx, (nc, aux)

        xs_c = self._xs_caches(caches["layers"] if caches else None, n_layers, mode)
        x, (ncaches, auxs) = jax.lax.scan(body, x, (params_layers, xs_c))
        new_caches = None
        if mode == "prefill":
            new_caches = {"layers": ncaches, "length": jnp.int32(x.shape[1])}
        elif mode == "decode":
            new_caches = {"layers": ncaches, "length": caches["length"] + 1}
        return x, new_caches, auxs.sum()

    # ------------------------------------------------------------------
    def _full_forward(self, params, batch, mode, caches=None):
        cfg = self.cfg
        fam = cfg.family
        if fam == "vlm":
            return self._vlm_forward(params, batch, mode, caches)
        if fam == "audio":
            return self._audio_forward(params, batch, mode, caches)

        tokens = batch["tokens"]
        B, S = tokens.shape
        if mode == "decode":
            positions = jnp.broadcast_to(caches["length"], (B, 1))
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self._embed(params, tokens)

        if fam in ("dense", "moe"):
            apply3 = self._moe_layer_apply if fam == "moe" else self._dense_layer_apply
            x, ncaches, aux = self._decoder_stack(
                params["layers"], x, positions, mode, caches, apply3)
        elif fam == "ssm":
            apply3 = lambda p, q, pos, m, c: (*self._ssm_layer_apply(p, q, m, c), jnp.float32(0))
            x, ncaches, aux = self._decoder_stack(
                params["layers"], x, positions, mode, caches, apply3)
        elif fam == "hybrid":
            x, ncaches, aux = self._hybrid_backbone(params, x, positions, mode, caches)
        else:  # pragma: no cover
            raise ValueError(fam)

        x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, ncaches, aux

    # -- hybrid (zamba2) ----------------------------------------------------
    def _hybrid_backbone(self, params, x, positions, mode, caches):
        cfg = self.cfg
        period = cfg.shared_attn_every
        n_shared = cfg.n_layers // period
        head = n_shared * period
        tail = cfg.n_layers - head
        glen = caches["length"] if (caches is not None and mode == "decode") else None

        mp_all = params["layers"]
        mp_head = jax.tree.map(lambda a: a[:head].reshape(n_shared, period, *a.shape[1:]),
                               mp_all)
        mp_tail = jax.tree.map(lambda a: a[head:], mp_all)

        mc_all = caches["mamba"] if caches is not None and mode != "prefill" else None
        if mc_all is not None:
            mc_head = jax.tree.map(
                lambda a: a[:head].reshape(n_shared, period, *a.shape[1:]), mc_all)
            mc_tail = jax.tree.map(lambda a: a[head:], mc_all)
        else:
            mc_head = jnp.zeros((n_shared, period, 1), jnp.int32)
            mc_tail = jnp.zeros((max(tail, 1), 1), jnp.int32)
        sc_all = (caches["shared"] if caches is not None and mode != "prefill"
                  else jnp.zeros((n_shared, 1), jnp.int32))

        def mamba_fn(q, qp, qc):
            cache_in = self._with_len(qc, glen) if mode == "decode" else None
            return self._ssm_layer_apply(qp, q, mode, cache_in)

        def super_body(carry, xs):
            xx = carry
            mp, mc, sc = xs

            def inner(c2, xs2):
                lp, lc = xs2
                fn = self._remat(mamba_fn, mode)
                yy, ncc = fn(c2, lp, lc)
                return yy, (ncc if ncc is not None else jnp.int32(0))

            xx, nmc = jax.lax.scan(inner, xx, (mp, mc))
            cache_in = self._with_len(sc, glen) if mode == "decode" else None
            fn = self._remat(
                lambda q, qp, qc: self._dense_layer_apply(qp, q, positions, mode, qc),
                mode)
            xx, nsc, _ = fn(xx, params["shared"], cache_in)
            return xx, (nmc, nsc if nsc is not None else jnp.int32(0))

        x, (nmc_head, nsc) = jax.lax.scan(super_body, x, (mp_head, mc_head, sc_all))

        if tail:
            def tail_body(c2, xs2):
                lp, lc = xs2
                fn = self._remat(mamba_fn, mode)
                yy, ncc = fn(c2, lp, lc)
                return yy, (ncc if ncc is not None else jnp.int32(0))
            x, nmc_tail = jax.lax.scan(tail_body, x, (mp_tail, mc_tail))

        if mode == "train":
            return x, None, jnp.float32(0)
        nmc = jax.tree.map(lambda h: h.reshape(head, *h.shape[2:]), nmc_head)
        if tail:
            nmc = jax.tree.map(lambda h, t: jnp.concatenate([h, t], 0), nmc, nmc_tail)
        length = (caches["length"] + 1) if mode == "decode" else jnp.int32(x.shape[1])
        return x, {"mamba": nmc, "shared": nsc, "length": length}, jnp.float32(0)

    # -- vlm ----------------------------------------------------------------
    def _vlm_forward(self, params, batch, mode, caches=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        glen = caches["length"] if (caches is not None and mode == "decode") else None
        if mode == "decode":
            positions = jnp.broadcast_to(caches["length"], (B, 1))
            vis = None
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            vis = jnp.einsum("bnd,df->bnf", batch["vision_embeds"],
                             params["vis_proj"]["w"])
        x = self._embed(params, tokens)
        n_cross = cfg.n_layers // cfg.cross_attn_every

        scs = (caches["self"] if caches is not None and mode == "decode"
               else jnp.zeros((n_cross, cfg.cross_attn_every - 1, 1), jnp.int32))
        ccs = (caches["cross"] if caches is not None and mode == "decode"
               else jnp.zeros((n_cross, 1), jnp.int32))

        def super_body(carry, xs):
            xx = carry
            sp, cp, sc, cc = xs

            def inner(c2, xs2):
                lp, lc = xs2
                cache_in = self._with_len(lc, glen) if mode == "decode" else None
                fn = self._remat(
                    lambda q, qp, qc: self._dense_layer_apply(qp, q, positions, mode, qc),
                    mode)
                yy, ncc, _ = fn(c2, lp, cache_in)
                return yy, (ncc if ncc is not None else jnp.int32(0))

            xx, nsc = jax.lax.scan(inner, xx, (sp, sc))

            def cross_fn(q, qp, qc):
                h, ncc = cross_attn_apply(qp["attn"], cfg,
                                          rmsnorm(q, qp["n1"]["scale"], cfg.norm_eps),
                                          vis, mode, qc)
                q = q + h
                q = q + mlp_apply(qp["mlp"], rmsnorm(q, qp["n2"]["scale"], cfg.norm_eps))
                return q, ncc
            fn = self._remat(cross_fn, mode)
            cc_in = cc if mode == "decode" else None
            xx, ncc = fn(xx, cp, cc_in)
            return xx, (nsc, ncc if ncc is not None else jnp.int32(0))

        x, (nsc, ncc) = jax.lax.scan(
            super_body, x, (params["self_layers"], params["cross_layers"], scs, ccs))
        x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = self._logits(params, x)
        if mode == "train":
            return logits, None, jnp.float32(0)
        length = (caches["length"] + 1) if mode == "decode" else jnp.int32(S)
        return logits, {"self": nsc, "cross": ncc, "length": length}, jnp.float32(0)

    # -- audio (enc-dec) -----------------------------------------------------
    def _audio_forward(self, params, batch, mode, caches=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, St = tokens.shape
        glen = caches["length"] if (caches is not None and mode == "decode") else None

        if mode == "decode":
            positions = jnp.broadcast_to(caches["length"], (B, 1))
            enc_out = None
        else:
            frames = batch["frames"]
            Sa = frames.shape[1]
            h = jnp.einsum("bsa,ad->bsd", frames, params["audio_proj"]["w"])
            pos_enc = jnp.broadcast_to(jnp.arange(Sa), (B, Sa))

            def enc_body(c2, lp):
                def enc_fn(q, qp):
                    a, _ = gqa_apply(qp["attn"], cfg,
                                     rmsnorm(q, qp["n1"]["scale"], cfg.norm_eps),
                                     pos_enc, "encode", None)
                    q = q + a
                    q = q + mlp_apply(qp["mlp"], rmsnorm(q, qp["n2"]["scale"], cfg.norm_eps))
                    return q
                fn = self._remat(enc_fn, mode)
                return fn(c2, lp), None

            h, _ = jax.lax.scan(enc_body, h, params["enc_layers"])
            enc_out = h
            positions = jnp.broadcast_to(jnp.arange(St), (B, St))

        x = self._embed(params, tokens)
        n = cfg.n_dec_layers
        scs = (caches["self"] if caches is not None and mode == "decode"
               else jnp.zeros((n, 1), jnp.int32))
        ccs = (caches["cross"] if caches is not None and mode == "decode"
               else jnp.zeros((n, 1), jnp.int32))

        def dec_body(carry, xs):
            lp, lc_self, lc_cross = xs
            cs_in = self._with_len(lc_self, glen) if mode == "decode" else None
            cc_in = lc_cross if mode == "decode" else None

            def dec_fn(q, qp, qcs, qcc):
                a, ncs = gqa_apply(qp["attn"], cfg,
                                   rmsnorm(q, qp["n1"]["scale"], cfg.norm_eps),
                                   positions, mode, qcs)
                q = q + a
                c, ncc = cross_attn_apply(qp["cross"], cfg,
                                          rmsnorm(q, qp["nc"]["scale"], cfg.norm_eps),
                                          enc_out, mode, qcc)
                q = q + c
                q = q + mlp_apply(qp["mlp"], rmsnorm(q, qp["n2"]["scale"], cfg.norm_eps))
                return q, ncs, ncc
            fn = self._remat(dec_fn, mode)
            xx, ncs, ncc = fn(carry, lp, cs_in, cc_in)
            return xx, (ncs if ncs is not None else jnp.int32(0),
                        ncc if ncc is not None else jnp.int32(0))

        x, (nsc, ncc) = jax.lax.scan(dec_body, x, (params["dec_layers"], scs, ccs))
        x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = self._logits(params, x)
        if mode == "train":
            return logits, None, jnp.float32(0)
        length = (caches["length"] + 1) if mode == "decode" else jnp.int32(St)
        return logits, {"self": nsc, "cross": ncc, "length": length}, jnp.float32(0)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        tokens = batch["tokens"]
        inp = {**batch, "tokens": tokens[:, :-1]}
        logits, _, aux = self._full_forward(params, inp, "train")
        targets = tokens[:, 1:]
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = (lse - gold).mean()
        loss = nll + self.cfg.moe.router_aux_coef * aux if self.cfg.moe else nll
        return loss, {"nll": nll, "aux": aux}

    def prefill(self, params, batch):
        logits, caches, _ = self._full_forward(params, batch, "prefill")
        return logits[:, -1], caches

    def decode_step(self, params, caches, tokens):
        logits, ncaches, _ = self._full_forward(params, {"tokens": tokens}, "decode", caches)
        return logits[:, -1], ncaches
