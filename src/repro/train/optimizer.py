"""Optimizers (no external deps): AdamW and Adafactor.

State shards exactly like the parameters (the ZeRO property falls out of the
param PartitionSpecs).  AdamW keeps f32 moments; Adafactor keeps factored
row/col second moments (rank-1) for >=2-D params — grok-1-314B uses it so
params + state fit 16 GiB/chip HBM (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "make_optimizer", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> state
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)
    state_specs: Callable  # param_specs -> state_specs


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _wd_mask(path_leaf) -> bool:
    # no weight decay on norms/biases/scalars
    return path_leaf.ndim >= 2


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params),
        }

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m2 / bc1
            vhat = v2 / bc2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if _wd_mask(p):
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * step_).astype(p.dtype),
                    m2.astype(moment_dtype), v2.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs}

    return Optimizer(init, update, state_specs)


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments; no first moment (memory ~= params/r + params/c)."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                r = beta * s["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(axis=-2)
                rc = r.mean(axis=-1, keepdims=True)
                vhat = (r / jnp.maximum(rc, eps))[..., None] * c[..., None, :]
                ns = {"r": r, "c": c}
            else:
                vhat = beta * s["v"] + (1 - beta) * g2
                ns = {"v": vhat}
            u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and _wd_mask(p):
                u = u + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * u).astype(p.dtype), ns)

        flat_p, tree = jax.tree.flatten(params)
        flat_g = tree.flatten_up_to(grads)
        flat_s = tree.flatten_up_to(state["f"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tree.unflatten([o[0] for o in outs])
        new_f = tree.unflatten([o[1] for o in outs])
        return new_params, {"f": new_f}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def one(sp):
            # r drops the last dim's axis, c drops the second-to-last
            axes = tuple(sp)
            if len(axes) >= 2:
                return {"r": P(*axes[:-1]), "c": P(*(axes[:-2] + axes[-1:]))}
            return {"v": P(*axes)}

        return {"f": jax.tree.map(one, param_specs,
                                  is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))}

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
