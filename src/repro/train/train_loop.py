"""train_step factory: value_and_grad + clip + optimizer, with optional
microbatch gradient accumulation (scan) and donated state."""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import Optimizer, clip_by_global_norm

__all__ = ["make_train_step"]


def make_train_step(model, optimizer: Optimizer, *, grad_clip: float = 1.0,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).  ``batch`` leading dim must divide by
    ``microbatches`` (gradient accumulation via scan keeps peak activation
    memory ~1/microbatches)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def mb(carry, mbatch):
                acc = carry
                (l, m), g = grad_fn(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, m)

            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, ms) = jax.lax.scan(mb, zero, split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        return new_params, new_opt, metrics

    return train_step
