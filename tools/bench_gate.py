#!/usr/bin/env python
"""Benchmark regression gate: diff fresh BENCH_*.json against committed
baselines and fail (exit 1) on any drift in the *deterministic* metrics.

The simulator's headline numbers are counted, not measured: IOPS, dispatched
bytes, read amplification, modelled IO times, and the attributed latency
percentiles are pure functions of (code, seed, device constants).  On equal
code they reproduce bit-for-bit, so the gate can be strict:

* integers (``n_iops``, ``bytes_read``, tier op counts, ...) must be equal;
* deterministic floats (``model_io_s``, ``per_row_us`` percentiles, ...)
  must match to 1e-6 relative (rounding at the artifact write site is the
  only slack needed);
* nearest-rank percentile metrics (keys carrying a ``p50``/``p99``/``p999``
  segment, e.g. the serving plane's per-tenant latency summaries) are
  modelled, not measured — they follow the strict rules above even when the
  key also contains a rate-marker substring;
* ``slo.*`` metrics (objectives, breach counters, detection delays, burn
  rates — anything under an ``slo`` path segment or an ``slo_``-prefixed
  key) are virtual-clock outputs: always strict, never rate-skipped — a
  drifted detection delay is a regression of the monitoring plane itself;
* failure/recovery metrics (the chaos bench's ``fault.*`` subtree, the
  ``shed.*``/``retry.*``/``failover.*``/``error.*`` counter families and
  any ``availability*`` key) get the same always-strict treatment: the
  fault schedules are seeded and the clock is virtual, so these reproduce
  bit-for-bit on equal code;
* retrieval metrics (``recall*`` keys, ``*_qps`` throughput on the virtual
  clock, anything under a ``search`` path segment or a ``fullscan``-marked
  key) are counted/modelled outputs of the search bench: always strict — a
  drifted recall@k is an index regression, not machine noise;
* wall-clock and throughput numbers (``rows_per_s``, ``cpu_decode_s``,
  speedups) are machine noise and are ignored unless ``--rates`` opts in,
  which checks them only within a loose ``--rate-tol`` band.

Keys present in the baseline but missing from the current artifact are
failures (a silently dropped metric is a regression of the *benchmark*);
keys new in the current artifact are fine — they are tomorrow's baseline.
The ``meta`` subtree (git sha, timestamp, host facts) is provenance, not a
metric, and is never compared.

Usage::

    python benchmarks/run.py --smoke take decode dataset ingest
    python tools/bench_gate.py --baseline benchmarks/baselines/smoke

compares every ``BENCH_*.json`` in the baseline dir against its same-named
sibling in the current directory (override with ``--current``).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import List

# substrings marking a metric as measured (machine-dependent) rather than
# counted — skipped unless --rates
RATE_MARKERS = ("rows_per_s", "per_s", "speedup", "cpu_", "wall", "walk",
                "tokens", "mtok", "mvals")
# exact key names that are wall-clock measurements without a marker substring
RATE_EXACT = frozenset({"scan_s"})
# nearest-rank percentile metrics (p50/p99/p999 latency summaries from the
# serving plane and the latency attributor) are *modelled*, not measured:
# deterministic on equal code, so they get the strict rules (ints counted,
# floats 1e-6) even when the key also carries a rate marker — e.g.
# "p99_speedup_serial_over_interleaved" is a modelled ratio, not wall clock.
PCT_RE = re.compile(r"(?:^|_)p\d+(?:_|$)")
# SLO subsystem outputs (breach counters, detection delays, burn thresholds,
# dotted slo.* counter names) are deterministic virtual-clock metrics: any
# path that enters an "slo" segment — or a key prefixed "slo_"/"slo." — is
# compared strictly regardless of rate-marker substrings.
SLO_RE = re.compile(r"(?:^|\.)slo[._]|(?:^|\.)slo$")
# Failure/recovery-plane outputs (the chaos bench's fault.* subtree, the
# shed./retry./failover./error. counter families, availability gates) are
# virtual-clock deterministic like slo.*: always strict, never rate-skipped
# — a drifted availability or shed count is a regression of the recovery
# machinery itself.
FAULT_RE = re.compile(
    r"(?:^|\.)fault[._]|(?:^|\.)fault$"
    r"|(?:^|[._])(?:shed|retry|failover|error)[._]"
    r"|(?:^|[._])availability")
# Retrieval-quality and search-throughput outputs (the search bench's
# recall@k gate, search/full-scan QPS on the virtual clock, any key under a
# "search" path segment) are counted/modelled like the rest of the
# simulator: always strict — a drifted recall or QPS is a regression of the
# index or the serving path, never machine noise.
SEARCH_RE = re.compile(
    r"(?:^|[._])recall|(?:^|[._])qps"
    r"|(?:^|\.)search[._]|(?:^|\.)search$"
    r"|(?:^|[._])fullscan")
FLOAT_RTOL = 1e-6


def _is_percentile_key(key: str) -> bool:
    return PCT_RE.search(key.lower()) is not None


def _is_slo_path(path: str) -> bool:
    return SLO_RE.search(path.lower()) is not None


def _is_fault_path(path: str) -> bool:
    return FAULT_RE.search(path.lower()) is not None


def _is_search_path(path: str) -> bool:
    return SEARCH_RE.search(path.lower()) is not None


def _is_rate_key(key: str) -> bool:
    k = key.lower()
    return k in RATE_EXACT or any(m in k for m in RATE_MARKERS)


def compare(baseline, current, *, rates: bool = False,
            rate_tol: float = 0.5, path: str = "") -> List[str]:
    """Recursive diff; returns human-readable failure lines (empty = pass)."""
    fails: List[str] = []
    if isinstance(baseline, dict):
        if not isinstance(current, dict):
            return [f"{path}: expected object, got {type(current).__name__}"]
        for key, bval in baseline.items():
            if key == "meta":
                continue
            sub = f"{path}.{key}" if path else key
            if key not in current:
                fails.append(f"{sub}: missing from current artifact")
                continue
            fails += compare(bval, current[key], rates=rates,
                             rate_tol=rate_tol, path=sub)
        return fails
    if isinstance(baseline, list):
        if not isinstance(current, list) or len(current) != len(baseline):
            return [f"{path}: list shape changed "
                    f"({len(baseline)} -> {len(current) if isinstance(current, list) else type(current).__name__})"]
        for i, (b, c) in enumerate(zip(baseline, current)):
            fails += compare(b, c, rates=rates, rate_tol=rate_tol,
                             path=f"{path}[{i}]")
        return fails

    # leaf: classify by the final key segment.  Percentile keys are checked
    # first — a modelled percentile stays strict even if its name happens to
    # contain a rate-marker substring.
    leaf_key = path.rsplit(".", 1)[-1]
    if not _is_percentile_key(leaf_key) and not _is_slo_path(path) \
            and not _is_fault_path(path) and not _is_search_path(path) \
            and _is_rate_key(leaf_key):
        if rates and isinstance(baseline, (int, float)) \
                and isinstance(current, (int, float)) and baseline:
            rel = abs(current - baseline) / abs(baseline)
            if rel > rate_tol:
                fails.append(f"{path}: rate drifted {rel:.1%} "
                             f"(> {rate_tol:.0%}): {baseline} -> {current}")
        return fails
    if isinstance(baseline, bool) or isinstance(current, bool) \
            or isinstance(baseline, str) or baseline is None:
        if baseline != current:
            fails.append(f"{path}: {baseline!r} -> {current!r}")
        return fails
    if isinstance(baseline, int) and isinstance(current, int):
        if baseline != current:
            fails.append(f"{path}: counted metric changed: "
                         f"{baseline} -> {current}")
        return fails
    if isinstance(baseline, (int, float)) and isinstance(current, (int, float)):
        denom = max(abs(baseline), abs(current), 1e-12)
        if abs(current - baseline) / denom > FLOAT_RTOL:
            fails.append(f"{path}: deterministic float drifted: "
                         f"{baseline} -> {current}")
        return fails
    if baseline != current:
        fails.append(f"{path}: {baseline!r} -> {current!r}")
    return fails


def gate(baseline_dir: str, current_dir: str, *, rates: bool = False,
         rate_tol: float = 0.5, names: List[str] | None = None,
         out=sys.stdout) -> int:
    """Compare artifacts; print a report; return the process exit code."""
    if names:
        base_paths = [os.path.join(baseline_dir, n) for n in names]
    else:
        base_paths = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if not base_paths:
        print(f"bench_gate: no baselines under {baseline_dir}", file=out)
        return 2
    n_fail = 0
    for bp in base_paths:
        name = os.path.basename(bp)
        cp = os.path.join(current_dir, name)
        if not os.path.exists(cp):
            print(f"FAIL {name}: current artifact missing ({cp})", file=out)
            n_fail += 1
            continue
        with open(bp) as f:
            base = json.load(f)
        with open(cp) as f:
            cur = json.load(f)
        fails = compare(base, cur, rates=rates, rate_tol=rate_tol)
        if fails:
            n_fail += 1
            print(f"FAIL {name}: {len(fails)} regression(s)", file=out)
            for line in fails:
                print(f"  {line}", file=out)
        else:
            print(f"OK   {name}", file=out)
    return 1 if n_fail else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*",
                    help="specific BENCH_*.json basenames (default: every "
                         "baseline in --baseline)")
    ap.add_argument("--baseline", default="benchmarks/baselines/smoke",
                    help="directory holding the committed baseline artifacts")
    ap.add_argument("--current", default=".",
                    help="directory holding the freshly produced artifacts")
    ap.add_argument("--rates", action="store_true",
                    help="also check measured rates (rows_per_s etc.) "
                         "within --rate-tol")
    ap.add_argument("--rate-tol", type=float, default=0.5,
                    help="relative tolerance for --rates (default 0.5)")
    args = ap.parse_args(argv)
    return gate(args.baseline, args.current, rates=args.rates,
                rate_tol=args.rate_tol, names=args.names or None)


if __name__ == "__main__":
    sys.exit(main())
