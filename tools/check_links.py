#!/usr/bin/env python
"""Docs link check: every relative markdown link must resolve to a real file.

  python tools/check_links.py README.md docs/ARCHITECTURE.md ...

External links (http/https/mailto) and pure anchors are skipped; anchors on
relative links are checked against the target file's existence only.  Exits
non-zero listing every broken link (the CI docs gate).
"""

import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check(path: str) -> list:
    broken = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for target in LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not os.path.exists(os.path.join(base, rel)):
                    broken.append(f"{path}:{lineno}: broken link -> {target}")
    return broken


def main(argv) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    broken = []
    for path in argv:
        if not os.path.exists(path):
            broken.append(f"{path}: file not found")
            continue
        broken.extend(check(path))
    for b in broken:
        print(b)
    if not broken:
        print(f"ok: all relative links resolve in {len(argv)} file(s)")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
