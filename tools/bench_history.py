#!/usr/bin/env python
"""Append each benchmark run's headline metrics to ``BENCH_TRAJECTORY.jsonl``.

The bench artifacts (``BENCH_*.json``) are per-run snapshots; the gate
(:mod:`tools.bench_gate`) pins them against committed baselines, but neither
answers "how did the headline move across the last N commits".  This tool is
the missing trajectory: one JSON line per run, carrying

* the run provenance every artifact already stamps (``meta.run``: git sha,
  timestamp, store spec, smoke flag) — the sha/timestamp come from the
  artifact, **not** from the clock at append time, so replaying old
  artifacts reconstructs history faithfully;
* every artifact's ``headline`` subtree (the numbers each bench declares
  to be its point), keyed by bench name.

Appending is idempotent per (git_sha, smoke, benches) triple: re-running CI
on the same commit updates nothing unless ``--force`` is given, so the file
stays one line per distinct run instead of one per retry.  Lines are
self-contained JSON objects (JSONL), NaN-free by construction (the dump
site refuses NaN), and safe to commit or upload as a CI artifact.

Usage::

    python benchmarks/run.py --smoke take serve ...
    python tools/bench_history.py                    # appends one line
    python tools/bench_history.py --print            # dump the trajectory
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional

DEFAULT_OUT = "BENCH_TRAJECTORY.jsonl"


def collect(current_dir: str = ".",
            names: Optional[List[str]] = None) -> Optional[Dict]:
    """Fold the current directory's BENCH_*.json into one trajectory row.

    Returns ``None`` when no artifacts are present.  ``meta.run`` is taken
    from the first artifact (all artifacts of one run stamp the same run
    metadata); each artifact contributes its ``headline`` subtree under its
    bench name (``BENCH_serve.json`` -> ``serve``) plus, when present, the
    SLO detection summary — the serving plane's monitoring headline — the
    chaos bench's ``fault`` recovery summary (availability under faults,
    failover and shedding effectiveness, recovery time), and the search
    bench's retrieval summary (recall@k, index-vs-full-scan QPS, warm
    NVMe hit rate)."""
    if names:
        paths = [os.path.join(current_dir, n) for n in names]
    else:
        paths = sorted(glob.glob(os.path.join(current_dir, "BENCH_*.json")))
    paths = [p for p in paths if os.path.exists(p)]
    if not paths:
        return None
    row: Dict = {"run": None, "benches": {}}
    for p in paths:
        with open(p) as f:
            art = json.load(f)
        bench = os.path.basename(p)[len("BENCH_"):-len(".json")]
        run = (art.get("meta") or {}).get("run")
        if row["run"] is None and run:
            row["run"] = run
        entry: Dict = {}
        if "headline" in art:
            entry["headline"] = art["headline"]
        slo = art.get("slo")
        if isinstance(slo, dict):
            deg = slo.get("degraded") or {}
            entry["slo"] = {
                "detection_delay_s": deg.get("detection_delay_s"),
                "breaches": deg.get("breaches"),
                "healthy_breaches": slo.get("healthy_breaches"),
            }
        fault = art.get("fault")
        if isinstance(fault, dict):
            # the chaos bench's recovery headline: availability under
            # injected faults, failover/shed effectiveness, recovery time
            entry["fault"] = {
                "availability_premium_transient": fault.get(
                    "availability_premium_transient"),
                "blackout_failed_with_failover": fault.get(
                    "blackout_failed_with_failover"),
                "blackout_failed_without_failover": fault.get(
                    "blackout_failed_without_failover"),
                "shed_trips": fault.get("shed_trips"),
                "recovery_s_with_shedding": fault.get(
                    "recovery_s_with_shedding"),
            }
        hl = art.get("headline")
        if isinstance(hl, dict) and "recall_at_k" in hl:
            # the search bench's retrieval headline: answer quality and
            # index-vs-brute-force throughput across commits
            entry["search"] = {
                "recall_at_k": hl.get("recall_at_k"),
                "search_qps": hl.get("search_qps"),
                "fullscan_qps": hl.get("fullscan_qps"),
                "qps_search_over_fullscan": hl.get(
                    "qps_search_over_fullscan"),
                "warm_nvme_hit_rate": hl.get("warm_nvme_hit_rate"),
            }
        if entry:
            row["benches"][bench] = entry
    return row if row["benches"] else None


def _same_run(a: Dict, b: Dict) -> bool:
    ra, rb = a.get("run") or {}, b.get("run") or {}
    return (ra.get("git_sha") == rb.get("git_sha")
            and ra.get("smoke") == rb.get("smoke")
            and sorted(a.get("benches", {})) == sorted(b.get("benches", {})))


def append(row: Dict, out_path: str = DEFAULT_OUT,
           force: bool = False) -> bool:
    """Append ``row`` unless the last line already records the same run
    (same git sha + smoke flag + bench set).  Returns True if written."""
    last = None
    if os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    last = line
    if last is not None and not force:
        try:
            if _same_run(json.loads(last), row):
                return False
        except json.JSONDecodeError:
            pass  # corrupt tail: append anyway, history stays readable
    with open(out_path, "a") as f:
        json.dump(row, f, sort_keys=True, allow_nan=False)
        f.write("\n")
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*",
                    help="specific BENCH_*.json basenames (default: all in "
                         "--current)")
    ap.add_argument("--current", default=".",
                    help="directory holding the fresh artifacts")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"trajectory file to append to (default "
                         f"{DEFAULT_OUT})")
    ap.add_argument("--force", action="store_true",
                    help="append even if the last line records the same run")
    ap.add_argument("--print", dest="show", action="store_true",
                    help="pretty-print the existing trajectory and exit")
    args = ap.parse_args(argv)
    if args.show:
        if not os.path.exists(args.out):
            print(f"bench_history: no trajectory at {args.out}")
            return 1
        with open(args.out) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                row = json.loads(line)
                run = row.get("run") or {}
                heads = []
                for bench, entry in sorted(row.get("benches", {}).items()):
                    hl = entry.get("headline") or {}
                    nums = [f"{k}={v}" for k, v in sorted(hl.items())
                            if isinstance(v, (int, float))][:3]
                    sr = entry.get("search")
                    if sr:  # retrieval columns: quality before throughput
                        nums = [f"recall={sr.get('recall_at_k')}",
                                f"qps={sr.get('search_qps')}",
                                f"vs_scan={sr.get('qps_search_over_fullscan')}x"]
                    heads.append(f"{bench}({', '.join(nums)})")
                print(f"{run.get('git_sha')} {run.get('timestamp')} "
                      f"smoke={run.get('smoke')}: {'; '.join(heads)}")
        return 0
    row = collect(args.current, args.names or None)
    if row is None:
        print("bench_history: no BENCH_*.json artifacts found", file=sys.stderr)
        return 1
    wrote = append(row, args.out, force=args.force)
    n_benches = len(row["benches"])
    sha = (row.get("run") or {}).get("git_sha")
    print(f"bench_history: {'appended' if wrote else 'unchanged'} "
          f"({n_benches} benches, sha={sha}) -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
