#!/usr/bin/env python
"""Terminal dashboard over a serve-bench artifact: per-tier sparklines from
the metrics plane's gauge series plus the tenant SLO table.

Reads the JSON the benchmark embeds (``metrics_plane`` = the
:meth:`~repro.obs.MetricsPlane.export` form, ``slo`` = the monitor's
summary) and renders plain text — no dependencies, safe to run in CI and
upload as an artifact next to the trace.  Sparklines use the usual eighth-
block ramp; scales are printed alongside so the glyphs stay honest.

Usage::

    python benchmarks/run.py --smoke serve
    python tools/obs_report.py BENCH_serve.json
    python tools/obs_report.py BENCH_serve.json --out OBS_REPORT.txt
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

SPARKS = "▁▂▃▄▅▆▇█"


def sparkline(values: List[float], width: int = 48,
              lo: Optional[float] = None,
              hi: Optional[float] = None) -> str:
    """Render ``values`` as a fixed-width sparkline (resampled by stride).

    ``lo``/``hi`` pin the scale (e.g. 0..1 for utilization) so two lines
    are visually comparable; by default the series' own range is used."""
    if not values:
        return ""
    if len(values) > width:
        step = len(values) / width
        values = [values[min(int(i * step), len(values) - 1)]
                  for i in range(width)]
    vlo = min(values) if lo is None else lo
    vhi = max(values) if hi is None else hi
    span = vhi - vlo
    if span <= 0:
        return SPARKS[0] * len(values)
    out = []
    for v in values:
        k = int((v - vlo) / span * (len(SPARKS) - 1))
        out.append(SPARKS[max(0, min(k, len(SPARKS) - 1))])
    return "".join(out)


def _fmt(v, nd: int = 3) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def render(artifact: Dict) -> str:
    """The full text report for one artifact."""
    lines: List[str] = []
    run = (artifact.get("meta") or {}).get("run") or {}
    lines.append(f"obs report · sha={run.get('git_sha')} "
                 f"smoke={run.get('smoke')} ts={run.get('timestamp')}")
    plane = artifact.get("metrics_plane") or {}
    series: Dict[str, Dict] = plane.get("series") or {}
    if series:
        lines.append("")
        lines.append("gauge series (virtual clock)")
        width = max(len(name) for name in series)
        for name in sorted(series):
            s = series[name]
            vs = s.get("v") or []
            if not vs:
                continue
            pinned = name.endswith(".utilization")
            spark = sparkline(vs, lo=0.0 if pinned else None,
                              hi=1.0 if pinned else None)
            lines.append(f"  {name:<{width}}  {spark}  "
                         f"last={_fmt(vs[-1])} max={_fmt(max(vs))} "
                         f"n={s.get('n_samples', len(vs))}")
    lat = plane.get("latency") or {}
    if lat:
        lines.append("")
        lines.append("windowed latency (log-bucket, live horizon)")
        for name in sorted(lat):
            s = lat[name]
            lines.append(f"  {name}: n={s.get('count')} "
                         f"p50={_fmt(s.get('p50'), 5)} "
                         f"p99={_fmt(s.get('p99'), 5)} "
                         f"max={_fmt(s.get('max'), 5)} s")
    slo = artifact.get("slo") or {}
    table = (slo.get("degraded") or {}).get("table") or []
    if table:
        lines.append("")
        deg = slo.get("degraded") or {}
        lines.append(f"tenant SLO (degradation at "
                     f"t={_fmt(deg.get('t_degradation_s'))}s, premium alert "
                     f"+{_fmt(deg.get('detection_delay_s'))}s)")
        hdr = (f"  {'tenant':<10} {'slo_ms':>9} {'target':>7} {'reqs':>6} "
               f"{'bad':>5} {'bad%':>7} {'breach':>7} {'alert_t':>9}")
        lines.append(hdr)
        for row in table:
            bf = row.get("bad_fraction")
            lines.append(
                f"  {row.get('tenant', '?'):<10} "
                f"{_fmt(row.get('objective_ms')):>9} "
                f"{_fmt(row.get('target'), 2):>7} "
                f"{row.get('requests', 0):>6} "
                f"{row.get('bad', 0):>5} "
                f"{(_fmt(bf * 100, 1) + '%') if bf is not None else '-':>7} "
                f"{row.get('breaches', 0):>7} "
                f"{_fmt(row.get('first_alert_t')):>9}")
    counters = plane.get("counters") or {}
    breaches = {k: v for k, v in counters.items()
                if k.startswith("slo.breach.")}
    if breaches:
        lines.append("")
        lines.append("breach counters: " + ", ".join(
            f"{k}={v}" for k, v in sorted(breaches.items())))
    if len(lines) == 1:
        lines.append("(artifact carries no metrics_plane/slo sections)")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", nargs="?", default="BENCH_serve.json",
                    help="bench artifact with metrics_plane/slo sections")
    ap.add_argument("--out", default=None,
                    help="also write the report to this path")
    args = ap.parse_args(argv)
    try:
        with open(args.artifact) as f:
            artifact = json.load(f)
    except OSError as e:
        print(f"obs_report: cannot read {args.artifact}: {e}",
              file=sys.stderr)
        return 1
    text = render(artifact)
    sys.stdout.write(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
