"""Property tests for the log-bucket histogram and its windowed ring.

Requires ``hypothesis`` (skipped when absent, same policy as the other
property suites).  The properties are the tentpole contracts stated in
``repro/obs/timeseries.py``:

* merge is exact, associative, and commutative — merging histograms is
  indistinguishable (bucket-for-bucket) from observing the concatenated
  population in any order;
* ``quantile(q)`` is within ``rel_err`` relative of the exact nearest-rank
  value over the observed samples, for every q and every rel_err;
* window rotation never loses counts: at all times
  ``total.count == dropped + live counts``, under arbitrary (including
  out-of-order) virtual timestamps.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs.metrics import percentile  # noqa: E402
from repro.obs.timeseries import LogBucketHistogram, WindowedHistogram  # noqa: E402

# latency/occupancy-like magnitudes: non-negative, wide dynamic range
values = st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                   allow_infinity=False)
value_lists = st.lists(values, min_size=0, max_size=200)
rel_errs = st.sampled_from([0.05, 0.01, 0.001])


def _fill(xs, rel_err):
    h = LogBucketHistogram(rel_err)
    for x in xs:
        h.observe(x)
    return h


def _same(a, b):
    return (a.buckets == b.buckets and a.zero_count == b.zero_count
            and a.count == b.count and a.min == b.min and a.max == b.max
            and abs(a.sum - b.sum) <= 1e-9 * max(abs(a.sum), abs(b.sum), 1.0))


@settings(max_examples=100, deadline=None)
@given(xs=value_lists, ys=value_lists, rel_err=rel_errs)
def test_merge_commutes_and_equals_concatenation(xs, ys, rel_err):
    ab = _fill(xs, rel_err).merge(_fill(ys, rel_err))
    ba = _fill(ys, rel_err).merge(_fill(xs, rel_err))
    cat = _fill(xs + ys, rel_err)
    assert _same(ab, ba)
    assert _same(ab, cat)


@settings(max_examples=100, deadline=None)
@given(xs=value_lists, ys=value_lists, zs=value_lists, rel_err=rel_errs)
def test_merge_is_associative(xs, ys, zs, rel_err):
    left = _fill(xs, rel_err).merge(_fill(ys, rel_err)) \
                             .merge(_fill(zs, rel_err))
    right_inner = _fill(ys, rel_err).merge(_fill(zs, rel_err))
    right = _fill(xs, rel_err).merge(right_inner)
    assert _same(left, right)


@settings(max_examples=100, deadline=None)
@given(xs=st.lists(values, min_size=1, max_size=200),
       q=st.floats(min_value=0.0, max_value=100.0),
       rel_err=rel_errs)
def test_quantile_within_relative_error_of_nearest_rank(xs, q, rel_err):
    h = _fill(xs, rel_err)
    exact = percentile(xs, q)
    approx = h.quantile(q)
    # 1e-9 absolute slack covers float round-off in gamma powers near zero
    assert abs(approx - exact) <= rel_err * abs(exact) + 1e-9


@settings(max_examples=100, deadline=None)
@given(obs=st.lists(st.tuples(st.floats(min_value=0.0, max_value=100.0,
                                        allow_nan=False),
                              values),
                    min_size=0, max_size=300),
       window=st.sampled_from([0.25, 1.0, 3.0]),
       n_windows=st.integers(min_value=1, max_value=8))
def test_window_rotation_never_loses_counts(obs, window, n_windows):
    w = WindowedHistogram(window=window, n_windows=n_windows, rel_err=0.01)
    for i, (t, v) in enumerate(obs):
        w.observe(t, v)
        live = w.live_count  # lazy expiry may move counts into dropped
        assert w.total.count == w.dropped + live == i + 1


@settings(max_examples=50, deadline=None)
@given(obs=st.lists(st.tuples(st.floats(min_value=0.0, max_value=10.0,
                                        allow_nan=False),
                              values),
                    min_size=1, max_size=200))
def test_windowed_quantile_matches_merged_population(obs):
    # a horizon wide enough to hold every observation: merged() must see
    # the full population, and its quantiles obey the bucket bound
    w = WindowedHistogram(window=1.0, n_windows=11, rel_err=0.01)
    for t, v in obs:
        w.observe(t, v)
    assert w.dropped == 0 and w.live_count == len(obs)
    xs = [v for _, v in obs]
    exact = percentile(xs, 99)
    assert abs(w.quantile(99) - exact) <= 0.01 * abs(exact) + 1e-9
