"""Property-based event-loop contracts (optional: require ``hypothesis``).

The lone-batch degeneration property, stated over arbitrary drain shapes:
for ANY drain record (any tier subset, any phase structure, any op/byte
buckets), a job simulated alone through the interleaved event loop
completes in exactly its serial-drain price — the same per-(batch, phase)
arithmetic as ``TierStats.model_time`` restricted to that one drain.  With
a single outstanding batch the event loop IS the old serial pricing; only
concurrency changes timings, and then only by sharing latency rounds.
"""

import math

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.io_sim import DRAM, NVME, S3  # noqa: E402
from repro.store import EventLoop, build_job  # noqa: E402
from repro.store.stats import DrainRecord, TierStats  # noqa: E402

DEVICES = [DRAM, NVME, S3]

# one tier's slice of a drain: {phase: ops} with plausible byte loads
_PHASE = st.integers(0, 3)
_BUCKET = st.tuples(_PHASE, st.integers(1, 500),
                    st.integers(0, 4 << 20))


def _record(buckets_by_tier):
    tiers = {}
    for tier, buckets in buckets_by_tier.items():
        phase_ops, phase_bytes = {}, {}
        for phase, ops, nbytes in buckets:
            phase_ops[phase] = phase_ops.get(phase, 0) + ops
            phase_bytes[phase] = phase_bytes.get(phase, 0) + nbytes
        if phase_ops:
            tiers[tier] = (phase_ops, phase_bytes)
    return DrainRecord("take:p", 1, tiers)


@settings(max_examples=200, deadline=None)
@given(
    buckets_by_tier=st.dictionaries(
        st.integers(0, 2), st.lists(_BUCKET, min_size=1, max_size=4),
        min_size=1, max_size=3),
    queue_depth=st.integers(1, 256),
)
def test_single_outstanding_batch_degenerates_to_serial_drain_price(
        buckets_by_tier, queue_depth):
    rec = _record(buckets_by_tier)
    job = build_job(rec, DEVICES)

    # the reference price: TierStats.model_time over this one drain,
    # reconstructed through the public accounting API
    expect = 0.0
    for tier in sorted(rec.tiers):
        phase_ops, phase_bytes = rec.tiers[tier]
        ts = TierStats(name="t")
        for phase in sorted(phase_ops):
            ts.add_op(phase_bytes.get(phase, 0), phase)
            for _ in range(phase_ops[phase] - 1):
                ts.add_op(0, phase)
        expect += ts.model_time(DEVICES[tier], queue_depth)

    serial = job.serial_time(queue_depth)
    assert serial == pytest.approx(expect, rel=1e-12, abs=1e-15)

    loop = EventLoop(DEVICES, queue_depth)
    inter = loop.run([job], mode="interleaved")
    assert len(inter.completions) == 1
    assert inter.completions[0].done == pytest.approx(serial, rel=1e-12,
                                                      abs=1e-15)
    assert loop.run([job], mode="serial").completions[0].done == serial


@settings(max_examples=60, deadline=None)
@given(
    jobs_spec=st.lists(
        st.tuples(st.dictionaries(st.integers(0, 2),
                                  st.lists(_BUCKET, min_size=1, max_size=2),
                                  min_size=1, max_size=2),
                  st.floats(0.0, 0.01)),
        min_size=1, max_size=8),
    queue_depth=st.integers(1, 64),
)
def test_interleaving_never_worse_than_serial_and_conserves_jobs(
        jobs_spec, queue_depth):
    jobs = [build_job(_record(buckets), DEVICES, submit=at, seq=i)
            for i, (buckets, at) in enumerate(jobs_spec)]
    loop = EventLoop(DEVICES, queue_depth)
    inter = loop.run(jobs, mode="interleaved")
    serial = loop.run(jobs, mode="serial")
    assert len(inter.completions) == len(serial.completions) == len(jobs)
    assert inter.makespan <= serial.makespan * (1 + 1e-9)
    for c in inter.completions:
        assert c.done >= c.submit
        assert not math.isnan(c.latency)
