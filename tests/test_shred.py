"""Shredding (Dremel rep/def) correctness: paper examples + hypothesis
roundtrip properties over arbitrary nested types."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import arrays as A
from repro.core import types as T
from repro.core.shred import shred, unshred


def rt(pyvals, typ):
    arr = A.from_pylist(pyvals, typ)
    back = unshred(shred(arr), typ)
    assert A.to_pylist(back) == pyvals


def test_paper_fig6_levels():
    """Struct<List<String>> example from the paper, exact rep/def codes."""
    typ = T.Struct((("x", T.List(T.Utf8())),))
    vals = [{"x": ["AB", "C"]}, {"x": None}, None, {"x": [None]}, {"x": []}]
    leaves = shred(A.from_pylist(vals, typ))
    l = leaves[0]
    assert l.rep.tolist() == [1, 0, 1, 1, 1, 1]
    assert l.defs.tolist() == [0, 0, 3, 4, 1, 2]
    assert l.max_rep == 1 and l.max_def == 4
    # def meanings match fig 6: 1=null item, 2=empty list, 3=null list, 4=null struct
    assert l.def_meanings[1] == "null_item"
    assert l.def_meanings[2].startswith("empty_list")
    assert l.def_meanings[3].startswith("null_list")
    assert l.def_meanings[4].startswith("null_struct")


CASES = [
    ([1, 2, None, 4], T.int64()),
    (["a", None, "bcd"], T.utf8()),
    ([None, [1, 2], [], None, [3]], T.List(T.int32())),
    ([[[1], [2, 3]], None, [[]], [None], []], T.List(T.List(T.int32()))),
    ([{"a": 1, "b": "x"}, None, {"a": None, "b": None}],
     T.Struct((("a", T.int64()), ("b", T.utf8())))),
    ([[1.0, 2.0], None, [3.0, 4.0]],
     T.FixedSizeList(T.Primitive("float32", nullable=False), 2)),
    ([[{"s": ["ab", None], "v": 1.0}, None, {"s": None, "v": None}], None, [],
      [{"s": [], "v": 2.5}]],
     T.List(T.Struct((("s", T.List(T.utf8())), ("v", T.float64()))))),
    ([], T.List(T.int64())),
    ([None, None], T.int32()),
]


@pytest.mark.parametrize("pyvals,typ", CASES)
def test_roundtrip_cases(pyvals, typ):
    rt(pyvals, typ)


# -- hypothesis: random nested types & values -------------------------------

def _type_strategy(depth=2):
    prim = st.sampled_from([T.int64(), T.int32(), T.float64(), T.utf8()])
    if depth == 0:
        return prim
    sub = _type_strategy(depth - 1)
    return st.one_of(
        prim,
        st.builds(lambda c, n: T.List(c, nullable=n), sub, st.booleans()),
        st.builds(lambda c, n: T.Struct((("f", c),), nullable=n), sub, st.booleans()),
    )


def _value_for(typ, draw, size):
    if isinstance(typ, T.Primitive):
        if typ.dtype.startswith("f"):
            gen = st.floats(-100, 100, allow_nan=False).map(lambda x: float(np.float64(x)))
        else:
            gen = st.integers(-1000, 1000)
    elif isinstance(typ, T.Utf8):
        gen = st.text(alphabet="abcXYZ", max_size=6)
    elif isinstance(typ, T.List):
        gen = st.lists(_value_strategy(typ.child), max_size=4)
    elif isinstance(typ, T.Struct):
        gen = st.fixed_dictionaries({n: _value_strategy(f) for n, f in typ.fields})
    else:
        raise TypeError(typ)
    return gen


def _value_strategy(typ):
    base = _value_for(typ, None, None)
    if typ.nullable:
        return st.one_of(st.none(), base)
    return base


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_roundtrip_property(data):
    typ = data.draw(_type_strategy())
    n = data.draw(st.integers(0, 12))
    vals = [data.draw(_value_strategy(typ)) for _ in range(n)]
    rt(vals, typ)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_entry_stream_invariants(data):
    """Entries with def==0 exactly equal the number of stored values; every
    top-level row contributes >=1 entry."""
    typ = data.draw(_type_strategy())
    n = data.draw(st.integers(1, 10))
    vals = [data.draw(_value_strategy(typ)) for _ in range(n)]
    arr = A.from_pylist(vals, typ)
    for leaf in shred(arr):
        n_valid = int((leaf.defs == 0).sum()) if leaf.defs is not None else leaf.n_entries
        assert n_valid == len(leaf.values)
        if leaf.max_rep > 0:
            assert int((leaf.rep == leaf.max_rep).sum()) == n
        else:
            assert leaf.n_entries == n
