"""Shredding (Dremel rep/def) correctness: paper examples + case table.

The hypothesis roundtrip properties over arbitrary nested types live in
``test_shred_properties.py`` so this module runs on a bare interpreter."""

import numpy as np
import pytest

from repro.core import arrays as A
from repro.core import types as T
from repro.core.shred import shred, unshred


def rt(pyvals, typ):
    arr = A.from_pylist(pyvals, typ)
    back = unshred(shred(arr), typ)
    assert A.to_pylist(back) == pyvals


def test_paper_fig6_levels():
    """Struct<List<String>> example from the paper, exact rep/def codes."""
    typ = T.Struct((("x", T.List(T.Utf8())),))
    vals = [{"x": ["AB", "C"]}, {"x": None}, None, {"x": [None]}, {"x": []}]
    leaves = shred(A.from_pylist(vals, typ))
    l = leaves[0]
    assert l.rep.tolist() == [1, 0, 1, 1, 1, 1]
    assert l.defs.tolist() == [0, 0, 3, 4, 1, 2]
    assert l.max_rep == 1 and l.max_def == 4
    # def meanings match fig 6: 1=null item, 2=empty list, 3=null list, 4=null struct
    assert l.def_meanings[1] == "null_item"
    assert l.def_meanings[2].startswith("empty_list")
    assert l.def_meanings[3].startswith("null_list")
    assert l.def_meanings[4].startswith("null_struct")


CASES = [
    ([1, 2, None, 4], T.int64()),
    (["a", None, "bcd"], T.utf8()),
    ([None, [1, 2], [], None, [3]], T.List(T.int32())),
    ([[[1], [2, 3]], None, [[]], [None], []], T.List(T.List(T.int32()))),
    ([{"a": 1, "b": "x"}, None, {"a": None, "b": None}],
     T.Struct((("a", T.int64()), ("b", T.utf8())))),
    ([[1.0, 2.0], None, [3.0, 4.0]],
     T.FixedSizeList(T.Primitive("float32", nullable=False), 2)),
    ([[{"s": ["ab", None], "v": 1.0}, None, {"s": None, "v": None}], None, [],
      [{"s": [], "v": 2.5}]],
     T.List(T.Struct((("s", T.List(T.utf8())), ("v", T.float64()))))),
    ([], T.List(T.int64())),
    ([None, None], T.int32()),
]


@pytest.mark.parametrize("pyvals,typ", CASES)
def test_roundtrip_cases(pyvals, typ):
    rt(pyvals, typ)
