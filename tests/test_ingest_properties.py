"""Property-based crash-consistency tests (optional: require ``hypothesis``).

The flush-then-commit fence's contract, stated as a property: for ANY
interleaving of appends (committed or staged), commits, interrupted flushes
(a crash after any prefix of the flush's dispatched extents), and crashes,
every manifest version that was ever committed remains readable with exactly
the rows it committed.  The whole module is skipped on a bare interpreter;
example-based equivalents live in ``test_ingest.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import arrays as A  # noqa: E402
from repro.core.file import WriteOptions  # noqa: E402
from repro.dataset import DatasetWriter  # noqa: E402
from repro.store import FlushPolicy, SimulatedCrash, TieredStore  # noqa: E402

# one scripted ingest step: (op, size-ish argument)
#   append  — stage a fragment of `arg` rows (committed if arg is odd)
#   commit  — durability fence, possibly interrupted after `arg` flush extents
#   crash   — tear unflushed state, rewind to the last committed version
_STEP = st.one_of(
    st.tuples(st.just("append"), st.integers(2, 40)),
    st.tuples(st.just("commit"), st.integers(0, 3)),
    st.tuples(st.just("crash"), st.just(0)),
)


@settings(max_examples=30, deadline=None)
@given(
    script=st.lists(_STEP, min_size=1, max_size=10),
    mode=st.sampled_from(["write-back", "flush-on-evict", "write-through"]),
    cache_blocks=st.integers(4, 64),
    interrupt=st.booleans(),
)
def test_any_crash_prefix_keeps_every_committed_version_readable(
        script, mode, cache_blocks, interrupt):
    w = DatasetWriter(
        store=lambda d: TieredStore.cached(d, cache_bytes=cache_blocks * 4096),
        flush=FlushPolicy(mode, deadline_batches=3),
        opts=WriteOptions("lance"))
    next_val = 0            # appended values are globally sequential ints
    committed_rows = 0      # mirror of the last committed row count
    version_rows = []       # version v committed version_rows[v-1] rows

    def check_all_versions():
        assert w.version == len(version_rows)
        assert w.n_rows == committed_rows
        for v, n in enumerate(version_rows, start=1):
            r = w.reader(v)
            assert r.n_rows == n
            # spot-check the decoded rows, including both edges
            rows = np.unique(np.clip([0, n // 2, n - 1], 0, n - 1))
            assert A.to_pylist(r.take("c", rows)) == rows.tolist()

    for op, arg in script:
        if op == "append":
            n = arg
            table = {"c": A.PrimitiveArray.build(
                np.arange(next_val, next_val + n, dtype=np.int64),
                nullable=False)}
            w.append(table, commit=bool(arg % 2))
            next_val += n
            if arg % 2:
                committed_rows = next_val
                version_rows.append(committed_rows)
        elif op == "commit":
            if interrupt:
                w.flush_policy.fail_after = arg
            try:
                m = w.commit()
            except SimulatedCrash:
                # interrupted fence: the new version must NOT exist and the
                # torn state must rewind cleanly
                w.flush_policy.fail_after = None
                w.simulate_crash()
                next_val = committed_rows
            else:
                if m is not None:  # None: still-empty dataset
                    committed_rows = m.n_rows
                    if m.version > len(version_rows):
                        version_rows.append(committed_rows)
            w.flush_policy.fail_after = None
        else:  # crash
            w.simulate_crash()
            next_val = committed_rows
        check_all_versions()

    # full-scan audit at the end: the latest version holds exactly the
    # sequential prefix that survived every crash
    if version_rows:
        assert A.to_pylist(w.scan("c")) == list(range(committed_rows))
