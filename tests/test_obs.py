"""Observability layer: span tracer, metrics registry, per-request latency
attribution, the store's drain log, and the bench regression gate.

The two contracts everything else leans on:

* tracing is *observation only* — logical IO stats and modelled times are
  bit-identical traced vs untraced, and a disabled tracer allocates no span
  objects on the hot path;
* attribution is *exact* — per-tier attributed drain costs sum to each
  tier's ``model_time`` within 1e-9 relative (floating-point remainder
  assignment, not approximation).
"""

import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import arrays as A
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.io_sim import NVME
from repro.obs import (
    MetricsRegistry,
    NULL_SPAN,
    NULL_TRACER,
    NullTracer,
    Tracer,
    attribute,
    percentile,
)
from repro.store import DrainRecord, TierStats, WorkloadStats

ROOT = Path(__file__).resolve().parent.parent


def _load_module(path, name):
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _mb_reader(n=20_000, seed=0, **kw):
    rng = np.random.default_rng(seed)
    arr = A.PrimitiveArray.build(
        rng.integers(0, 1 << 20, n).astype(np.int64),
        validity=rng.random(n) > 0.03)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
    return FileReader(fb, **kw), n


# ---------------------------------------------------------------------------
# TierStats / WorkloadStats direct coverage
# ---------------------------------------------------------------------------


def test_tier_stats_phase_buckets_roundtrip():
    s = TierStats("t")
    s.add_op(4096, phase=0)
    s.add_op(8192, phase=0, prefetch=True)
    s.add_write_op(4096, phase=1, flush=True)
    assert s.phase_ops == {0: 2, 1: 1}
    assert s.phase_bytes == {0: 12288, 1: 4096}
    assert (s.n_iops, s.write_iops) == (2, 1)
    assert (s.prefetch_bytes, s.flush_bytes) == (8192, 4096)
    drained = s.end_batch()
    assert drained == ({0: 2, 1: 1}, {0: 12288, 1: 4096})
    assert s.phase_ops == {} and s.phase_bytes == {}
    assert s.batch_phases == [{0: 2, 1: 1}]
    assert s.end_batch() is None          # empty batch drains nothing
    snap = s.snapshot()
    s.reset()
    assert snap.batch_phases == [{0: 2, 1: 1}] and s.batch_phases == []


def test_tier_stats_hit_rate_never_nan():
    s = TierStats("t")
    assert s.hit_rate is None
    s.hits, s.misses = 3, 1
    assert s.hit_rate == 0.75


def test_more_phases_cost_strictly_more_latency():
    """Same ops and bytes, deeper dependency chain => strictly more queue
    drains => strictly more modelled time."""
    flat, deep = TierStats("flat"), TierStats("deep")
    for i in range(8):
        flat.add_op(4096, phase=0)
        deep.add_op(4096, phase=i)
    flat.end_batch()
    deep.end_batch()
    assert deep.model_time(NVME) > flat.model_time(NVME)
    # the gap is exactly the 7 extra round trips
    assert deep.model_time(NVME) - flat.model_time(NVME) == \
        pytest.approx(7 * NVME.latency)


def test_workload_scan_fraction_none_and_bias_flip():
    w = WorkloadStats()
    assert w.scan_fraction is None
    assert w.preferred_admission() == "always"   # cold-start default
    # the scan must beat takes by the hysteresis margin to earn a flip
    w.note_batch("scan:c", prefetch=True, n_ops=4, nbytes=1100)
    w.note_batch("take:c", prefetch=False, n_ops=4, nbytes=999)
    assert w.scan_fraction == pytest.approx(1100 / 2099)
    assert w.preferred_admission() == "second_touch"
    # bias < 1 discounts scans: the same trace now reads take-heavy
    w2 = WorkloadStats(scan_bias=0.5)
    w2.note_batch("scan:c", prefetch=True, n_ops=4, nbytes=1100)
    w2.note_batch("take:c", prefetch=False, n_ops=4, nbytes=999)
    assert w2.preferred_admission() == "always"


# ---------------------------------------------------------------------------
# Attribution exactness
# ---------------------------------------------------------------------------


def test_attribution_sums_match_model_time_1e9():
    fr, n = _mb_reader(store="tiered")
    rng = np.random.default_rng(3)
    for _ in range(6):
        fr.take("c", rng.integers(0, n, 64))
    fr.scan("c")
    att = attribute(fr.store, queue_depth=fr.scheduler.queue_depth)
    sums = att.tier_sums()
    devices = [lvl.device for lvl in fr.store.levels] + [fr.store.backing]
    checked = 0
    for stats, dev in zip(fr.store.tier_stats(), devices):
        mt = stats.model_time(dev, fr.scheduler.queue_depth)
        if mt:
            assert abs(sums[stats.name] - mt) / mt < 1e-9
            checked += 1
    assert checked >= 2  # NVMe cache and S3 backing both saw traffic
    assert att.total == pytest.approx(fr.modelled_time(), rel=1e-9)


def test_attribution_per_request_population():
    fr, n = _mb_reader(store="tiered")
    rng = np.random.default_rng(4)
    for _ in range(5):
        fr.take("c", rng.integers(0, n, 32))
    att = attribute(fr.store, queue_depth=fr.scheduler.queue_depth)
    lats = att.per_request_latencies("take:c")
    assert len(lats) == 5 * 32            # one latency per requested row
    assert all(x >= 0 for x in lats)
    pct = att.percentiles("take:c")
    assert pct["count"] == 160
    assert pct["p50"] <= pct["p99"] <= pct["p999"] <= pct["max"]
    assert att.percentiles("no-such-label") is None   # never NaN


def test_attribution_drain_log_labels_and_requests():
    fr, n = _mb_reader(store="tiered")
    fr.take("c", np.arange(10))
    fr.scan("c")
    log = fr.store.drain_log
    assert [r.label for r in log] == ["take:c", "scan:c"]
    assert isinstance(log[0], DrainRecord)
    assert log[0].n_requests == 10 and log[1].n_requests == 0
    # every logged tier bucket is a ({phase: ops}, {phase: bytes}) pair
    for rec in log:
        for ops, nbytes in rec.tiers.values():
            assert set(ops) == set(nbytes)
            assert all(v > 0 for v in ops.values())


# ---------------------------------------------------------------------------
# Tracer: zero-cost disabled, schema, bit-identity
# ---------------------------------------------------------------------------


def test_disabled_tracer_allocates_no_spans():
    tr = NullTracer()
    s1 = tr.span("a", x=1)
    s2 = tr.span("b")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN   # singleton, no allocation
    assert NULL_TRACER.span("c") is NULL_SPAN
    with s1 as sp:
        sp.set(ignored=True)
    tr.instant("i")
    tr.counter("c", {"v": 1})
    assert tr.events == []


def test_traced_vs_untraced_bit_identical():
    plain, n = _mb_reader(store="tiered")
    traced, _ = _mb_reader(store="tiered", tracer=Tracer())
    rng_a, rng_b = np.random.default_rng(9), np.random.default_rng(9)
    for _ in range(4):
        plain.take("c", rng_a.integers(0, n, 48))
        traced.take("c", rng_b.integers(0, n, 48))
    plain.scan("c")
    traced.scan("c")
    sa, sb = plain.io_stats(), traced.io_stats()
    assert (sa.n_iops, sa.bytes_read) == (sb.n_iops, sb.bytes_read)
    assert plain.modelled_time() == traced.modelled_time()   # bit-equal
    for ta, tb in zip(plain.tier_stats(), traced.tier_stats()):
        assert (ta.n_iops, ta.bytes_read, ta.hits, ta.misses) == \
            (tb.n_iops, tb.bytes_read, tb.hits, tb.misses)
    assert len(traced.tracer.events) > 0 and plain.tracer.events == []


def test_trace_export_chrome_schema(tmp_path):
    tr = Tracer()
    fr, n = _mb_reader(store="tiered", tracer=tr)
    fr.take("c", np.random.default_rng(1).integers(0, n, 32))
    doc = tr.trace_events()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["traceEvents"], "instrumented take emitted no events"
    for ev in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
        # "M" = thread_name metadata naming the per-request tracks
        assert ev["ph"] in ("X", "i", "C", "M")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    names = {e["name"] for e in doc["traceEvents"]}
    assert any(nm.startswith("take:") for nm in names)
    assert any(nm.startswith("drain:") for nm in names)
    out = tmp_path / "trace.json"
    n_events = tr.export(str(out))
    loaded = json.loads(out.read_text())
    assert len(loaded["traceEvents"]) == n_events


def test_trace_export_refuses_nan(tmp_path):
    tr = Tracer()
    tr.instant("bad", value=float("nan"))
    with pytest.raises(ValueError):
        tr.export(str(tmp_path / "t.json"))


def test_pallas_fallback_reason_event():
    tr = Tracer()
    rng = np.random.default_rng(0)
    arr = A.PrimitiveArray.build(rng.standard_normal(512).astype(np.float32))
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
    fr = FileReader(fb, decode="pallas", tracer=tr)
    fr.take("c", rng.integers(0, 512, 16))
    evs = [e for e in tr.events if e["name"] == "pallas_fallback"]
    assert evs and evs[0]["args"]["reason"] == "float-values"
    assert tr.metrics.counter_values("decode.fallback") == \
        {"decode.fallback.miniblock.float-values": 1}


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == 50
    assert percentile(xs, 99) == 99
    assert percentile(xs, 99.9) == 100
    assert percentile(xs, 100) == 100
    assert percentile([7.0], 50) == 7.0
    with pytest.raises(ValueError):
        percentile([], 50)


def test_metrics_registry_counters_and_histograms():
    m = MetricsRegistry()
    m.counter("a.b").inc()
    m.counter("a.b").inc(2)
    m.counter("a.c").inc()
    assert m.counter_values("a.") == {"a.b": 3, "a.c": 1}
    h = m.histogram("lat")
    h.observe_many([1.0, 2.0, 3.0, 4.0])
    s = h.summary()
    assert s["count"] == 4 and s["mean"] == pytest.approx(2.5)
    assert s["p50"] == 2.0 and s["max"] == 4.0
    m.reset()
    assert m.counter_values() == {}


# ---------------------------------------------------------------------------
# bench_gate + run.py harness
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bench_gate():
    return _load_module(ROOT / "tools" / "bench_gate.py", "bench_gate")


def test_bench_gate_compare_rules(bench_gate):
    base = {"meta": {"run": {"git_sha": "aaa"}},
            "cell": {"n_iops": 10, "model_io_s": 0.5,
                     "rows_per_s": 1000, "bytes_read": 4096}}
    same = json.loads(json.dumps(base))
    same["meta"]["run"]["git_sha"] = "bbb"     # provenance never compared
    same["cell"]["rows_per_s"] = 1            # measured rate ignored
    assert bench_gate.compare(base, same) == []
    worse = json.loads(json.dumps(base))
    worse["cell"]["n_iops"] = 11
    fails = bench_gate.compare(base, worse)
    assert len(fails) == 1 and "n_iops" in fails[0]
    drift = json.loads(json.dumps(base))
    drift["cell"]["model_io_s"] = 0.5000001
    assert bench_gate.compare(base, drift) == []       # within 1e-6 rel
    drift["cell"]["model_io_s"] = 0.51
    assert bench_gate.compare(base, drift)
    missing = json.loads(json.dumps(base))
    del missing["cell"]["bytes_read"]
    assert any("missing" in f for f in bench_gate.compare(base, missing))
    # --rates opts measured numbers into a loose band
    assert bench_gate.compare(base, same, rates=True, rate_tol=0.5)


def test_bench_gate_exit_codes(bench_gate, tmp_path):
    basedir, curdir = tmp_path / "base", tmp_path / "cur"
    basedir.mkdir()
    curdir.mkdir()
    art = {"cell": {"n_iops": 10}}
    (basedir / "BENCH_x.json").write_text(json.dumps(art))
    (curdir / "BENCH_x.json").write_text(json.dumps(art))
    assert bench_gate.gate(str(basedir), str(curdir)) == 0
    (curdir / "BENCH_x.json").write_text(
        json.dumps({"cell": {"n_iops": 12}}))
    assert bench_gate.gate(str(basedir), str(curdir)) == 1
    (curdir / "BENCH_x.json").unlink()
    assert bench_gate.gate(str(basedir), str(curdir)) == 1
    assert bench_gate.gate(str(tmp_path / "nothing"), str(curdir)) == 2


def test_committed_smoke_baselines_exist():
    """CI's regression gate is only as real as the committed baselines."""
    basedir = ROOT / "benchmarks" / "baselines" / "smoke"
    names = {p.name for p in basedir.glob("BENCH_*.json")}
    assert {"BENCH_take.json", "BENCH_decode.json",
            "BENCH_dataset.json", "BENCH_ingest.json"} <= names
    take = json.loads((basedir / "BENCH_take.json").read_text())
    assert take["meta"]["run"]["smoke"] is True
    pct = take["serving_latency"]["per_row_us"]
    assert {"p50", "p99", "p999"} <= set(pct)
    assert take["serving_latency"]["attribution_residual_rel"] < 1e-9
    assert take["pallas_fallback_probe"]["n_events"] >= 1


@pytest.fixture(scope="module")
def bench_run():
    return _load_module(ROOT / "benchmarks" / "run.py", "bench_run")


def test_run_name_validation(bench_run):
    with pytest.raises(SystemExit) as ei:
        bench_run._parse_args(["take_decoed"])       # typo must not pass
    assert "unknown benchmark" in str(ei.value)
    assert bench_run._parse_args(["take"]) == {"take"}
    assert bench_run._parse_args(["take_decode"]) == {"take_decode"}
    with pytest.raises(SystemExit):
        bench_run._parse_args(["--store", "bogus"])
    with pytest.raises(SystemExit) as ei:
        bench_run._parse_args(["--list"])
    assert ei.value.code == 0


def test_run_meta_and_nan_refusal(bench_run, tmp_path):
    out = tmp_path / "BENCH_t.json"
    bench_run._dump_json(str(out), {"v": 1})
    doc = json.loads(out.read_text())
    assert {"git_sha", "store", "smoke", "timestamp", "traced"} <= \
        set(doc["meta"]["run"])
    with pytest.raises(ValueError):
        bench_run._dump_json(str(out), {"v": float("nan")})


# ---------------------------------------------------------------------------
# Serving plane: attribution exactness with flushes in flight, per-request
# trace tracks, percentile gate rules
# ---------------------------------------------------------------------------


def test_attribution_exact_with_reads_and_flushes_in_flight():
    """Per-tier attributed sums must stay exact to model_time (1e-9) when a
    service window holds concurrent reads AND write-back flush runs — the
    event loop is a timing overlay and must not perturb the accounting the
    attributor prices."""
    from repro.dataset import DatasetWriter
    from repro.store import TieredStore

    rng = np.random.default_rng(6)
    arr = A.PrimitiveArray.build(
        rng.integers(0, 1 << 16, 4000).astype(np.int64))
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    w = DatasetWriter(
        files=[fb],
        store=lambda d: TieredStore.cached(d, cache_bytes=16 * 4096),
        flush="write-back")
    with w.scheduler.service_window() as win:
        for i in range(4):
            with win.request(tenant="reader", at=i * 1e-4):
                w.take("c", rng.integers(0, 4000, 64))
            with win.request(tenant="ingest", at=i * 1e-4):
                w.append({"c": A.PrimitiveArray.build(
                    rng.integers(0, 100, 300).astype(np.int64))},
                    commit=(i % 2 == 1))
        res = win.run("interleaved")
    # flush runs really were in flight alongside the reads
    labels = {c.label for c in res.completions}
    assert any(lab.startswith("take:") for lab in labels)
    assert any(lab.startswith("flush:") for lab in labels)
    qd = w.scheduler.queue_depth
    att = attribute(w.store, queue_depth=qd)
    sums = att.tier_sums()
    devices = [lvl.device for lvl in w.store.levels] + [w.store.backing]
    checked = 0
    for stats, dev in zip(w.tier_stats(), devices):
        mt = stats.model_time(dev, qd)
        if mt:
            assert abs(sums[stats.name] - mt) / mt < 1e-9
            checked += 1
    assert checked >= 2


def test_trace_per_request_tracks_for_concurrent_takers():
    """Bugfix regression: multi-request traces used to emit one flat span
    stream; scheduler spans must carry a per-request tid (plus the request
    id in args) so Perfetto renders concurrent takers as separate lanes."""
    tr = Tracer()
    fr, n = _mb_reader(store="tiered", tracer=tr)
    with fr.scheduler.service_window() as win:
        with win.request(tenant="a", request="a/0"):
            fr.take("c", np.arange(40))
        with win.request(tenant="b", request="b/0"):
            fr.take("c", np.arange(40, 80))
    drains = [e for e in tr.events
              if e["ph"] == "X" and e["name"].startswith("drain:")]
    assert len(drains) == 2
    assert drains[0]["tid"] != drains[1]["tid"]          # separate lanes
    assert {d["args"]["request"] for d in drains} == {"a/0", "b/0"}
    # thread_name metadata labels each lane with its request id
    meta = {e["tid"]: e["args"]["name"] for e in tr.events
            if e["ph"] == "M" and e["name"] == "thread_name"}
    for d in drains:
        assert meta[d["tid"]] == d["args"]["request"]
    # child spans (coalesce/dispatch) ride the same lane as their drain
    children = [e for e in tr.events if e["ph"] == "X"
                and e["name"].startswith(("coalesce", "dispatch:"))]
    assert children and all(e["tid"] in meta for e in children)
    # untagged requests still get stable distinct per-batch tracks
    tr2 = Tracer()
    fr2, _ = _mb_reader(store="tiered", tracer=tr2)
    fr2.take("c", np.arange(10))
    fr2.take("c", np.arange(10, 20))
    d2 = [e for e in tr2.events
          if e["ph"] == "X" and e["name"].startswith("drain:")]
    assert d2[0]["tid"] != d2[1]["tid"]


def test_bench_gate_percentile_keys_are_strict(bench_gate):
    """Percentile metrics are modelled, not measured: they must be compared
    deterministically even when the key carries a rate-marker substring."""
    assert bench_gate._is_percentile_key("p99_interleaved_ms")
    assert bench_gate._is_percentile_key("latency_p50")
    assert bench_gate._is_percentile_key("p999")
    assert bench_gate._is_percentile_key("p99_speedup_serial_over_interleaved")
    assert not bench_gate._is_percentile_key("rows_per_s")
    assert not bench_gate._is_percentile_key("phase2_ops")
    assert not bench_gate._is_percentile_key("top99")
    base = {"headline": {"p99_interleaved_ms": 10.0, "p50_count": 7,
                         "p99_speedup_serial_over_interleaved": 3.0,
                         "rows_per_s": 100.0}}
    drift = json.loads(json.dumps(base))
    drift["headline"]["p99_interleaved_ms"] = 10.5
    fails = bench_gate.compare(base, drift)
    assert len(fails) == 1 and "p99_interleaved_ms" in fails[0]
    # the speedup percentile is NOT skipped as a rate
    drift2 = json.loads(json.dumps(base))
    drift2["headline"]["p99_speedup_serial_over_interleaved"] = 2.0
    assert bench_gate.compare(base, drift2)
    # integer percentile metadata stays counted-strict
    drift3 = json.loads(json.dumps(base))
    drift3["headline"]["p50_count"] = 8
    assert bench_gate.compare(base, drift3)
    # plain rates are still ignored without --rates
    drift4 = json.loads(json.dumps(base))
    drift4["headline"]["rows_per_s"] = 9.0
    assert bench_gate.compare(base, drift4) == []
