"""Tiered storage subsystem: block cache, batched scheduler, readahead, the
end-to-end tiered read path through FileReader, and the write path (dirty
blocks, flush policies, durability accounting)."""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.io_sim import NVME, S3, Disk, IOTracker
from repro.store import (
    BlockCache,
    FlushPolicy,
    IOScheduler,
    SequentialReadahead,
    SimulatedCrash,
    TieredStore,
    WorkloadStats,
    make_store,
)


# ---------------------------------------------------------------------------
# BlockCache
# ---------------------------------------------------------------------------


def test_cache_lru_hit_miss_evict():
    c = BlockCache(3 * 4096, policy="lru")
    for b in (0, 1, 2):
        assert not c.lookup(b)
        c.admit(b)
    assert c.lookup(0) and c.lookup(1) and c.lookup(2)
    assert (c.hits, c.misses, c.evictions) == (3, 3, 0)
    c.lookup(0)  # 0 is now MRU; 1 is LRU
    assert not c.lookup(3)
    c.admit(3)   # evicts 1
    assert c.evictions == 1
    assert 1 not in c and 0 in c and 2 in c and 3 in c
    assert c.resident_bytes == 3 * 4096


def test_cache_clock_second_chance():
    c = BlockCache(2 * 4096, policy="clock")
    c.admit(0)
    c.admit(1)
    c.lookup(0)          # ref bit set on 0
    c.admit(2)           # clock must spare 0 (referenced) and evict 1
    assert 0 in c and 2 in c and 1 not in c
    assert c.evictions == 1
    assert len(c) == 2


def test_cache_second_touch_admission():
    c = BlockCache(4 * 4096, admission="second_touch")
    c.lookup(7)
    assert not c.admit(7)   # first touch: ghost only
    assert 7 not in c
    c.lookup(7)
    assert c.admit(7)       # second touch: admitted
    assert 7 in c


def test_second_touch_holds_through_dispatch():
    """Regression: the demand dispatch path must consult the admission
    policy exactly once per miss — a double admit() turned second_touch
    into always-admit (first call ghosts the id, second 'second-touches'
    it)."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = TieredStore.cached(disk, cache_bytes=16 * 4096,
                               admission="second_touch")
    store.dispatch_extent(0, 4096, phase=0)
    assert len(store.levels[0].cache) == 0   # first touch: ghost only
    store.dispatch_extent(0, 4096, phase=0)
    assert len(store.levels[0].cache) == 1   # second touch: resident
    assert store.backing_stats.n_iops == 2   # both misses paid the backing
    store.dispatch_extent(0, 4096, phase=0)
    assert store.levels[0].cache.hits == 1   # third read is a cache hit
    # prefetch fills that the policy rejects are not billed to the backing
    store.dispatch_extent(8 * 4096, 9 * 4096, phase=0, prefetch=True)
    assert store.backing_stats.prefetch_iops == 0
    assert len(store.levels[0].cache) == 1


def test_cache_rejects_bad_config():
    with pytest.raises(ValueError):
        BlockCache(100, block_bytes=4096)
    with pytest.raises(ValueError):
        BlockCache(1 << 20, policy="marvellous")
    with pytest.raises(ValueError):
        BlockCache(1 << 20, admission="never")


# ---------------------------------------------------------------------------
# workload-driven admission ("auto")
# ---------------------------------------------------------------------------


def test_workload_stats_mix_and_preference():
    ws = WorkloadStats()
    assert ws.preferred_admission() == "always"  # cold-start default
    ws.note_batch("scan:c", prefetch=True, n_ops=4, nbytes=1 << 20)
    assert ws.preferred_admission() == "second_touch"
    assert ws.n_scan_batches == 1 and ws.scan_bytes == 1 << 20
    for _ in range(3):
        ws.note_batch("take:c", prefetch=False, n_ops=100, nbytes=1 << 19)
    assert ws.take_bytes > ws.scan_bytes
    assert ws.preferred_admission() == "always"
    assert 0.0 < ws.scan_fraction < 0.5
    ws.reset()
    assert ws.n_scan_batches == ws.n_take_batches == 0


def test_admission_auto_flips_with_trace():
    """admission="auto" must follow the observed mix: a scan-heavy trace
    flips the active policy to second_touch, a take-heavy one back."""
    disk = Disk(np.zeros(1 << 22, np.uint8))
    store = TieredStore.cached(disk, admission="auto")
    cache = store.levels[0].cache
    sched = IOScheduler(store)
    assert cache.admission == "auto" and cache.active_admission == "always"

    # scan-heavy: one big streaming batch dominates the byte mix
    with sched.batch("scan:c", prefetch=True) as io:
        io.read(0, 1 << 20)
    assert cache.active_admission == "second_touch"
    assert cache.admission_flips == 1
    # ...and the flip applied to that very batch: first-touch blocks were
    # only ghosted, so the single-pass scan did not flood the cache
    assert len(cache) == 0

    # take-heavy: many small random batches overtake the scan bytes
    for i in range(0, 3 << 20, 4096):
        with sched.batch("take:c") as io:
            io.read(i % (1 << 20), 4096)
    assert cache.active_admission == "always"
    assert cache.admission_flips == 2


def test_admission_pinned_policies_do_not_flip():
    c = BlockCache(1 << 20, admission="second_touch")
    c.set_active_admission("always")
    assert c.active_admission == "second_touch"  # pinned by construction
    with pytest.raises(ValueError):
        c.set_active_admission("auto")


def test_make_store_tiered_auto_spec():
    disk = Disk(np.zeros(1 << 16, np.uint8))
    store = make_store("tiered-auto", disk)
    assert store.levels[0].cache.admission == "auto"
    assert store.levels[0].cache.active_admission == "always"


def test_tiered_store_accepts_shared_cache():
    """Satellite: several stores over one address space can share one
    BlockCache instance (one NVMe budget, no re-plumbing)."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    cache = BlockCache(16 * 4096)
    s1 = TieredStore.cached(disk, cache=cache)
    s2 = TieredStore.cached(disk, cache=cache)
    assert s1.levels[0].cache is s2.levels[0].cache
    s1.dispatch_extent(0, 4096, phase=0)       # s1 warms block 0
    s2.dispatch_extent(0, 4096, phase=0)       # s2 hits it
    assert cache.hits == 1 and cache.misses == 1
    assert s2.backing_stats.n_iops == 0        # no second backing read
    with pytest.raises(ValueError):            # sector mismatch is rejected
        TieredStore.cached(disk, sector=8192, cache=cache)


# ---------------------------------------------------------------------------
# scheduler vs. legacy accounting
# ---------------------------------------------------------------------------


def _strings(n):
    return A.from_pylist([f"value-{i:06d}" * 3 for i in range(n)], T.Utf8(False))


@pytest.mark.parametrize("enc", ["lance-miniblock", "lance-fullzip", "parquet",
                                 "arrow"])
def test_scheduler_trace_matches_legacy_tracker(enc):
    """The scheduler's logical stats must be bit-identical to replaying the
    same trace through the legacy IOTracker (no accounting regression)."""
    arr = _strings(2000)
    fb = write_table({"c": arr}, WriteOptions(enc))
    fr = FileReader(fb)  # flat single-tier store
    fr.take("c", np.arange(0, 2000, 37))
    fr.scan("c")
    tr = IOTracker(fr.disk)
    for o, sz, p in fr.scheduler.ops:
        tr.read(o, sz, p)
    for gap in (0, 64, 4096):
        a, b = fr.io_stats(gap), tr.stats(gap)
        assert (a.n_iops, a.bytes_read, a.max_phase, a.n_coalesced) == \
               (b.n_iops, b.bytes_read, b.max_phase, b.n_coalesced)


def test_flat_dispatch_count_equals_coalesced():
    """On a single-tier store each per-phase coalesced extent becomes exactly
    one dispatched device op (fixed-width take: no zero-length requests)."""
    arr = A.PrimitiveArray.build(np.arange(4000, dtype=np.int64), nullable=False)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance-fullzip")))
    fr.take("c", np.random.default_rng(0).choice(4000, 128, replace=False))
    st = fr.io_stats()
    backing = fr.tier_stats()[-1]
    assert backing.n_iops == st.n_coalesced
    # dispatched bytes are sector-aligned, so never less than logical bytes
    assert backing.bytes_read >= st.bytes_read
    assert backing.max_phase == st.max_phase


# ---------------------------------------------------------------------------
# tiered end-to-end
# ---------------------------------------------------------------------------


def test_tiered_take_cold_then_warm():
    arr = _strings(3000)
    fb = write_table({"c": arr}, WriteOptions("lance"))
    rows = np.random.default_rng(1).choice(3000, 200, replace=False)
    want = [A.to_pylist(arr)[i] for i in rows]

    cold = FileReader(fb, store="flat-s3")
    cold.take("c", rows)
    t_cold = cold.modelled_time()

    fr = FileReader(fb, store="tiered")
    assert A.to_pylist(fr.take("c", rows)) == want  # data plane is unchanged
    nvme, s3 = fr.tier_stats()
    assert s3.n_iops > 0 and nvme.misses > 0  # cold pass fills from S3

    fr.reset_io()
    assert A.to_pylist(fr.take("c", rows)) == want
    t_warm = fr.modelled_time()
    nvme, s3 = fr.tier_stats()
    assert s3.n_iops == 0 and nvme.hit_rate == 1.0  # fully warm
    assert t_warm < t_cold  # the acceptance headline

    fr.drop_caches()
    fr.reset_io()
    fr.take("c", rows)
    assert fr.tier_stats()[1].n_iops > 0  # cold again after dropping


def test_tiered_eviction_under_pressure():
    arr = A.PrimitiveArray.build(np.arange(200_000, dtype=np.int64),
                                 nullable=False)
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    tiny = lambda d: TieredStore.cached(d, cache_bytes=8 * 4096)
    fr = FileReader(fb, store=tiny)
    fr.take("c", np.arange(0, 200_000, 997))  # way beyond 8 blocks
    nvme = fr.tier_stats()[0]
    assert nvme.evictions > 0
    assert len(fr.store.levels[0].cache) <= 8


def test_hot_store_promotes_through_levels():
    arr = _strings(1000)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance")), store="hot")
    rows = np.arange(0, 1000, 13)
    fr.take("c", rows)
    fr.reset_io()
    fr.take("c", rows)
    ram, nvme, s3 = fr.tier_stats()
    assert s3.n_iops == 0        # warm: nothing reaches S3
    assert ram.hits > 0          # served from the RAM-hot tier
    assert fr.modelled_time() < 1e-3


def test_prefetch_on_full_scan():
    arr = _strings(20_000)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
    fr = FileReader(fb, store="tiered")
    # small demand chunks so readahead has a stream to get ahead of
    got = fr.scan("c", io_chunk=16 * 1024)
    assert A.to_pylist(got) == A.to_pylist(arr)
    nvme, s3 = fr.tier_stats()
    assert s3.prefetch_iops > 0 and s3.prefetch_bytes > 0
    assert nvme.hits > 0  # demand reads landed on prefetched blocks
    # prefetch fills holes, it never re-reads: total backing bytes stay
    # within one readahead window of the demand footprint
    no_ra = FileReader(fb, store="tiered", readahead=None)
    no_ra.scan("c", io_chunk=16 * 1024)
    s3_no_ra = no_ra.tier_stats()[1]
    assert s3_no_ra.prefetch_iops == 0 and s3_no_ra.hits == 0
    assert s3.bytes_read <= s3_no_ra.bytes_read + (1 << 20)


def test_readahead_policy_unit():
    ra = SequentialReadahead(window_bytes=1 << 16, min_run=2)
    assert ra.observe(0, 4096) is None          # first read: no pattern yet
    pf = ra.observe(4096, 8192)                 # sequential: prefetch ahead
    assert pf == (8192, 8192 + (1 << 16))
    # next sequential read slides the window: only the uncovered tail is new
    assert ra.observe(8192, 12_288) == (8192 + (1 << 16), 12_288 + (1 << 16))
    ra.reset()
    assert ra.observe(0, 4096) is None
    assert ra.observe(1 << 30, (1 << 30) + 4096) is None  # random jump


def test_sequential_batches_each_pay_round_trips():
    """Regression: two sequential takes are two queue drains — the modelled
    latency term must double, not collapse into one phase bucket."""
    arr = A.PrimitiveArray.build(np.arange(4000, dtype=np.int64), nullable=False)
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    fr = FileReader(fb, store="flat-s3")
    rows = np.arange(0, 4000, 31)
    fr.take("c", rows)
    t1 = fr.modelled_time()
    fr.take("c", rows)  # no reset: same counters, second round trip
    t2 = fr.modelled_time()
    assert t2 > 1.8 * t1  # S3 latency dominates; each take pays its own


def test_tier_stats_snapshots_survive_reset():
    """Regression: tier_stats() must return detached copies, not the live
    counters that reset_io() zeroes in place."""
    arr = _strings(500)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance")), store="tiered")
    fr.take("c", np.arange(0, 500, 7))
    before = fr.tier_stats()
    assert before[-1].n_iops > 0
    saved = before[-1].n_iops
    fr.reset_io()
    assert before[-1].n_iops == saved  # snapshot unaffected by the reset
    assert fr.tier_stats()[-1].n_iops == 0


def test_make_store_specs():
    disk = Disk(np.zeros(1 << 16, np.uint8))
    assert make_store(None, disk).backing is NVME
    assert make_store("flat-s3", disk).backing is S3
    assert len(make_store("tiered", disk).levels) == 1
    assert len(make_store("hot", disk).levels) == 2
    with pytest.raises(ValueError):
        make_store("warmish", disk)
    with pytest.raises(ValueError):
        make_store(TieredStore.flat(Disk(np.zeros(8, np.uint8))), disk)


def test_batch_rejects_use_after_close():
    disk = Disk(np.zeros(1 << 16, np.uint8))
    sched = IOScheduler(TieredStore.flat(disk))
    with sched.batch("t") as io:
        io.read(0, 16)
    with pytest.raises(RuntimeError):
        io.read(0, 16)
    assert sched.stats().n_iops == 1


# ---------------------------------------------------------------------------
# write path: dirty blocks, flush policies, durability accounting
# ---------------------------------------------------------------------------


def _wb_store(disk, mode="write-back", cache_blocks=16, **kw):
    store = TieredStore.cached(disk, cache_bytes=cache_blocks * 4096)
    store.set_flush_policy(FlushPolicy(mode, **kw))
    return store


def test_cache_dirty_state_and_force_insert():
    c = BlockCache(4 * 4096, admission="second_touch")
    c.mark_dirty(7)          # bypasses the admission filter
    assert 7 in c and c.is_dirty(7)
    assert c.dirty_bytes == 4096 and c.dirty_blocks == [7]
    c.clean(7)
    assert not c.is_dirty(7) and 7 in c  # residency survives the flush
    assert c.dirty_bytes == 0


def test_cache_invalidate_reuses_slot_without_eviction():
    c = BlockCache(2 * 4096, policy="clock")
    c.admit(0)
    c.admit(1)
    assert c.invalidate(0) and 0 not in c and len(c) == 1
    assert not c.invalidate(0)  # already gone
    c.admit(2)                  # must reuse the tombstoned slot
    assert len(c) == 2 and c.evictions == 0
    lru = BlockCache(2 * 4096, policy="lru")
    lru.admit(5)
    assert lru.invalidate(5) and 5 not in lru and lru.evictions == 0


def test_write_back_dirty_accounting_invariants():
    """The core dirty-byte invariants: absorbed bytes become dirty on the
    cache tier (no backing traffic), flushing moves exactly those bytes to
    the backing tier as flush writes, and dirty_bytes returns to zero."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk)
    sched = IOScheduler(store)
    with sched.write_batch("append:0") as wb:
        wb.write(0, b"x" * 10_000)          # 3 sectors
    nvme, s3 = store.tier_stats()
    assert nvme.write_iops == 1 and nvme.bytes_written == 3 * 4096
    assert nvme.dirty_bytes == 3 * 4096
    assert s3.write_iops == 0               # nothing durable yet
    assert store.dirty_extents() == [(0, 3 * 4096)]
    flushed = store.flush_all()
    assert flushed == 3
    nvme, s3 = store.tier_stats()
    assert nvme.dirty_bytes == 0
    assert s3.write_iops == 1 and s3.flush_iops == 1   # one contiguous run
    assert s3.bytes_written == s3.flush_bytes == 3 * 4096
    assert sched.write_stats().n_iops == 1
    assert sched.write_stats().bytes_read == 10_000    # logical write trace


def test_write_through_is_immediately_durable():
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk, mode="write-through")
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(4096, b"y" * 4096)
    nvme, s3 = store.tier_stats()
    assert s3.write_iops == 1 and s3.flush_iops == 0
    assert nvme.dirty_bytes == 0 and store.dirty_extents() == []
    # the written block was admitted clean: the next read is NVMe-warm
    with sched.batch("take:c") as io:
        io.read(4096, 100)
    assert store.levels[0].cache.hits == 1
    assert store.tier_stats()[1].n_iops == 0  # reads: no S3 traffic


def test_write_through_fill_bypasses_admission_filter():
    """Regression: the write-through fill must force-insert — under
    second_touch (or auto flipped to it) a plain admit() only ghosts the
    block and the writer's own fresh bytes would cold-miss to S3."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = TieredStore.cached(disk, admission="second_touch")
    store.set_flush_policy(FlushPolicy("write-through"))
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(0, b"w" * 4096)
    assert 0 in store.levels[0].cache     # resident despite second_touch
    with sched.batch("take:c") as io:
        io.read(0, 100)
    assert store.levels[0].cache.hits == 1
    assert store.tier_stats()[1].n_iops == 0  # no S3 read for fresh bytes


def test_unattached_store_defaults_to_write_through():
    disk = Disk(np.zeros(16 * 4096, np.uint8))
    store = TieredStore.cached(disk)  # no flush policy attached
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(0, b"z" * 4096)
    assert store.tier_stats()[1].write_iops == 1
    assert store.dirty_extents() == []


def test_flush_on_evict_writes_back_dirty_victim():
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk, mode="flush-on-evict", cache_blocks=2)
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(0, b"a" * (3 * 4096))  # 3 dirty blocks into a 2-block cache
    nvme, s3 = store.tier_stats()
    assert s3.flush_iops == 1           # the evicted victim was written back
    assert nvme.dirty_bytes == 2 * 4096
    assert nvme.evictions == 1


def test_write_back_high_watermark_flushes_down():
    disk = Disk(np.zeros(256 * 4096, np.uint8))
    store = _wb_store(disk, cache_blocks=16, high_watermark=0.5,
                      low_watermark=0.25, deadline_batches=1000)
    sched = IOScheduler(store)
    with sched.write_batch() as wb:     # 10 of 16 blocks dirty: > 0.5
        wb.write(0, b"b" * (10 * 4096))
    cache = store.levels[0].cache
    assert cache.dirty_bytes <= int(0.25 * 16 * 4096) + 4096
    assert store.tier_stats()[1].flush_iops >= 1
    assert store.flush_policy.n_flush_events >= 1


def test_write_back_deadline_flushes_aged_blocks():
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk, deadline_batches=2)
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(0, b"c" * 4096)
    assert store.levels[0].cache.dirty_bytes == 4096
    with sched.batch("take:c") as io:   # read batches tick the deadline too
        io.read(8 * 4096, 64)
    assert store.tier_stats()[1].flush_iops == 1
    assert store.levels[0].cache.dirty_bytes == 0


def test_discard_dirty_counts_lost_bytes():
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk)
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(0, b"d" * (2 * 4096))
        wb.write(8 * 4096, b"d" * 4096)
    lost = store.discard_dirty()
    assert lost == [(0, 2 * 4096), (8 * 4096, 9 * 4096)]
    nvme, s3 = store.tier_stats()
    assert nvme.lost_bytes == 3 * 4096
    assert nvme.dirty_bytes == 0 and s3.write_iops == 0
    # the discarded blocks are gone from the cache, not 'warm garbage'
    assert len(store.levels[0].cache) == 0


def test_flush_fault_injection_is_a_clean_prefix():
    """An interrupted flush must be a prefix: extents dispatched before the
    crash are durable (clean), the rest stay dirty — never half-flushed
    accounting."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk)
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(0, b"e" * 4096)            # run 1
        wb.write(8 * 4096, b"e" * 4096)     # run 2 (disjoint)
    store.flush_policy.fail_after = 1
    with pytest.raises(SimulatedCrash):
        store.flush_all()
    store.flush_policy.fail_after = None
    cache = store.levels[0].cache
    assert not cache.is_dirty(0)            # first extent made it
    assert cache.is_dirty(8)                # second did not
    assert store.tier_stats()[1].flush_iops == 1


def test_model_time_prices_writes():
    """Write traffic must show up in the modelled wall time (the queue-depth
    drain term prices the flush round trip on the backing device)."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk)
    sched = IOScheduler(store)
    t0 = sched.model_time()
    with sched.write_batch() as wb:
        wb.write(0, b"f" * (4 * 4096))
    t_dirty = sched.model_time()
    assert t_dirty > t0                     # NVMe absorption is priced
    store.flush_all()
    assert sched.model_time() > t_dirty + 0.9 * S3.latency  # S3 drain priced


def test_write_batch_rejects_use_after_close():
    disk = Disk(np.zeros(16 * 4096, np.uint8))
    sched = IOScheduler(TieredStore.flat(disk))
    with sched.write_batch() as wb:
        wb.write(0, b"g" * 16)
    with pytest.raises(RuntimeError):
        wb.write(0, b"g")
    assert sched.n_write_batches == 1
    # reads and writes are separate logical traces
    assert sched.stats().n_iops == 0
    assert sched.write_stats().n_iops == 1


def test_flush_policy_validation():
    with pytest.raises(ValueError):
        FlushPolicy("write-sideways")
    with pytest.raises(ValueError):
        FlushPolicy(high_watermark=0.0)
    with pytest.raises(ValueError):
        FlushPolicy(low_watermark=0.9, high_watermark=0.5)
    with pytest.raises(ValueError):
        FlushPolicy(deadline_batches=0)


def test_disk_write_grow_zero():
    disk = Disk(np.zeros(8, np.uint8))
    disk.write(2, b"\x05\x06")
    assert disk.read(0, 5).tolist() == [0, 0, 5, 6, 0]
    assert disk.grow(8) == 16
    assert disk.read(2, 2).tolist() == [5, 6]  # old bytes survive the grow
    disk.zero(2, 4)
    assert disk.read(2, 2).tolist() == [0, 0]
    with pytest.raises(ValueError):
        disk.write(15, b"ab")
    with pytest.raises(ValueError):
        disk.grow(-1)


def test_retriever_tiered():
    from repro.data import synth
    from repro.serve.engine import Retriever

    emb = synth.scenario("embeddings", 1500)
    fb = write_table({"embedding": emb}, WriteOptions("lance"))
    r = Retriever(fb, "embedding", store="tiered")
    ids = np.array([5, 900, 1400])
    r.fetch(ids)
    cold = r.modelled_time()
    _, st = r.fetch(ids)
    assert st.n_iops == len(ids)  # full-zip fixed width: 1 IOP/row
    assert r.modelled_time() < cold
    assert r.tier_stats()[1].n_iops == 0  # warm: no S3 traffic


# ---------------------------------------------------------------------------
# Admission hysteresis: the auto policy must not thrash on the boundary
# ---------------------------------------------------------------------------


def test_admission_hysteresis_no_thrash_on_alternating_mix():
    """An alternating scan/take workload whose byte mix oscillates around
    the boundary must not flip the preference batch to batch: inside the
    +-10% band the previous decision sticks (each flip resets second-touch
    ghost state, so thrashing is not free)."""
    ws = WorkloadStats()
    # establish a clear scan majority -> one flip to second_touch
    ws.note_batch("scan:c", prefetch=True, n_ops=4, nbytes=150_000)
    assert ws.preferred_admission() == "second_touch"
    # pull the mix back to parity: inside the band the flip does NOT revert
    ws.note_batch("take:c", prefetch=False, n_ops=40, nbytes=145_000)
    assert ws.preferred_admission() == "second_touch"
    # alternate batches that rock the byte majority back and forth across
    # parity while staying inside the +-10% band: a memoryless majority
    # test would flip on every sign change, the hysteresis never does
    prefs = []
    sign_changes = 0
    for i in range(20):
        if i % 2:
            ws.note_batch("scan:c", prefetch=True, n_ops=1, nbytes=10_000)
        else:
            ws.note_batch("take:c", prefetch=False, n_ops=10, nbytes=10_000)
        prefs.append(ws.preferred_admission())
        ratio = ws.scan_bytes / ws.take_bytes
        assert 1.0 / 1.1 <= ratio <= 1.1      # genuinely inside the band
        if (ws.scan_bytes > ws.take_bytes) != (i % 2 == 0):
            sign_changes += 1
    assert sign_changes >= 8                  # the majority really oscillated
    assert set(prefs) == {"second_touch"}     # sticky: zero flips in the band
    # a decisive take majority still flips (hysteresis delays, not disables)
    ws.note_batch("take:c", prefetch=False, n_ops=100, nbytes=300_000)
    assert ws.preferred_admission() == "always"


def test_admission_hysteresis_zero_restores_majority_test():
    ws = WorkloadStats(hysteresis=0.0)
    ws.note_batch("scan:c", prefetch=True, n_ops=1, nbytes=1001)
    ws.note_batch("take:c", prefetch=False, n_ops=1, nbytes=1000)
    assert ws.preferred_admission() == "second_touch"
    ws.note_batch("take:c", prefetch=False, n_ops=1, nbytes=2)
    assert ws.preferred_admission() == "always"
    with pytest.raises(ValueError):
        WorkloadStats(hysteresis=-0.1)


# ---------------------------------------------------------------------------
# Partial-block RMW accounting for sub-sector appends
# ---------------------------------------------------------------------------


def test_rmw_sub_sector_write_charges_backing_read():
    """A write-through append landing mid-sector pays one read-modify-write
    sector read on the backing tier; a second write to the now-resident
    sector is free (the write-through fill made the edge mergeable in
    cache)."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk, mode="write-through")
    sched = IOScheduler(store)
    with sched.write_batch("append:0") as wb:
        wb.write(100, b"z" * 1000)          # head+tail edges in one sector
    nvme, s3 = store.tier_stats()
    assert s3.rmw_iops == 1 and s3.rmw_bytes == 4096
    assert s3.n_iops == 1                   # the RMW read is real read IO
    assert s3.write_iops == 1
    # the logical write trace records the append, not the device artifact
    assert sched.write_stats().n_iops == 1
    assert sched.write_stats().bytes_read == 1000
    assert sched.stats().n_iops == 0        # logical *read* trace untouched
    with sched.write_batch("append:1") as wb:
        wb.write(1100, b"z" * 500)          # same sector, now resident
    assert store.tier_stats()[1].rmw_iops == 1  # no new RMW


def test_rmw_aligned_and_eof_writes_are_free():
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk, mode="write-through")
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(4096, b"a" * 8192)         # sector-aligned both ends
    assert store.tier_stats()[1].rmw_iops == 0
    with sched.write_batch() as wb:
        wb.write(64 * 4096 - 1000, b"b" * 1000)  # unaligned head, ends at EOF
    s3 = store.tier_stats()[1]
    assert s3.rmw_iops == 1                 # head edge only: no bytes beyond
    assert s3.rmw_bytes == 4096


def test_rmw_write_back_flush_path():
    """Write-back: the RMW charge lands when the flush writes the dirty run
    down, and dirty residency of the edge sector suppresses it."""
    disk = Disk(np.zeros(64 * 4096, np.uint8))
    store = _wb_store(disk)
    sched = IOScheduler(store)
    with sched.write_batch("append:0") as wb:
        wb.write(100, b"z" * 1000)
    nvme, s3 = store.tier_stats()
    # absorb: the edge sector is not resident anywhere yet -> RMW at absorb
    assert s3.rmw_iops == 1 and nvme.dirty_bytes == 4096
    with sched.write_batch("append:1") as wb:
        wb.write(1100, b"z" * 500)          # edge sector resident dirty
    assert store.tier_stats()[1].rmw_iops == 1   # suppressed
    store.flush_all()
    s3 = store.tier_stats()[1]
    assert s3.rmw_iops == 1                 # flush itself never re-charges
    assert s3.flush_iops == 1


def test_rmw_counters_survive_snapshot_and_reset():
    disk = Disk(np.zeros(16 * 4096, np.uint8))
    store = _wb_store(disk, mode="write-through")
    sched = IOScheduler(store)
    with sched.write_batch() as wb:
        wb.write(50, b"q" * 100)
    snap = store.tier_stats()[1]
    assert snap.rmw_iops == 1 and snap.rmw_bytes == 4096
    store.reset_stats()
    live = store.backing_stats
    assert live.rmw_iops == 0 and live.rmw_bytes == 0
    assert snap.rmw_iops == 1               # snapshot is decoupled
