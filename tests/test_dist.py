"""Distribution substrate: checkpoint round-trip + elastic re-shard, fault
policies, gradient compression, sharding resolution.

The ``repro.dist`` package is not in the tree yet (ROADMAP open item);
skip the whole module until it lands rather than erroring at collection."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "repro.dist", reason="repro.dist substrate not yet in tree (ROADMAP)")

from repro.dist.checkpoint import Checkpointer, latest_step  # noqa: E402
from repro.dist.collectives import dequantize_int8, quantize_int8  # noqa: E402
from repro.dist.fault import DataCursor, HeartbeatMonitor, RestartPolicy, run_with_restarts  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"w": jnp.ones((4, 3), jnp.bfloat16), "s": jnp.int32(7)}}
    ck.save(5, tree, blocking=True)
    assert latest_step(str(tmp_path)) == 5
    out = ck.restore(5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(5)}
    for s in [1, 2, 3, 4]:
        ck.save(s, tree)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_elastic_reshard_subprocess(tmp_path):
    """Save on a 4x2 mesh, restore onto 8x1 — elastic re-sharding."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.dist.checkpoint import Checkpointer

m1 = jax.make_mesh((4, 2), ("data", "model"))
m2 = jax.make_mesh((8, 1), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
tree = {{"w": jax.device_put(x, NamedSharding(m1, P("data", "model")))}}
ck = Checkpointer(r"{tmp_path}")
ck.save(1, tree, blocking=True)
out = ck.restore(1, tree, {{"w": NamedSharding(m2, P("data", None))}})
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
assert out["w"].sharding.mesh.shape["data"] == 8
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"), cwd=REPO)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_quantize_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    resid = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    # over many steps, error feedback makes the *sum* of dequantized values
    # approach the sum of the true values
    total = jnp.zeros_like(x)
    for _ in range(20):
        q, s, resid = quantize_int8(x, resid)
        total = total + dequantize_int8(q, s)
    err = float(jnp.abs(total / 20 - x).max())
    assert err < 0.01


def test_compressed_psum_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.collectives import compressed_psum

mesh = jax.make_mesh((4,), ("pod",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)).astype(np.float32))

def f(xx):
    out, _ = compressed_psum(xx[0], "pod")
    return out[None]

with jax.set_mesh(mesh):
    got = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("pod", None),
                                out_specs=P("pod", None), check_vma=False))(x)
want = x.sum(0)
rel = float(jnp.abs(np.asarray(got)[0] - want).max() / jnp.abs(want).max())
assert rel < 0.05, rel
print("PSUM_OK", rel)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"), cwd=REPO)
    assert "PSUM_OK" in r.stdout, r.stdout + r.stderr


def test_heartbeat_flags_stragglers():
    hb = HeartbeatMonitor(window=20, threshold=3.0)
    import time

    for s in range(15):
        hb.start_step()
        hb._t0 -= 0.10  # pretend 100ms steps
        hb.end_step(s)
    hb.start_step()
    hb._t0 -= 1.0  # a 1s straggler
    hb.end_step(15)
    assert 15 in hb.flagged


def test_restart_policy_gives_up():
    p = RestartPolicy(max_restarts=2, backoff_s=0.0)
    assert p.should_restart()
    assert p.should_restart()
    assert not p.should_restart()


def test_run_with_restarts_resumes():
    calls = []
    state = {"failed": False}

    def step(s):
        calls.append(s)
        if s == 3 and not state["failed"]:
            state["failed"] = True
            raise RuntimeError("boom")

    def on_failure(e):
        return 2  # restored checkpoint at step 1

    last = run_with_restarts(step, start_step=0, n_steps=6,
                             policy=RestartPolicy(backoff_s=0.0),
                             on_failure=on_failure)
    assert last == 6
    assert calls == [0, 1, 2, 3, 2, 3, 4, 5]


def test_data_cursor_deterministic():
    c = DataCursor(seed=1, global_batch=8, n_rows=1000)
    a = c.rows_for_step(42)
    b = c.rows_for_step(42)
    np.testing.assert_array_equal(a, b)
    assert (c.rows_for_step(43) != a).any()


def test_sharding_policy_resolution():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.dist.sharding import ShardingPolicy
from repro.models.common import DP, TP

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
pol = ShardingPolicy(mesh, fsdp=True)
assert pol.param_spec(P(DP, TP)) == P("data", "model")
assert pol.act_spec(P(DP, None)) == P(("pod", "data"), None)
pol2 = ShardingPolicy(mesh, fsdp=False)
assert pol2.param_spec(P(DP, TP)) == P(None, "model")
print("POLICY_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env=dict(os.environ, PYTHONPATH="src"), cwd=REPO)
    assert "POLICY_OK" in r.stdout, r.stdout + r.stderr
