"""Failure/recovery layer: transient-error injection, retry/timeout/backoff,
tier failover, SLO-driven load shedding — deterministic unit tests.

The tentpole contracts:

* fault schedules are consulted ONLY by the interleaved timing overlay:
  serial pricing, logical accounting, and any run without error-capable
  faults (including ``TransientErrors(error_prob=0)``) are bit-identical
  to the healthy loop, with or without a :class:`RetryPolicy` attached;
* same seed + same fault schedule ⇒ bit-identical completions, counters
  and failed-request sets across repeated ``run()`` calls;
* failures surface as per-request ``JobCompletion.error`` values — shed,
  retried, failed-over and failed jobs all complete exactly once
  (completed + failed + shed == submitted);
* a blackout on a cache tier degrades latency, not availability, when
  failover is on — and provably bites when it is off.
"""

import math

import pytest

from repro.core.io_sim import (DRAM, NVME, S3, Blackout, CorrelatedFault,
                               Degradation, TransientErrors)
from repro.obs.slo import BurnWindow, Shedder, SLObjective, SLOMonitor
from repro.store import EventLoop, QoS, RetryPolicy, build_job
from repro.store.stats import DrainRecord

DEVICES = [NVME, S3]


def _rec(label, ops=64, nb=64 * 4096, tier=0, phase=0):
    return DrainRecord(label, 1, {tier: ({phase: ops}, {phase: nb})})


def _jobs(n=40, spacing=1e-4, tenant="t", ops=64):
    return [build_job(_rec(f"j{i}", ops=ops), DEVICES, tenant=tenant,
                      submit=i * spacing, seq=i) for i in range(n)]


# ---------------------------------------------------------------------------
# Fault models
# ---------------------------------------------------------------------------


def test_transient_errors_validation_and_window():
    with pytest.raises(ValueError):
        TransientErrors(0.0, error_prob=1.5)
    with pytest.raises(ValueError):
        TransientErrors(2.0, 1.0)
    with pytest.raises(ValueError):
        Blackout(2.0, 1.0)
    f = TransientErrors(1.0, 2.0, 0.5, seed=7)
    assert f.active(1.0) and f.active(1.999) and not f.active(2.0)
    assert not f.active(0.999)


def test_op_fails_at_is_deterministic_and_seeded():
    d = NVME.with_fault(TransientErrors(1.0, 2.0, 0.5, seed=7))
    draws = [d.op_fails_at(1.5, 0, i, 0, 0) for i in range(500)]
    assert draws == [d.op_fails_at(1.5, 0, i, 0, 0) for i in range(500)]
    frac = sum(draws) / len(draws)
    assert 0.4 < frac < 0.6  # unbiased-ish at p=0.5
    # different seed -> different failure set
    d2 = NVME.with_fault(TransientErrors(1.0, 2.0, 0.5, seed=8))
    assert draws != [d2.op_fails_at(1.5, 0, i, 0, 0) for i in range(500)]
    # outside the window nothing fails
    assert not any(d.op_fails_at(0.5, 0, i, 0, 0) for i in range(100))
    assert not any(d.op_fails_at(2.5, 0, i, 0, 0) for i in range(100))


def test_failure_sets_nest_in_error_prob():
    # same key fails at p_lo ⇒ fails at p_hi (threshold draws share the
    # uniform), the structural basis of makespan monotonicity
    lo = NVME.with_fault(TransientErrors(0.0, 1.0, 0.1, seed=3))
    hi = NVME.with_fault(TransientErrors(0.0, 1.0, 0.4, seed=3))
    keys = [(0, i, s, a) for i in range(50) for s in range(4)
            for a in range(2)]
    for k in keys:
        if lo.op_fails_at(0.5, *k):
            assert hi.op_fails_at(0.5, *k)


def test_blackout_fails_everything_and_error_fault_flags():
    b = NVME.with_fault(Blackout(1.0, 2.0))
    assert all(b.op_fails_at(1.5, 0, i, 0, 0) for i in range(50))
    assert b.has_error_faults
    assert not NVME.has_error_faults
    # Degradation alone cannot fail ops
    deg = NVME.with_fault(Degradation(0.0, 1.0))
    assert not deg.has_error_faults
    # error faults never stretch latency/bandwidth factors
    assert b.latency_factor_at(1.5) == 1.0
    assert b.bandwidth_factor_at(1.5) == 1.0


def test_correlated_fault_stamps_named_tiers():
    cf = CorrelatedFault(Blackout(1.0, 2.0), (NVME.name, S3.name))
    out = cf.apply([NVME, S3, DRAM])
    assert len(out[0].faults) == 1 and len(out[1].faults) == 1
    assert not out[2].faults
    assert out[0].faults[0] is out[1].faults[0]  # same window object
    with pytest.raises(ValueError):
        CorrelatedFault(Blackout(0.0), ("nope",)).apply([NVME, S3])


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(timeout_k=0.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=-0.1)


# ---------------------------------------------------------------------------
# Healthy-path bit-identity (contract #8)
# ---------------------------------------------------------------------------


def test_policy_on_healthy_devices_is_bit_identical():
    jobs = _jobs()
    base = EventLoop(DEVICES, queue_depth=64).run(jobs)
    armed = EventLoop(DEVICES, queue_depth=64, retry=RetryPolicy()).run(jobs)
    assert base.completions == armed.completions
    assert armed.counters == {}


def test_zero_prob_transient_errors_is_bit_identical_to_healthy():
    jobs = _jobs()
    base = EventLoop(DEVICES, queue_depth=64).run(jobs)
    d0 = [NVME.with_fault(TransientErrors(0.0, error_prob=0.0)), S3]
    out = EventLoop(d0, queue_depth=64, retry=RetryPolicy()).run(jobs)
    assert base.completions == out.completions
    assert out.counters == {}


def test_serial_mode_is_blind_to_error_faults():
    jobs = _jobs()
    base = EventLoop(DEVICES, queue_depth=64).run(jobs, mode="serial")
    db = [NVME.with_fault(Blackout(0.0)), S3]
    out = EventLoop(db, queue_depth=64, retry=RetryPolicy()).run(
        jobs, mode="serial")
    assert base.completions == out.completions


# ---------------------------------------------------------------------------
# Retry / backoff / deadline
# ---------------------------------------------------------------------------


def _transient_run(error_prob=0.05, seed=3, policy=None, jobs=None):
    jobs = jobs if jobs is not None else _jobs()
    M = EventLoop(DEVICES, queue_depth=64).run(jobs).makespan
    dev = [NVME.with_fault(
        TransientErrors(0.25 * M, 0.75 * M, error_prob, seed=seed)), S3]
    loop = EventLoop(dev, queue_depth=64, retry=policy or RetryPolicy())
    return loop.run(jobs), M


def test_transient_errors_retry_and_stretch_makespan():
    out, M = _transient_run()
    assert out.counters.get("retry.nvme_970evo", 0) > 0
    assert out.makespan >= M
    assert len(out.completions) == 40
    # transient faults with failover available lose nothing
    assert not out.errors
    assert out.availability() == 1.0


def test_faulted_run_is_replayable_bit_identical():
    jobs = _jobs()
    a, _ = _transient_run(jobs=jobs)
    b, _ = _transient_run(jobs=jobs)
    assert a.completions == b.completions
    assert a.counters == b.counters
    assert [c.label for c in a.errors] == [c.label for c in b.errors]


def test_different_fault_seed_changes_schedule():
    jobs = _jobs()
    a, _ = _transient_run(seed=3, jobs=jobs)
    b, _ = _transient_run(seed=4, jobs=jobs)
    assert a.completions != b.completions


def test_backoff_delays_are_priced_on_the_virtual_clock():
    # one job, first attempt lands inside a blackout window the first
    # backoff (0.5 s, no jitter) clears: makespan grows by at least that
    # delay relative to the healthy price
    jobs = _jobs(n=1)
    healthy = EventLoop(DEVICES, queue_depth=64).run(jobs)
    assert healthy.makespan < 0.4
    pol = RetryPolicy(backoff_base=0.5, jitter=0.0)
    dev = [NVME.with_fault(Blackout(0.0, 0.4)), S3]
    out = EventLoop(dev, queue_depth=64, retry=pol).run(jobs)
    assert not out.errors
    assert out.makespan >= 0.5  # paid at least one backoff


def test_max_retries_bounds_attempts_then_fails_without_failover():
    jobs = _jobs(n=5)
    pol = RetryPolicy(max_retries=2, failover=False, jitter=0.0)
    dev = [NVME.with_fault(Blackout(0.0)), S3]
    out = EventLoop(dev, queue_depth=64, retry=pol).run(jobs)
    assert len(out.errors) == 5
    assert all(c.error == "io:nvme_970evo" for c in out.errors)
    assert out.availability() == 0.0
    # bounded: exactly max_retries backoffs' worth of re-draws per unit
    assert out.counters["retry.nvme_970evo"] == 5 * 64 * 2
    assert out.counters["error.t"] == 5


def test_deadline_exhausts_before_max_retries():
    jobs = _jobs(n=1)
    # huge backoffs + tiny timeout: the deadline trips on the first failure
    pol = RetryPolicy(max_retries=50, backoff_base=10.0, timeout_k=1.0,
                      failover=False, jitter=0.0)
    dev = [NVME.with_fault(Blackout(0.0)), S3]
    out = EventLoop(dev, queue_depth=64, retry=pol).run(jobs)
    assert len(out.errors) == 1
    # far fewer than 50 rounds of retries happened
    assert out.counters["retry.nvme_970evo"] <= 64 * 3


# ---------------------------------------------------------------------------
# Failover
# ---------------------------------------------------------------------------


def test_blackout_with_failover_degrades_latency_not_availability():
    jobs = _jobs()
    M = EventLoop(DEVICES, queue_depth=64).run(jobs).makespan
    dev = [NVME.with_fault(Blackout(0.3 * M)), S3]  # NVMe gone for good
    on = EventLoop(dev, queue_depth=64, retry=RetryPolicy()).run(jobs)
    off = EventLoop(dev, queue_depth=64,
                    retry=RetryPolicy(failover=False)).run(jobs)
    assert not on.errors                      # everything lands on S3
    assert on.counters.get("failover.nvme_970evo", 0) > 0
    assert on.makespan > M                    # but slower than healthy
    assert off.errors                         # without failover it bites
    assert off.availability("t") < 1.0
    # conservation either way: every submitted job completes exactly once
    assert len(on.completions) == len(jobs)
    assert len(off.completions) == len(jobs)


def test_failover_exhausts_on_last_tier():
    # fault the *backing* tier: there is nowhere left to go
    jobs = _jobs()
    dev = [NVME, S3.with_fault(Blackout(0.0))]
    rec = DrainRecord("s3only", 1, {1: ({0: 8}, {0: 8 << 20})})
    job = build_job(rec, dev, tenant="t")
    out = EventLoop(dev, queue_depth=64, retry=RetryPolicy()).run([job])
    assert len(out.errors) == 1
    assert out.errors[0].error == "io:s3"


def test_correlated_blackout_rides_out_short_window():
    jobs = _jobs()
    M = EventLoop(DEVICES, queue_depth=64).run(jobs).makespan
    cf = CorrelatedFault(Blackout(0.3 * M, 0.5 * M), (NVME.name, S3.name))
    out = EventLoop(cf.apply(DEVICES), queue_depth=64,
                    retry=RetryPolicy()).run(jobs)
    # backoff spans the window: retries land after it clears, nothing lost
    assert not out.errors
    assert out.counters.get("retry.nvme_970evo", 0) > 0


def test_accounting_plane_is_fault_blind():
    # retries/failover change timing only: the drain records (logical
    # IOPS/bytes) are inputs the loop never mutates, so re-building jobs
    # from the same records yields identical unit loads
    rec = _rec("j", ops=64)
    a = build_job(rec, DEVICES)
    dev = [NVME.with_fault(Blackout(0.0)), S3]
    EventLoop(dev, queue_depth=64, retry=RetryPolicy()).run([a])
    b = build_job(rec, DEVICES)
    assert [(u.ops, u.nbytes, u.pipe) for u in a.units] == \
           [(u.ops, u.nbytes, u.pipe) for u in b.units]


# ---------------------------------------------------------------------------
# SLO-driven load shedding
# ---------------------------------------------------------------------------


def _shed_setup(n=200, spacing=3e-4):
    jobs = []
    seq = 0
    for i in range(n):
        for tenant in ("premium", "standard", "standard"):
            seq += 1
            jobs.append(build_job(_rec(f"{tenant}{i}"), DEVICES,
                                  tenant=tenant, submit=i * spacing,
                                  seq=seq))
    qos = QoS(priority={"premium": 1})
    healthy = EventLoop(DEVICES, queue_depth=64, qos=qos).run(jobs)
    return jobs, qos, healthy


def test_shedder_validation():
    mon = SLOMonitor({"p": SLObjective(1.0)})
    with pytest.raises(ValueError):
        Shedder(mon, ("p",), ("p",))          # protect ∩ shed
    with pytest.raises(ValueError):
        Shedder(mon, ("p",), ("s",), on_burn=1.0, off_burn=2.0)
    with pytest.raises(ValueError):
        Shedder(mon, ("p",), ("s",), hold_s=-1.0)


def test_shedding_engages_with_hysteresis_and_protects_premium():
    jobs, qos, healthy = _shed_setup()
    M = healthy.makespan
    obj = SLObjective(latency_s=healthy.percentiles("premium")["p99"] * 5,
                      target=0.99)
    win = BurnWindow(long_s=M / 8, short_s=M / 64)
    dev = [NVME.with_fault(Degradation(0.2 * M, 0.8 * M,
                                       latency_factor=2.0,
                                       throughput_factor=1.0)), S3]

    def run(shed_on):
        mon = SLOMonitor({"premium": obj}, windows=(win,))
        sh = Shedder(mon, protect=("premium",), shed=("standard",),
                     on_burn=4.0, off_burn=1.0,
                     hold_s=M / 4) if shed_on else None
        res = EventLoop(dev, queue_depth=64, qos=qos, retry=RetryPolicy(),
                        slo=mon, shedder=sh).run(jobs)
        return res, sh

    on, sh = run(True)
    off, _ = run(False)
    assert sh.trips == 1                      # hold-down prevents flapping
    assert on.counters["shed.standard"] > 0
    assert "shed.premium" not in on.counters  # never sheds the protected
    assert on.availability("premium") == 1.0
    # relief is real: premium p99 improves and the makespan recovers
    assert on.percentiles("premium")["p99"] < off.percentiles("premium")["p99"]
    assert on.makespan < off.makespan
    # conservation: completed + failed + shed == submitted
    assert len(on.completions) == len(jobs)
    shed = [c for c in on.completions if c.error == "shed"]
    assert len(shed) == on.counters["shed.standard"]
    assert all(c.tenant == "standard" for c in shed)


def test_shedder_reset_restores_purity():
    jobs, qos, healthy = _shed_setup(n=120)
    M = healthy.makespan
    obj = SLObjective(latency_s=healthy.percentiles("premium")["p99"] * 5)
    win = BurnWindow(long_s=M / 8, short_s=M / 64)
    dev = [NVME.with_fault(Degradation(0.2 * M, 0.8 * M,
                                       latency_factor=2.0)), S3]

    def once():
        mon = SLOMonitor({"premium": obj}, windows=(win,))
        sh = Shedder(mon, ("premium",), ("standard",), hold_s=M / 4)
        return EventLoop(dev, queue_depth=64, qos=qos, retry=RetryPolicy(),
                         slo=mon, shedder=sh).run(jobs)

    assert once().completions == once().completions


def test_shed_requests_do_not_feed_slo_monitor():
    mon = SLOMonitor({"standard": SLObjective(latency_s=1e-9)})
    # no protected tenants ⇒ burn stays 0; a huge hold-down keeps the
    # forced engagement latched for the whole (sub-second) run
    sh = Shedder(mon, protect=(), shed=("standard",), hold_s=1e9)
    sh.active = True  # force shedding
    jobs = _jobs(n=10, tenant="standard")
    out = EventLoop(DEVICES, queue_depth=64, slo=mon, shedder=sh).run(jobs)
    assert len(out.errors) == 10
    assert mon.table()[0]["requests"] == 0    # rejections are not evidence


def test_slo_observe_error_counts_against_budget():
    mon = SLOMonitor({"t": SLObjective(latency_s=100.0)})
    mon.observe("t", 1.0, 0.001, error=True)  # fast but failed
    row = mon.table()[0]
    assert row["bad"] == 1
    assert mon.registry.counter("slo.errors.t").value == 1


def test_current_burn_query():
    mon = SLOMonitor({"t": SLObjective(latency_s=1.0, target=0.9)})
    for i in range(10):
        mon.observe("t", 1.0 + i * 0.01, 2.0)  # all bad
    assert mon.current_burn("t", 1.1) == pytest.approx(10.0)  # 1.0/0.1
    assert mon.current_burn("nope", 1.1) == 0.0


# ---------------------------------------------------------------------------
# Window integration
# ---------------------------------------------------------------------------


def test_service_window_run_accepts_fault_devices_and_retry(tmp_path):
    import numpy as np

    from repro.core import arrays as A
    from repro.core.file import FileReader, WriteOptions, write_table

    rng = np.random.default_rng(0)
    arr = A.PrimitiveArray.build(rng.integers(0, 1 << 20, 20_000)
                                 .astype(np.int64))
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
    reader = FileReader(fb, store="tiered")
    sched = reader.scheduler
    assert isinstance(sched.retry_policy, RetryPolicy)  # compiled in
    with sched.service_window() as win:
        for i in range(6):
            with win.request(tenant="t", at=i * 1e-3, request=f"r{i}"):
                reader.take("c", rng.integers(0, 20_000, 64))
    healthy = win.run("interleaved")
    assert not healthy.errors
    M = healthy.makespan
    faulted = [d.with_fault(Blackout(0.0)) if d.name == NVME.name else d
               for d in sched._devices()]
    out = win.run("interleaved", devices=faulted)
    again = win.run("interleaved", devices=faulted)
    assert out.completions == again.completions  # faulted purity
    assert len(out.completions) == len(healthy.completions)
    # with failover compiled in, a cache blackout loses nothing
    assert not out.errors
    # and the healthy run is still reproducible afterwards
    assert win.run("interleaved").completions == healthy.completions


# ---------------------------------------------------------------------------
# Fault-aware cache admission
# ---------------------------------------------------------------------------


def _tiered(backing):
    import numpy as np

    from repro.core.io_sim import Disk
    from repro.store import IOScheduler, TieredStore

    disk = Disk(np.arange(1 << 16, dtype=np.uint8) % 251)
    store = TieredStore.cached(disk, backing=backing, cache_bytes=1 << 20)
    return store, IOScheduler(store, queue_depth=64)


def test_brownout_blocks_are_not_admitted():
    """A block fetched while its source tier is inside a fault window is
    served but NOT cached: brownout traffic must not evict the working set
    (the regression: pre-gate, a brownout polluted the cache with
    slow-path blocks that then looked "hot")."""
    store, sch = _tiered(S3.with_fault(Degradation(0.0, latency_factor=8.0)))
    with sch.batch("take") as io:
        io.read(0, 4096 * 4)
    assert len(store.levels[0].cache) == 0
    assert store.admission_fault_skips == 4
    # served, not admitted: the reads were still priced on the backing tier
    assert store.backing_stats.n_iops > 0
    # error-window faults gate admission too (a blacked-out tier is not
    # producing working-set evidence either)
    store_b, sch_b = _tiered(S3.with_fault(Blackout(0.0)))
    with sch_b.batch("take") as io:
        io.read(0, 4096 * 2)
    assert len(store_b.levels[0].cache) == 0
    assert store_b.admission_fault_skips == 2


def test_admission_resumes_outside_the_fault_window():
    """The gate follows the virtual clock: a future window admits
    normally, and the skip counter resets with the stats."""
    store, sch = _tiered(S3.with_fault(Degradation(start=1e9)))
    with sch.batch("take") as io:
        io.read(0, 4096 * 4)
    assert len(store.levels[0].cache) == 4
    assert store.admission_fault_skips == 0
    # advance the virtual clock into the window: admission stops
    store2, sch2 = _tiered(S3.with_fault(Degradation(start=1e-9)))
    with sch2.batch("warmup") as io:
        io.read(0, 4096)  # admitted at t=0 (window not yet open)
    assert len(store2.levels[0].cache) == 1
    assert sch2.vclock > 1e-9  # the drain advanced the clock into the window
    with sch2.batch("take") as io:
        io.read(4096 * 8, 4096 * 2)
    assert len(store2.levels[0].cache) == 1  # nothing new admitted
    assert store2.admission_fault_skips == 2
    store2.reset_stats()
    assert store2.admission_fault_skips == 0


def test_healthy_store_admission_is_unchanged():
    """No faults -> the gate is never consulted and behaviour is the
    seed's: every miss admitted (committed baselines stay bit-identical)."""
    store, sch = _tiered(S3)
    with sch.batch("take") as io:
        io.read(0, 4096 * 4)
    assert len(store.levels[0].cache) == 4
    assert store.admission_fault_skips == 0
