"""Property-based shredding tests over arbitrary nested types (optional:
require ``hypothesis``).  Example-based cases stay in ``test_shred.py``."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import arrays as A  # noqa: E402
from repro.core import types as T  # noqa: E402
from repro.core.shred import shred, unshred  # noqa: E402


def rt(pyvals, typ):
    arr = A.from_pylist(pyvals, typ)
    back = unshred(shred(arr), typ)
    assert A.to_pylist(back) == pyvals


def _type_strategy(depth=2):
    prim = st.sampled_from([T.int64(), T.int32(), T.float64(), T.utf8()])
    if depth == 0:
        return prim
    sub = _type_strategy(depth - 1)
    return st.one_of(
        prim,
        st.builds(lambda c, n: T.List(c, nullable=n), sub, st.booleans()),
        st.builds(lambda c, n: T.Struct((("f", c),), nullable=n), sub, st.booleans()),
    )


def _value_for(typ, draw, size):
    if isinstance(typ, T.Primitive):
        if typ.dtype.startswith("f"):
            gen = st.floats(-100, 100, allow_nan=False).map(lambda x: float(np.float64(x)))
        else:
            gen = st.integers(-1000, 1000)
    elif isinstance(typ, T.Utf8):
        gen = st.text(alphabet="abcXYZ", max_size=6)
    elif isinstance(typ, T.List):
        gen = st.lists(_value_strategy(typ.child), max_size=4)
    elif isinstance(typ, T.Struct):
        gen = st.fixed_dictionaries({n: _value_strategy(f) for n, f in typ.fields})
    else:
        raise TypeError(typ)
    return gen


def _value_strategy(typ):
    base = _value_for(typ, None, None)
    if typ.nullable:
        return st.one_of(st.none(), base)
    return base


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_roundtrip_property(data):
    typ = data.draw(_type_strategy())
    n = data.draw(st.integers(0, 12))
    vals = [data.draw(_value_strategy(typ)) for _ in range(n)]
    rt(vals, typ)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_entry_stream_invariants(data):
    """Entries with def==0 exactly equal the number of stored values; every
    top-level row contributes >=1 entry."""
    typ = data.draw(_type_strategy())
    n = data.draw(st.integers(1, 10))
    vals = [data.draw(_value_strategy(typ)) for _ in range(n)]
    arr = A.from_pylist(vals, typ)
    for leaf in shred(arr):
        n_valid = int((leaf.defs == 0).sum()) if leaf.defs is not None else leaf.n_entries
        assert n_valid == len(leaf.values)
        if leaf.max_rep > 0:
            assert int((leaf.rep == leaf.max_rep).sum()) == n
        else:
            assert leaf.n_entries == n
