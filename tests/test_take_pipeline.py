"""The batched random-access (take) pipeline.

Property-style equivalence: for every structural encoding and data shape,
``take(rows)`` must equal ``scan()`` gathered at ``rows`` — including
unsorted and duplicated row ids (the pipeline dedupes before IO and fans
results back out to request order).  Plus the decode-route contract: the
Pallas mini-block decoder (interpret mode on CPU) is bit-identical to the
numpy path, with clean fallback for codecs the kernel doesn't cover.
"""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.file import FileReader, WriteOptions, write_table

rng = np.random.default_rng(123)


def _dataset(kind: str, n: int) -> A.Array:
    if kind == "primitive":
        return A.PrimitiveArray.build(
            rng.integers(0, 1 << 20, n).astype(np.int64), nullable=False)
    if kind == "nullable":
        return A.PrimitiveArray.build(
            rng.integers(0, 1 << 20, n).astype(np.int64),
            validity=rng.random(n) > 0.1)
    if kind == "utf8":
        vals = [None if rng.random() < 0.1 else
                bytes(rng.integers(97, 123, rng.integers(0, 12), dtype=np.uint8))
                for _ in range(n)]
        return A.VarBinaryArray.build(vals, utf8=True)
    if kind == "fixed-size-list":
        return A.FixedSizeListArray.build(
            rng.integers(0, 1 << 10, (n, 4)).astype(np.int32),
            validity=rng.random(n) > 0.1)
    if kind == "nested-list":
        py = []
        for _ in range(n):
            u = rng.random()
            if u < 0.1:
                py.append(None)
            elif u < 0.2:
                py.append([])
            else:
                py.append([None if rng.random() < 0.1 else int(v)
                           for v in rng.integers(0, 1 << 16, rng.integers(1, 6))])
        return A.from_pylist(py, T.List(T.Primitive("int64", nullable=True)))
    raise ValueError(kind)


ENCODINGS = [
    ("lance", WriteOptions("lance")),
    ("lance-miniblock", WriteOptions("lance-miniblock")),
    ("lance-fullzip", WriteOptions("lance-fullzip")),
    ("parquet", WriteOptions("parquet")),
    ("arrow", WriteOptions("arrow")),
]
KINDS = ["primitive", "nullable", "utf8", "fixed-size-list", "nested-list"]


def _messy_rows(n: int, k: int) -> np.ndarray:
    """Unsorted row ids with duplicates (and a reversed tail)."""
    rows = rng.integers(0, n, k)
    rows[: k // 4] = rows[k // 2: k // 2 + k // 4][::-1]  # forced duplicates
    return rows


@pytest.mark.parametrize("encname,opts", ENCODINGS, ids=[e[0] for e in ENCODINGS])
@pytest.mark.parametrize("kind", KINDS)
def test_take_equals_scan_gather(encname, opts, kind):
    # large enough that mini-block rows cross chunk boundaries for lists
    n = 3000 if kind == "nested-list" else 600
    arr = _dataset(kind, n)
    fr = FileReader(write_table({"c": arr}, opts))
    want = A.to_pylist(fr.scan("c"))
    assert want == A.to_pylist(arr)
    rows = _messy_rows(n, 41)
    got = A.to_pylist(fr.take("c", rows))
    assert got == [want[i] for i in rows]


@pytest.mark.parametrize("encname,opts", ENCODINGS[:3], ids=[e[0] for e in ENCODINGS[:3]])
def test_take_reversed_and_empty(encname, opts):
    arr = _dataset("nullable", 500)
    fr = FileReader(write_table({"c": arr}, opts))
    want = A.to_pylist(arr)
    rows = np.arange(499, -1, -7)
    assert A.to_pylist(fr.take("c", rows)) == [want[i] for i in rows]
    assert len(fr.take("c", np.zeros(0, np.int64))) == 0


@pytest.mark.parametrize("enc", ["lance-miniblock", "lance-fullzip"])
def test_take_out_of_range_raises(enc):
    arr = _dataset("primitive", 200)
    fr = FileReader(write_table({"c": arr}, WriteOptions(enc)))
    with pytest.raises(IndexError):
        fr.take("c", np.array([0, 200]))
    with pytest.raises(IndexError):
        fr.take("c", np.array([-1]))


def test_packed_take_out_of_range_raises():
    arr = A.StructArray.build(
        [("f0", A.PrimitiveArray.build(np.arange(100, dtype=np.int64),
                                       nullable=False))], nullable=False)
    fr = FileReader(write_table({"s": arr},
                                WriteOptions("lance", packed_columns=("s",))))
    with pytest.raises(IndexError):
        fr.take("s", np.array([100]))


def test_fullzip_take_dedupes_fixed_width_io():
    """Duplicate rows must not re-read identical spans: 1 IOP per *unique*
    row on the fixed-width (no repetition index) path."""
    arr = A.FixedSizeListArray.build(
        rng.standard_normal((400, 32)).astype(np.float32), nullable=False)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance-fullzip")))
    rows = np.array([7, 3, 7, 7, 3, 11, 3])
    fr.reset_io()
    got = fr.take("c", rows)
    st = fr.io_stats()
    assert st.n_iops == 3  # unique rows only
    assert st.max_phase == 1
    want = A.to_pylist(arr)
    assert A.to_pylist(got) == [want[i] for i in rows]


def test_fullzip_take_dedupes_rep_index_io():
    """Var-width path: 2 IOPS (index + span) per unique row, duplicates
    fanned out from the decoded result."""
    arr = _dataset("utf8", 400)
    fr = FileReader(write_table({"c": arr}, WriteOptions("lance-fullzip")))
    rows = np.array([5, 2, 5, 2, 9, 5])
    fr.reset_io()
    got = fr.take("c", rows)
    st = fr.io_stats()
    assert st.n_iops == 2 * 3
    assert st.max_phase == 2
    want = A.to_pylist(arr)
    assert A.to_pylist(got) == [want[i] for i in rows]


def test_packed_struct_take_dup_unsorted():
    n = 300
    children = [(f"f{i}", A.PrimitiveArray.build(
        rng.integers(0, 1 << 30, n).astype(np.int64), nullable=False))
        for i in range(3)]
    arr = A.StructArray.build(children, nullable=False)
    fr = FileReader(write_table({"s": arr},
                                WriteOptions("lance", packed_columns=("s",))))
    rows = np.array([250, 3, 250, 17, 3, 250])
    fr.reset_io()
    got = fr.take("s", rows)
    assert fr.io_stats().n_iops == 3  # deduped, one IOP per unique row
    want = A.to_pylist(arr)
    assert A.to_pylist(got) == [want[i] for i in rows]


# ---------------------------------------------------------------------------
# pallas decode route
# ---------------------------------------------------------------------------


def _bit_identical(a: A.Array, b: A.Array):
    assert np.array_equal(a.validity, b.validity)
    if isinstance(a, A.VarBinaryArray):
        assert np.array_equal(a.offsets, b.offsets)
        assert np.array_equal(a.data, b.data)
    elif isinstance(a, A.ListArray):
        assert np.array_equal(a.offsets, b.offsets)
        _bit_identical(a.child, b.child)
    elif isinstance(a, A.StructArray):
        for (_, ca), (_, cb) in zip(a.children, b.children):
            _bit_identical(ca, cb)
    else:
        assert a.values.dtype == b.values.dtype
        assert np.array_equal(a.values, b.values)


@pytest.mark.parametrize("kind", ["primitive", "nullable"])
def test_miniblock_pallas_parity(kind):
    """decode='pallas' (interpret mode) is bit-identical to numpy on the
    bit-packed flat integer path, for take and scan."""
    pytest.importorskip("jax")
    arr = _dataset(kind, 5000)  # several chunks
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
    fr_np = FileReader(fb, decode="numpy")
    fr_pl = FileReader(fb, decode="pallas")
    rows = _messy_rows(5000, 67)
    _bit_identical(fr_np.take("c", rows), fr_pl.take("c", rows))
    _bit_identical(fr_np.scan("c"), fr_pl.scan("c"))
    # identical logical IO regardless of decode route
    fr_np.reset_io(); fr_np.take("c", rows)
    fr_pl.reset_io(); fr_pl.take("c", rows)
    a, b = fr_np.io_stats(), fr_pl.io_stats()
    assert (a.n_iops, a.bytes_read, a.max_phase) == (b.n_iops, b.bytes_read, b.max_phase)


def test_miniblock_pallas_fallback_codecs():
    """Codecs the kernel doesn't cover (floats/utf8) fall back to numpy and
    still roundtrip under decode='pallas'."""
    pytest.importorskip("jax")
    for kind in ["utf8", "fixed-size-list"]:
        arr = _dataset(kind, 400)
        fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
        fr = FileReader(fb, decode="pallas")
        want = A.to_pylist(arr)
        rows = np.array([3, 1, 3, 99, 1])
        assert A.to_pylist(fr.take("c", rows)) == [want[i] for i in rows]


def _struct_nullable(n: int) -> A.Array:
    """Nullable struct with a nullable int field: max_def == 2, so the def
    stream is multi-bit (the widened kernel's nested-null coverage)."""
    inner = A.PrimitiveArray.build(
        rng.integers(0, 1 << 12, n).astype(np.int64),
        validity=rng.random(n) > 0.15)
    return A.StructArray.build([("f", inner)], validity=rng.random(n) > 0.1)


WIDENED = [
    ("fixed-size-list", lambda: _dataset("fixed-size-list", 5000), {}),
    ("nested-list", lambda: _dataset("nested-list", 6000), {}),
    ("bytepack", lambda: A.PrimitiveArray.build(
        (rng.integers(0, 1 << 16, 5000) + 123_456).astype(np.int64),
        validity=rng.random(5000) > 0.1), {"fixed_codec": "bytepack"}),
    ("struct-def2", lambda: _struct_nullable(5000), {}),
]


@pytest.mark.parametrize("name,build,kw", WIDENED, ids=[w[0] for w in WIDENED])
def test_miniblock_pallas_widened_coverage(name, build, kw):
    """Chunk shapes that used to hit the numpy fallback — multi-bit def
    streams, rep streams, FoR bytepack, fixed-size-list values — now decode
    through the kernel bit-identically, with identical logical IO."""
    pytest.importorskip("jax")
    arr = build()
    n = len(arr)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock", **kw))
    fr_np = FileReader(fb, decode="numpy")
    fr_pl = FileReader(fb, decode="pallas")
    rows = _messy_rows(n, 67)
    _bit_identical(fr_np.take("c", rows), fr_pl.take("c", rows))
    _bit_identical(fr_np.scan("c"), fr_pl.scan("c"))
    fr_np.reset_io(); fr_np.take("c", rows)
    fr_pl.reset_io(); fr_pl.take("c", rows)
    a, b = fr_np.io_stats(), fr_pl.io_stats()
    assert (a.n_iops, a.bytes_read, a.max_phase) == (b.n_iops, b.bytes_read, b.max_phase)


def test_miniblock_widened_chunks_use_kernel():
    """The widened shapes actually route through the kernel (no silent
    fallback): the batched pallas decode path must claim the chunks."""
    pytest.importorskip("jax")
    for name, build, kw in WIDENED:
        arr = build()
        fb = write_table({"c": arr}, WriteOptions("lance-miniblock", **kw))
        fr = FileReader(fb, decode="pallas")
        for reader in fr._leaf_readers("c"):
            if not reader._pallas_eligible():
                # the nested-list *values* leaf is int64 -> must be eligible;
                # only non-integer leaves may fall back
                raise AssertionError(f"{name}: column not kernel-eligible")
            n_chunks = len(reader.meta["chunks"])
            kp = [reader._chunk_kernel_params(
                reader.meta["chunks"][c]["bufmeta"][
                    (1 if reader.proto.max_rep else 0)
                    + (1 if reader.proto.max_def else 0)])
                for c in range(n_chunks)]
            assert all(p is not None for p in kp), f"{name}: chunk fell back"


@pytest.mark.parametrize("kind", ["primitive", "nullable", "fixed-size-list"])
def test_fullzip_pallas_gather_route(kind):
    """decode='pallas' routes the fixed-stride full-zip take through the
    fullzip_gather kernel: bit-identical to the host permutation, with
    identical logical IO (duplicates still served from one read)."""
    pytest.importorskip("jax")
    arr = _dataset(kind, 700)
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    fr_np = FileReader(fb, decode="numpy")
    fr_pl = FileReader(fb, decode="pallas")
    rows = _messy_rows(700, 53)
    _bit_identical(fr_np.take("c", rows), fr_pl.take("c", rows))
    fr_np.reset_io(); fr_np.take("c", rows)
    fr_pl.reset_io(); fr_pl.take("c", rows)
    a, b = fr_np.io_stats(), fr_pl.io_stats()
    assert (a.n_iops, a.bytes_read, a.useful_bytes, a.max_phase) == \
           (b.n_iops, b.bytes_read, b.useful_bytes, b.max_phase)


def test_fullzip_pallas_var_width_unaffected():
    """The gather route only covers fixed strides; variable-width full-zip
    under decode='pallas' still takes the row-parallel host path."""
    pytest.importorskip("jax")
    arr = _dataset("utf8", 400)
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    want = A.to_pylist(arr)
    rows = np.array([7, 1, 7, 390, 1])
    got = A.to_pylist(FileReader(fb, decode="pallas").take("c", rows))
    assert got == [want[i] for i in rows]


# ---------------------------------------------------------------------------
# bounded-memory scan windows
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("encname,opts", ENCODINGS[:4], ids=[e[0] for e in ENCODINGS[:4]])
@pytest.mark.parametrize("kind", ["utf8", "fixed-size-list", "nested-list"])
@pytest.mark.parametrize("io_chunk", [64, 257, 8 << 20])
def test_scan_windows_any_chunk_size(encname, opts, kind, io_chunk):
    """Windowed scans decode at entry/page boundaries and carry tails, so
    any io_chunk (down to a few bytes over the largest header) roundtrips —
    for variable-width, fixed-stride, and repeated leaves alike."""
    arr = _dataset(kind, 500)
    fr = FileReader(write_table({"c": arr}, opts))
    assert A.to_pylist(fr.scan("c", io_chunk=io_chunk)) == A.to_pylist(arr)


def test_decode_knob_in_write_options():
    """WriteOptions(decode=...) is recorded in the footer and picked up as
    the reader default; an explicit reader arg overrides it."""
    pytest.importorskip("jax")
    arr = _dataset("primitive", 300)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock", decode="pallas"))
    fr = FileReader(fb)
    assert fr.decode == "pallas"
    assert FileReader(fb, decode="numpy").decode == "numpy"
    want = A.to_pylist(arr)
    assert A.to_pylist(fr.take("c", np.array([5, 0, 5]))) == [want[5], want[0], want[5]]
    with pytest.raises(ValueError):
        WriteOptions("lance-miniblock", decode="gpu")
    with pytest.raises(ValueError):
        FileReader(fb, decode="gpu")
