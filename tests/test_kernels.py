"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import bitpack
from repro.kernels import ops
from repro.kernels.miniblock_decode import MAX_ENTRIES
from repro.kernels.ref import bitunpack_ref, fullzip_gather_ref, miniblock_decode_ref

rng = np.random.default_rng(0)


@pytest.mark.parametrize("bits", [1, 3, 5, 8, 11, 16, 21, 32])
@pytest.mark.parametrize("n", [1, 100, 8192, 20_000])
def test_bitunpack_sweep(bits, n):
    v = rng.integers(0, 2 ** min(bits, 62), n, dtype=np.uint64)
    words = jnp.asarray(ops.pack_words(bitpack(v, bits)))
    got_pl = np.asarray(ops.bitunpack(words, n, bits))
    got_ref = np.asarray(ops.bitunpack(words, n, bits, use_pallas=False))
    assert (got_pl == v).all()
    assert (got_ref == v).all()


@pytest.mark.parametrize("nullable", [True, False])
@pytest.mark.parametrize("n_chunks", [1, 4])
def test_miniblock_decode_sweep(nullable, n_chunks):
    C = n_chunks
    DW = (MAX_ENTRIES + 31) // 32 + 1
    VW = MAX_ENTRIES + 2
    def_words = np.zeros((C, DW), np.uint32)
    val_words = np.zeros((C, VW), np.uint32)
    params = np.zeros((C, 3), np.int32)
    want_vals, want_valid = [], []
    for c in range(C):
        n = int(rng.integers(50, MAX_ENTRIES))
        bits = int(rng.integers(1, 24))
        ref = int(rng.integers(-100, 100))
        if nullable:
            defs = (rng.random(n) < 0.2).astype(np.uint8)
        else:
            defs = np.zeros(n, np.uint8)
        valid = defs == 0
        vals = rng.integers(0, 2 ** bits, int(valid.sum()), dtype=np.uint64)
        dw = ops.pack_words(bitpack(defs.astype(np.uint64), 1))
        vw = ops.pack_words(bitpack(vals, bits))
        def_words[c, : len(dw)] = dw
        val_words[c, : len(vw)] = vw
        params[c] = [n, bits, ref]
        ev = np.zeros(MAX_ENTRIES, np.int32)
        ev[:n][valid] = vals.astype(np.int64) + ref
        em = np.zeros(MAX_ENTRIES, bool)
        em[:n] = valid
        want_vals.append(ev)
        want_valid.append(em)
    for use_pallas in [True, False]:
        vs, ms = ops.miniblock_decode(
            jnp.asarray(def_words), jnp.asarray(val_words), jnp.asarray(params),
            nullable=nullable, use_pallas=use_pallas)
        for c in range(C):
            assert (np.asarray(ms[c]) == want_valid[c]).all()
            got = np.where(want_valid[c], np.asarray(vs[c]), 0)
            want = np.where(want_valid[c], want_vals[c], 0)
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("stride", [8, 24, 136, 512])
@pytest.mark.parametrize("n_take", [1, 7, 64])
def test_fullzip_gather_sweep(stride, n_take):
    zipped = rng.integers(0, 256, (300, stride), dtype=np.uint8)
    rows = rng.integers(0, 300, n_take).astype(np.int32)
    for use_pallas in [True, False]:
        got = np.asarray(ops.fullzip_gather(jnp.asarray(zipped), jnp.asarray(rows),
                                            use_pallas=use_pallas))
        np.testing.assert_array_equal(got, zipped[rows])


def test_kernel_matches_host_miniblock_column():
    """Integration: decode a real mini-block-encoded column on device and
    compare against the host reader."""
    from repro.core import arrays as A, types as T
    from repro.core.file import FileReader, WriteOptions, write_table
    from repro.core.compression import min_bits

    n = 9000
    vals = rng.integers(0, 50_000, n).astype(np.int64)
    validity = rng.random(n) < 0.9
    arr = A.PrimitiveArray(T.int64(), validity, vals)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock", fixed_codec="bitpack"))
    fr = FileReader(fb)
    want = fr.scan("c")

    # re-encode chunk payloads into kernel inputs
    col = fr.columns["c"]["leaves"][0]
    meta = col["meta"]
    C = len(meta["chunks"])
    DW = (MAX_ENTRIES + 31) // 32 + 1
    maxvw = 0
    packed = []
    for ci, cm in enumerate(meta["chunks"]):
        off = meta["chunk_offsets"][ci]
        raw = fr.disk.read(col["base"] + off, cm["words"] * 8)
        from repro.core.miniblock import _parse_chunk

        bufs = _parse_chunk(raw)
        dw = ops.pack_words(bufs[0])
        vw_meta = cm["bufmeta"][1]
        vw = ops.pack_words(bufs[1])
        ref = 0
        bits = vw_meta["bits"]
        packed.append((cm["n_entries"], bits, ref, dw, vw))
        maxvw = max(maxvw, len(vw))
    def_words = np.zeros((C, DW), np.uint32)
    val_words = np.zeros((C, maxvw), np.uint32)
    params = np.zeros((C, 3), np.int32)
    for c, (ne, bits, ref, dw, vw) in enumerate(packed):
        def_words[c, : len(dw)] = dw
        val_words[c, : len(vw)] = vw
        params[c] = [ne, bits, ref]
    vs, ms = ops.miniblock_decode(jnp.asarray(def_words), jnp.asarray(val_words),
                                  jnp.asarray(params), nullable=True)
    got_vals = []
    for c, (ne, *_rest) in enumerate(packed):
        m = np.asarray(ms[c][:ne])
        got_vals.append(np.asarray(vs[c][:ne])[m])
    got = np.concatenate(got_vals)
    np.testing.assert_array_equal(got, vals[validity])
