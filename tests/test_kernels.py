"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import bitpack
from repro.kernels import ops
from repro.kernels.miniblock_decode import MAX_ENTRIES
from repro.kernels.ref import bitunpack_ref, fullzip_gather_ref, miniblock_decode_ref

rng = np.random.default_rng(0)


@pytest.mark.parametrize("bits", [1, 3, 5, 8, 11, 16, 21, 32])
@pytest.mark.parametrize("n", [1, 100, 8192, 20_000])
def test_bitunpack_sweep(bits, n):
    v = rng.integers(0, 2 ** min(bits, 62), n, dtype=np.uint64)
    words = jnp.asarray(ops.pack_words(bitpack(v, bits)))
    got_pl = np.asarray(ops.bitunpack(words, n, bits))
    got_ref = np.asarray(ops.bitunpack(words, n, bits, use_pallas=False))
    assert (got_pl == v).all()
    assert (got_ref == v).all()


@pytest.mark.parametrize("rep_bits,def_bits", [(0, 0), (0, 1), (0, 2), (1, 2), (2, 3)])
@pytest.mark.parametrize("vpe", [1, 4])
@pytest.mark.parametrize("n_chunks", [1, 4])
def test_miniblock_decode_sweep(rep_bits, def_bits, vpe, n_chunks):
    """Widened kernel coverage: any rep/def level width, values-per-entry
    (fixed-size lists), per-chunk bit width + FoR reference."""
    C = n_chunks
    tile = 1024
    rep_words = np.zeros((C, (tile * rep_bits + 31) // 32 + 1 if rep_bits else 1), np.uint32)
    def_words = np.zeros((C, (tile * def_bits + 31) // 32 + 1 if def_bits else 1), np.uint32)
    val_words = np.zeros((C, (tile * vpe * 24 + 31) // 32 + 1), np.uint32)
    params = np.zeros((C, 3), np.int32)
    want = []
    for c in range(C):
        n = int(rng.integers(50, tile))
        bits = int(rng.integers(1, 24))
        ref = int(rng.integers(-100, 100))
        reps = rng.integers(0, 2 ** rep_bits, n, dtype=np.uint64) if rep_bits else None
        defs = (rng.integers(0, 2 ** def_bits, n, dtype=np.uint64)
                if def_bits else np.zeros(n, np.uint64))
        valid = defs == 0
        vals = rng.integers(0, 2 ** bits, int(valid.sum()) * vpe, dtype=np.uint64)
        if rep_bits:
            w = ops.pack_words(bitpack(reps, rep_bits))
            rep_words[c, : len(w)] = w
        if def_bits:
            w = ops.pack_words(bitpack(defs, def_bits))
            def_words[c, : len(w)] = w
        w = ops.pack_words(bitpack(vals, bits))
        val_words[c, : len(w)] = w
        params[c] = [n, bits, ref]
        er = np.zeros(tile, np.int32)
        if rep_bits:
            er[:n] = reps
        ed = np.zeros(tile, np.int32)
        ed[:n] = defs
        ev = np.zeros(tile * vpe, np.int32)
        vmask = np.zeros(tile * vpe, bool)
        vmask[: n * vpe] = np.repeat(valid, vpe)
        ev[vmask] = vals.astype(np.int64) + ref
        want.append((er, ed, ev, vmask))
    for use_pallas in [True, False]:
        r, d, v = ops.miniblock_decode(
            jnp.asarray(rep_words), jnp.asarray(def_words),
            jnp.asarray(val_words), jnp.asarray(params),
            rep_bits=rep_bits, def_bits=def_bits, vpe=vpe, tile_entries=tile,
            use_pallas=use_pallas)
        for c, (er, ed, ev, vmask) in enumerate(want):
            np.testing.assert_array_equal(np.asarray(r[c]), er)
            np.testing.assert_array_equal(np.asarray(d[c]), ed)
            np.testing.assert_array_equal(
                np.where(vmask, np.asarray(v[c]), 0), ev)


@pytest.mark.parametrize("stride", [8, 24, 136, 512])
@pytest.mark.parametrize("n_take", [1, 7, 64])
def test_fullzip_gather_sweep(stride, n_take):
    zipped = rng.integers(0, 256, (300, stride), dtype=np.uint8)
    rows = rng.integers(0, 300, n_take).astype(np.int32)
    for use_pallas in [True, False]:
        got = np.asarray(ops.fullzip_gather(jnp.asarray(zipped), jnp.asarray(rows),
                                            use_pallas=use_pallas))
        np.testing.assert_array_equal(got, zipped[rows])


def test_kernel_matches_host_miniblock_column():
    """Integration: decode a real mini-block-encoded column on device and
    compare against the host reader."""
    from repro.core import arrays as A, types as T
    from repro.core.file import FileReader, WriteOptions, write_table
    from repro.core.compression import min_bits

    n = 9000
    vals = rng.integers(0, 50_000, n).astype(np.int64)
    validity = rng.random(n) < 0.9
    arr = A.PrimitiveArray(T.int64(), validity, vals)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock", fixed_codec="bitpack"))
    fr = FileReader(fb)
    want = fr.scan("c")

    # re-encode chunk payloads into kernel inputs
    col = fr.columns["c"]["leaves"][0]
    meta = col["meta"]
    C = len(meta["chunks"])
    DW = (MAX_ENTRIES + 31) // 32 + 1
    maxvw = 0
    packed = []
    for ci, cm in enumerate(meta["chunks"]):
        off = meta["chunk_offsets"][ci]
        raw = fr.disk.read(col["base"] + off, cm["words"] * 8)
        from repro.core.miniblock import _parse_chunk

        bufs = _parse_chunk(raw)
        dw = ops.pack_words(bufs[0])
        vw_meta = cm["bufmeta"][1]
        vw = ops.pack_words(bufs[1])
        ref = 0
        bits = vw_meta["bits"]
        packed.append((cm["n_entries"], bits, ref, dw, vw))
        maxvw = max(maxvw, len(vw))
    def_words = np.zeros((C, DW), np.uint32)
    val_words = np.zeros((C, maxvw), np.uint32)
    params = np.zeros((C, 3), np.int32)
    for c, (ne, bits, ref, dw, vw) in enumerate(packed):
        def_words[c, : len(dw)] = dw
        val_words[c, : len(vw)] = vw
        params[c] = [ne, bits, ref]
    _, ds, vs = ops.miniblock_decode(
        jnp.asarray(np.zeros((C, 1), np.uint32)), jnp.asarray(def_words),
        jnp.asarray(val_words), jnp.asarray(params),
        rep_bits=0, def_bits=1)
    got_vals = []
    for c, (ne, *_rest) in enumerate(packed):
        m = np.asarray(ds[c][:ne]) == 0
        got_vals.append(np.asarray(vs[c][:ne])[m])
    got = np.concatenate(got_vals)
    np.testing.assert_array_equal(got, vals[validity])


# ---------------------------------------------------------------------------
# ivf_topk: batched distance + deterministic top-k (the IVF search kernel)
# ---------------------------------------------------------------------------


class _FallbackRecorder:
    """Minimal tracer surface for the ops-layer fallback hook."""

    enabled = True

    def __init__(self):
        self.calls = []

    def fallback(self, encoding, reason, **args):
        self.calls.append((encoding, reason))


@pytest.mark.parametrize("dim", [3, 64, 128, 200])
@pytest.mark.parametrize("nq,nc,k", [(1, 7, 3), (5, 300, 10), (9, 129, 1)])
def test_ivf_topk_parity_sweep(dim, nq, nc, k):
    """Pallas route bit-identical to the jnp oracle in interpret mode."""
    r = np.random.default_rng(dim * 1000 + nq)
    q = r.standard_normal((nq, dim)).astype(np.float32)
    c = r.standard_normal((nc, dim)).astype(np.float32)
    ids = r.permutation(nc).astype(np.int64)
    mask = r.integers(0, 2, (nq, nc)).astype(np.int32)
    for m in (None, mask):
        d1, w1 = ops.ivf_topk(q, c, ids, k, mask=m, use_pallas=True)
        d0, w0 = ops.ivf_topk(q, c, ids, k, mask=m, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w0))
        assert np.asarray(d1).shape == (nq, k)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_ivf_topk_matches_brute_force(dtype):
    r = np.random.default_rng(7)
    q = r.standard_normal((4, 24)).astype(dtype)
    c = r.standard_normal((50, 24)).astype(dtype)
    ids = np.arange(100, 150, dtype=np.int64)
    d, w = ops.ivf_topk(q, c, ids, 5)
    brute = ((c[None] - q[:, None]) ** 2).sum(-1).argsort(axis=1)[:, :5]
    np.testing.assert_array_equal(np.asarray(w), ids[brute])


def test_ivf_topk_tie_break_by_row_id():
    """Equal-distance candidates win in ascending row-id order, regardless
    of their position in the candidate matrix."""
    q = np.zeros((1, 8), np.float32)
    c = np.zeros((6, 8), np.float32)  # all distance 0: pure tie
    ids = np.array([40, 5, 99, 17, 3, 60], np.int64)
    for use_pallas in (True, False):
        _, w = ops.ivf_topk(q, c, ids, 4, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(w)[0], [3, 5, 17, 40])


def test_ivf_topk_exhaustion_sentinels():
    """k beyond the eligible count pads with (inf, sentinel -> caller)."""
    r = np.random.default_rng(3)
    q = r.standard_normal((2, 16)).astype(np.float32)
    c = r.standard_normal((3, 16)).astype(np.float32)
    for use_pallas in (True, False):
        d, w = ops.ivf_topk(q, c, np.arange(3), 6, use_pallas=use_pallas)
        d, w = np.asarray(d), np.asarray(w)
        assert (w[:, 3:] == ops.IVF_ID_SENTINEL).all()
        assert np.isinf(d[:, 3:]).all()
        assert (w[:, :3] != ops.IVF_ID_SENTINEL).all()


def test_ivf_topk_no_silent_fallback():
    """Eligible input on the Pallas route must NOT emit a fallback."""
    tr = _FallbackRecorder()
    r = np.random.default_rng(0)
    q = r.standard_normal((2, 32)).astype(np.float32)
    c = r.standard_normal((20, 32)).astype(np.float32)
    ops.ivf_topk(q, c, np.arange(20), 4, use_pallas=True, tracer=tr)
    assert tr.calls == []


def test_ivf_topk_fallback_reasons():
    tr = _FallbackRecorder()
    r = np.random.default_rng(0)
    q64 = r.standard_normal((2, 8))
    c64 = r.standard_normal((10, 8))
    q32, c32 = q64.astype(np.float32), c64.astype(np.float32)
    ops.ivf_topk(q64, c64, np.arange(10), 3, tracer=tr)
    ops.ivf_topk(q32, np.zeros((0, 8), np.float32), np.zeros(0, np.int64),
                 3, tracer=tr)
    ops.ivf_topk(q32, c32, np.arange(10, dtype=np.int64) + (1 << 31), 3,
                 tracer=tr)
    assert tr.calls == [("ivf", "non-float32"), ("ivf", "no-candidates"),
                        ("ivf", ">31-bit-ids")]
    # the fallback route still answers correctly (wide ids kept intact)
    d, w = ops.ivf_topk(q32, c32, np.arange(10, dtype=np.int64) + (1 << 31), 3)
    brute = ((c32[None] - q32[:, None]) ** 2).sum(-1).argsort(axis=1)[:, :3]
    np.testing.assert_array_equal(np.asarray(w), brute + (1 << 31))


def test_ivf_topk_telemetry_counter():
    """The structured reason lands as a decode.fallback.ivf.* counter and a
    pallas_fallback instant — same contract as the decode kernels."""
    from repro.obs import Tracer

    tr = Tracer()
    r = np.random.default_rng(0)
    ops.ivf_topk(r.standard_normal((1, 8)), r.standard_normal((4, 8)),
                 np.arange(4), 2, tracer=tr)
    assert tr.metrics.counter_values("decode.fallback") == \
        {"decode.fallback.ivf.non-float32": 1}
    evs = [e for e in tr.events if e["name"] == "pallas_fallback"]
    assert evs and evs[0]["args"]["reason"] == "non-float32"
