"""Dataset ingest path: appendable versioned datasets, the flush-then-commit
crash-safety fence, compaction, and write-back vs write-through accounting."""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.file import WriteOptions, write_table
from repro.dataset import DatasetReader, DatasetWriter, write_fragments
from repro.store import FlushPolicy, SimulatedCrash, TieredStore


def _ints(lo, n):
    return {"c": A.PrimitiveArray.build(
        np.arange(lo, lo + n, dtype=np.int64), nullable=False)}


def _mixed(lo, n):
    ints = A.PrimitiveArray.build(
        np.arange(lo, lo + n, dtype=np.int64),
        validity=(np.arange(lo, lo + n) % 7 != 0))
    strs = A.from_pylist(
        [None if i % 5 == 0 else f"s{lo + i}" for i in range(n)], T.Utf8(True))
    return {"i": ints, "s": strs}


# ---------------------------------------------------------------------------
# append + versioning
# ---------------------------------------------------------------------------


def test_append_then_read_every_version():
    """A dataset appended to N times is readable at every manifest version,
    and each version sees exactly the rows committed by then."""
    w = DatasetWriter(opts=WriteOptions("lance"))
    sizes = [50, 80, 30, 120]
    for k, n in enumerate(sizes):
        m = w.append(_ints(sum(sizes[:k]), n))
        assert m.version == k + 1
    assert w.version == len(sizes)
    for v in range(1, len(sizes) + 1):
        r = w.reader(v)
        want = sum(sizes[:v])
        assert r.n_rows == want
        assert A.to_pylist(r.scan("c")) == list(range(want))
        rows = np.array([0, want - 1, want // 2, 0])
        assert A.to_pylist(r.take("c", rows)) == rows.tolist()
    with pytest.raises(IndexError):  # old versions cannot see new rows
        w.reader(1).take("c", np.array([sizes[0]]))


def test_append_matches_single_file_reader():
    """Appended fragments must decode exactly like one file holding the same
    rows (messy rows: unsorted, duplicated, crossing every boundary)."""
    w = DatasetWriter(opts=WriteOptions("lance"))
    n = 600
    for lo in range(0, n, 200):
        w.append(_mixed(lo, 200))
    from repro.core.file import FileReader

    single = FileReader(write_table(_mixed(0, n), WriteOptions("lance")))
    rng = np.random.default_rng(0)
    rows = np.concatenate([rng.integers(0, n, 100),
                           [0, n - 1, 199, 200, 201, 0]])
    for col in ("i", "s"):
        assert A.to_pylist(w.take(col, rows)) == \
            A.to_pylist(single.take(col, rows))


def test_writer_seeds_from_existing_files():
    files = write_fragments(_ints(0, 300), 3, WriteOptions("lance"))
    w = DatasetWriter(files=files)
    assert w.version == 1 and w.n_rows == 300
    assert A.to_pylist(w.take("c", np.array([0, 150, 299]))) == [0, 150, 299]
    ds = DatasetReader(files)  # same data through the read-only path
    assert A.to_pylist(ds.scan("c")) == A.to_pylist(w.scan("c"))


def test_append_rejects_schema_mismatch():
    w = DatasetWriter()
    w.append(_ints(0, 10))
    with pytest.raises(ValueError):
        w.append({"other": A.PrimitiveArray.build(
            np.arange(5, dtype=np.int64), nullable=False)})
    with pytest.raises(ValueError):
        w.reader(2)
    with pytest.raises(ValueError):
        DatasetWriter().reader()


def test_uncommitted_rows_are_invisible():
    w = DatasetWriter()
    w.append(_ints(0, 40))
    w.append(_ints(40, 40), commit=False)
    assert w.n_rows == 40 and w.version == 1
    with pytest.raises(IndexError):
        w.take("c", np.array([40]))
    m = w.commit()
    assert m.version == 2 and w.n_rows == 80
    assert A.to_pylist(w.take("c", np.array([79]))) == [79]
    # commit with nothing staged does not mint an empty version
    assert w.commit().version == 2


# ---------------------------------------------------------------------------
# crash consistency (flush-then-commit fence)
# ---------------------------------------------------------------------------


def test_crash_discards_pending_keeps_committed():
    w = DatasetWriter(flush="write-back")
    w.append(_ints(0, 100))
    w.append(_ints(100, 60), commit=False)
    assert w.dirty_bytes > 0
    torn = w.simulate_crash()
    assert torn > 0
    assert w.version == 1 and w.n_rows == 100
    assert A.to_pylist(w.scan("c")) == list(range(100))
    # per-tier accounting recorded the loss
    assert w.tier_stats()[0].lost_bytes > 0
    # the writer keeps working after the crash
    w.append(_ints(100, 50))
    assert w.n_rows == 150 and w.version == 2
    assert A.to_pylist(w.take("c", np.array([149, 0]))) == [149, 0]


def test_crash_before_first_commit_leaves_empty_dataset():
    w = DatasetWriter(flush="write-back")
    w.append(_ints(0, 30), commit=False)
    w.simulate_crash()
    assert w.version == 0 and w.n_rows == 0
    with pytest.raises(ValueError):
        w.reader()
    w.append(_ints(0, 10))  # schema slate is clean again
    assert w.n_rows == 10


def test_interrupted_flush_never_corrupts_committed_version():
    """A commit whose flush dies mid-way must not mint the new version, and
    the previous version must read back intact after the crash."""
    w = DatasetWriter(flush=FlushPolicy("flush-on-evict"))
    w.append(_ints(0, 200))
    want_v1 = list(range(200))
    w.append(_ints(200, 200), commit=False)
    # contiguous appends flush as one extent; die before it is dispatched
    w.flush_policy.fail_after = 0
    with pytest.raises(SimulatedCrash):
        w.commit()
    w.flush_policy.fail_after = None
    w.simulate_crash()
    assert w.version == 1
    assert A.to_pylist(w.scan("c")) == want_v1
    assert A.to_pylist(w.reader(1).take("c", np.array([199, 0]))) == [199, 0]


def test_commit_fence_makes_bytes_durable_before_manifest():
    """After a successful commit nothing is dirty — the manifest can never
    reference bytes that a crash could still tear."""
    w = DatasetWriter(flush="write-back")
    for lo in range(0, 300, 100):
        w.append(_ints(lo, 100))
        assert w.dirty_bytes == 0  # every committed version is fully durable
        assert w.simulate_crash() == 0  # crashing now loses nothing
        assert w.n_rows == lo + 100


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------


def test_compact_merges_small_fragments():
    w = DatasetWriter(opts=WriteOptions("lance"))
    for lo in range(0, 500, 50):  # 10 small fragments
        w.append(_mixed(lo, 50))
    v_before = w.version
    before_i = A.to_pylist(w.scan("i"))
    m = w.compact(max_rows=250)
    assert m.version == v_before + 1
    assert len(m.fragments) == 2  # 10 x 50 rows -> 2 x 250 rows
    assert m.n_rows == 500
    assert A.to_pylist(w.scan("i")) == before_i
    rng = np.random.default_rng(1)
    rows = rng.integers(0, 500, 64)
    assert A.to_pylist(w.take("s", rows)) == \
        [A.to_pylist(_mixed(0, 500)["s"])[i] for i in rows]
    # time travel: pre-compaction versions still read the old fragments
    assert w.reader(3).n_rows == 150
    assert A.to_pylist(w.reader(3).scan("i")) == before_i[:150]
    # nothing small enough to merge: no new version
    assert w.compact(max_rows=100).version == m.version


def test_compact_requires_rows_and_commits_pending():
    w = DatasetWriter()
    with pytest.raises(ValueError):
        w.compact(0)
    with pytest.raises(ValueError):
        w.compact(10)
    w.append(_ints(0, 20), commit=False)
    w.append(_ints(20, 20), commit=False)
    m = w.compact(max_rows=100)  # auto-commits the pending appends first
    assert w.n_rows == 40 and len(m.fragments) == 1
    assert A.to_pylist(w.scan("c")) == list(range(40))


# ---------------------------------------------------------------------------
# write-back vs write-through over the shared store
# ---------------------------------------------------------------------------


def test_write_back_batches_backing_writes():
    """Same appends, same commits: write-back must reach the backing device
    with fewer write IOPS (batched at the commit fence) than write-through
    (one dispatch per append), with identical total manifest state."""
    def ingest(policy):
        w = DatasetWriter(
            store=lambda d: TieredStore.cached(d, cache_bytes=8 << 20),
            flush=policy)
        for i in range(6):
            w.append(_ints(i * 50, 50), commit=(i % 3 == 2))
        return w

    wt, wb = ingest("write-through"), ingest("write-back")
    assert wt.n_rows == wb.n_rows == 300
    s3_wt = {s.name: s for s in wt.tier_stats()}["s3"]
    s3_wb = {s.name: s for s in wb.tier_stats()}["s3"]
    assert s3_wb.write_iops < s3_wt.write_iops
    assert s3_wb.flush_iops == s3_wb.write_iops  # all via the flusher
    assert s3_wt.flush_iops == 0
    assert A.to_pylist(wt.scan("c")) == A.to_pylist(wb.scan("c"))


def test_ingested_rows_are_nvme_warm():
    """Appended blocks are resident (dirty or write-through-filled): a take
    of freshly ingested rows must not touch S3."""
    for policy in ("write-through", "write-back"):
        w = DatasetWriter(flush=policy)
        w.append(_ints(0, 400))
        w.reset_io()
        w.take("c", np.arange(0, 400, 7))
        tiers = {s.name: s for s in w.tier_stats()}
        assert tiers["s3"].n_iops == 0, policy
        assert tiers["nvme_970evo"].hit_rate == 1.0, policy
