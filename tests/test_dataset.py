"""Multi-file dataset layer: fragment manifest, global-row takes through one
shared scheduler/cache, cross-file coalescing, and workload-driven admission.
"""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.io_sim import Disk, DiskView
from repro.dataset import DatasetReader, Manifest, write_fragments
from repro.store import TieredStore


def _table(n=2000, seed=0):
    rng = np.random.default_rng(seed)
    ints = A.PrimitiveArray.build(
        rng.integers(0, 1 << 20, n).astype(np.int64),
        validity=rng.random(n) > 0.05)
    strs = A.from_pylist(
        [None if i % 17 == 0 else f"v{i}" * (i % 5 + 1) for i in range(n)],
        T.Utf8(True))
    lists = A.from_pylist(
        [None if i % 13 == 0 else list(range(i % 4)) for i in range(n)],
        T.List(T.int64(), True))
    return {"i": ints, "s": strs, "l": lists}


def _messy_rows(n, seed=1):
    """Unsorted, duplicated, spanning every fragment boundary."""
    rng = np.random.default_rng(seed)
    half = n // 2
    return np.concatenate([
        rng.integers(0, n, 300),
        [0, n - 1, half - 1, half, half + 1, half, 0, n - 1],
    ])


# ---------------------------------------------------------------------------
# correctness: dataset take/scan == single-file take/scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("enc", ["lance-miniblock", "lance-fullzip",
                                 "parquet", "arrow"])
def test_dataset_take_matches_single_file(enc):
    table = _table()
    n = 2000
    files = write_fragments(table, 4, WriteOptions(enc))
    ds = DatasetReader(files, store="tiered")
    single = FileReader(write_table(table, WriteOptions(enc)))
    rows = _messy_rows(n)
    for col in table:
        got = A.to_pylist(ds.take(col, rows))
        want = A.to_pylist(single.take(col, rows))
        assert got == want
        assert A.to_pylist(ds.scan(col)) == A.to_pylist(table[col])


def test_dataset_take_packed_struct():
    rng = np.random.default_rng(0)
    n = 1200
    children = [(f"f{i}", A.PrimitiveArray.build(
        rng.integers(0, 1 << 30, n).astype(np.int64), nullable=False))
        for i in range(3)]
    table = {"p": A.StructArray.build(children, nullable=False)}
    opts = WriteOptions("lance", packed_columns=("p",))
    files = write_fragments(table, 3, opts)
    ds = DatasetReader(files)
    single = FileReader(write_table(table, opts))
    rows = _messy_rows(n)
    assert A.to_pylist(ds.take("p", rows)) == \
        A.to_pylist(single.take("p", rows))


def test_dataset_take_empty_and_bounds():
    files = write_fragments(_table(400), 2, WriteOptions("lance"))
    ds = DatasetReader(files)
    assert A.to_pylist(ds.take("i", np.array([], np.int64))) == []
    with pytest.raises(IndexError):
        ds.take("i", np.array([400]))
    with pytest.raises(IndexError):
        ds.take("i", np.array([-1]))


# ---------------------------------------------------------------------------
# one shared dispatch / cross-file coalescing
# ---------------------------------------------------------------------------


def test_dataset_take_is_one_dispatch_per_phase():
    """A take spanning >=2 fragments must run as ONE scheduler batch (one
    queue drain) with each dependency phase dispatched once — not one drain
    per fragment as per-file stores would."""
    arr = A.PrimitiveArray.build(np.arange(4000, dtype=np.int64),
                                 nullable=False)
    files = write_fragments({"c": arr}, 2, WriteOptions("lance-fullzip"))
    ds = DatasetReader(files)
    rows = np.array([1, 3999, 2001, 7, 1999, 2000])
    got = ds.take("c", rows)
    assert A.to_pylist(got) == rows.tolist()
    assert ds.scheduler.n_batches == 1
    backing = ds.store.backing_stats
    assert len(backing.batch_phases) == 1  # one queue drain for both files
    # fixed-stride full-zip: a single span phase holds both files' spans
    assert list(backing.batch_phases[0]) == [0]


def test_cross_file_coalescing_reduces_backing_iops():
    """Two tiny fragments land in one global 4 KiB block: the shared store
    reads it once; disjoint per-file stores pay the backing device twice."""
    arr = A.PrimitiveArray.build(np.arange(200, dtype=np.int64),
                                 nullable=False)
    files = write_fragments({"c": arr}, 2, WriteOptions("lance-fullzip"))
    assert sum(len(f) for f in files) <= 4096  # both files share block 0

    ds = DatasetReader(files, store="tiered")
    ds.take("c", np.array([99, 100, 5, 199]))
    shared_s3 = ds.tier_stats()[-1].n_iops

    per_file = [FileReader(fb, store="tiered") for fb in files]
    per_file[0].take("c", np.array([99, 5]))
    per_file[1].take("c", np.array([0, 99]))
    split_s3 = sum(fr.tier_stats()[-1].n_iops for fr in per_file)

    assert shared_s3 < split_s3
    assert shared_s3 == 1


def test_shared_cache_second_reader_hits_warm_blocks():
    """Two FileReaders over one disk + one TieredStore: reader 2's take is
    served by blocks reader 1 warmed (the shared-NVMe-budget contract)."""
    arr = A.PrimitiveArray.build(np.arange(5000, dtype=np.int64),
                                 nullable=False)
    disk = Disk.from_bytes(write_table({"c": arr},
                                       WriteOptions("lance-fullzip")))
    store = TieredStore.cached(disk)
    fr1 = FileReader(disk, store=store)
    fr2 = FileReader(disk, store=store)
    rows = np.arange(0, 5000, 11)
    fr1.take("c", rows)
    s3_after_warm = store.backing_stats.n_iops
    assert s3_after_warm > 0
    hits_before = store.levels[0].cache.hits
    fr2.take("c", rows)
    assert store.levels[0].cache.hits > hits_before
    assert store.backing_stats.n_iops == s3_after_warm  # no new S3 traffic


def test_dataset_second_pass_warm():
    """Dataset-level warm pass: a repeat take over every fragment is served
    entirely from the shared cache."""
    table = _table(1600)
    files = write_fragments(table, 4, WriteOptions("lance"))
    ds = DatasetReader(files, store="tiered")
    rows = _messy_rows(1600)
    ds.take("i", rows)
    t_cold = ds.modelled_time()
    ds.reset_io()
    ds.take("i", rows)
    nvme, s3 = ds.tier_stats()
    assert s3.n_iops == 0 and nvme.hit_rate == 1.0
    assert ds.modelled_time() < t_cold


def test_dataset_scan_readahead_crosses_fragments():
    """A dataset scan is one prefetch-flagged batch: readahead sees the
    global request stream and keeps prefetching across the file boundary
    (the inter-file gap is a footer, far below max_gap)."""
    table = {"s": A.from_pylist([f"value-{i:06d}" * 3 for i in range(8000)],
                                T.Utf8(False))}
    files = write_fragments(table, 2, WriteOptions("lance-miniblock"))
    ds = DatasetReader(files, store="tiered")
    got = ds.scan("s", io_chunk=16 * 1024)
    assert A.to_pylist(got) == A.to_pylist(table["s"])
    assert ds.scheduler.n_batches == 1
    nvme, s3 = ds.tier_stats()
    assert s3.prefetch_iops > 0 and nvme.hits > 0
    # prefetch reached past fragment 0: the high-water mark of the single
    # readahead stream is inside fragment 1's global extent
    frag1 = ds.manifest.fragments[1]
    assert ds.scheduler.readahead._ra_until > frag1.base


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------


def test_manifest_geometry_and_locate():
    files = write_fragments(_table(1000), 4, WriteOptions("lance"))
    m = Manifest.from_files(files)
    assert m.n_fragments == 4 and m.n_rows == 1000
    assert [f.n_rows for f in m.fragments] == [250] * 4
    assert all(f.base % 8 == 0 for f in m.fragments)
    assert m.column_names == ["i", "s", "l"]
    fi, local = m.locate([0, 249, 250, 999, 500])
    assert fi.tolist() == [0, 0, 1, 3, 2]
    assert local.tolist() == [0, 249, 0, 249, 0]
    with pytest.raises(IndexError):
        m.locate([1000])


def test_manifest_rejects_schema_mismatch():
    a = write_table({"x": A.PrimitiveArray.build(
        np.arange(10, dtype=np.int64), nullable=False)})
    b = write_table({"y": A.PrimitiveArray.build(
        np.arange(10, dtype=np.int64), nullable=False)})
    with pytest.raises(ValueError):
        Manifest.from_files([a, b])
    with pytest.raises(ValueError):
        Manifest.from_files([])
    with pytest.raises(ValueError):
        Manifest.from_files([b"not a lance file"])


def test_write_fragments_validation():
    table = _table(10)
    with pytest.raises(ValueError):
        write_fragments(table, 0)
    with pytest.raises(ValueError):
        write_fragments(table, 11)


def test_disk_view_bounds():
    disk = Disk(np.arange(64, dtype=np.uint8))
    v = DiskView(disk, 16, 32)
    assert len(v) == 32
    assert v.read(0, 4).tolist() == [16, 17, 18, 19]
    data, offs = v.read_gather([0, 30], [2, 2])
    assert data.tolist() == [16, 17, 46, 47]
    with pytest.raises(ValueError):
        v.read(30, 4)
    with pytest.raises(ValueError):
        v.read_gather([30], [4])
    with pytest.raises(ValueError):
        DiskView(disk, 60, 8)


def test_file_reader_injection_validation():
    fb = write_table({"c": A.PrimitiveArray.build(
        np.arange(10, dtype=np.int64), nullable=False)})
    with pytest.raises(ValueError):
        FileReader(fb, base=8)  # base without a shared scheduler
    ds = DatasetReader([fb])
    with pytest.raises(ValueError):
        FileReader(fb, store="tiered", scheduler=ds.scheduler)
    with pytest.raises(ValueError):  # does not fit the shared disk
        FileReader(fb, scheduler=ds.scheduler, base=1 << 30)


# ---------------------------------------------------------------------------
# serving path
# ---------------------------------------------------------------------------


def test_retriever_over_dataset():
    from repro.data import synth
    from repro.serve.engine import Retriever

    emb = synth.scenario("embeddings", 900)
    files = write_fragments({"embedding": emb}, 3, WriteOptions("lance"))
    r = Retriever(files, "embedding", store="tiered")
    ids = np.array([5, 299, 300, 899, 450])  # crosses every fragment
    out, st = r.fetch(ids)
    assert len(out) == len(ids)
    assert A.to_pylist(out) == [A.to_pylist(emb)[i] for i in ids]
    assert st.n_iops == len(ids)  # full-zip fixed width: 1 IOP/row
    cold = r.modelled_time()
    r.fetch(ids)
    assert r.modelled_time() < cold
    assert r.tier_stats()[-1].n_iops == 0  # warm: no S3 traffic
