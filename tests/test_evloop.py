"""Event-loop serving plane: job construction, lone-job degeneration to the
serial-drain price, completion reordering, QoS fairness/starvation, service
windows, and the Zipf multi-tenant serve workload.

The tentpole contracts:

* a job simulated alone (or in immediate mode, with no window open) costs
  exactly its serial-drain price — the same per-(batch, phase) arithmetic as
  ``TierStats.model_time`` restricted to that one drain;
* interleaving shares latency rounds, it never invents bandwidth: the
  makespan of an interleaved run is never worse than the serial baseline;
* both pricings are pure overlays over the same executed workload —
  logical IOPS/bytes and per-tier accounting are identical with or without
  a window open.
"""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.io_sim import DRAM, NVME, S3, Disk
from repro.store import (
    EventLoop,
    Job,
    QoS,
    ServiceWindow,
    TieredStore,
    build_job,
    latency_percentiles,
)
from repro.store.stats import DrainRecord


def _reader(n=20_000, seed=0, **kw):
    rng = np.random.default_rng(seed)
    arr = A.PrimitiveArray.build(
        rng.integers(0, 1 << 20, n).astype(np.int64),
        validity=rng.random(n) > 0.03)
    fb = write_table({"c": arr}, WriteOptions("lance-miniblock"))
    return FileReader(fb, **kw), n


def _rec(label, tiers, n_requests=1):
    """Shorthand synthetic drain: tiers = {tier: (ops, nbytes, phase)}."""
    return DrainRecord(label, n_requests,
                       {t: ({p: ops}, {p: nb})
                        for t, (ops, nb, p) in tiers.items()})


# ---------------------------------------------------------------------------
# Job construction + lone-job degeneration
# ---------------------------------------------------------------------------


def test_build_job_pipe_shares_sum_to_throughput_term():
    rec = DrainRecord("take:c", 3,
                      {0: ({0: 5, 1: 3}, {0: 40_000, 1: 24_000}),
                       1: ({1: 2}, {1: 8_000_000})})
    job = build_job(rec, [NVME, S3])
    # phase-major chain, fastest tier first within a phase
    assert [(u.phase, u.tier) for u in job.units] == [(0, 0), (1, 0), (1, 1)]
    tp_nvme = sum(u.pipe for u in job.units if u.tier == 0)
    total_ops, total_bytes = 8, 64_000
    avg = max(total_bytes / total_ops, 1.0)
    eff = max(avg, NVME.min_read)
    tp = max(total_ops / min(NVME.iops_4k, NVME.seq_bw / eff),
             total_bytes / NVME.seq_bw)
    assert tp_nvme == tp                    # exact remainder assignment


def test_lone_job_interleaved_equals_serial_price():
    fr, n = _reader(store="tiered")
    fr.take("c", np.arange(64))
    fr.scan("c")
    devices = [lvl.device for lvl in fr.store.levels] + [fr.store.backing]
    qd = fr.scheduler.queue_depth
    for rec in fr.store.drain_log:
        job = build_job(rec, devices)
        serial = job.serial_time(qd)
        res = EventLoop(devices, qd).run([job], mode="interleaved")
        assert res.completions[0].done == pytest.approx(serial, rel=1e-12)
        res_s = EventLoop(devices, qd).run([job], mode="serial")
        assert res_s.completions[0].done == serial


def test_immediate_mode_completion_is_bit_identical_to_model_time():
    """With no window open, each batch close lands one completion on the
    scheduler's virtual clock at exactly the old serial-drain price — for a
    single batch that IS the store's model_time, bit for bit."""
    fr, n = _reader(store="tiered")
    fr.take("c", np.arange(128))
    sch = fr.scheduler
    assert len(sch.completions) == 1
    c = sch.completions[0]
    assert c.label == "take:c" and c.submit == 0.0
    assert c.latency == fr.modelled_time()   # bit-identical, not approx
    assert sch.vclock == c.done
    fr.take("c", np.arange(128, 256))
    assert len(sch.completions) == 2
    assert sch.completions[1].done > c.done  # the clock only advances


def test_interleaved_makespan_never_worse_than_serial():
    devices = [NVME, S3]
    jobs = []
    rng = np.random.default_rng(5)
    for i in range(30):
        tiers = {0: (int(rng.integers(1, 40)), int(rng.integers(1, 9)) * 4096, 0)}
        if i % 3 == 0:
            tiers[1] = (int(rng.integers(1, 6)), 200_000, 1)
        jobs.append(build_job(_rec(f"take:{i}", tiers), devices,
                              submit=float(i) * 1e-4, seq=i))
    for qd in (1, 4, 64):
        loop = EventLoop(devices, qd)
        inter = loop.run(jobs, mode="interleaved")
        serial = loop.run(jobs, mode="serial")
        assert len(inter.completions) == len(serial.completions) == 30
        assert inter.makespan <= serial.makespan * (1 + 1e-12)


def test_completion_reordering_small_warm_beats_large_cold():
    devices = [NVME, S3]
    cold = build_job(_rec("take:cold", {1: (4, 400_000, 0)}), devices, seq=0)
    warm = build_job(_rec("take:warm", {0: (1, 4096, 0)}), devices, seq=1)
    loop = EventLoop(devices, queue_depth=64)
    inter = loop.run([cold, warm], mode="interleaved")
    order = [c.label for c in sorted(inter.completions, key=lambda c: c.done)]
    assert order == ["take:warm", "take:cold"]   # reordered past the cold job
    serial = loop.run([cold, warm], mode="serial")
    order_s = [c.label for c in sorted(serial.completions,
                                       key=lambda c: c.done)]
    assert order_s == ["take:cold", "take:warm"]  # FIFO holds the warm one
    # occupancy report covers the tiers that saw rounds
    assert set(inter.tiers) == {"nvme_970evo", "s3"}
    assert inter.tiers["s3"]["max_outstanding"] == 4


def test_rounds_amortize_across_concurrent_jobs():
    """Ten 1-op jobs under queue depth 16 share latency rounds instead of
    paying ten round trips: the first arrival dispatches immediately
    (event-driven), the other nine pack into the next round together."""
    devices = [NVME]
    jobs = [build_job(_rec(f"take:{i}", {0: (1, 4096, 0)}), devices, seq=i)
            for i in range(10)]
    loop = EventLoop(devices, queue_depth=16)
    inter = loop.run(jobs, mode="interleaved")
    assert inter.tiers["nvme_970evo"]["rounds"] == 2
    assert inter.tiers["nvme_970evo"]["max_outstanding"] == 9
    serial = loop.run(jobs, mode="serial")
    # serial pays the full round trip per job
    assert serial.makespan >= 10 * NVME.latency
    assert inter.makespan < 3 * NVME.latency


# ---------------------------------------------------------------------------
# QoS: weighted fairness, strict priority, starvation guard
# ---------------------------------------------------------------------------


def _contended_jobs(devices, n_per_tenant=16, tenants=("gold", "bronze")):
    jobs, seq = [], 0
    for i in range(n_per_tenant):
        for t in tenants:
            jobs.append(build_job(
                _rec(f"take:{t}:{i}", {0: (8, 8 * 4096, 0)}), devices,
                tenant=t, seq=seq))
            seq += 1
    return jobs


def test_qos_weights_bias_round_admission():
    devices = [NVME]
    jobs = _contended_jobs(devices)
    qos = QoS(weights={"gold": 8.0, "bronze": 1.0})
    res = EventLoop(devices, queue_depth=8, qos=qos).run(jobs)
    mean = {t: np.mean([c.latency for c in res.completions if c.tenant == t])
            for t in ("gold", "bronze")}
    assert mean["gold"] < mean["bronze"]
    # flat weights: the same stream serves in near arrival order instead
    flat = EventLoop(devices, queue_depth=8, qos=QoS()).run(jobs)
    mean_flat = {t: np.mean([c.latency for c in flat.completions
                             if c.tenant == t]) for t in ("gold", "bronze")}
    assert mean_flat["gold"] == pytest.approx(mean_flat["bronze"], rel=0.2)


def test_qos_strict_priority_and_starvation_guard():
    devices = [NVME]
    jobs = _contended_jobs(devices, n_per_tenant=64)
    # strict priority with a tight guard: bronze is delayed but bounded
    guarded = QoS(priority={"gold": 1, "bronze": 0}, starvation_rounds=4)
    res = EventLoop(devices, queue_depth=8, qos=guarded).run(jobs)
    done = {t: max(c.done for c in res.completions if c.tenant == t)
            for t in ("gold", "bronze")}
    first_bronze = min(c.done for c in res.completions
                       if c.tenant == "bronze")
    # the guard front-runs starved bronze units: some bronze completes well
    # before the gold flood fully drains
    assert first_bronze < done["gold"]
    # with an effectively infinite guard, strict priority starves bronze
    # until gold is done
    starved = QoS(priority={"gold": 1, "bronze": 0},
                  starvation_rounds=10**9)
    res2 = EventLoop(devices, queue_depth=8, qos=starved).run(jobs)
    first_bronze2 = min(c.done for c in res2.completions
                        if c.tenant == "bronze")
    gold_done2 = max(c.done for c in res2.completions if c.tenant == "gold")
    assert first_bronze2 >= gold_done2 - NVME.latency
    assert first_bronze < first_bronze2      # the guard provably helped


def test_latency_percentiles_shape():
    assert latency_percentiles([]) is None
    p = latency_percentiles([3.0, 1.0, 2.0])
    assert p["count"] == 3 and p["p50"] == 2.0 and p["max"] == 3.0
    assert p["p50"] <= p["p99"] <= p["p999"] <= p["max"]


# ---------------------------------------------------------------------------
# ServiceWindow: capture, purity, nesting, flush interleaving
# ---------------------------------------------------------------------------


def test_service_window_captures_instead_of_advancing_vclock():
    fr, n = _reader(store="tiered")
    sch = fr.scheduler
    with sch.service_window() as win:
        with win.request(tenant="a", at=0.0):
            fr.take("c", np.arange(50))
        with win.request(tenant="b", at=0.001):
            fr.take("c", np.arange(50, 90))
    assert sch.vclock == 0.0 and sch.completions == []
    assert [j.tenant for j in win.jobs] == ["a", "b"]
    assert [j.submit for j in win.jobs] == [0.0, 0.001]
    inter = win.run("interleaved")
    serial = win.run("serial")
    assert len(inter.completions) == len(serial.completions) == 2
    # purity: re-running gives identical timings
    again = win.run("interleaved")
    assert [c.done for c in again.completions] == \
        [c.done for c in inter.completions]
    # a lone-window single job still degenerates to the serial price
    assert inter.completions[0].done <= serial.completions[-1].done


def test_service_window_accounting_is_identical_to_no_window():
    """The window is a timing overlay: logical IOPS/bytes and per-tier
    counters must be bit-identical with and without it."""
    rows = np.arange(0, 2000, 7)

    def run(windowed):
        fr, _ = _reader(store="tiered")
        if windowed:
            with fr.scheduler.service_window() as win:
                with win.request(tenant="t"):
                    fr.take("c", rows)
        else:
            fr.take("c", rows)
        st = fr.io_stats()
        tiers = [(s.n_iops, s.bytes_read, s.write_iops) for s in
                 fr.store.tier_stats()]
        return (st.n_iops, st.bytes_read, tiers)

    assert run(False) == run(True)


def test_service_windows_do_not_nest():
    fr, _ = _reader(store="tiered")
    with fr.scheduler.service_window():
        with pytest.raises(RuntimeError, match="nest"):
            with fr.scheduler.service_window():
                pass
    # cleanly closed: a new window opens fine
    with fr.scheduler.service_window():
        pass


def test_window_captures_flush_drains_as_jobs():
    from repro.dataset import DatasetWriter

    rng = np.random.default_rng(2)
    arr = A.PrimitiveArray.build(rng.integers(0, 1000, 500).astype(np.int64))
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    w = DatasetWriter(files=[fb], flush="write-back")
    with w.scheduler.service_window() as win:
        with win.request(tenant="reader", at=0.0):
            w.take("c", np.arange(20))
        with win.request(tenant="ingest", at=0.0005):
            w.append({"c": A.PrimitiveArray.build(
                rng.integers(0, 1000, 200).astype(np.int64))}, commit=True)
    labels = [j.label for j in win.jobs]
    assert any(lab.startswith("take:") for lab in labels)
    assert any(lab.startswith("flush:") for lab in labels)
    # the flush job inherited the ingest tenant's tag — reads and write
    # runs share the same queues in one event-loop run
    flush_jobs = [j for j in win.jobs if j.label.startswith("flush:")]
    assert all(j.tenant == "ingest" for j in flush_jobs)
    res = win.run("interleaved")
    assert len(res.completions) == len(win.jobs)


def test_event_loop_rejects_unknown_mode():
    with pytest.raises(ValueError, match="mode"):
        EventLoop([NVME]).run([], mode="warp")


def test_scheduler_reset_clears_serving_state():
    fr, _ = _reader(store="tiered")
    fr.take("c", np.arange(10))
    sch = fr.scheduler
    assert sch.vclock > 0 and sch.completions
    sch.reset()
    assert sch.vclock == 0.0 and sch.completions == []


# ---------------------------------------------------------------------------
# Zipf multi-tenant serve workload
# ---------------------------------------------------------------------------


def test_zipf_workload_deterministic_and_skewed():
    from repro.serve.workload import TenantSpec, ZipfWorkload

    tenants = [TenantSpec("a", share=1.0, weight=2.0),
               TenantSpec("b", share=3.0)]
    wl1 = ZipfWorkload(5000, tenants, n_requests=400, zipf_s=1.2, seed=9)
    wl2 = ZipfWorkload(5000, tenants, n_requests=400, zipf_s=1.2, seed=9)
    r1, r2 = wl1.generate(), wl2.generate()
    assert [r.tenant for r in r1] == [r.tenant for r in r2]
    assert all(np.array_equal(x.rows, y.rows) for x, y in zip(r1, r2))
    assert [r.at for r in r1] == [r.at for r in r2]
    # arrivals strictly increase; b gets ~3x the requests of a
    ats = [r.at for r in r1]
    assert all(x < y for x, y in zip(ats, ats[1:]))
    n_b = sum(r.tenant == "b" for r in r1)
    assert 2.0 < n_b / (400 - n_b) < 4.5
    # Zipf skew: the top 1% of rows absorb far more than 1% of the traffic
    rows = np.concatenate([r.rows for r in r1])
    hot = np.mean(rows < 50)
    assert hot > 0.15
    q = wl1.qos()
    assert q.weight_for("a") == 2.0 and q.weight_for("b") == 1.0


def test_zipf_workload_validation():
    from repro.serve.workload import TenantSpec, ZipfWorkload

    with pytest.raises(ValueError):
        ZipfWorkload(0, [TenantSpec("a")], n_requests=5)
    with pytest.raises(ValueError):
        ZipfWorkload(10, [TenantSpec("a")], n_requests=0)


def test_drive_prices_same_workload_under_both_models():
    from repro.dataset import DatasetWriter
    from repro.serve.workload import (TenantSpec, ZipfWorkload, drive,
                                      tenant_summary)

    rng = np.random.default_rng(11)
    arr = A.PrimitiveArray.build(
        rng.integers(0, 1 << 16, 3000).astype(np.int64))
    fb = write_table({"c": arr}, WriteOptions("lance-fullzip"))
    w = DatasetWriter(
        files=[fb],
        store=lambda d: TieredStore.cached(d, cache_bytes=8 * 4096),
        flush="write-back")
    tenants = [TenantSpec("p", share=1.0, weight=4.0, rows_per_request=16),
               TenantSpec("s", share=2.0, rows_per_request=16)]
    wl = ZipfWorkload(w.n_rows, tenants, n_requests=40,
                      arrival_rate=500.0, seed=4)
    inter, serial, _win = drive(w, "c", wl.generate(), qos=wl.qos())
    assert len(inter.completions) == len(serial.completions) == 40
    assert inter.makespan <= serial.makespan * (1 + 1e-12)
    summ = tenant_summary(inter, ["p", "s"])
    assert {"p", "s", "all"} <= set(summ)
    assert summ["all"]["count"] == 40
    assert summ["all"]["p50"] <= summ["all"]["p99"] <= summ["all"]["p999"]
