"""Property-based failure/recovery contracts (optional: require ``hypothesis``).

Stated over arbitrary drain shapes and fault schedules:

(a) ``TransientErrors(error_prob=0)`` is bit-identical to the healthy run —
    the recovery layer must not perturb timings unless an op can actually
    fail (healthy-path bit-identity, ARCHITECTURE.md contract #8);
(b) retries and failover never change the logical accounting plane: the
    drain records priced into a job are never mutated by a faulted run;
(c) makespan is monotone non-decreasing in ``error_prob`` up to sub-round
    scheduling slack, for an uncontended job — failure draws nest (one
    uniform per (tier, unit, slot, attempt) compared against the
    threshold), so raising the probability only adds failures.  The slack
    and the single-job restriction are load-bearing: requeued slots repack
    rounds and failover re-prices only the surviving slots, so completions
    can shift by a few device slot times either way, and under contention
    a backed-off unit frees round slots for *other* jobs entirely —
    empirically up to ~10% of makespan.  What nests is the failure set,
    not the schedule built from it;
(d) failover never loses or duplicates a request:
    completed + failed + shed == submitted, each label exactly once.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.io_sim import NVME, S3, Blackout, TransientErrors  # noqa: E402
from repro.obs.slo import Shedder, SLObjective, SLOMonitor  # noqa: E402
from repro.store import EventLoop, RetryPolicy, build_job  # noqa: E402
from repro.store.stats import DrainRecord  # noqa: E402

DEVICES = [NVME, S3]

# one tier's slice of a drain: {phase: ops} with plausible byte loads
_PHASE = st.integers(0, 2)
_BUCKET = st.tuples(_PHASE, st.integers(1, 64), st.integers(0, 1 << 20))


def _record(buckets_by_tier):
    tiers = {}
    for tier, buckets in buckets_by_tier.items():
        phase_ops, phase_bytes = {}, {}
        for phase, ops, nbytes in buckets:
            phase_ops[phase] = phase_ops.get(phase, 0) + ops
            phase_bytes[phase] = phase_bytes.get(phase, 0) + nbytes
        if phase_ops:
            tiers[tier] = (phase_ops, phase_bytes)
    return DrainRecord("take:p", 1, tiers)


_JOBS = st.lists(
    st.tuples(st.dictionaries(st.integers(0, 1),
                              st.lists(_BUCKET, min_size=1, max_size=2),
                              min_size=1, max_size=2),
              st.floats(0.0, 0.01)),
    min_size=1, max_size=6)


def _build(jobs_spec, tenant="t"):
    return [build_job(_record(buckets), DEVICES, tenant=tenant, submit=at,
                      seq=i) for i, (buckets, at) in enumerate(jobs_spec)]


@settings(max_examples=100, deadline=None)
@given(jobs_spec=_JOBS, queue_depth=st.integers(1, 64),
       seed=st.integers(0, 2**32))
def test_zero_error_prob_is_bit_identical_to_healthy(jobs_spec, queue_depth,
                                                     seed):
    jobs = _build(jobs_spec)
    healthy = EventLoop(DEVICES, queue_depth).run(jobs)
    dev = [NVME.with_fault(TransientErrors(0.0, error_prob=0.0, seed=seed)),
           S3.with_fault(TransientErrors(0.0, error_prob=0.0, seed=seed))]
    out = EventLoop(dev, queue_depth, retry=RetryPolicy(seed=seed)).run(jobs)
    assert out.completions == healthy.completions
    assert out.counters == {}


@settings(max_examples=100, deadline=None)
@given(jobs_spec=_JOBS, queue_depth=st.integers(1, 64),
       error_prob=st.floats(0.0, 0.5), seed=st.integers(0, 2**32))
def test_retries_never_change_logical_accounting(jobs_spec, queue_depth,
                                                 error_prob, seed):
    jobs = _build(jobs_spec)
    loads = [[(u.tier, u.phase, u.ops, u.nbytes) for u in j.units]
             for j in jobs]
    dev = [NVME.with_fault(TransientErrors(0.0, error_prob=error_prob,
                                           seed=seed)), S3]
    out = EventLoop(dev, queue_depth, retry=RetryPolicy(seed=seed)).run(jobs)
    # the job structures priced from the drain records are untouched: the
    # recovery layer retries *timing*, never logical IOPS/bytes
    assert [[(u.tier, u.phase, u.ops, u.nbytes) for u in j.units]
            for j in jobs] == loads
    assert len(out.completions) == len(jobs)
    # and a replay from the same inputs is bit-identical (determinism)
    again = EventLoop(dev, queue_depth,
                      retry=RetryPolicy(seed=seed)).run(jobs)
    assert again.completions == out.completions
    assert again.counters == out.counters


@settings(max_examples=60, deadline=None)
@given(ops=st.integers(1, 200), nbytes=st.integers(0, 1 << 20),
       phase=_PHASE, queue_depth=st.integers(1, 256),
       probs=st.tuples(st.floats(0.0, 0.9), st.floats(0.0, 0.9)),
       seed=st.integers(0, 2**32))
def test_makespan_monotone_in_error_prob(ops, nbytes, phase, queue_depth,
                                         probs, seed):
    lo, hi = sorted(probs)
    rec = DrainRecord("take:p", 1, {0: ({phase: ops}, {phase: nbytes})})
    jobs = [build_job(rec, DEVICES, seq=0)]

    def run(p):
        dev = [NVME.with_fault(TransientErrors(0.0, error_prob=p,
                                               seed=seed)), S3]
        return EventLoop(dev, queue_depth,
                         retry=RetryPolicy(jitter=0.0)).run(jobs)

    m_lo, m_hi = run(lo).makespan, run(hi).makespan
    assert m_hi >= m_lo * (1 - 1e-3) - 1e-3


@settings(max_examples=60, deadline=None)
@given(jobs_spec=_JOBS, error_prob=st.floats(0.0, 1.0),
       seed=st.integers(0, 2**32), shed_every=st.integers(0, 3),
       failover=st.booleans())
def test_failover_conserves_requests(jobs_spec, error_prob, seed, shed_every,
                                     failover):
    # tenants alternate so a forced shedder can reject a deterministic
    # subset; NVMe takes transient errors, S3 a mid-run blackout — requests
    # may retry, fail over, exhaust or be shed, but each submitted label
    # completes exactly once: completed + failed + shed == submitted
    jobs = []
    for i, (buckets, at) in enumerate(jobs_spec):
        tenant = "shed" if shed_every and i % shed_every == 0 else "keep"
        jobs.append(build_job(_record(buckets), DEVICES, tenant=tenant,
                              submit=at, seq=i))
    dev = [NVME.with_fault(TransientErrors(0.0, error_prob=error_prob,
                                           seed=seed)),
           S3.with_fault(Blackout(0.02, 0.06))]
    mon = SLOMonitor({"keep": SLObjective(1.0)})
    sh = Shedder(mon, protect=("keep",), shed=("shed",), hold_s=1e9)
    sh.active = True  # latched for the whole run by the huge hold-down
    pol = RetryPolicy(max_retries=2, failover=failover, seed=seed)
    out = EventLoop(dev, 32, retry=pol, shedder=sh).run(jobs)
    assert len(out.completions) == len(jobs)
    assert sorted(c.label for c in out.completions) == \
        sorted(j.label for j in jobs)
    done = sum(1 for c in out.completions if c.error is None)
    shed = sum(1 for c in out.completions if c.error == "shed")
    failed = sum(1 for c in out.completions
                 if c.error and c.error.startswith("io:"))
    assert done + shed + failed == len(jobs)
    assert shed == sum(1 for j in jobs if j.tenant == "shed")
    # every error is one of the documented sinks; no other values leak out
    assert all(c.error in (None, "shed", "io:nvme_970evo", "io:s3")
               for c in out.completions)
