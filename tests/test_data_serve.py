"""Data pipeline (full-scan consumer) + serving (random-access consumer)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import arrays as A
from repro.core.file import FileReader, WriteOptions, write_table
from repro.data import synth
from repro.data.loader import TokenLoader, write_token_file
from repro.models.registry import build_model
from repro.serve.engine import BatchedEngine, Retriever
from repro.serve.kv_cache import BLOCK, PagedKVCache


def test_token_file_roundtrip():
    fb = write_token_file(n_rows=64, seq_len=100, vocab=1000, seed=0)
    fr = FileReader(fb)
    arr = fr.scan("tokens")
    assert isinstance(arr, A.ListArray)
    assert (arr.child.values < 1000).all()
    # miniblock chosen for int32 tokens (4 B/value << 128)
    assert fr.columns["tokens"]["leaves"][0]["meta"]["encoding"] == "miniblock"


def test_loader_deterministic_cursor():
    fb = write_token_file(n_rows=64, seq_len=100, vocab=1000, seed=0)
    l1 = TokenLoader(fb, batch=4, seq_len=32, seed=5)
    l2 = TokenLoader(fb, batch=4, seq_len=32, seed=5)
    try:
        for s in [0, 3, 17]:
            np.testing.assert_array_equal(
                l1.batch_for_step(s)["tokens"], l2.batch_for_step(s)["tokens"])
        a = next(iter(l1))
        assert a["tokens"].shape == (4, 33)
    finally:
        l1.close()
        l2.close()


def test_loader_exhausts_cleanly_after_stop():
    fb = write_token_file(n_rows=64, seq_len=100, vocab=1000, seed=0)
    ld = TokenLoader(fb, batch=4, seq_len=32, seed=5)
    next(iter(ld))
    ld.stop()
    # once stopped, iteration ends instead of hanging on an empty queue —
    # any prefetched batches are discarded behind the sentinel
    with pytest.raises(StopIteration):
        for _ in range(16):
            next(ld)
    # idempotent: the latch keeps raising
    with pytest.raises(StopIteration):
        next(ld)
    assert not ld._thread.is_alive()


def test_loader_killed_producer_raises_stopiteration(monkeypatch):
    """A producer that dies mid-stream must not deadlock the consumer."""
    import threading

    monkeypatch.setattr(threading, "excepthook", lambda args: None)
    fb = write_token_file(n_rows=64, seq_len=100, vocab=1000, seed=0)

    class Dying(TokenLoader):
        def _token_stream(self):
            raise RuntimeError("producer crashed")

    ld = Dying(fb, batch=4, seq_len=32)
    with pytest.raises(StopIteration):
        next(ld)
    ld._thread.join(timeout=5.0)
    assert not ld._thread.is_alive()
    ld.stop()  # no-op after crash, must not raise


def test_paged_kv_cache():
    rng = np.random.default_rng(0)
    kv = PagedKVCache(n_blocks=32, kv_features=16)
    kv.add_request(0)
    kv.add_request(1)
    a = rng.standard_normal((200, 16)).astype(np.float32)
    b = rng.standard_normal((40, 16)).astype(np.float32)
    kv.append(0, a)
    kv.append(1, b)
    got_a = np.asarray(kv.gather(0), np.float32)
    got_b = np.asarray(kv.gather(1), np.float32)
    np.testing.assert_allclose(got_a, a, rtol=1e-2)
    np.testing.assert_allclose(got_b, b, rtol=1e-2)
    assert kv.utilization > 0
    kv.release(0)
    kv.add_request(2)
    kv.append(2, b)
    np.testing.assert_allclose(np.asarray(kv.gather(2), np.float32), b, rtol=1e-2)


def test_engine_generates():
    cfg = reduced_config("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = BatchedEngine(model, params)
    prompts = jnp.asarray(np.random.default_rng(0).integers(1, cfg.vocab, (2, 16)),
                          jnp.int32)
    out = eng.generate({"tokens": prompts}, n_new=8)
    assert out.tokens.shape == (2, 8)
    assert (out.tokens >= 0).all() and (out.tokens < cfg.vocab).all()


def test_engine_matches_autoregressive_forward():
    """Each greedy decode token attains the full-forward max logit.

    Exact argmax-index equality is flaky in bfloat16: the reference logits
    regularly have exact top-2 ties, and the chunked prefill vs step-decode
    paths (which differ by ~1e-2 in logit value) may break the tie
    differently.  Instead, replay the engine's token trajectory through full
    prefills and require every decoded token's reference logit to be within
    bf16 noise of the reference max."""
    cfg = reduced_config("mamba2-780m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = BatchedEngine(model, params)
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab, (1, 16)), jnp.int32)
    out = eng.generate({"tokens": prompts}, n_new=4)
    seq = prompts
    for tok in out.tokens[0].tolist():
        logits, _, _ = model._full_forward(params, {"tokens": seq}, "prefill")
        ref = np.asarray(logits[0, -1], np.float32)
        assert ref[tok] >= ref.max() - 0.02, (tok, ref[tok], ref.max())
        seq = jnp.concatenate(
            [seq, jnp.full((1, 1), tok, jnp.int32)], axis=1)


def test_retriever_iops():
    emb = synth.scenario("embeddings", 2000)
    fb = write_table({"embedding": emb}, WriteOptions("lance"))
    r = Retriever(fb, "embedding")
    ids = np.array([3, 999, 1500])
    out, stats = r.fetch(ids)
    assert stats.n_iops == len(ids)  # fixed-width full-zip: 1 IOP/row
    got = np.asarray(out.values)
    np.testing.assert_allclose(got, emb.values[ids], rtol=1e-6)


def test_retriever_search_end_to_end():
    """search(): probe -> posting fetch -> kernel top-k -> winner take,
    all through one shared store; a perturbed stored vector finds itself."""
    from repro.dataset import DatasetWriter, IvfIndex, write_fragments

    emb = synth.scenario("embeddings", 600)
    files = write_fragments({"embedding": emb}, 3, WriteOptions("lance"))
    w = DatasetWriter(files=files, store="tiered")
    ivf = IvfIndex.build(w, "embedding", n_partitions=8, n_fragments=2, seed=0)
    r = Retriever(w.reader(), "embedding", index=ivf)
    vecs = np.asarray(emb.values, np.float32)
    rng = np.random.default_rng(2)
    targets = rng.integers(0, 600, 3)
    q = vecs[targets] + 0.01 * rng.standard_normal((3, 512)).astype(np.float32)
    res = r.search(q, k=5, nprobe=8)  # nprobe == P: exact
    assert (res.ids[:, 0] == targets).all()  # each query finds its doc
    assert res.values is not None
    np.testing.assert_allclose(np.asarray(res.values.values),
                               vecs[res.winner_rows], rtol=1e-6)
    # index reads and data reads share one drain log / attribution stream
    labels = {rec.label for rec in w.store.drain_log}
    assert any(l.startswith("take:centroid") for l in labels)
    assert any(l.startswith("take:posting") for l in labels)
    assert any(l.startswith("take:embedding") for l in labels)


def test_retrieval_serve_example_runs(monkeypatch, capsys):
    """End-to-end smoke of examples/retrieval_serve.py (scaled down)."""
    import importlib.util
    import pathlib

    path = pathlib.Path(__file__).resolve().parents[1] / "examples" \
        / "retrieval_serve.py"
    spec = importlib.util.spec_from_file_location("retrieval_serve", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    monkeypatch.setattr(mod, "N_DOCS", 400)
    monkeypatch.setattr(mod, "N_FRAGMENTS", 2)
    monkeypatch.setattr(mod, "N_PARTITIONS", 8)
    monkeypatch.setattr(mod, "NPROBE", 4)
    monkeypatch.setattr(mod, "reduced_config",
                        lambda name: reduced_config("smollm-360m"))
    mod.main()
    out = capsys.readouterr().out
    assert "[search]" in out and "[serve] generated" in out
    assert "nvme_hit_rate=1.00" in out  # warm repeat fully cached
