"""Device-model behaviour + the paper's S3-vs-NVMe observations."""

import numpy as np
import pytest

from repro.core import arrays as A, types as T
from repro.core.file import FileReader, WriteOptions, write_table
from repro.core.io_sim import HBM, NVME, S3, IOStats, model_time


def test_device_model_shapes():
    """Fig 1 qualitative shape: NVMe wins small random reads; S3 needs
    ~100 KiB reads to amortize; both converge at large sequential."""
    small = IOStats(n_iops=1000, bytes_read=1000 * 4096,
                    useful_bytes=1000 * 4096, max_phase=1)
    big = IOStats(n_iops=1000, bytes_read=1000 * (1 << 20),
                  useful_bytes=1000 * (1 << 20), max_phase=1)
    assert model_time(small, NVME) < model_time(small, S3) / 50
    # at 1 MiB reads both are bandwidth-bound and much closer
    ratio = model_time(big, S3) / model_time(big, NVME)
    assert ratio < 5


def test_phases_hurt_more_on_s3():
    """Paper §6.1.2: the dependent-phase effect 'is more significant in S3,
    where IOPS are far more expensive'.  Arrow's 3-phase List<String> take
    vs Lance full-zip's 2-phase take: the gap widens on S3."""
    vals = [["ab", None, "cd"], None, ["xyz"], []] * 100
    arr = A.from_pylist(vals, T.List(T.utf8()))
    rows = np.arange(0, 400, 13)

    def stats_for(opts):
        fr = FileReader(write_table({"c": arr}, opts))
        fr.reset_io()
        fr.take("c", rows)
        return fr.io_stats()

    st_arrow = stats_for(WriteOptions("arrow"))
    st_lance = stats_for(WriteOptions("lance-fullzip"))
    assert st_arrow.max_phase > st_lance.max_phase
    # the absolute penalty of the extra dependent phase is ~1000x larger on
    # S3 (30 ms round trips) than on NVMe (90 us)
    nvme_extra = model_time(st_arrow, NVME) - model_time(st_lance, NVME)
    s3_extra = model_time(st_arrow, S3) - model_time(st_lance, S3)
    assert s3_extra > 100 * max(nvme_extra, 1e-9)
    assert s3_extra > 0


def test_hbm_model_is_dma_shaped():
    """DESIGN.md §2.1: the TPU translation treats an IOP as a DMA; tiny
    reads cost a full min-granule."""
    tiny = IOStats(n_iops=10_000, bytes_read=10_000 * 8,
                   useful_bytes=10_000 * 8, max_phase=1)
    padded = IOStats(n_iops=10_000, bytes_read=10_000 * 512,
                     useful_bytes=10_000 * 512, max_phase=1)
    assert abs(model_time(tiny, HBM) - model_time(padded, HBM)) / \
        model_time(padded, HBM) < 0.01


def test_coalescing_counter():
    from repro.core.io_sim import Disk, IOTracker

    disk = Disk(np.zeros(10_000, np.uint8))
    tr = IOTracker(disk)
    tr.read(0, 100)
    tr.read(50, 100)   # overlaps -> coalesces
    tr.read(500, 100)  # far -> separate
    st = tr.stats()
    assert st.n_iops == 3
    assert st.n_coalesced == 2


def test_coalescing_is_per_phase():
    """Regression: adjacent reads in *different* dependency phases must not
    merge — a phase-1 read could only be issued after phase 0 returned, so a
    single combined request never existed."""
    from repro.core.io_sim import Disk, IOTracker

    disk = Disk(np.zeros(10_000, np.uint8))
    tr = IOTracker(disk)
    tr.read(0, 100, phase=0)
    tr.read(100, 100, phase=1)  # adjacent but causally later
    st = tr.stats()
    assert st.n_coalesced == 2
    assert st.max_phase == 2
    # within one phase the merge still happens
    tr.reset()
    tr.read(0, 100, phase=1)
    tr.read(100, 100, phase=1)
    assert tr.stats().n_coalesced == 1


def test_empty_trace_stats():
    """Regression: an empty trace has zero phases (not 1) and no coalesced
    ops."""
    from repro.core.io_sim import Disk, IOTracker

    tr = IOTracker(Disk(np.zeros(10, np.uint8)))
    st = tr.stats()
    assert st.n_iops == 0 and st.n_coalesced == 0 and st.max_phase == 0
    assert np.isnan(st.read_amplification)


def test_disk_read_bounds_and_copies(tmp_path):
    """Regression: out-of-range reads raise on both backing paths, and the
    returned arrays are writable copies that never alias the store."""
    from repro.core.io_sim import Disk

    payload = np.arange(64, dtype=np.uint8)
    fpath = tmp_path / "blob.bin"
    fpath.write_bytes(payload.tobytes())
    for disk in (Disk(payload.copy()), Disk(path=str(fpath))):
        with pytest.raises(ValueError):
            disk.read(60, 8)       # crosses the end
        with pytest.raises(ValueError):
            disk.read(64, 1)       # starts at the end
        with pytest.raises(ValueError):
            disk.read(-1, 4)       # negative offset
        with pytest.raises(ValueError):
            disk.read(0, -4)       # negative size
        got = disk.read(8, 8)
        np.testing.assert_array_equal(got, payload[8:16])
        got[:] = 0  # must not corrupt the backing store
        np.testing.assert_array_equal(disk.read(8, 8), payload[8:16])
        assert disk.read(64, 0).size == 0  # empty read at EOF is legal
