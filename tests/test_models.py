"""Per-architecture smoke tests (reduced configs, task spec f): one forward /
train step on CPU asserting shapes + finite values, and decode-vs-forward
consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config, SHAPES
from repro.models.registry import (
    build_model, cache_specs, input_specs, model_flops, param_counts,
    supports_shape,
)
from repro.train.optimizer import make_optimizer
from repro.train.train_loop import make_train_step

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = list(ARCHS)


def _batch(cfg, B=2, S=32, train=True):
    b = {"tokens": jax.random.randint(KEY, (B, S + (1 if train else 0)), 1, cfg.vocab)}
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_vision_tokens, cfg.d_vision)).astype(jnp.bfloat16)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(KEY, (B, S, cfg.d_audio)).astype(jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, specs = model.init(KEY)
    # spec tree matches param tree structure
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda s: 0, specs,
                                        is_leaf=lambda s: not isinstance(s, dict)))
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg)
    p2, o2, metrics = step(params, opt_state, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, p2))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_loss_decreases(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init(KEY)
    opt = make_optimizer(cfg.optimizer, lr=3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg)  # overfit one batch
    losses = []
    for i in range(8):
        params, opt_state, m = step(params, opt_state, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init(KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S, train=False)
    last, cache = jax.jit(model.prefill)(params, batch)
    assert last.shape == (B, cfg.vocab)

    def pad_seq(x, axis, to):
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, to - x.shape[axis])
        return jnp.pad(x, pad)

    fam = cfg.family
    if fam in ("dense", "moe"):
        cache = {"layers": {k: pad_seq(v, 2, S + 8) for k, v in cache["layers"].items()},
                 "length": cache["length"]}
    elif fam == "hybrid":
        cache = {"mamba": cache["mamba"],
                 "shared": {k: pad_seq(v, 2, S + 8) for k, v in cache["shared"].items()},
                 "length": cache["length"]}
    elif fam == "vlm":
        cache = {"self": {k: pad_seq(v, 3, S + 8) for k, v in cache["self"].items()},
                 "cross": cache["cross"], "length": cache["length"]}
    elif fam == "audio":
        cache = {"self": {k: pad_seq(v, 2, S + 8) for k, v in cache["self"].items()},
                 "cross": cache["cross"], "length": cache["length"]}
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    logits1, _ = jax.jit(model.decode_step)(params, cache, nxt)

    toks2 = jnp.concatenate([batch["tokens"], nxt], axis=1)
    logits_ref, _, _ = model._full_forward(params, {**batch, "tokens": toks2}, "prefill")
    ref = logits_ref[:, -1].astype(np.float32)
    got = logits1.astype(np.float32)
    err = float(jnp.abs(ref - got).max() / (jnp.abs(ref).max() + 1e-6))
    assert err < 0.06, (arch, err)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_exactness(arch):
    """The FULL configs carry the assigned numbers (exercised abstractly)."""
    cfg = get_config(arch)
    total, active = param_counts(cfg)
    expected = {
        "smollm-360m": 0.36e9, "qwen1.5-4b": 4e9, "qwen2-72b": 72.7e9,
        "qwen1.5-32b": 32e9, "mamba2-780m": 0.78e9, "grok-1-314b": 314e9,
        "deepseek-v2-lite-16b": 15.7e9, "zamba2-7b": 7e9,
        "llama-3.2-vision-90b": 90e9, "seamless-m4t-medium": 1.2e9,
    }[arch]
    assert 0.65 * expected <= total <= 1.35 * expected, (arch, total)


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_and_cache_specs_constructible(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    ok, _ = supports_shape(cfg, sh)
    if not ok:
        pytest.skip("shape unsupported by design")
    ins = input_specs(cfg, sh)
    assert "tokens" in ins
    if sh.kind == "decode":
        shapes, specs = cache_specs(cfg, sh, dp_total=16)
        assert jax.tree.structure(shapes) == jax.tree.structure(
            jax.tree.map(lambda s: 0, specs, is_leaf=lambda s: not isinstance(s, dict)))
    assert model_flops(cfg, sh) > 0


def test_moe_sharded_matches_reference_subprocess():
    """EP a2a dispatch vs dense reference — run on 8 fake devices."""
    import subprocess, sys, os

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import reduced_config
import dataclasses
from repro.configs.base import MoECfg
from repro.models.moe import init_moe, moe_apply_reference, moe_apply_sharded

cfg = reduced_config("grok-1-314b")
cfg = dataclasses.replace(cfg, moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=64,
                                          capacity_factor=8.0))
mesh = jax.make_mesh((4, 2), ("data", "model"))
params, _ = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, data_size=4)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)
ref, _ = moe_apply_reference(params, cfg, x)
pspec = {"router": {"w": P(None, None)}, "wi": P("data", None, "model"),
         "wg": P("data", None, "model"), "wo": P("data", "model", None)}
from repro.compat import set_mesh, shard_map
with set_mesh(mesh):
    out, aux = jax.jit(shard_map(
        lambda pp, xx: moe_apply_sharded(pp, cfg, xx),
        mesh=mesh, in_specs=(pspec, P(("data",), None, None)),
        out_specs=(P(("data",), None, None), {"aux": P(), "dropped": P()})))(params, x)
err = float(jnp.abs(ref - out).max() / (jnp.abs(ref).max() + 1e-9))
print("rel err", err, "dropped", float(aux["dropped"]))
assert err < 2e-2, err
print("MOE_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "MOE_OK" in r.stdout, r.stdout + r.stderr
